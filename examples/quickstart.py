"""Quickstart: DF* PageRank on a dynamic graph in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401  (enables x64 for fp64 ranks)
from repro.core.api import update_pagerank
from repro.core.reference import l1_error, static_pagerank_ref
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.generators import random_batch_update, rmat_edges
from repro.graph.structure import from_coo

# 1. build a power-law digraph (RMAT, 1024 vertices)
edges, n = rmat_edges(scale=10, edge_factor=10, seed=0)
graph = from_coo(edges[:, 0], edges[:, 1], n,
                 edge_capacity=len(edges) + 256)
print(f"graph: {n} vertices, {len(edges)} edges")

# 2. static PageRank (paper defaults: α=0.85, τ=1e-10 L∞, self-loops)
res0 = update_pagerank(graph, graph, None, None, "static")
print(f"static: {int(res0.iterations)} iterations, "
      f"Σranks={float(jnp.sum(res0.ranks)):.6f}")

# 3. a batch update: 80% insertions / 20% deletions (paper §5.2.2)
dele, ins = random_batch_update(edges, n, 64, seed=1)
update = make_batch_update(dele, ins, 128, 128)
graph_t = apply_batch(graph, update)

# 4. update ranks with each approach, compare work + error
sv = np.asarray(graph_t.src)[np.asarray(graph_t.valid)]
dv = np.asarray(graph_t.dst)[np.asarray(graph_t.valid)]
ref, _ = static_pagerank_ref(sv, dv, n, tol=1e-14)
print(f"{'method':<16}{'iters':>6}{'affected':>10}{'edge-work':>12}"
      f"{'L1 error':>12}")
for method in ("static", "naive", "traversal", "frontier",
               "frontier_prune"):
    r = update_pagerank(graph, graph_t, update, res0.ranks, method)
    print(f"{method:<16}{int(r.iterations):>6}"
          f"{int(jnp.sum(r.affected_ever)):>10}"
          f"{int(r.edges_processed):>12}"
          f"{l1_error(r.ranks, ref):>12.2e}")
print("\nDF/DF-P touch a fraction of the graph at matching accuracy — "
      "the paper's contribution.")
