"""Online serving scenario: live edge events + interleaved rank queries.

A background engine thread micro-batches events through DF-P while the
foreground thread plays "user traffic" — point-rank lookups, global
top-k and personalized top-k — always answered from a consistent
published snapshot.

    PYTHONPATH=src python examples/online_serving.py [--engine kernel]

``--engine kernel`` serves from the Pallas frontier-gated path with
device-side incremental PackedGraph maintenance; off-TPU the kernel runs
in interpret mode (``use_kernel=True`` below forces it even on CPU so CI
smoke-tests the real kernel body, not the jnp oracle).

``--mesh N`` (with ``--engine kernel``) shards the packed structure by
dst-window ranges over an N-way ``model`` mesh — the multi-device smoke
lane runs ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` with
``--mesh 4``.  Off-TPU the sharded loop gates on the jnp oracle
(interpret-mode Pallas is not SPMD-safe under shard_map; DESIGN.md §9).
"""
import argparse
import time

import numpy as np

import repro  # noqa: F401
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.serve import (IngestQueue, QueryClient, RankStore, ServeEngine,
                         ServeMetrics)

ap = argparse.ArgumentParser()
ap.add_argument("--engine", default="xla", choices=["xla", "kernel"])
ap.add_argument("--mesh", type=int, default=0,
                help="shard the kernel engine over an N-way model mesh "
                     "(0 = single device); requires N visible devices")
ap.add_argument("--trace", default="",
                help="write a Chrome-trace JSON of the run here (also "
                     "enables per-iteration frontier telemetry)")
ap.add_argument("--metrics-path", default="",
                help="write the final Prometheus exposition text here")
ap.add_argument("--monitor", action="store_true",
                help="enable the correctness monitor (sentinels, shadow "
                     "verification, flight recorder, SLO alerts)")
ap.add_argument("--shadow-every", type=int, default=8,
                help="shadow-verify every Kth batch (with --monitor)")
ap.add_argument("--incident-dir", default="",
                help="dump a replayable incident bundle here on the "
                     "first error-severity incident (implies --monitor)")
ap.add_argument("--inject-fault", default="",
                help="DEBUG: GEN[:KIND[:VERTEX[:SCALE]]] one-shot "
                     "corruption, e.g. 3:rank:0:4.0 (implies --monitor)")
args = ap.parse_args()

mesh = None
if args.mesh > 0:
    import jax
    from jax.sharding import Mesh
    if len(jax.devices()) < args.mesh:
        raise SystemExit(
            f"--mesh {args.mesh} needs {args.mesh} devices, have "
            f"{len(jax.devices())}; on CPU force them with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={args.mesh}")
    mesh = Mesh(np.array(jax.devices()[: args.mesh]), ("model",))

edges, n = rmat_edges(11, 8, seed=42)
graph = from_coo(edges[:, 0], edges[:, 1], n,
                 edge_capacity=len(edges) + 4096)

metrics = ServeMetrics()
ingest = IngestQueue(flush_size=64, flush_interval=0.02, max_pending=4096)
store = RankStore()
monitor = None
if args.monitor or args.incident_dir or args.inject_fault:
    from repro.obs import CorrectnessMonitor, MonitorConfig
    monitor = CorrectnessMonitor(MonitorConfig(
        shadow_every=args.shadow_every,
        incident_dir=args.incident_dir or None))
engine = ServeEngine(graph, ingest, store, metrics=metrics,
                     method="frontier_prune", engine=args.engine, mesh=mesh,
                     kernel_opts=dict(use_kernel=True, be=256, vb=256),
                     monitor=monitor)
engine.bootstrap()
if args.inject_fault:
    parts = args.inject_fault.split(":")
    engine.inject_fault(int(parts[0]),
                        kind=parts[1] if len(parts) > 1 else "rank",
                        vertex=int(parts[2]) if len(parts) > 2 else 0,
                        scale=float(parts[3]) if len(parts) > 3 else 2.0)
    print("fault armed:", args.inject_fault)
client = QueryClient(store, ingest, metrics)

if args.trace:
    from repro import obs
    obs.start_tracing(args.trace)

ingest.submit_insert(0, 1)                   # warm the compiled step
engine.drain()

engine.start()                               # updates run in the background
rng = np.random.default_rng(0)
try:
    for burst in range(10):
        for _ in range(50):                  # 50 edge events arrive...
            u, v = rng.integers(0, n, size=2)
            if u != v:
                metrics.record_admission(
                    ingest.submit_insert(int(u), int(v)) is not None)
        r = client.top_k(5)                  # ...while users keep querying
        print(f"burst {burst}: gen={r.generation:4d} "
              f"stale={r.staleness_events:3d}ev "
              f"top5={r.vertices.tolist()}")
        time.sleep(0.05)
finally:
    engine.stop(drain=True)

if args.trace:
    from repro import obs
    obs.get_tracer().write(args.trace)
    obs.stop_tracing(write=False)
    print("trace written to", args.trace)
if args.metrics_path:
    from repro import obs
    obs.MetricsExporter(metrics).write(args.metrics_path)
    print("metrics written to", args.metrics_path)

engine.close()           # joins the shadow thread, flushes its mailbox
if monitor is not None:
    s = monitor.summary()
    print(f"incidents detected: {s['incidents_total']} "
          f"{s['incidents_by_kind']}")
    if monitor.last_bundle:
        print("incident bundle:", monitor.last_bundle)

ppr = client.personalized_top_k(seeds=[0, 1, 2], k=5)
print("personalized top5 from {0,1,2}:", ppr.vertices.tolist())
print("metrics:", {k: round(v, 2) if isinstance(v, float) else v
                   for k, v in metrics.as_dict().items()})
print("serving example complete")
