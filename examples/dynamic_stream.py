"""Temporal-stream scenario: the paper's §5.1.4 evaluation protocol with
fault-tolerant restart — kill it mid-stream and re-run; it resumes from
the last checkpoint.

    PYTHONPATH=src python examples/dynamic_stream.py
"""
import sys

from repro.launch.pagerank import main

sys.exit(main([
    "--dataset", "sx-mathoverflow",
    "--method", "frontier_prune",
    "--batch-frac", "1e-3",
    "--batches", "12",
    "--ckpt-every", "4",
    "--check-error",
]))
