"""Beyond-paper scenario: DF-frontier incremental GNN embedding refresh.

A GraphSAGE embedding service over a dynamic graph: on each edge batch,
only embeddings in the affected receptive field are refreshed (the
paper's frontier technique applied to GNNs — core/incremental_gnn.py).

    PYTHONPATH=src python examples/incremental_gnn_serving.py
"""
import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs.graphsage_reddit import SMOKE as SAGE_SMOKE
from repro.core.incremental_gnn import incremental_refresh
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import random_batch_update, rmat_edges
from repro.graph.structure import from_coo
from repro.models.gnn import GraphBatch, init_sage, sage_forward

cfg = SAGE_SMOKE
edges, n = rmat_edges(10, 8, seed=2)
graph = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 64)
rng = np.random.default_rng(0)
feats = jnp.asarray(rng.standard_normal((n, cfg.d_in)), jnp.float32)
params = init_sage(cfg, jax.random.PRNGKey(0))


def full_forward(g, x):
    gb = GraphBatch(node_feats=x, edge_src=g.src, edge_dst=g.dst,
                    edge_mask=g.valid, node_mask=jnp.ones((n,), bool))
    return sage_forward(cfg, params, gb)


emb = full_forward(graph, feats)
print(f"serving embeddings for {n} nodes, dim {emb.shape[1]}")

for step in range(5):
    dele, ins = random_batch_update(edges, n, 8, seed=10 + step)
    upd = make_batch_update(dele, ins, 16, 16)
    graph_t = apply_batch(graph, upd)
    touched = touched_vertices_mask(upd, n)
    res = incremental_refresh(
        graph_t, feats, emb, touched,
        layer_fn=full_forward, n_layers=cfg.n_layers)
    exact = full_forward(graph_t, feats)
    # exactness on refreshed nodes + work saved
    err = float(jnp.max(jnp.abs(jnp.where(
        res.affected_ever[:, None], res.embeddings - exact, 0.0))))
    stale = float(jnp.max(jnp.abs(res.embeddings - exact)))
    print(f"batch {step}: refreshed {int(res.nodes_recomputed):5d}/{n} "
          f"nodes  refreshed-err={err:.1e}  residual-stale={stale:.2e}")
    graph, emb = graph_t, res.embeddings
print("\nonly the affected receptive field was recomputed per batch.")
