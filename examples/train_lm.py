"""End-to-end driver: train a ~100M-class LM for a few hundred steps on
CPU using the full substrate (config registry, data pipeline, AdamW,
checkpointing).  Loss must drop — synthetic corpus has learnable motifs.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys

from repro.launch.train import main

sys.exit(main(["--arch", "qwen2.5-3b", "--smoke",
               "--steps", "200", "--batch", "8", "--seq", "128",
               "--ckpt-dir", "/tmp/repro_lm_ckpt"]
              + sys.argv[1:]))
