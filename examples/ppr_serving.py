"""PPR serving scenario: an engine-maintained random-walk index answers
personalized top-k while edge events stream in, with the exact DF-P
solver as the accuracy oracle.

Runs the full path the CI smoke needs — build (bootstrap) → repair
(micro-batch steps) → query (index vs oracle) — on a tiny graph, checks
the repaired index is bit-identical to a fresh build on the final
graph, and scores index answers against the exact solver.  Exits
non-zero if the repair invariants or the accuracy floor fail.

    PYTHONPATH=src python examples/ppr_serving.py

With ``--mesh N`` the engine shards the index over an N-way ``model``
mesh (ppr/shard.py): builds, repairs and queries then run per shard
under shard_map, and the final sharded index must *unshard* to exactly
the single-device fresh build — the mesh CI smoke runs this under
``XLA_FLAGS=--xla_force_host_platform_device_count=8 --mesh 4``.
"""
import argparse
import sys

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.extensions import personalized_pagerank
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.ppr import (IndexConfig, ShardedWalkIndex, build_walk_index,
                       precision_at_k, unshard_walk_index)
from repro.serve import (IngestQueue, QueryClient, RankStore, ServeEngine,
                         ServeMetrics)

ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
ap.add_argument("--mesh", type=int, default=0,
                help="shard the walk index over an N-way model mesh "
                     "(0 = single-device index)")
ap.add_argument("--events", type=int, default=200)
args = ap.parse_args()

mesh = None
if args.mesh > 0:
    if len(jax.devices()) < args.mesh:
        ap.error(f"--mesh {args.mesh} needs {args.mesh} devices but only "
                 f"{len(jax.devices())} are visible (on CPU set "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:args.mesh]),
                             ("model",))

edges, n = rmat_edges(8, 8, seed=42)                  # 256 vertices
graph = from_coo(edges[:, 0], edges[:, 1], n,
                 edge_capacity=len(edges) + 1024)
cfg = IndexConfig(num_walks=256, max_len=20, seed=7)

metrics = ServeMetrics()
ingest = IngestQueue(flush_size=32, flush_interval=0.0)
store = RankStore()
engine = ServeEngine(graph, ingest, store, metrics=metrics,
                     method="frontier_prune", ppr_index=cfg, mesh=mesh)
engine.bootstrap()                                    # builds the index
client = QueryClient(store, ingest, metrics, min_effective_walks=256)

rng = np.random.default_rng(0)
for _ in range(args.events):                          # stream edge events
    u, v = rng.integers(0, n, size=2)
    if u != v:
        ingest.submit_insert(int(u), int(v))
    engine.step()                                     # repairs per batch
engine.drain()

snap = store.snapshot()
m = metrics.as_dict()
kind = (f"sharded x{snap.ppr_index.num_shards}"
        if isinstance(snap.ppr_index, ShardedWalkIndex) else "single")
print(f"generation {snap.generation}, events {m['events_applied']}, "
      f"walks resampled {m['walks_resampled']}, index {kind}")
if mesh is not None and not isinstance(snap.ppr_index, ShardedWalkIndex):
    print("FAIL: mesh engine did not shard the walk index")
    sys.exit(1)

# repair across the whole stream == one fresh build on the final graph
# (a sharded index must unshard to the very same array)
fresh = build_walk_index(snap.graph, cfg)
served = snap.ppr_index
steps = (unshard_walk_index(served).steps
         if isinstance(served, ShardedWalkIndex) else served.steps)
if not bool(jnp.all(steps == fresh.steps)):
    print("FAIL: repaired index differs from a fresh build")
    sys.exit(1)

# index answers vs the exact DF-P oracle on warm seeds
deg = np.asarray(served.csr.deg)
seeds = rng.choice(np.flatnonzero(deg >= 4), 6, replace=False)
precisions = []
for s in seeds:
    approx = client.personalized_top_k([int(s)], 10, mode="index")
    exact = client.personalized_top_k([int(s)], 10, mode="exact")
    oracle = personalized_pagerank(
        snap.graph, jnp.zeros((n,), bool).at[int(s)].set(True)).ranks
    precisions.append(precision_at_k(approx.vertices, np.asarray(oracle),
                                     10))
    print(f"seed {s:3d} (deg {deg[s]:2d}): index {approx.vertices[:5]} "
          f"exact {exact.vertices[:5]}")
mean_p = float(np.mean(precisions))
print(f"mean precision@10 vs oracle: {mean_p:.2f}")
if mean_p < 0.7:
    print("FAIL: index accuracy below smoke floor 0.7")
    sys.exit(1)
print("ppr serving example complete")
