"""PPR serving scenario: an engine-maintained random-walk index answers
personalized top-k while edge events stream in, with the exact DF-P
solver as the accuracy oracle.

Runs the full path the CI smoke needs — build (bootstrap) → repair
(micro-batch steps) → query (index vs oracle) — on a tiny graph, checks
the repaired index is bit-identical to a fresh build on the final
graph, and scores index answers against the exact solver.  Exits
non-zero if the repair invariants or the accuracy floor fail.

    PYTHONPATH=src python examples/ppr_serving.py
"""
import sys

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.extensions import personalized_pagerank
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.ppr import IndexConfig, build_walk_index, precision_at_k
from repro.serve import (IngestQueue, QueryClient, RankStore, ServeEngine,
                         ServeMetrics)

edges, n = rmat_edges(8, 8, seed=42)                  # 256 vertices
graph = from_coo(edges[:, 0], edges[:, 1], n,
                 edge_capacity=len(edges) + 1024)
cfg = IndexConfig(num_walks=256, max_len=20, seed=7)

metrics = ServeMetrics()
ingest = IngestQueue(flush_size=32, flush_interval=0.0)
store = RankStore()
engine = ServeEngine(graph, ingest, store, metrics=metrics,
                     method="frontier_prune", ppr_index=cfg)
engine.bootstrap()                                    # builds the index
client = QueryClient(store, ingest, metrics, min_effective_walks=256)

rng = np.random.default_rng(0)
for _ in range(200):                                  # stream edge events
    u, v = rng.integers(0, n, size=2)
    if u != v:
        ingest.submit_insert(int(u), int(v))
    engine.step()                                     # repairs per batch
engine.drain()

snap = store.snapshot()
m = metrics.as_dict()
print(f"generation {snap.generation}, events {m['events_applied']}, "
      f"walks resampled {m['walks_resampled']}")

# repair across the whole stream == one fresh build on the final graph
fresh = build_walk_index(snap.graph, cfg)
if not bool(jnp.all(snap.ppr_index.steps == fresh.steps)):
    print("FAIL: repaired index differs from a fresh build")
    sys.exit(1)

# index answers vs the exact DF-P oracle on warm seeds
deg = np.asarray(snap.ppr_index.csr.deg)
seeds = rng.choice(np.flatnonzero(deg >= 4), 6, replace=False)
precisions = []
for s in seeds:
    approx = client.personalized_top_k([int(s)], 10, mode="index")
    exact = client.personalized_top_k([int(s)], 10, mode="exact")
    oracle = personalized_pagerank(
        snap.graph, jnp.zeros((n,), bool).at[int(s)].set(True)).ranks
    precisions.append(precision_at_k(approx.vertices, np.asarray(oracle),
                                     10))
    print(f"seed {s:3d} (deg {deg[s]:2d}): index {approx.vertices[:5]} "
          f"exact {exact.vertices[:5]}")
mean_p = float(np.mean(precisions))
print(f"mean precision@10 vs oracle: {mean_p:.2f}")
if mean_p < 0.7:
    print("FAIL: index accuracy below smoke floor 0.7")
    sys.exit(1)
print("ppr serving example complete")
