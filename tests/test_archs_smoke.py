"""Per-arch smoke tests: every (arch × shape) cell, reduced config, one
forward/train step on CPU; asserts output shapes + finite values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REGISTRY, all_cells
from repro.models import transformer as T
from repro.optim.adamw import init_adamw
from repro.train import inputs as I
from repro.train import steps as S

CELLS = [(spec.arch_id, cell.name) for spec, cell in all_cells()
         if not cell.skip]
SKIPPED = [(spec.arch_id, cell.name) for spec, cell in all_cells()
           if cell.skip]


@pytest.mark.parametrize("arch_id,cell_name", CELLS)
def test_cell_smoke(arch_id, cell_name):
    spec = REGISTRY[arch_id]
    cell = spec.shapes[cell_name]
    cfg = I.effective_config(spec, cell, True)
    batch = I.build_inputs(spec, cell, concrete=True, smoke=True, seed=1)
    params = I.init_fn(spec, True)(jax.random.PRNGKey(0))

    if spec.family == "lm":
        if cell.kind == "train":
            p2, o2, loss = jax.jit(S.make_lm_train_step(cfg))(
                params, init_adamw(params), batch)
            assert np.isfinite(float(loss))
            assert jax.tree_util.tree_structure(p2) == \
                jax.tree_util.tree_structure(params)
        elif cell.kind == "prefill":
            out = jax.jit(S.make_lm_prefill(cfg))(params, batch["tokens"])
            assert out.shape == (batch["tokens"].shape[0], cfg.vocab)
            assert np.isfinite(np.asarray(out)).all()
        else:
            cache = T.init_cache(cfg, batch["batch"], batch["ctx"],
                                 length=5)
            logits, c2 = jax.jit(S.make_lm_decode_step(cfg))(
                params, cache, batch["tokens"])
            assert logits.shape == (batch["batch"], 1, cfg.vocab)
            assert np.isfinite(np.asarray(logits)).all()
            assert int(c2.length) == 6
    elif spec.family == "gnn":
        p2, o2, loss = jax.jit(S.make_gnn_train_step(arch_id, cfg))(
            params, init_adamw(params), batch)
        assert np.isfinite(float(loss)), loss
    else:
        if cell.kind == "recsys_train":
            p2, o2, loss = jax.jit(S.make_recsys_train_step(cfg))(
                params, init_adamw(params), batch)
            assert np.isfinite(float(loss))
        elif cell.kind == "recsys_serve":
            out = jax.jit(S.make_recsys_serve(cfg))(params, batch)
            assert np.isfinite(np.asarray(out)).all()
            assert (np.asarray(out) >= 0).all() and \
                (np.asarray(out) <= 1).all()
        else:
            out = jax.jit(S.make_recsys_retrieval(cfg))(params, batch)
            assert out.shape[0] == batch["cand_ids"].shape[0]
            assert np.isfinite(np.asarray(out)).all()


def test_skip_cells_are_the_full_attention_long_context():
    assert set(SKIPPED) == {
        ("qwen2.5-3b", "long_500k"), ("glm4-9b", "long_500k"),
        ("qwen3-moe-30b-a3b", "long_500k"), ("arctic-480b", "long_500k")}


def test_total_cell_count():
    assert len(CELLS) + len(SKIPPED) == 40


def test_lm_train_loss_decreases():
    """A few steps on the reduced config must reduce loss (learnable
    synthetic motifs)."""
    from repro.data.lm import batches
    spec = REGISTRY["qwen2.5-3b"]
    cfg = spec.smoke_config
    params = I.init_fn(spec, True)(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step = jax.jit(S.make_lm_train_step(cfg, peak_lr=2e-3, warmup=5,
                                        total=60))
    data = batches(cfg.vocab, 8, 64, seed=3)
    losses = []
    for i in range(30):
        params, opt, loss = step(params, opt, next(data))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_moe_dispatch_conservation():
    """Tokens kept by dispatch get exactly their router weight back."""
    from repro.models.moe import init_moe, moe_ffn
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 16, 32, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16), jnp.float32)
    out, aux = moe_ffn(p, x, top_k=2, capacity_factor=4.0)  # no drops
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_decode_matches_prefill_logits():
    """Decoding token-by-token must match prefill at the same position."""
    spec = REGISTRY["qwen2.5-3b"]
    cfg = dataclasses.replace(spec.smoke_config, dtype="float32")
    params = T.init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits_full, _ = T.forward(cfg, params, toks)
    cache = T.init_cache(cfg, 2, 16)
    for t in range(8):
        logits_t, cache = T.decode_step(cfg, params, cache, toks[:, t:t+1])
    np.testing.assert_allclose(
        np.asarray(logits_t[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=2e-3, atol=2e-3)


def test_embedding_bag_matches_manual():
    from repro.models.recsys import embedding_bag
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
    ids = jnp.asarray([3, 7, 7, 40, 2], jnp.int32)
    bags = jnp.asarray([0, 0, 1, 1, 2], jnp.int32)
    out = embedding_bag(table, ids, bags, 4, mode="sum")
    ref = np.zeros((4, 8), np.float32)
    for i, b in zip([3, 7, 7, 40, 2], [0, 0, 1, 1, 2]):
        ref[b] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
    mean = embedding_bag(table, ids, bags, 4, mode="mean")
    assert np.allclose(np.asarray(mean)[0], ref[0] / 2, rtol=1e-6)
