"""Chaos harness: schedule grammar, fault-injectable transport semantics,
and the seeded end-to-end run (serve/chaos.py).

The end-to-end tests are the PR's acceptance check in miniature: under a
seeded schedule of replica kill, partition, delta drop and writer kill,
every surviving/promoted node must reconverge to writer parity (the
harness asserts L∞ ≤ 1e-6 internally — bitwise in practice) and no
committed generation may be lost across the failover.
"""
import numpy as np
import pytest

import repro  # noqa: F401
from repro.serve import FaultyTransport, LinkDown, LogicalClock, \
    parse_schedule
from repro.serve.chaos import ChaosAction, ChaosHarness


# ---------------------------------------------------------------------------
# schedule grammar
# ---------------------------------------------------------------------------

def test_parse_schedule_grammar():
    acts = parse_schedule(
        "kill:r0@600+200; partition:r1@300+200;kill_writer@900;"
        "delay:r1@50+100")
    assert acts == sorted(acts, key=lambda a: a.at)
    assert acts[0] == ChaosAction("delay", "r1", 50, 100)
    assert acts[1] == ChaosAction("partition", "r1", 300, 200)
    assert acts[2] == ChaosAction("kill", "r0", 600, 200)
    assert acts[3] == ChaosAction("kill_writer", None, 900, None)
    assert parse_schedule("") == []
    assert parse_schedule("kill:r0@5") == [ChaosAction("kill", "r0", 5,
                                                       None)]


@pytest.mark.parametrize("bad,msg", [
    ("kill:r0", "missing '@offset'"),
    ("explode:r0@5", "unknown kind"),
    ("kill_writer:r0@5", "takes no target"),
    ("partition@5", "needs a target"),
])
def test_parse_schedule_rejects(bad, msg):
    with pytest.raises(ValueError, match=msg):
        parse_schedule(bad)


# ---------------------------------------------------------------------------
# transport semantics
# ---------------------------------------------------------------------------

def test_transport_delivery_order_and_delay():
    t = FaultyTransport(seed=0, delay=0.5)
    t.register("a")
    t.send("w", "a", "m1", now=0.0)
    t.send("w", "a", "m2", now=0.1)
    assert t.deliver("a", now=0.4) == []          # nothing due yet
    assert t.deliver("a", now=0.55) == ["m1"]
    assert t.deliver("a", now=1.0) == ["m2"]
    assert t.delivered == 2


def test_transport_partition_blocks_both_planes():
    t = FaultyTransport(seed=0)
    t.register("w")
    t.register("a")

    class W:
        name, alive = "w", True
    t.set_writer(W())
    t.partition("a")
    t.send("w", "a", "m", now=0.0)                # data plane: dropped
    assert t.dropped == 1
    assert t.deliver("a", now=1.0) == []
    with pytest.raises(LinkDown):                 # control plane: raises
        t.writer_for("a")
    t.heal("a")
    assert t.writer_for("a") is not None
    t.send("w", "a", "m2", now=0.0)
    assert t.deliver("a", now=1.0) == ["m2"]


def test_transport_kill_loses_inbox():
    t = FaultyTransport(seed=0)
    t.register("a")
    t.send("w", "a", "m", now=0.0)
    t.kill("a")                                   # process death
    assert t.deliver("a", now=1.0) == []
    t.revive("a")
    assert t.deliver("a", now=1.0) == []          # the inbox is gone


def test_transport_duplicate_and_drop_counters():
    t = FaultyTransport(seed=1, dup_p=1.0)
    t.register("a")
    t.send("w", "a", "m", now=0.0)
    assert t.duplicated == 1
    assert len(t.deliver("a", now=1.0)) == 2
    t2 = FaultyTransport(seed=1, drop_p=1.0)
    t2.register("a")
    t2.send("w", "a", "m", now=0.0)
    assert t2.dropped == 1 and t2.deliver("a", now=1.0) == []


def test_transport_seeded_faults_are_deterministic():
    def counters(seed):
        t = FaultyTransport(seed=seed, drop_p=0.3, dup_p=0.2,
                            reorder_p=0.3)
        t.register("a")
        for i in range(200):
            t.send("w", "a", i, now=i * 0.01)
        got = t.deliver("a", now=100.0)
        return (t.dropped, t.duplicated, t.reordered, tuple(got))
    assert counters(7) == counters(7)
    assert counters(7) != counters(8)


# ---------------------------------------------------------------------------
# end-to-end chaos runs (the harness asserts parity internally)
# ---------------------------------------------------------------------------

def test_clean_run_reaches_parity():
    h = ChaosHarness(num_replicas=2, events=160, scale=7, seed=3)
    rep = h.run()
    assert rep.parity_checks >= 1
    assert rep.parity_max_linf <= 1e-6
    assert rep.failovers == 0 and rep.generations > 0


def test_chaos_run_recovers_from_kill_partition_and_failover():
    h = ChaosHarness(
        num_replicas=2, events=320, scale=7, seed=7, drop_p=0.05,
        schedule="partition:r1@80+60;kill:r0@160+60;kill_writer@260",
        staleness_slo_events=64)
    rep = h.run()
    assert rep.parity_checks >= 3          # heal, restart, failover, end
    assert rep.parity_max_linf <= 1e-6
    assert rep.failovers == 1
    assert h.writer.epoch == 1
    assert rep.resyncs >= 1
    assert rep.incidents["writer_failover"] == 1
    assert rep.incidents.get("replica_resync", 0) >= 1
    # no committed generation lost: the promoted writer kept counting
    assert rep.generations > 0
    assert rep.events_fed == 320
    assert rep.transport["dropped"] > 0


def test_chaos_run_is_seed_deterministic():
    def run(seed):
        h = ChaosHarness(num_replicas=1, events=160, scale=7, seed=seed,
                         drop_p=0.1, schedule="partition:r0@40+40")
        rep = h.run()
        ranks = np.asarray(h.writer.engine.store.snapshot().ranks)
        return rep.generations, rep.resyncs, rep.parity_checks, ranks
    g1, r1, p1, ranks1 = run(11)
    g2, r2, p2, ranks2 = run(11)
    assert (g1, r1, p1) == (g2, r2, p2)
    np.testing.assert_array_equal(ranks1, ranks2)
