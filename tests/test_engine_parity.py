"""Cross-engine differential harness: one seeded update stream, three
engines, lock-step assertions.

``run_parity`` drives the SAME stream (graph/generators.update_stream:
insert/delete mixes over skewed RMAT or uniform graphs, deletion-heavy
and insert-only regimes) through

  * the f64 XLA engine (``update_pagerank``),
  * the single-pod kernel engine (incrementally maintained PackedGraph
    + ``hybrid_pagerank``), and
  * the sharded kernel engine (window-range shards on a ``model`` mesh,
    routed deltas, shard_map'd hybrid ladder),

asserting at EVERY micro-batch that the surviving-edge sets are
identical (graph vs packed vs sharded oracle) and that pairwise rank L1
≤ 1e-6 — each engine carries its *own* rank chain, so drift compounds
and cannot hide.  Parameterized over frontier / frontier_prune.

The in-process tests run the full three-engine harness on a 1-way mesh
(every sharded code path: routing, stacking, shard_map, psum); the
``slow``-marked subprocess test reruns it on a real 4-way forced-device
mesh (conftest keeps this process at one device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import pagerank as pr
from repro.core.api import KERNEL_FLAGS, update_pagerank
from repro.core.kernel_engine import hybrid_pagerank
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import update_stream
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.shard import sharded_edge_set
from repro.kernels.pagerank_spmv.update import (apply_batch_packed,
                                                pack_graph, packed_edge_set)

_PACK = dict(be=32, vb=16, spill_lanes_per_window=64)


def _edge_set(g):
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    return set(zip(src[valid].tolist(), dst[valid].tolist()))


def run_parity(regime, method, *, graph="rmat", seed=0, num_batches=6,
               num_shards=None, scale=5, edge_factor=4, batch_size=18,
               l1_tol=1e-6, exchange="halo", wire="packed"):
    """Drive one stream through all engines; assert in lock-step.

    ``num_shards``: include the sharded kernel engine on a mesh over the
    first ``num_shards`` visible devices (None = xla vs kernel only);
    ``exchange``/``wire`` select its iteration-exchange recipe.
    Returns the number of batches driven.
    """
    init, n, batches = update_stream(scale, edge_factor, regime=regime,
                                     graph=graph, num_batches=num_batches,
                                     batch_size=batch_size, seed=seed)
    cap = len(init) + num_batches * (batch_size + 2) + 64
    g = from_coo(init[:, 0], init[:, 1], n, edge_capacity=cap)
    packed = pack_graph(g, **_PACK)
    sharded = None
    if num_shards:
        from jax.sharding import Mesh

        from repro.dist.pagerank_dist import ShardedKernelEngine
        mesh = Mesh(np.array(jax.devices()[:num_shards]), ("model",))
        sharded = ShardedKernelEngine(mesh, g, pack_kw=dict(_PACK),
                                      exchange=exchange, wire=wire)
    flags = KERNEL_FLAGS[method]
    r0 = pr.static_pagerank(g).ranks
    ranks = {"xla": r0, "kernel": r0, "sharded": r0}
    for bi, (dels, ins) in enumerate(batches):
        upd = make_batch_update(dels, ins, max(8, len(dels)),
                                max(8, len(ins)))
        g_new = apply_batch(g, upd)
        want_edges = _edge_set(g_new)
        packed = apply_batch_packed(packed, upd)
        assert packed_edge_set(packed) == want_edges, (regime, method, bi)
        touched = touched_vertices_mask(upd, n)
        aff = pr.initial_affected(g, g_new, touched)
        out = {"xla": update_pagerank(g, g_new, upd, ranks["xla"], method),
               "kernel": hybrid_pagerank(g_new, packed, ranks["kernel"],
                                         aff, use_kernel=False, **flags)}
        if sharded is not None:
            sharded.apply_update(upd)
            assert sharded_edge_set(sharded.sharded, sharded.spec) \
                == want_edges, (regime, method, bi)
            out["sharded"] = sharded.solve(g_new, ranks["sharded"], aff,
                                           **flags)
        names = list(out)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                l1 = float(jnp.sum(jnp.abs(out[a].ranks - out[b].ranks)))
                assert l1 <= l1_tol, (regime, method, bi, a, b, l1)
        g = g_new
        for k in out:
            ranks[k] = out[k].ranks
    return len(batches)


# ---------------------------------------------------------------------------
# in-process: full three-engine harness, 1-way mesh
# ---------------------------------------------------------------------------

_SEEDS = {("insert_only", "frontier"): 11,
          ("insert_only", "frontier_prune"): 12,
          ("mixed", "frontier"): 13,
          ("mixed", "frontier_prune"): 14,
          ("delete_heavy", "frontier"): 15,
          ("delete_heavy", "frontier_prune"): 16}


@pytest.mark.parametrize("method", ["frontier", "frontier_prune"])
@pytest.mark.parametrize("regime",
                         ["insert_only", "mixed", "delete_heavy"])
def test_engine_parity_rmat(regime, method):
    assert run_parity(regime, method, num_shards=1,
                      seed=_SEEDS[(regime, method)]) >= 4


@pytest.mark.parametrize("method", ["frontier", "frontier_prune"])
def test_engine_parity_uniform(method):
    assert run_parity("mixed", method, graph="uniform", num_shards=1,
                      seed=17) >= 4


@pytest.mark.parametrize("exchange,wire", [("psum", "packed"),
                                           ("halo", "quantized")])
def test_engine_parity_exchange_variants(exchange, wire):
    # default runs ride the halo/packed exchange; keep the psum loop and
    # the quantized wire under the same lock-step differential
    assert run_parity("mixed", "frontier", num_shards=1, seed=13,
                      num_batches=4, exchange=exchange, wire=wire) >= 4


def run_halo_differential(num_shards, *, regime="mixed", seed=29,
                          num_batches=8, scale=6, edge_factor=4,
                          batch_size=18, l1_tol=1e-6):
    """Halo-vs-psum differential: the SAME stream through three sharded
    engines (full-psum baseline, halo exchange, halo on the quantized
    int8/s16 flag wire), lock-step rank L1 ≤ tol at every batch, plus
    the comm-volume claims: halo wire ∝ boundary slots (sublinear in the
    padded vertex count once shards cut few edges) and the quantized
    wire strictly cheaper than the packed one."""
    from jax.sharding import Mesh

    from repro.dist.pagerank_dist import ShardedKernelEngine
    init, n, batches = update_stream(scale, edge_factor, regime=regime,
                                     num_batches=num_batches,
                                     batch_size=batch_size, seed=seed)
    cap = len(init) + num_batches * (batch_size + 2) + 64
    g = from_coo(init[:, 0], init[:, 1], n, edge_capacity=cap)
    mesh = Mesh(np.array(jax.devices()[:num_shards]), ("model",))
    engines = {
        "psum": ShardedKernelEngine(mesh, g, pack_kw=dict(_PACK),
                                    exchange="psum"),
        "halo": ShardedKernelEngine(mesh, g, pack_kw=dict(_PACK)),
        "halo_q": ShardedKernelEngine(mesh, g, pack_kw=dict(_PACK),
                                      wire="quantized"),
    }
    ranks = {k: pr.static_pagerank(g).ranks for k in engines}
    flags = KERNEL_FLAGS["frontier_prune"]
    for bi, (dels, ins) in enumerate(batches):
        upd = make_batch_update(dels, ins, max(8, len(dels)),
                                max(8, len(ins)))
        g_new = apply_batch(g, upd)
        touched = touched_vertices_mask(upd, n)
        aff = pr.initial_affected(g, g_new, touched)
        out = {}
        for k, eng in engines.items():
            eng.apply_update(upd)
            out[k] = eng.solve(g_new, ranks[k], aff, **flags)
        for k in ("halo", "halo_q"):
            l1 = float(jnp.sum(jnp.abs(out[k].ranks - out["psum"].ranks)))
            assert l1 <= l1_tol, (bi, k, l1)
        info_h = engines["halo"].last_comm_info
        info_q = engines["halo_q"].last_comm_info
        it = info_h["f32_iterations"]
        if it:
            # per-iteration wire ∝ halo slots — and the slot capacity
            # tracks the live boundary (constant headroom, 64-rounded),
            # NOT the vertex count, which is the sublinearity claim at
            # any scale (at toy scale the 64-slot rounding can exceed a
            # tiny v_pad; what matters is that V never enters the bound)
            per_it = engines["halo"].last_comm_bytes / (it + 1)
            assert per_it == info_h["halo_slots"] * 8
            widest = int(np.asarray(engines["halo"].halo.count).max())
            cap = engines["halo"].halo.ids.shape[1]
            assert cap <= ((int(widest * 1.25) + 64 + 63) // 64) * 64, \
                (bi, widest, cap)
            assert engines["halo_q"].last_comm_bytes \
                < engines["halo"].last_comm_bytes, (bi, info_h, info_q)
        g = g_new
        for k in out:
            ranks[k] = out[k].ranks
    return len(batches)


def test_halo_vs_psum_differential_one_way():
    assert run_halo_differential(1, num_batches=4) >= 4


# ---------------------------------------------------------------------------
# long-horizon drift: 500 DF-P batches vs the shadow reference
# ---------------------------------------------------------------------------

def test_dfp_long_stream_drift_stays_bounded():
    """DF-P prunes below-threshold frontier vertices, so each batch can
    leave slightly stale ranks; over a long stream that error compounds.
    Drive 500 mixed insert/delete micro-batches through one continuous
    DF-P rank chain and let the shadow verifier (every 25th batch,
    synchronous) diff it against a from-scratch f64 reference solve:
    the accumulated drift must stay an order of magnitude under the
    monitor's default production budgets (measured max ~5e-6 L1 /
    ~3.4e-7 L-inf on this seed; budgets below carry ~10x headroom)."""
    from repro.obs import ShadowVerifier
    num_batches, batch_size = 500, 8
    init, n, batches = update_stream(5, 4, regime="mixed",
                                     num_batches=num_batches,
                                     batch_size=batch_size, seed=123)
    cap = len(init) + num_batches * (batch_size + 2) + 64
    g = from_coo(init[:, 0], init[:, 1], n, edge_capacity=cap)
    ranks = pr.static_pagerank(g).ranks
    sv = ShadowVerifier(every=25, background=False,
                        l1_budget=5e-5, linf_budget=5e-6)
    for bi, (dels, ins) in enumerate(batches):
        upd = make_batch_update(dels, ins, max(8, len(dels)),
                                max(8, len(ins)))
        g_new = apply_batch(g, upd)
        out = update_pagerank(g, g_new, upd, ranks, "frontier_prune")
        g, ranks = g_new, out.ranks
        sv.maybe_submit(bi + 1, bi + 1, g, ranks)
    assert sv.samples == num_batches // 25
    assert sv.take_incidents() == []          # every sample under budget
    assert max(r.l1 for r in sv.reports) <= 5e-5
    assert max(r.linf for r in sv.reports) <= 5e-6
    # drift is bounded, not monotone: the frontier keeps re-touching
    # most of the graph, so late samples look like early ones
    assert sv.reports[-1].l1 <= 5e-5


# ---------------------------------------------------------------------------
# subprocess: the same harness on a real >= 4-way host-device mesh
# ---------------------------------------------------------------------------

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_engine_parity_four_way_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    code = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.path.insert(0, "tests")
        import repro
        from test_engine_parity import run_halo_differential, run_parity
        from test_kernel_sharded import run_trace_stream
        run_parity("mixed", "frontier_prune", num_shards=4, seed=3)
        run_parity("delete_heavy", "frontier", num_shards=4, seed=5,
                   num_batches=4)
        run_parity("insert_only", "frontier_prune", graph="uniform",
                   num_shards=4, seed=7, num_batches=4)
        # halo-vs-psum differential on a real multi-shard boundary
        run_halo_differential(4, num_batches=6)
        # acceptance: a 50-batch stream on the 4-way mesh compiles one
        # route + one per-shard update + one kernel loop, total
        delta = run_trace_stream(4, num_batches=50)
        assert not any(delta.values()), delta
        print("PARITY4 OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=_REPO, timeout=540)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "PARITY4 OK" in r.stdout
