"""Cross-engine differential harness: one seeded update stream, three
engines, lock-step assertions.

``run_parity`` drives the SAME stream (graph/generators.update_stream:
insert/delete mixes over skewed RMAT or uniform graphs, deletion-heavy
and insert-only regimes) through

  * the f64 XLA engine (``update_pagerank``),
  * the single-pod kernel engine (incrementally maintained PackedGraph
    + ``hybrid_pagerank``), and
  * the sharded kernel engine (window-range shards on a ``model`` mesh,
    routed deltas, shard_map'd hybrid ladder),

asserting at EVERY micro-batch that the surviving-edge sets are
identical (graph vs packed vs sharded oracle) and that pairwise rank L1
≤ 1e-6 — each engine carries its *own* rank chain, so drift compounds
and cannot hide.  Parameterized over frontier / frontier_prune.

The in-process tests run the full three-engine harness on a 1-way mesh
(every sharded code path: routing, stacking, shard_map, psum); the
``slow``-marked subprocess test reruns it on a real 4-way forced-device
mesh (conftest keeps this process at one device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import pagerank as pr
from repro.core.api import KERNEL_FLAGS, update_pagerank
from repro.core.kernel_engine import hybrid_pagerank
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import update_stream
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.shard import sharded_edge_set
from repro.kernels.pagerank_spmv.update import (apply_batch_packed,
                                                pack_graph, packed_edge_set)

_PACK = dict(be=32, vb=16, spill_lanes_per_window=64)


def _edge_set(g):
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    return set(zip(src[valid].tolist(), dst[valid].tolist()))


def run_parity(regime, method, *, graph="rmat", seed=0, num_batches=6,
               num_shards=None, scale=5, edge_factor=4, batch_size=18,
               l1_tol=1e-6):
    """Drive one stream through all engines; assert in lock-step.

    ``num_shards``: include the sharded kernel engine on a mesh over the
    first ``num_shards`` visible devices (None = xla vs kernel only).
    Returns the number of batches driven.
    """
    init, n, batches = update_stream(scale, edge_factor, regime=regime,
                                     graph=graph, num_batches=num_batches,
                                     batch_size=batch_size, seed=seed)
    cap = len(init) + num_batches * (batch_size + 2) + 64
    g = from_coo(init[:, 0], init[:, 1], n, edge_capacity=cap)
    packed = pack_graph(g, **_PACK)
    sharded = None
    if num_shards:
        from jax.sharding import Mesh

        from repro.dist.pagerank_dist import ShardedKernelEngine
        mesh = Mesh(np.array(jax.devices()[:num_shards]), ("model",))
        sharded = ShardedKernelEngine(mesh, g, pack_kw=dict(_PACK))
    flags = KERNEL_FLAGS[method]
    r0 = pr.static_pagerank(g).ranks
    ranks = {"xla": r0, "kernel": r0, "sharded": r0}
    for bi, (dels, ins) in enumerate(batches):
        upd = make_batch_update(dels, ins, max(8, len(dels)),
                                max(8, len(ins)))
        g_new = apply_batch(g, upd)
        want_edges = _edge_set(g_new)
        packed = apply_batch_packed(packed, upd)
        assert packed_edge_set(packed) == want_edges, (regime, method, bi)
        touched = touched_vertices_mask(upd, n)
        aff = pr.initial_affected(g, g_new, touched)
        out = {"xla": update_pagerank(g, g_new, upd, ranks["xla"], method),
               "kernel": hybrid_pagerank(g_new, packed, ranks["kernel"],
                                         aff, use_kernel=False, **flags)}
        if sharded is not None:
            sharded.apply_update(upd)
            assert sharded_edge_set(sharded.sharded, sharded.spec) \
                == want_edges, (regime, method, bi)
            out["sharded"] = sharded.solve(g_new, ranks["sharded"], aff,
                                           **flags)
        names = list(out)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                l1 = float(jnp.sum(jnp.abs(out[a].ranks - out[b].ranks)))
                assert l1 <= l1_tol, (regime, method, bi, a, b, l1)
        g = g_new
        for k in out:
            ranks[k] = out[k].ranks
    return len(batches)


# ---------------------------------------------------------------------------
# in-process: full three-engine harness, 1-way mesh
# ---------------------------------------------------------------------------

_SEEDS = {("insert_only", "frontier"): 11,
          ("insert_only", "frontier_prune"): 12,
          ("mixed", "frontier"): 13,
          ("mixed", "frontier_prune"): 14,
          ("delete_heavy", "frontier"): 15,
          ("delete_heavy", "frontier_prune"): 16}


@pytest.mark.parametrize("method", ["frontier", "frontier_prune"])
@pytest.mark.parametrize("regime",
                         ["insert_only", "mixed", "delete_heavy"])
def test_engine_parity_rmat(regime, method):
    assert run_parity(regime, method, num_shards=1,
                      seed=_SEEDS[(regime, method)]) >= 4


@pytest.mark.parametrize("method", ["frontier", "frontier_prune"])
def test_engine_parity_uniform(method):
    assert run_parity("mixed", method, graph="uniform", num_shards=1,
                      seed=17) >= 4


# ---------------------------------------------------------------------------
# subprocess: the same harness on a real >= 4-way host-device mesh
# ---------------------------------------------------------------------------

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_engine_parity_four_way_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    code = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.path.insert(0, "tests")
        import repro
        from test_engine_parity import run_parity
        from test_kernel_sharded import run_trace_stream
        run_parity("mixed", "frontier_prune", num_shards=4, seed=3)
        run_parity("delete_heavy", "frontier", num_shards=4, seed=5,
                   num_batches=4)
        run_parity("insert_only", "frontier_prune", graph="uniform",
                   num_shards=4, seed=7, num_batches=4)
        # acceptance: a 50-batch stream on the 4-way mesh compiles one
        # route + one per-shard update + one kernel loop, total
        delta = run_trace_stream(4, num_batches=50)
        assert not any(delta.values()), delta
        print("PARITY4 OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=_REPO, timeout=540)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "PARITY4 OK" in r.stdout
