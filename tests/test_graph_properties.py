"""Hypothesis property tests on the dynamic-graph substrate invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:        # pragma: no cover
    HAVE_HYP = False
    pytestmark = pytest.mark.skip(reason="hypothesis not installed")

import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.structure import from_coo, sort_edges_by_dst

if HAVE_HYP:
    N = 24

    @st.composite
    def graph_and_update(draw):
        n_edges = draw(st.integers(1, 40))
        edges = draw(st.lists(
            st.tuples(st.integers(0, N - 1), st.integers(0, N - 1)),
            min_size=n_edges, max_size=n_edges))
        edges = [(u, v) for u, v in edges if u != v]
        n_del = draw(st.integers(0, min(4, len(edges))))
        dels = edges[:n_del]
        n_ins = draw(st.integers(0, 4))
        ins = draw(st.lists(
            st.tuples(st.integers(0, N - 1), st.integers(0, N - 1)),
            min_size=n_ins, max_size=n_ins))
        ins = [(u, v) for u, v in ins if u != v]
        return edges, dels, ins

    @given(graph_and_update())
    @settings(max_examples=40, deadline=None)
    def test_apply_batch_edge_set_semantics(data):
        """apply_batch realises exactly (E \\ Δ⁻) ∪ Δ⁺ as a set."""
        edges, dels, ins = data
        if not edges:
            return
        e = np.asarray(edges, np.int32)
        g = from_coo(e[:, 0], e[:, 1], N, edge_capacity=len(e) + 16)
        upd = make_batch_update(
            np.asarray(dels, np.int32).reshape(-1, 2),
            np.asarray(ins, np.int32).reshape(-1, 2), 8, 8)
        g2 = apply_batch(g, upd)
        got = set(map(tuple, np.stack(
            [np.asarray(g2.src)[np.asarray(g2.valid)],
             np.asarray(g2.dst)[np.asarray(g2.valid)]], 1).tolist()))
        want = (set(map(tuple, edges)) - set(map(tuple, dels))) \
            | set(map(tuple, ins))
        assert got == want

    @given(graph_and_update())
    @settings(max_examples=25, deadline=None)
    def test_pagerank_ranks_sum_to_one(data):
        edges, _, _ = data
        if not edges:
            return
        e = np.unique(np.asarray(edges, np.int32), axis=0)
        g = from_coo(e[:, 0], e[:, 1], N, edge_capacity=len(e) + 4)
        res = pr.static_pagerank(g)
        assert abs(float(jnp.sum(res.ranks)) - 1.0) < 1e-8
        assert (np.asarray(res.ranks) > 0).all()

    @given(graph_and_update())
    @settings(max_examples=25, deadline=None)
    def test_dst_sort_preserves_edge_set(data):
        edges, _, _ = data
        if not edges:
            return
        e = np.unique(np.asarray(edges, np.int32), axis=0)
        g = from_coo(e[:, 0], e[:, 1], N, edge_capacity=len(e) + 8)
        gs = sort_edges_by_dst(g)
        a = set(map(tuple, np.stack(
            [np.asarray(g.src)[np.asarray(g.valid)],
             np.asarray(g.dst)[np.asarray(g.valid)]], 1).tolist()))
        b = set(map(tuple, np.stack(
            [np.asarray(gs.src)[np.asarray(gs.valid)],
             np.asarray(gs.dst)[np.asarray(gs.valid)]], 1).tolist()))
        assert a == b
        d = np.asarray(gs.dst)[np.asarray(gs.valid)]
        assert (np.diff(d) >= 0).all()

    @given(graph_and_update())
    @settings(max_examples=20, deadline=None)
    def test_df_fixed_point_independent_of_history(data):
        """DF from ANY warm start converges to the same fixed point."""
        edges, dels, ins = data
        if len(edges) < 3:
            return
        e = np.unique(np.asarray(edges, np.int32), axis=0)
        g = from_coo(e[:, 0], e[:, 1], N, edge_capacity=len(e) + 16)
        upd = make_batch_update(
            np.asarray(dels, np.int32).reshape(-1, 2),
            np.asarray(ins, np.int32).reshape(-1, 2), 8, 8)
        g2 = apply_batch(g, upd)
        res_static = pr.static_pagerank(g2)
        prev = pr.static_pagerank(g).ranks
        touched = touched_vertices_mask(upd, N)
        res_df = pr.dynamic_frontier_pagerank(g, g2, touched, prev)
        np.testing.assert_allclose(np.asarray(res_df.ranks),
                                   np.asarray(res_static.ranks),
                                   rtol=0, atol=5e-7)
