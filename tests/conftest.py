"""Shared fixtures.  NOTE: no XLA_FLAGS device forcing here — smoke tests
must see the real single CPU device; only launch/dryrun.py forces 512."""
import numpy as np
import pytest

import repro  # noqa: F401  (enables x64)
from repro.graph.generators import rmat_edges, erdos_renyi_edges
from repro.graph.structure import from_coo


@pytest.fixture(scope="session")
def small_rmat():
    edges, n = rmat_edges(8, 8, seed=1)
    return edges, n


@pytest.fixture(scope="session")
def small_graph(small_rmat):
    edges, n = small_rmat
    return from_coo(edges[:, 0], edges[:, 1], n,
                    edge_capacity=len(edges) * 2)


@pytest.fixture(scope="session")
def er_graph():
    edges, n = erdos_renyi_edges(300, 2000, seed=7)
    return edges, n
