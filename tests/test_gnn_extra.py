"""Extra pool GNNs (GCN/GIN/GAT): smoke + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.generators import rmat_edges
from repro.models.gnn import GraphBatch
from repro.models.gnn_extra import (GATConfig, GCNConfig, GINConfig,
                                    gat_forward, gcn_forward, gin_forward,
                                    init_gat, init_gcn, init_gin,
                                    segment_softmax)


def _batch(n, d_in, seed=0):
    edges, nv = rmat_edges(6, 6, seed=seed)
    rng = np.random.default_rng(seed)
    return GraphBatch(
        node_feats=jnp.asarray(rng.standard_normal((nv, d_in)), jnp.float32),
        edge_src=jnp.asarray(edges[:, 0]), edge_dst=jnp.asarray(edges[:, 1]),
        edge_mask=jnp.ones((len(edges),), bool),
        node_mask=jnp.ones((nv,), bool)), nv


@pytest.mark.parametrize("which", ["gcn", "gin", "gat"])
def test_forward_shapes_and_finite(which):
    cfg = dict(gcn=GCNConfig(d_in=12, n_classes=4, d_hidden=16),
               gin=GINConfig(d_in=12, n_classes=4, d_hidden=16),
               gat=GATConfig(d_in=12, n_classes=4, d_hidden=16,
                             n_heads=2))[which]
    init = dict(gcn=init_gcn, gin=init_gin, gat=init_gat)[which]
    fwd = dict(gcn=gcn_forward, gin=gin_forward, gat=gat_forward)[which]
    g, nv = _batch(64, 12)
    params = init(cfg, jax.random.PRNGKey(0))
    out = jax.jit(lambda p, gb: fwd(cfg, p, gb))(params, g)
    assert out.shape == (nv, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_segment_softmax_normalises_per_destination():
    scores = jnp.asarray([[1.0], [2.0], [3.0], [0.5]])
    seg = jnp.asarray([0, 0, 1, 1])
    mask = jnp.ones((4,), bool)
    att = segment_softmax(scores, seg, mask, 4)
    s0 = float(att[0, 0] + att[1, 0])
    s1 = float(att[2, 0] + att[3, 0])
    assert abs(s0 - 1.0) < 1e-6 and abs(s1 - 1.0) < 1e-6
    # masked edges get zero attention and the rest renormalises
    att2 = segment_softmax(scores, seg, jnp.asarray([True, False, True,
                                                     True]), 4)
    assert float(att2[1, 0]) == 0.0
    assert abs(float(att2[0, 0]) - 1.0) < 1e-6


def test_gcn_grad_flows():
    cfg = GCNConfig(d_in=8, n_classes=3, d_hidden=8)
    g, nv = _batch(32, 8, seed=3)
    params = init_gcn(cfg, jax.random.PRNGKey(1))
    labels = jnp.zeros((nv,), jnp.int32)

    def loss(p):
        logits = gcn_forward(cfg, p, g)
        return -jnp.mean(jax.nn.log_softmax(logits)[:, 0])

    grads = jax.grad(loss)(params)
    norm = sum(float(jnp.sum(jnp.abs(w))) + float(jnp.sum(jnp.abs(b)))
               for w, b in grads)
    assert np.isfinite(norm) and norm > 0
