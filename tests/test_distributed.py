"""Distributed-engine tests: shard_map PageRank equals the single-device
engine; dry-run cells lower+compile on a small forced-device mesh.

Multi-device tests run in a SUBPROCESS because the device count must be
forced before jax initialises (conftest keeps the main process at 1
device for smoke realism).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SUB = dict(cwd=_REPO, timeout=540)


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, **_SUB)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


def test_distributed_pagerank_matches_reference():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        import repro
        from repro.graph.generators import rmat_edges
        from repro.graph.structure import from_coo
        from repro.graph.partition import partition_graph
        from repro.core.reference import static_pagerank_ref, l1_error
        from repro.dist.pagerank_dist import (build_distributed_step,
                                              distributed_in_shardings)
        from repro.launch.mesh import make_test_mesh

        edges, n = rmat_edges(8, 8, seed=5)
        g = from_coo(edges[:,0], edges[:,1], n, edge_capacity=len(edges)+8)
        mesh = make_test_mesh(8)
        m, p = mesh.shape["model"], mesh.shape["data"]
        part = partition_graph(g, m, p)
        v_pad = part.v_per_shard * m
        deg = np.zeros(n, np.int64); np.add.at(deg, edges[:,0], 1)
        inv = np.zeros(v_pad, np.float32)
        inv[:n] = 1.0/(deg+1)
        ranks0 = np.zeros(v_pad, np.float32); ranks0[:n] = 1.0/n
        seeds = np.zeros(v_pad, bool); seeds[:n] = True   # static-from-warm
        # reshape edge stripes to [M, P, E_dev]
        fn = build_distributed_step(mesh, n_vertices=n, tol=1e-9,
                                    prune=False, frontier_tol=1e-7)
        sh = distributed_in_shardings(mesh)
        args = [jnp.asarray(part.src), jnp.asarray(part.dst_local),
                jnp.asarray(part.valid), jnp.asarray(ranks0),
                jnp.asarray(inv), jnp.asarray(seeds)]
        args = [jax.device_put(a, s) for a, s in zip(args, sh)]
        ranks, iters, delta = jax.jit(fn)(*args)
        ref, _ = static_pagerank_ref(edges[:,0], edges[:,1], n, tol=1e-12)
        err = l1_error(np.asarray(ranks)[:n], ref)
        print("L1", err, "iters", int(iters))
        assert err < 5e-5, err
    """)
    assert "L1" in out


def test_distributed_stream_matches_reference():
    """api-level wiring: update_pagerank(mesh=...) replays a random-update
    stream with DF-P on the mesh; every batch's fixed point must match the
    static oracle of the mutated graph."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        import repro
        from repro.core.api import update_pagerank
        from repro.core.reference import static_pagerank_ref, l1_error
        from repro.graph.dynamic import apply_batch, make_batch_update
        from repro.graph.generators import rmat_edges, random_batch_update
        from repro.graph.structure import from_coo
        from repro.launch.mesh import make_test_mesh

        edges, n = rmat_edges(8, 8, seed=11)
        g = from_coo(edges[:,0], edges[:,1], n, edge_capacity=len(edges)+64)
        mesh = make_test_mesh(8)
        ranks = update_pagerank(g, g, None, None, "static", mesh=mesh).ranks
        for i in range(3):
            live = np.stack([np.asarray(g.src), np.asarray(g.dst)], 1)
            live = live[np.asarray(g.valid)]
            dele, ins = random_batch_update(live, n, 16, seed=i)
            upd = make_batch_update(dele, ins, 16, 16)
            g_new = apply_batch(g, upd)
            r = update_pagerank(g, g_new, upd, ranks, "frontier_prune",
                                mesh=mesh)
            sv = np.asarray(g_new.src)[np.asarray(g_new.valid)]
            dv = np.asarray(g_new.dst)[np.asarray(g_new.valid)]
            ref, _ = static_pagerank_ref(sv, dv, n, tol=1e-12)
            err = l1_error(r.ranks, ref)
            assert err < 5e-5, (i, err)
            assert int(r.iterations) > 0
            g, ranks = g_new, r.ranks
        print("STREAM OK")
    """)
    assert "STREAM OK" in out


def test_dryrun_cells_compile_on_small_mesh():
    """One representative cell per family + multi-pod pagerank."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, repro
        from repro.configs.registry import get_arch
        from repro.launch.dryrun import run_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cells = [("qwen2.5-3b", "decode_32k", mesh),
                 ("graphsage-reddit", "minibatch_lg", mesh),
                 ("deepfm", "train_batch", mesh),
                 ("df-pagerank", "temporal_so", mesh3)]
        for arch, shape, m in cells:
            spec = get_arch(arch)
            rec = run_cell(spec, spec.shapes[shape], m, "test")
            assert rec["status"] == "OK", rec
            assert rec["cost"].get("flops", 0) > 0
        print("ALL OK")
    """)
    assert "ALL OK" in out


def test_elastic_restore_onto_different_mesh(tmp_path):
    out = _run(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        import repro
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ft import checkpoint as ck
        state = dict(w=jnp.arange(64, dtype=jnp.float32).reshape(8, 8))
        ck.save("{tmp_path}", 1, state)
        # restore sharded onto a 2x4 mesh (different from writer's layout)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sh = dict(w=NamedSharding(mesh, P("data", "model")))
        out = ck.restore("{tmp_path}", 1,
                         jax.eval_shape(lambda: state), sh)
        assert out["w"].sharding.num_devices == 8
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(state["w"]))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out
