"""Data-layer tests: generators determinism, SNAP stand-ins, streams."""
import numpy as np

from repro.data.snap import PAPER_TABLE1, all_paper_datasets, load_temporal
from repro.graph.generators import (TemporalStream, grid_edges,
                                    random_batch_update, rmat_edges,
                                    temporal_stream_edges)


def test_rmat_deterministic_and_simple():
    e1, n1 = rmat_edges(8, 8, seed=4)
    e2, n2 = rmat_edges(8, 8, seed=4)
    np.testing.assert_array_equal(e1, e2)
    assert n1 == 256
    assert (e1[:, 0] != e1[:, 1]).all()          # no self loops
    assert len(np.unique(e1, axis=0)) == len(e1)  # no duplicates


def test_grid_degree_and_size():
    e, n = grid_edges(10)
    assert n == 100
    deg = np.zeros(n)
    np.add.at(deg, e[:, 0], 1)
    assert deg.max() == 4 and deg.min() == 2      # corners


def test_temporal_stream_properties():
    e = temporal_stream_edges(1000, 5000, seed=1)
    assert e.shape == (5000, 2)
    assert (e[:, 0] != e[:, 1]).all()
    assert e.max() < 1000
    # locality: consecutive edges share communities far above chance
    st = TemporalStream(e, 1000, batch_frac=1e-3, num_batches=5)
    assert st.batch_size == 5
    assert len(st.preload_edges()) == 4500
    assert len(st.batch(0)) == 5


def test_snap_standins_cover_paper_table():
    for name in PAPER_TABLE1:
        ds = load_temporal(name)
        assert ds.synthetic
        assert ds.num_vertices > 0
        assert len(ds.edges) > 1000
        ratio_paper = PAPER_TABLE1[name][1] / PAPER_TABLE1[name][0]
        ratio_ours = len(ds.edges) / ds.num_vertices
        assert 0.5 < ratio_ours / ratio_paper < 2.0   # |E_T|/|V| preserved


def test_random_batch_update_mix():
    e, n = rmat_edges(8, 8, seed=2)
    dele, ins = random_batch_update(e, n, 100, seed=3)
    assert 15 <= len(dele) <= 25          # ~20%
    assert 70 <= len(ins) <= 85           # ~80%
    # deletions come from live edges
    live = set(map(tuple, e.tolist()))
    assert all(tuple(d) in live for d in dele.tolist())
