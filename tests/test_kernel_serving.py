"""Kernel serving path: incremental PackedGraph maintenance, streaming
parity vs rebuild + f64 engine, single-compilation contract, spill
exhaustion, hybrid precision, work counters, ServeEngine integration."""
import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import pagerank as pr
from repro.core.api import update_pagerank
from repro.core.kernel_engine import (TRACE_COUNTS as LOOP_TRACES,
                                      hybrid_pagerank, kernel_pagerank_loop)
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import erdos_renyi_edges, rmat_edges
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.pagerank_spmv import pack_blocks
from repro.kernels.pagerank_spmv.update import (TRACE_COUNTS as UPD_TRACES,
                                                apply_batch_packed,
                                                pack_graph, packed_edge_set)
from repro.serve import IngestQueue, RankStore, ServeEngine, ServeMetrics

N = 48


def _graph_edge_set(g):
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    return set(zip(src[valid].tolist(), dst[valid].tolist()))


def _random_update(rng, live, n_del=4, n_ins=6):
    """Interleaved deletions (live + absent) and insertions (+dup)."""
    dels = []
    if len(live) and n_del:
        picks = rng.choice(len(live), size=min(n_del, len(live)),
                           replace=False)
        dels.extend(map(tuple, live[picks].tolist()))
    e = rng.integers(0, N, size=(2, 2))
    dels.extend(map(tuple, e[e[:, 0] != e[:, 1]].tolist()))  # absent: no-op
    e = rng.integers(0, N, size=(n_ins, 2))
    ins = list(map(tuple, e[e[:, 0] != e[:, 1]].tolist()))
    if ins:
        ins.append(ins[0])                                   # in-batch dup
    if dels:
        ins.append(dels[0])                                  # delete→reinsert
    return (np.asarray(dels, np.int32).reshape(-1, 2),
            np.asarray(ins, np.int32).reshape(-1, 2))


# ---------------------------------------------------------------------------
# streaming parity: N micro-batches == fresh rebuild (set) == f64 ranks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_streaming_packed_parity(seed):
    rng = np.random.default_rng(seed)
    init = np.unique(rng.integers(0, N, size=(120, 2)), axis=0)
    init = init[init[:, 0] != init[:, 1]]
    g = from_coo(init[:, 0], init[:, 1], N, edge_capacity=len(init) + 128)
    packed = pack_graph(g, be=32, vb=16, spill_lanes_per_window=32)
    ranks = pr.static_pagerank(g).ranks
    ranks_xla = ranks

    for step in range(8):
        live = np.asarray(sorted(_graph_edge_set(g)), np.int32).reshape(-1, 2)
        dels, ins = _random_update(rng, live)
        upd = make_batch_update(dels, ins, 8, 16)
        g_new = apply_batch(g, upd)
        packed = apply_batch_packed(packed, upd)

        # (a) bitwise parity with a fresh pack_blocks rebuild on the
        # packed structure's live-edge *set*
        rebuilt = pack_graph(g_new, be=32, vb=16)
        assert packed_edge_set(packed) == packed_edge_set(rebuilt), step
        assert packed_edge_set(packed) == _graph_edge_set(g_new), step

        # (b) kernel-engine ranks track the f64 XLA engine
        touched = touched_vertices_mask(upd, N)
        aff = pr.initial_affected(g, g_new, touched)
        hyb = hybrid_pagerank(g_new, packed, ranks, aff, closed_form=True,
                              prune=True, expand=True, use_kernel=False)
        xla = update_pagerank(g, g_new, upd, ranks_xla, "frontier_prune")
        l1 = float(jnp.sum(jnp.abs(hyb.ranks - xla.ranks)))
        assert l1 <= 1e-6, (step, l1)
        g, ranks, ranks_xla = g_new, hyb.ranks, xla.ranks


# ---------------------------------------------------------------------------
# one compiled update + one compiled kernel loop for a 100-batch stream
# ---------------------------------------------------------------------------

def test_hundred_batch_stream_compiles_once():
    rng = np.random.default_rng(7)
    init = np.unique(rng.integers(0, N, size=(100, 2)), axis=0)
    init = init[init[:, 0] != init[:, 1]]
    g = from_coo(init[:, 0], init[:, 1], N, edge_capacity=len(init) + 256)
    packed = pack_graph(g, be=32, vb=16, spill_lanes_per_window=64)
    ranks = pr.static_pagerank(g).ranks.astype(jnp.float32)
    aff0 = jnp.zeros((N,), bool).at[0].set(True)

    def one_batch(seed):
        nonlocal g, packed, ranks
        dels, ins = _random_update(np.random.default_rng(seed),
                                   np.asarray(sorted(_graph_edge_set(g)),
                                              np.int32).reshape(-1, 2),
                                   n_del=2, n_ins=3)
        upd = make_batch_update(dels, ins, 8, 8)
        g = apply_batch(g, upd)
        packed = apply_batch_packed(packed, upd)
        touched = touched_vertices_mask(upd, N)
        res = kernel_pagerank_loop(g, packed, ranks, aff0 | touched,
                                   use_kernel=False)
        ranks = res.ranks

    one_batch(0)                                     # batch 1 compiles
    upd_traces = dict(UPD_TRACES)
    loop_traces = dict(LOOP_TRACES)
    for i in range(1, 100):                          # batches 2..100 reuse
        one_batch(i)
    assert dict(UPD_TRACES) == upd_traces, "apply_batch_packed retraced"
    assert dict(LOOP_TRACES) == loop_traces, "kernel loop retraced"


# ---------------------------------------------------------------------------
# capacity error paths
# ---------------------------------------------------------------------------

def test_pack_blocks_capacity_error_message():
    edges = np.asarray([[0, 1], [2, 1], [3, 1], [4, 1]], np.int32)
    with pytest.raises(ValueError, match="entries exceed capacity"):
        pack_blocks(edges[:, 0], edges[:, 1], np.ones(4, bool), 8,
                    be=2, vb=8, num_entries=1)


def test_spill_exhaustion_checked_error():
    g = from_coo(np.array([0]), np.array([1]), 64, edge_capacity=64)
    packed = pack_graph(g, be=8, vb=64, spill_lanes_per_window=0)
    ins = np.asarray([[i, 1] for i in range(2, 14)], np.int32)
    upd = make_batch_update(np.zeros((0, 2), np.int32), ins, 8, 16)
    with pytest.raises(ValueError, match="exceed spill capacity"):
        apply_batch_packed(packed, upd)
    # check=False keeps going (drops the overflow) for out-of-band audit
    out = apply_batch_packed(packed, upd, check=False)
    assert len(packed_edge_set(out)) == 8   # 1 live + 7 free lanes claimed


# ---------------------------------------------------------------------------
# engine="kernel" API + precision ladder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["frontier", "frontier_prune"])
def test_update_pagerank_kernel_engine_matches_xla(method):
    edges, n = rmat_edges(8, 8, seed=3)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) * 2)
    r0 = pr.static_pagerank(g).ranks
    from repro.graph.generators import random_batch_update
    dele, ins = random_batch_update(edges, n, 16, seed=4)
    upd = make_batch_update(dele, ins, 32, 32)
    g2 = apply_batch(g, upd)
    xla = update_pagerank(g, g2, upd, r0, method)
    ker = update_pagerank(g, g2, upd, r0, method, engine="kernel",
                          use_kernel=False)
    linf = float(jnp.max(jnp.abs(xla.ranks - ker.ranks)))
    assert linf <= 1e-6
    assert ker.ranks.dtype == jnp.float64
    assert int(ker.edges_processed) > 0
    assert int(ker.vertices_processed) > 0


def test_kernel_engine_mesh_needs_model_axis():
    # engine="kernel" + mesh is the sharded path (PR 5); it shards over
    # the mesh's model axis and must reject a mesh that lacks one
    import jax
    from jax.sharding import Mesh
    edges, n = erdos_renyi_edges(32, 64, seed=0)
    g = from_coo(edges[:, 0], edges[:, 1], n)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="no 'model' axis"):
        update_pagerank(g, g, None, None, "static", mesh=mesh,
                        engine="kernel")


def test_hybrid_no_polish_is_f32_precision():
    edges, n = erdos_renyi_edges(64, 400, seed=1)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 32)
    packed = pack_graph(g, be=64, vb=32)
    r0 = jnp.full((n,), 1.0 / n, jnp.float64)
    res = hybrid_pagerank(g, packed, r0, jnp.ones((n,), bool),
                          expand=False, polish=False, use_kernel=False)
    assert res.ranks.dtype == jnp.float64   # result contract holds
    ref = pr.static_pagerank(g)
    assert float(jnp.max(jnp.abs(res.ranks - ref.ranks))) < 1e-5  # f32-level


# ---------------------------------------------------------------------------
# work counters: gated runs skip work, full runs count everything
# ---------------------------------------------------------------------------

def test_kernel_loop_work_counters_window_granular():
    edges, n = rmat_edges(8, 8, seed=9)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 16)
    packed = pack_graph(g, be=128, vb=64)
    E = int(g.num_valid_edges())
    r0 = jnp.full((n,), 1.0 / n, jnp.float32)
    full = kernel_pagerank_loop(g, packed, r0, jnp.ones((n,), bool),
                                expand=False, use_kernel=False)
    assert int(full.edges_processed) == E * int(full.iterations)
    assert int(full.vertices_processed) == \
        packed.num_windows * packed.vb * int(full.iterations)
    # localized frontier: strictly less work than full sweeps
    warm = pr.static_pagerank(g).ranks
    aff = jnp.zeros((n,), bool).at[0].set(True)
    gated = kernel_pagerank_loop(g, packed, warm, aff, use_kernel=False)
    assert int(gated.edges_processed) < E * max(1, int(gated.iterations))


# ---------------------------------------------------------------------------
# ServeEngine integration: kernel engine serves the same ranks
# ---------------------------------------------------------------------------

def _serve(engine_name, feed, kernel_opts=None):
    edges, n = erdos_renyi_edges(N, 300, seed=2)
    graph = from_coo(edges[:, 0], edges[:, 1], n,
                     edge_capacity=len(edges) + 256)
    ingest = IngestQueue(flush_size=16, flush_interval=0.0)
    store = RankStore()
    metrics = ServeMetrics()
    eng = ServeEngine(graph, ingest, store, metrics=metrics,
                      method="frontier_prune", engine=engine_name,
                      kernel_opts=kernel_opts,
                      static_fallback_frac=1.0)
    eng.bootstrap()
    for u, v, kind in feed:
        if kind == "i":
            ingest.submit_insert(u, v)
        else:
            ingest.submit_delete(u, v)
        eng.step()
    eng.drain()
    return store.snapshot(), metrics


def _feed(seed, k=120):
    rng = np.random.default_rng(seed)
    feed = []
    for _ in range(k):
        u, v = rng.integers(0, N, size=2)
        if u == v:
            continue
        feed.append((int(u), int(v), "i" if rng.random() < 0.8 else "d"))
    return feed


def test_serve_engine_kernel_matches_xla():
    feed = _feed(11)
    snap_x, _ = _serve("xla", feed)
    snap_k, m = _serve("kernel", feed,
                       kernel_opts=dict(use_kernel=False, be=32, vb=16,
                                        spill_lanes_per_window=64))
    assert snap_k.generation == snap_x.generation
    linf = float(jnp.max(jnp.abs(snap_k.ranks - snap_x.ranks)))
    assert linf <= 1e-6, linf
    assert m.packed_rebuilds == 0


def test_serve_engine_kernel_rebuild_fallback():
    # little spill headroom + skewed growth (inserts pile into the last
    # window while deletes spread elsewhere): windows overflow, the
    # engine repacks at the pinned shapes — degrading the spill
    # guarantee if the regrown windows no longer fit it — and keeps
    # serving correct ranks with zero recompilation
    rng = np.random.default_rng(13)
    feed = []
    for _ in range(160):
        if rng.random() < 0.75:
            u, v = int(rng.integers(0, N)), int(rng.integers(32, N))
        else:
            u, v = int(rng.integers(0, N)), int(rng.integers(0, 32))
        if u != v:
            feed.append((u, v, "i" if rng.random() < 0.85 else "d"))
    snap_x, _ = _serve("xla", feed)
    from repro.core.kernel_engine import TRACE_COUNTS as LOOP_T
    from repro.kernels.pagerank_spmv.update import TRACE_COUNTS as UPD_T
    before = (dict(UPD_T), dict(LOOP_T))
    snap_k, m = _serve("kernel", feed,
                       kernel_opts=dict(use_kernel=False, be=8, vb=16,
                                        spill_lanes_per_window=8))
    after = (dict(UPD_T), dict(LOOP_T))
    assert m.packed_rebuilds >= 1
    linf = float(jnp.max(jnp.abs(snap_k.ranks - snap_x.ranks)))
    assert linf <= 1e-6, linf
    # pinned shapes/statics: at most the one initial trace per function,
    # rebuilds must not retrace
    for counts_b, counts_a in zip(before, after):
        for k, v in counts_a.items():
            assert v - counts_b.get(k, 0) <= 1, (k, counts_b, counts_a)
