"""repro.ppr: walk-index structure, estimator accuracy vs the exact
oracle, repair equivalence + resample-count invariant, deterministic
(process-independent) seeding, serve integration, query routing."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.extensions import personalized_pagerank
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.shard import ShardCapacityError
from repro.ppr import (IndexConfig, ShardedWalkIndex, build_sharded_walk_index,
                       build_walk_index, diagnostics, effective_walks,
                       error_bound, ppr_estimate, ppr_top_k, precision_at_k,
                       repair_walk_index, repair_walk_index_sharded,
                       shard_walk_index, stale_walks, truncation_bias,
                       unshard_walk_index, walks_for_error)
from repro.serve import (IngestQueue, QueryClient, RankStore, ServeEngine,
                         ServeMetrics)


@pytest.fixture(scope="module")
def small():
    edges, n = rmat_edges(8, 8, seed=1)               # 256 vertices
    g = from_coo(edges[:, 0], edges[:, 1], n,
                 edge_capacity=len(edges) + 512)
    return g, edges, n


@pytest.fixture(scope="module")
def index(small):
    g, _, _ = small
    return build_walk_index(g, IndexConfig(num_walks=64, max_len=16,
                                           seed=3))


# ---------------------------------------------------------------------------
# structure: layout, hop validity, determinism
# ---------------------------------------------------------------------------

def test_walk_layout(small, index):
    g, _, n = small
    assert index.steps.shape == (n, 64, 16)
    assert index.steps.dtype == jnp.int32
    # slot 0 is the source, always occupied
    assert bool(jnp.all(index.steps[:, :, 0] ==
                        jnp.arange(n, dtype=jnp.int32)[:, None]))
    # sentinel discipline: -1 once terminated, never revived
    m = np.asarray(index.mask())
    assert not np.any(~m[:, :, :-1] & m[:, :, 1:])
    assert int(index.steps.min()) >= -1
    assert int(index.steps.max()) < n


def test_hops_follow_edges_or_self_loop(small, index):
    _, edges, n = small
    live = set(map(tuple, edges.tolist()))
    s = np.asarray(index.steps)
    rng = np.random.default_rng(0)
    for v in rng.integers(0, n, 48):
        for r in rng.integers(0, 64, 4):
            w = s[v, r]
            for t in range(1, 16):
                if w[t] < 0:
                    break
                a, b = int(w[t - 1]), int(w[t])
                assert a == b or (a, b) in live       # self-loop or edge


def test_build_deterministic_same_key(small, index):
    g, _, _ = small
    again = build_walk_index(g, IndexConfig(num_walks=64, max_len=16,
                                            seed=3))
    assert bool(jnp.all(again.steps == index.steps))
    other = build_walk_index(g, IndexConfig(num_walks=64, max_len=16,
                                            seed=4))
    assert not bool(jnp.all(other.steps == index.steps))


def test_seeding_is_process_independent(tmp_path):
    """Regression (extends the PR 1 crc32-seeding fix): the walk index
    must be a pure function of (graph, config seed) so checkpointed
    serving restarts rebuild it bit-identically — no builtin hash() or
    other process-randomized state anywhere in the sampling path."""
    prog = (
        "import zlib, numpy as np, repro\n"
        "from repro.graph.generators import rmat_edges\n"
        "from repro.graph.structure import from_coo\n"
        "from repro.ppr import IndexConfig, build_walk_index\n"
        "e, n = rmat_edges(6, 4, seed=2)\n"
        "g = from_coo(e[:, 0], e[:, 1], n, edge_capacity=len(e) + 64)\n"
        "i = build_walk_index(g, IndexConfig(num_walks=8, max_len=8,"
        " seed=5))\n"
        "print(zlib.crc32(np.asarray(i.steps).tobytes()))\n"
    )
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    digests = []
    for hash_seed in ("0", "424242"):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(repo_root, "src"),
                   PYTHONHASHSEED=hash_seed, JAX_PLATFORMS="cpu")
        r = subprocess.run([sys.executable, "-c", prog],
                           capture_output=True, text=True, env=env,
                           cwd=str(tmp_path))
        assert r.returncode == 0, r.stderr
        digests.append(r.stdout.strip().splitlines()[-1])
    assert digests[0] == digests[1]


# ---------------------------------------------------------------------------
# estimator accuracy vs the power-iteration oracle
# ---------------------------------------------------------------------------

def test_direct_estimator_converges_to_oracle(small):
    """The raw (un-unrolled) visit-count estimator is unbiased: L1 error
    vs the exact solve shrinks ~1/sqrt(R)."""
    g, _, n = small
    sm = jnp.zeros((n,), bool).at[5].set(True)
    oracle = np.asarray(personalized_pagerank(g, sm).ranks)
    l1 = []
    for R in (64, 1024):
        idx = build_walk_index(g, IndexConfig(num_walks=R, max_len=24,
                                              seed=3))
        est = np.asarray(ppr_estimate(idx, [5], unroll=False))
        l1.append(np.abs(est - oracle).sum())
    assert l1[1] < 0.5 * l1[0]                        # 16x walks, >=2x better


@pytest.mark.slow
def test_topk_precision_vs_oracle_paper_scale(small):
    """Index top-10 matches the exact DF-P oracle at precision@10 >= 0.9
    (tie-tolerant) at paper-scale R on an RMAT graph, for both
    single-seed and seed-set queries."""
    g, _, n = small
    idx = build_walk_index(g, IndexConfig(num_walks=256, max_len=20,
                                          seed=7))
    deg = np.asarray(idx.csr.deg)
    rng = np.random.default_rng(1)
    seeds = rng.choice(np.flatnonzero(deg >= 2), 8, replace=False)
    ps = []
    for s in seeds:
        ap, _ = ppr_top_k(idx, [int(s)], 10)
        sm = jnp.zeros((n,), bool).at[int(s)].set(True)
        oracle = personalized_pagerank(g, sm).ranks
        ps.append(precision_at_k(np.asarray(ap), np.asarray(oracle), 10))
    assert np.mean(ps) >= 0.9, ps
    # seed-set query
    ss = [int(v) for v in seeds[:4]]
    ap, _ = ppr_top_k(idx, ss, 10)
    sm = jnp.zeros((n,), bool).at[jnp.asarray(ss)].set(True)
    oracle = personalized_pagerank(g, sm).ranks
    assert precision_at_k(np.asarray(ap), np.asarray(oracle), 10) >= 0.9


def test_estimate_is_distribution(index):
    est = np.asarray(ppr_estimate(index, [3, 9]))
    assert est.min() >= 0
    assert abs(est.sum() - 1.0) < 1e-9                # normalize=True


# ---------------------------------------------------------------------------
# repair: bitwise equivalence + resample-count invariant
# ---------------------------------------------------------------------------

def _batch(small, seed, n_del=6, n_ins=6):
    g, edges, n = small
    rng = np.random.default_rng(seed)
    dele = edges[rng.choice(len(edges), n_del, replace=False)]
    ins = rng.integers(0, n, size=(n_ins, 2)).astype(np.int32)
    ins = ins[ins[:, 0] != ins[:, 1]]
    return make_batch_update(dele, ins, max(8, n_del), max(8, n_ins))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_repair_matches_fresh_rebuild_bitwise(small, index, seed):
    """repair(index, Δ) == build(apply_batch(G, Δ)) bit-for-bit: same
    PRNG stream => untouched walks are kept verbatim AND resampled
    suffixes reproduce exactly what a fresh build would draw."""
    g, _, n = small
    upd = _batch(small, seed)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    repaired, resampled = repair_walk_index(index, g2, touched)
    fresh = build_walk_index(g2, IndexConfig(num_walks=64, max_len=16,
                                             seed=3))
    assert bool(jnp.all(repaired.steps == fresh.steps))
    assert bool(jnp.all(repaired.csr.indptr == fresh.csr.indptr))
    # resample-count invariant: exactly the walks intersecting touched
    stale, _ = stale_walks(index.steps, touched)
    assert resampled == int(jnp.sum(stale)) > 0


def test_repair_untouched_walks_kept_verbatim(small, index):
    g, _, n = small
    upd = _batch(small, 5)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    repaired, _ = repair_walk_index(index, g2, touched)
    stale, _ = stale_walks(index.steps, touched)
    keep = ~np.asarray(stale)
    assert np.array_equal(np.asarray(repaired.steps)[keep],
                          np.asarray(index.steps)[keep])


def test_repair_empty_batch_is_noop(small, index):
    g, _, n = small
    touched = jnp.zeros((n,), bool)
    repaired, resampled = repair_walk_index(index, g, touched)
    assert resampled == 0
    assert repaired.steps is index.steps


def test_repair_chain_over_stream(small):
    """Repair composes: N successive batches == one fresh build on the
    final graph (the serve-loop invariant)."""
    g, _, n = small
    cfg = IndexConfig(num_walks=32, max_len=12, seed=11)
    idx = build_walk_index(g, cfg)
    cur = g
    for seed in range(4):
        upd = _batch(small, 100 + seed, n_del=4, n_ins=8)
        nxt = apply_batch(cur, upd)
        idx, _ = repair_walk_index(idx, nxt,
                                   touched_vertices_mask(upd, n))
        cur = nxt
    fresh = build_walk_index(cur, cfg)
    assert bool(jnp.all(idx.steps == fresh.steps))


# ---------------------------------------------------------------------------
# error accounting
# ---------------------------------------------------------------------------

def test_error_accounting_roundtrip():
    R = walks_for_error(0.05, 0.1, 0.85, 16)
    assert R >= 1
    eps = error_bound(R, 0.1, 0.85, 16)
    assert eps <= 0.05 * 1.01                         # inverse within slack
    # more walks -> tighter bound; longer walks -> looser visit cap
    assert error_bound(4 * R, 0.1, 0.85, 16) < eps
    assert walks_for_error(0.025, 0.1, 0.85, 16) > R
    assert 0 < truncation_bias(0.85, 16) < 0.1


def test_diagnostics_shape(index):
    d = diagnostics(index)
    assert d["num_walks"] == 64 and d["max_len"] == 16
    assert 1.0 <= d["mean_length"] <= 16.0
    assert 0.0 <= d["truncated_frac"] <= 1.0
    assert d["nbytes"] == index.steps.size * 4


def test_effective_walks_routing_signal(small, index):
    _, _, n = small
    deg = np.asarray(index.csr.deg)
    v_hi = int(np.argmax(deg))
    assert effective_walks(index, [v_hi]) == deg[v_hi] * 64
    assert effective_walks(index, [v_hi, v_hi]) == deg[v_hi] * 64  # dedup


# ---------------------------------------------------------------------------
# serve integration: engine maintenance + query routing + memoization
# ---------------------------------------------------------------------------

def _service(g, **kw):
    metrics = ServeMetrics()
    ingest = IngestQueue(flush_size=16, flush_interval=0.0)
    store = RankStore()
    engine = ServeEngine(g, ingest, store, metrics=metrics, **kw)
    return ingest, store, engine, metrics


def test_engine_maintains_index_and_snapshot_carries_it(small):
    g, _, n = small
    cfg = IndexConfig(num_walks=16, max_len=12, seed=2)
    ingest, store, engine, metrics = _service(g, ppr_index=cfg)
    engine.bootstrap()
    assert store.snapshot().ppr_index is not None
    rng = np.random.default_rng(4)
    for _ in range(48):
        u, v = rng.integers(0, n, 2)
        if u != v:
            ingest.submit_insert(int(u), int(v))
        engine.step()
    engine.drain()
    snap = store.snapshot()
    fresh = build_walk_index(snap.graph, cfg)
    assert bool(jnp.all(snap.ppr_index.steps == fresh.steps))
    assert metrics.as_dict()["walks_resampled"] > 0


def test_engine_without_index_publishes_none(small):
    g, _, _ = small
    _, store, engine, _ = _service(g)
    engine.bootstrap()
    assert store.snapshot().ppr_index is None


def test_query_mode_routing(small):
    g, _, n = small
    cfg = IndexConfig(num_walks=64, max_len=16, seed=2)
    ingest, store, engine, metrics = _service(g, ppr_index=cfg)
    engine.bootstrap()
    client = QueryClient(store, ingest, metrics, min_effective_walks=64)
    deg = np.asarray(store.snapshot().ppr_index.csr.deg)
    warm = int(np.argmax(deg))
    r = client.personalized_top_k([warm], 5, mode="index")
    assert warm in r.vertices.tolist()                # seed holds mass
    r2 = client.personalized_top_k([warm], 5, mode="exact")
    assert warm in r2.vertices.tolist()
    # auto: warm seed -> index answer == forced-index answer
    ra = client.personalized_top_k([warm], 5, mode="auto")
    assert ra.vertices.tolist() == r.vertices.tolist()
    # auto: cold seed (deg 0 -> 0 effective walks) -> exact path
    cold = int(np.flatnonzero(deg == 0)[0])
    rc = client.personalized_top_k([cold], 5, mode="auto")
    assert rc.vertices[0] == cold
    with pytest.raises(ValueError):
        client.personalized_top_k([warm], 5, mode="nope")
    with pytest.raises(ValueError):                   # solver kw on index
        client.personalized_top_k([warm], 5, mode="index", max_iter=3)
    # auto + solver options routes to exact for ANY seed (never raises
    # data-dependently on the seed's degree)
    rw = client.personalized_top_k([warm], 5, mode="auto", max_iter=50)
    assert warm in rw.vertices.tolist()
    # seed validation is mode-independent
    for bad in ([], [n], [-1]):
        with pytest.raises(ValueError):
            client.personalized_top_k(bad, 5, mode="auto")


def test_query_mode_index_requires_index(small):
    g, _, _ = small
    _, store, engine, _ = _service(g)
    engine.bootstrap()
    client = QueryClient(store)
    with pytest.raises(ValueError):
        client.personalized_top_k([1], 5, mode="index")


# ---------------------------------------------------------------------------
# sharded index (ppr/shard.py): bitwise parity with the single-device path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 3, 4])
def test_sharded_build_matches_single_device(small, index, num_shards):
    """Per-shard build with global walk ids == the same slice of a full
    build — including the uneven split (S=3 pads the last shard)."""
    g, _, n = small
    cfg = IndexConfig(num_walks=64, max_len=16, seed=3)
    sharded = build_sharded_walk_index(g, cfg, num_shards=num_shards)
    want = shard_walk_index(index, num_shards)
    assert sharded.steps.shape == want.steps.shape
    assert bool(jnp.all(sharded.steps == want.steps))
    # unshard round-trips, dropping the padding rows
    assert bool(jnp.all(unshard_walk_index(sharded).steps == index.steps))


@pytest.mark.parametrize("num_shards", [2, 3, 4])
def test_sharded_repair_bitwise_vs_single_device(small, index, num_shards):
    """Sharded repair == unshard → single-device repair → reshard, walk
    for walk — the tentpole's acceptance invariant."""
    g, _, n = small
    upd = _batch(small, 7)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    want, want_n = repair_walk_index(index, g2, touched)
    got, got_n = repair_walk_index_sharded(
        shard_walk_index(index, num_shards), g2, touched)
    assert got_n == want_n > 0
    assert bool(jnp.all(unshard_walk_index(got).steps == want.steps))
    assert bool(jnp.all(got.csr.indptr == want.csr.indptr))


def test_sharded_repair_chain_over_stream(small):
    """The serve-loop invariant survives sharding: N sharded repairs ==
    one fresh single-device build on the final graph."""
    g, _, n = small
    cfg = IndexConfig(num_walks=32, max_len=12, seed=11)
    idx = build_sharded_walk_index(g, cfg, num_shards=4)
    cur = g
    for seed in range(4):
        upd = _batch(small, 100 + seed, n_del=4, n_ins=8)
        nxt = apply_batch(cur, upd)
        idx, _ = repair_walk_index_sharded(idx, nxt,
                                           touched_vertices_mask(upd, n))
        cur = nxt
    fresh = build_walk_index(cur, cfg)
    assert bool(jnp.all(unshard_walk_index(idx).steps == fresh.steps))


def test_sharded_repair_capacity_budget(small, index):
    """Overflowing an explicit per-shard budget raises a checked error
    naming the shards; check=False degrades (drops) instead — repaired
    rows are exact, dropped rows are the old rows, nothing corrupt."""
    from repro.ppr.shard import shard_stale_counts
    g, _, n = small
    upd = _batch(small, 3)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    sharded = shard_walk_index(index, 4)
    counts = shard_stale_counts(sharded, touched)
    assert counts.sum() > 0
    tight = max(1, int(counts.max()) // 2)
    with pytest.raises(ShardCapacityError) as ei:
        repair_walk_index_sharded(sharded, g2, touched, capacity=tight)
    assert ei.value.shards
    assert all(counts[s] > tight for s in ei.value.shards)
    got, _ = repair_walk_index_sharded(sharded, g2, touched,
                                       capacity=tight, check=False,
                                       min_capacity=1)
    want, _ = repair_walk_index(index, g2, touched)
    gu = np.asarray(unshard_walk_index(got).steps)
    row_old = (gu == np.asarray(index.steps)).all(-1)
    row_new = (gu == np.asarray(want.steps)).all(-1)
    assert np.all(row_old | row_new)
    assert not np.all(row_new)        # something was actually dropped
    assert not np.all(row_old)        # ... and something repaired


def test_sharded_query_matches_single_device(small, index):
    """Per-shard segment_sum + one (p)sum matches the single-device
    estimate to f64 rounding; top-k is identical."""
    sharded = shard_walk_index(index, 4)
    for unroll in (True, False):
        est_s = np.asarray(ppr_estimate(sharded, [7, 12], unroll=unroll))
        est_1 = np.asarray(ppr_estimate(index, [7, 12], unroll=unroll))
        np.testing.assert_allclose(est_s, est_1, rtol=0, atol=1e-12)
    vs, _ = ppr_top_k(sharded, [7], 10)
    v1, _ = ppr_top_k(index, [7], 10)
    assert vs.tolist() == v1.tolist()


def test_sharded_program_cache_bounded(small):
    """A temporal stream reuses a handful of compiled repair programs
    (pow2 capacities), mirroring the SpMV shard layer's contract."""
    import repro.ppr.shard as shard_mod
    g, _, n = small
    cfg = IndexConfig(num_walks=32, max_len=12, seed=11)
    idx = build_sharded_walk_index(g, cfg, num_shards=4)
    before = dict(shard_mod.TRACE_COUNTS)
    cur = g
    for seed in range(5):
        upd = _batch(small, 300 + seed, n_del=3, n_ins=5)
        cur = apply_batch(cur, upd)
        idx, _ = repair_walk_index_sharded(idx, cur,
                                           touched_vertices_mask(upd, n))
    delta = {k: shard_mod.TRACE_COUNTS[k] - before.get(k, 0)
             for k in shard_mod.TRACE_COUNTS}
    assert delta.get("repairs", 0) == 5
    # host path: no shard_map programs get built at all
    assert delta.get("build_repair", 0) == 0


# ---------------------------------------------------------------------------
# Pallas walk-repair kernel (kernels/walk_repair): bitwise vs the jnp path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 2])
def test_kernel_repair_bitwise_matches_jnp(small, index, seed):
    g, _, n = small
    upd = _batch(small, seed)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    want, want_n = repair_walk_index(index, g2, touched)
    got, got_n = repair_walk_index(index, g2, touched, use_kernel=True,
                                   interpret=True)
    assert got_n == want_n > 0
    assert bool(jnp.all(got.steps == want.steps))


def test_kernel_repair_bucket_tail(small, index):
    """A stale count far from the 128-lane bucket multiple exercises the
    gated-DMA tail: excess grid steps re-run the last active bucket
    idempotently and padding lanes stay inert."""
    g, _, n = small
    # touch exactly one vertex -> its own R=64 walks + visitors: a
    # count nowhere near a bucket boundary
    touched = jnp.zeros((n,), bool).at[7].set(True)
    want, want_n = repair_walk_index(index, g, touched)
    got, got_n = repair_walk_index(index, g, touched, use_kernel=True,
                                   interpret=True)
    assert got_n == want_n > 0
    assert bool(jnp.all(got.steps == want.steps))


# ---------------------------------------------------------------------------
# serve integration: mesh engine + the single-host-sync contract
# ---------------------------------------------------------------------------

def _one_shard_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]), ("model",))


def test_engine_mesh_shards_index_and_repairs(small):
    """An engine given a mesh builds the index sharded at bootstrap and
    keeps it bitwise equal to a fresh single-device build while
    streaming — the in-process 1-way mesh; the 4-way run is the slow
    subprocess test + the CI mesh smoke lane."""
    g, _, n = small
    cfg = IndexConfig(num_walks=16, max_len=12, seed=2)
    ingest, store, engine, metrics = _service(g, ppr_index=cfg,
                                              mesh=_one_shard_mesh())
    engine.bootstrap()
    assert isinstance(store.snapshot().ppr_index, ShardedWalkIndex)
    rng = np.random.default_rng(6)
    for _ in range(32):
        u, v = rng.integers(0, n, 2)
        if u != v:
            ingest.submit_insert(int(u), int(v))
        engine.step()
    engine.drain()
    snap = store.snapshot()
    fresh = build_walk_index(snap.graph, cfg)
    assert bool(jnp.all(unshard_walk_index(snap.ppr_index).steps ==
                        fresh.steps))
    assert metrics.as_dict()["walks_resampled"] > 0


def test_step_issues_single_host_sync(small):
    """The PPR repair wait is folded into the batch's one
    block_until_ready: an index-maintaining engine issues exactly as
    many host syncs per step as one without an index (the serve/engine
    double-sync bug, fixed)."""
    import repro.serve.engine as eng_mod
    g, _, n = small
    for kw in (dict(),
               dict(ppr_index=IndexConfig(num_walks=16, max_len=12,
                                          seed=2))):
        ingest, store, engine, _ = _service(g, **kw)
        engine.bootstrap()
        rng = np.random.default_rng(1)
        for _ in range(3):
            u, v = rng.integers(0, n, 2)
            if u == v:
                continue
            ingest.submit_insert(int(u), int(v))
            before = eng_mod.SYNC_COUNTS["block_until_ready"]
            assert engine.step(force=True)
            assert eng_mod.SYNC_COUNTS["block_until_ready"] == before + 1


@pytest.mark.slow
def test_sharded_mesh_multidevice_subprocess(tmp_path):
    """4-way mesh on 8 forced host devices: mesh build/repair parity and
    bounded shard_map compiles — the real-SPMD twin of the host-path
    tests above."""
    prog = (
        "import numpy as np, jax, jax.numpy as jnp, repro\n"
        "from jax.sharding import Mesh, NamedSharding, PartitionSpec\n"
        "import repro.ppr.shard as sm\n"
        "from repro.graph.dynamic import apply_batch, make_batch_update, \\\n"
        "    touched_vertices_mask\n"
        "from repro.graph.generators import rmat_edges\n"
        "from repro.graph.structure import from_coo\n"
        "from repro.ppr import (IndexConfig, build_sharded_walk_index,\n"
        "    build_walk_index, ppr_top_k, repair_walk_index,\n"
        "    repair_walk_index_sharded, unshard_walk_index)\n"
        "assert len(jax.devices()) == 8, jax.devices()\n"
        "mesh = Mesh(np.asarray(jax.devices()[:4]), ('model',))\n"
        "edges, n = rmat_edges(8, 8, seed=1)\n"
        "g = from_coo(edges[:, 0], edges[:, 1], n,\n"
        "             edge_capacity=len(edges) + 512)\n"
        "cfg = IndexConfig(num_walks=32, max_len=12, seed=3)\n"
        "idx = build_sharded_walk_index(g, cfg, mesh=mesh)\n"
        "one = build_walk_index(g, cfg)\n"
        "assert bool(jnp.all(unshard_walk_index(idx).steps == one.steps))\n"
        "spec = idx.steps.sharding.spec\n"
        "assert spec == PartitionSpec('model'), spec\n"
        "rng = np.random.default_rng(0)\n"
        "cur = g\n"
        "for s in range(6):\n"
        "    dele = edges[rng.choice(len(edges), 4, replace=False)]\n"
        "    ins = rng.integers(0, n, size=(8, 2)).astype(np.int32)\n"
        "    ins = ins[ins[:, 0] != ins[:, 1]]\n"
        "    upd = make_batch_update(dele, ins, 8, 8)\n"
        "    nxt = apply_batch(cur, upd)\n"
        "    t = touched_vertices_mask(upd, n)\n"
        "    idx, k1 = repair_walk_index_sharded(idx, nxt, t)\n"
        "    one, k2 = repair_walk_index(one, nxt, t)\n"
        "    assert k1 == k2, (k1, k2)\n"
        "    cur = nxt\n"
        "assert bool(jnp.all(unshard_walk_index(idx).steps == one.steps))\n"
        "v_s, _ = ppr_top_k(idx, [7], 10)\n"
        "v_1, _ = ppr_top_k(one, [7], 10)\n"
        "assert v_s.tolist() == v_1.tolist()\n"
        "assert sm.TRACE_COUNTS['build_build'] == 1\n"
        "assert sm.TRACE_COUNTS['build_stale'] == 1\n"
        "assert sm.TRACE_COUNTS['repairs'] == 6\n"
        "assert sm.TRACE_COUNTS['build_repair'] <= 3  # pow2 capacities\n"
        "print('MESH_PPR_OK')\n"
    )
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo_root, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd=str(tmp_path))
    assert r.returncode == 0, r.stderr
    assert "MESH_PPR_OK" in r.stdout


def test_exact_path_memoized_within_generation(small, monkeypatch):
    g, _, n = small
    ingest, store, engine, _ = _service(g)
    engine.bootstrap()
    client = QueryClient(store, ingest)
    import repro.serve.query as q
    calls = []
    orig = q.personalized_pagerank
    monkeypatch.setattr(q, "personalized_pagerank",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    r1 = client.personalized_top_k([3, 7], 5, mode="exact")
    r2 = client.personalized_top_k([7, 3], 5, mode="exact")  # same set
    assert calls == [1]                               # solved once
    assert r1.vertices.tolist() == r2.vertices.tolist()
    # distinct options / seed sets do solve
    client.personalized_top_k([3, 7], 5, mode="exact", max_iter=7)
    client.personalized_top_k([3], 5, mode="exact")
    assert len(calls) == 3
    # a new generation invalidates the memo key
    ingest.submit_insert(0, 9)
    engine.step(force=True)
    client.personalized_top_k([3, 7], 5, mode="exact")
    assert len(calls) == 4
