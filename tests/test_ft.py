"""Fault-tolerance tests: checkpoint atomicity + integrity digests,
restore, restart-replay, straggler rebalancing, replica membership,
elastic rescale of the kernel serving path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import update_pagerank
from repro.ft import checkpoint as ck
from repro.ft.straggler import (IterationBudget, active_edge_mask,
                                rebalance, stripe_skew)
from repro.graph.generators import rmat_edges
from repro.graph.partition import partition_graph
from repro.graph.structure import from_coo


def test_checkpoint_roundtrip(tmp_path):
    state = dict(a=jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                 b=[jnp.ones((2,), jnp.int32), jnp.zeros((), jnp.float64)])
    path = ck.save(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    assert ck.latest_step(str(tmp_path)) == 7
    out = ck.restore(str(tmp_path), 7, jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(state["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"][0]),
                                  np.asarray(state["b"][0]))


def test_checkpoint_gc_keeps_last(tmp_path):
    s = dict(x=jnp.zeros((2,)))
    for i in range(6):
        ck.save(str(tmp_path), i, s, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert ck.latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 0, dict(x=jnp.zeros((4,))))
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 0, dict(x=jnp.zeros((5,))))


def test_torn_write_is_not_a_checkpoint(tmp_path):
    ck.save(str(tmp_path), 3, dict(x=jnp.zeros((2,))))
    os.makedirs(tmp_path / "step_0000000009.tmp")   # simulated crash
    assert ck.latest_step(str(tmp_path)) == 3


def _corrupt_leaf(tmp_path, step, leaf=0):
    """Bit-flip one element in place: shape/dtype stay valid, crc32
    doesn't — the silent-corruption case digests exist to catch."""
    path = os.path.join(str(tmp_path), f"step_{step:010d}",
                        f"leaf_{leaf:05d}.npy")
    arr = np.load(path)
    arr.reshape(-1)[0] += 1
    np.save(path, arr)
    return path


def test_corrupt_leaf_raises_structured_error(tmp_path):
    ck.save(str(tmp_path), 4, dict(x=jnp.arange(8, dtype=jnp.float64)))
    _corrupt_leaf(tmp_path, 4)
    with pytest.raises(ck.CheckpointCorruptError) as e:
        ck.restore(str(tmp_path), 4, dict(x=jnp.zeros(8, jnp.float64)))
    assert e.value.step == 4
    assert "x" in e.value.leaf


def test_unreadable_manifest_is_corrupt_not_crash(tmp_path):
    ck.save(str(tmp_path), 1, dict(x=jnp.zeros(4)))
    with open(tmp_path / "step_0000000001" / "manifest.json", "w") as f:
        f.write("{not json")
    with pytest.raises(ck.CheckpointCorruptError):
        ck.restore(str(tmp_path), 1, dict(x=jnp.zeros(4)))


def test_restore_latest_valid_falls_back_past_corrupt_step(tmp_path):
    target = dict(x=jnp.zeros(8, jnp.float64))
    ck.save(str(tmp_path), 1, dict(x=jnp.full(8, 1.0)))
    ck.save(str(tmp_path), 2, dict(x=jnp.full(8, 2.0)))
    _corrupt_leaf(tmp_path, 2)                # newest step is torn
    step, state = ck.restore_latest_valid(str(tmp_path), target)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["x"]), np.full(8, 1.0))
    # every retained step corrupt -> the error propagates (a silent cold
    # start would hide the corruption)
    _corrupt_leaf(tmp_path, 1)
    with pytest.raises(ck.CheckpointCorruptError):
        ck.restore_latest_valid(str(tmp_path), target)


def test_restore_latest_valid_empty_dir(tmp_path):
    assert ck.restore_latest_valid(str(tmp_path / "nope"),
                                   dict(x=jnp.zeros(2))) == (None, None)


def test_manager_restore_latest_skips_corrupt(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=1, keep_last=3)
    for step in (1, 2, 3):
        mgr.maybe_save(step, dict(i=jnp.asarray(float(step))))
    _corrupt_leaf(tmp_path, 3)
    step, state = mgr.restore_latest(dict(i=jnp.zeros(())))
    assert step == 2 and float(state["i"]) == 2.0


def test_replica_roster_membership_and_liveness():
    from repro.ft.elastic import ReplicaRoster
    r = ReplicaRoster(heartbeat_timeout=1.0)
    r.join("a", now=0.0)
    r.beat("b", now=0.5)                      # implicit join via beat
    assert r.members() == ["a", "b"] and r.joins == 2
    assert r.alive(now=1.0) == ["a", "b"]
    assert r.alive(now=1.2) == ["b"]          # a's beat expired
    assert not r.is_alive("a", now=1.2)
    r.beat("a", now=1.3)
    assert r.is_alive("a", now=1.5)
    r.leave("a")
    assert r.members() == ["b"] and r.leaves == 1
    r.leave("ghost")                          # unknown leave is a no-op
    assert r.leaves == 1


def test_manager_restart(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=2)
    state = dict(r=jnp.arange(5, dtype=jnp.float64), i=jnp.asarray(0))
    for step in range(1, 5):
        state["i"] = jnp.asarray(step)
        mgr.maybe_save(step, state)
    step, restored = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 4 and int(restored["i"]) == 4


def test_straggler_rebalance_reduces_skew():
    edges, n = rmat_edges(9, 8, seed=13)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 8)
    # concentrated frontier = worst case for a static stripe
    affected = np.zeros(n, bool)
    affected[: n // 16] = True
    part_static = partition_graph(g, 4, 4)
    part_rebal = rebalance(g, affected, 4, 4)
    assert stripe_skew(part_rebal, affected) <= \
        stripe_skew(part_static, affected) + 1e-9


def test_iteration_budget_carries_frontier():
    b = IterationBudget(max_iter_per_batch=10)
    fresh = np.zeros(8, bool)
    fresh[0] = True
    assert b.seeds_for_batch(fresh)[0]
    leftover = np.zeros(8, bool)
    leftover[3] = True
    b.after_batch(converged=False, frontier=leftover)
    seeds = b.seeds_for_batch(fresh)
    assert seeds[0] and seeds[3]
    b.after_batch(converged=True, frontier=leftover)
    assert not b.seeds_for_batch(fresh)[3]


def test_stream_restart_equivalence(tmp_path):
    """Kill-and-restart produces the same ranks as an uninterrupted run."""
    from repro.data.snap import load_temporal
    from repro.graph.dynamic import apply_batch, make_batch_update
    from repro.graph.generators import TemporalStream

    ds = load_temporal("sx-mathoverflow")
    stream = TemporalStream(ds.edges, ds.num_vertices, 1e-3, 6)
    pre = stream.preload_edges()
    cap = len(pre) + stream.batch_size * stream.num_batches + 64
    g0 = from_coo(pre[:, 0], pre[:, 1], ds.num_vertices, edge_capacity=cap)
    r = update_pagerank(g0, g0, None, None, "static").ranks

    def run(start, g, ranks, upto):
        for i in range(start, upto):
            upd = make_batch_update(np.zeros((0, 2)), stream.batch(i), 8,
                                    max(8, stream.batch_size))
            g2 = apply_batch(g, upd)
            ranks = update_pagerank(g, g2, upd, ranks,
                                    "frontier_prune").ranks
            g = g2
        return g, ranks

    # uninterrupted
    _, ranks_full = run(0, g0, r, stream.num_batches)
    # interrupted at batch 3: save, "crash", restore, continue
    g_mid, ranks_mid = run(0, g0, r, 3)
    ck.save(str(tmp_path), 3, dict(ranks=ranks_mid))
    restored = ck.restore(str(tmp_path), 3, dict(
        ranks=jax.eval_shape(lambda: ranks_mid)))
    _, ranks_resumed = run(3, g_mid, restored["ranks"],
                           stream.num_batches)
    np.testing.assert_allclose(np.asarray(ranks_full),
                               np.asarray(ranks_resumed), atol=1e-12)


@pytest.mark.slow
def test_elastic_rescale_kernel_serving_path(tmp_path):
    """Checkpoint the kernel serving path on a 4-way mesh, restore onto
    1-way and 2-way via ``rescale_pagerank_state``: the resumed stream
    must land within L1 <= 1e-6 of the uninterrupted run, with zero
    extra retraces after the resumed engine's first batch.

    Subprocess: the device count must be forced before jax initialises
    (conftest keeps the main process at one device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    code = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        import repro
        from jax.sharding import Mesh
        from repro.ft import checkpoint as ck
        from repro.ft.elastic import rescale_pagerank_state
        from repro.graph.generators import rmat_edges
        from repro.graph.structure import from_coo
        from repro.kernels.pagerank_spmv.shard import TRACE_COUNTS
        from repro.serve import IngestQueue, RankStore, ServeEngine, \\
            ServeMetrics

        DIR = {str(tmp_path)!r}
        edges, n = rmat_edges(7, 8, seed=2)
        rng = np.random.default_rng(0)
        feed = [(int(u), int(v)) for u, v in rng.integers(0, n, (160, 2))
                if u != v]
        SPLIT = 80

        def fresh_graph():
            return from_coo(edges[:, 0], edges[:, 1], n,
                            edge_capacity=len(edges) + len(feed) + 64)

        def serve(mesh_devs, upto):
            mesh = Mesh(np.asarray(jax.devices()[:mesh_devs]), ("model",))
            ingest = IngestQueue(flush_size=16, flush_interval=0.0)
            eng = ServeEngine(fresh_graph(), ingest, RankStore(),
                              metrics=ServeMetrics(),
                              method="frontier_prune", engine="kernel",
                              mesh=mesh,
                              kernel_opts=dict(use_kernel=False, be=32,
                                               vb=16))
            eng.bootstrap()
            for u, v in feed[:upto]:
                ingest.submit_insert(u, v)
                eng.step()
            eng.drain()
            return eng

        # ---- uninterrupted 4-way reference over the whole feed --------
        ref = serve(4, len(feed))
        ranks_ref = np.asarray(ref.store.snapshot().ranks)

        # ---- 4-way run to SPLIT, checkpoint (ranks, batch_idx) --------
        half = serve(4, SPLIT)
        snap = half.store.snapshot()
        ck.save(DIR, SPLIT, dict(ranks=jnp.asarray(snap.ranks),
                                 batch_idx=jnp.asarray(np.int64(SPLIT))))

        # ---- restore onto 1-way and 2-way, resume the tail ------------
        for devs in (1, 2):
            mesh = Mesh(np.asarray(jax.devices()[:devs]), ("model",))
            idx, ranks_host, part = rescale_pagerank_state(
                DIR, fresh_graph(), mesh, dtype=np.float64)
            assert idx == SPLIT
            assert part is not None
            # rebuild the graph at the checkpoint frontier (the feed is
            # the log), then resume serving on the new mesh from the
            # restored ranks
            g = fresh_graph()
            ingest = IngestQueue(flush_size=16, flush_interval=0.0,
                                 start_seq=0)
            eng = ServeEngine(g, ingest, RankStore(),
                              metrics=ServeMetrics(),
                              method="frontier_prune", engine="kernel",
                              mesh=mesh,
                              kernel_opts=dict(use_kernel=False, be=32,
                                               vb=16))
            eng.bootstrap()
            for u, v in feed[:idx]:       # replay to the frontier
                ingest.submit_insert(u, v)
                eng.step()
            eng.drain()
            eng.bootstrap(ranks=jnp.asarray(ranks_host), last_seq=idx - 1)
            # first resumed batch may compile for the new mesh shape;
            # after it, the stream must add zero traces
            tail = feed[idx:]
            ingest2 = eng.ingest
            for u, v in tail[:16]:
                ingest2.submit_insert(u, v)
            eng.drain()
            before = dict(TRACE_COUNTS)
            for u, v in tail[16:]:
                ingest2.submit_insert(u, v)
                eng.step()
            eng.drain()
            after = dict(TRACE_COUNTS)
            retraces = {{k: after[k] - before.get(k, 0) for k in after
                         if after[k] != before.get(k, 0)}}
            assert not retraces, f"retraced after resume: {{retraces}}"
            ranks_out = np.asarray(eng.store.snapshot().ranks)
            l1 = float(np.abs(ranks_out - ranks_ref).sum())
            assert l1 <= 1e-6, (devs, l1)
            print(f"mesh {{devs}}-way: L1={{l1:.2e}} OK")
        print("RESCALE OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=540)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "RESCALE OK" in r.stdout
