"""Fault-tolerance tests: checkpoint atomicity, restore, restart-replay,
straggler rebalancing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import update_pagerank
from repro.ft import checkpoint as ck
from repro.ft.straggler import (IterationBudget, active_edge_mask,
                                rebalance, stripe_skew)
from repro.graph.generators import rmat_edges
from repro.graph.partition import partition_graph
from repro.graph.structure import from_coo


def test_checkpoint_roundtrip(tmp_path):
    state = dict(a=jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                 b=[jnp.ones((2,), jnp.int32), jnp.zeros((), jnp.float64)])
    path = ck.save(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    assert ck.latest_step(str(tmp_path)) == 7
    out = ck.restore(str(tmp_path), 7, jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(state["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"][0]),
                                  np.asarray(state["b"][0]))


def test_checkpoint_gc_keeps_last(tmp_path):
    s = dict(x=jnp.zeros((2,)))
    for i in range(6):
        ck.save(str(tmp_path), i, s, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert ck.latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 0, dict(x=jnp.zeros((4,))))
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 0, dict(x=jnp.zeros((5,))))


def test_torn_write_is_not_a_checkpoint(tmp_path):
    ck.save(str(tmp_path), 3, dict(x=jnp.zeros((2,))))
    os.makedirs(tmp_path / "step_0000000009.tmp")   # simulated crash
    assert ck.latest_step(str(tmp_path)) == 3


def test_manager_restart(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=2)
    state = dict(r=jnp.arange(5, dtype=jnp.float64), i=jnp.asarray(0))
    for step in range(1, 5):
        state["i"] = jnp.asarray(step)
        mgr.maybe_save(step, state)
    step, restored = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 4 and int(restored["i"]) == 4


def test_straggler_rebalance_reduces_skew():
    edges, n = rmat_edges(9, 8, seed=13)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 8)
    # concentrated frontier = worst case for a static stripe
    affected = np.zeros(n, bool)
    affected[: n // 16] = True
    part_static = partition_graph(g, 4, 4)
    part_rebal = rebalance(g, affected, 4, 4)
    assert stripe_skew(part_rebal, affected) <= \
        stripe_skew(part_static, affected) + 1e-9


def test_iteration_budget_carries_frontier():
    b = IterationBudget(max_iter_per_batch=10)
    fresh = np.zeros(8, bool)
    fresh[0] = True
    assert b.seeds_for_batch(fresh)[0]
    leftover = np.zeros(8, bool)
    leftover[3] = True
    b.after_batch(converged=False, frontier=leftover)
    seeds = b.seeds_for_batch(fresh)
    assert seeds[0] and seeds[3]
    b.after_batch(converged=True, frontier=leftover)
    assert not b.seeds_for_batch(fresh)[3]


def test_stream_restart_equivalence(tmp_path):
    """Kill-and-restart produces the same ranks as an uninterrupted run."""
    from repro.data.snap import load_temporal
    from repro.graph.dynamic import apply_batch, make_batch_update
    from repro.graph.generators import TemporalStream

    ds = load_temporal("sx-mathoverflow")
    stream = TemporalStream(ds.edges, ds.num_vertices, 1e-3, 6)
    pre = stream.preload_edges()
    cap = len(pre) + stream.batch_size * stream.num_batches + 64
    g0 = from_coo(pre[:, 0], pre[:, 1], ds.num_vertices, edge_capacity=cap)
    r = update_pagerank(g0, g0, None, None, "static").ranks

    def run(start, g, ranks, upto):
        for i in range(start, upto):
            upd = make_batch_update(np.zeros((0, 2)), stream.batch(i), 8,
                                    max(8, stream.batch_size))
            g2 = apply_batch(g, upd)
            ranks = update_pagerank(g, g2, upd, ranks,
                                    "frontier_prune").ranks
            g = g2
        return g, ranks

    # uninterrupted
    _, ranks_full = run(0, g0, r, stream.num_batches)
    # interrupted at batch 3: save, "crash", restore, continue
    g_mid, ranks_mid = run(0, g0, r, 3)
    ck.save(str(tmp_path), 3, dict(ranks=ranks_mid))
    restored = ck.restore(str(tmp_path), 3, dict(
        ranks=jax.eval_shape(lambda: ranks_mid)))
    _, ranks_resumed = run(3, g_mid, restored["ranks"],
                           stream.num_batches)
    np.testing.assert_allclose(np.asarray(ranks_full),
                               np.asarray(ranks_resumed), atol=1e-12)
