"""Correctness observability (DESIGN.md §12): invariant sentinels,
sampled shadow verification, flight-recorder capture → bit-for-bit
replay, SLO burn-rate arithmetic, and export hardening (JSONL rotation,
exporter lifecycle)."""
import json
import os
import threading
import time

import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.pagerank import static_pagerank
from repro.graph.generators import erdos_renyi_edges
from repro.graph.structure import from_coo
from repro.obs import (CorrectnessMonitor, JsonlSink, MetricsExporter,
                       MonitorConfig, ShadowVerifier, SloSet, SloTracker,
                       load_bundle, rank_digest, replay)
from repro.obs.sentinel import InvariantSentinel, SentinelConfig
from repro.serve import IngestQueue, RankStore, ServeEngine, ServeMetrics

N = 64


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def _graph(seed=0, m=400, cap_extra=512):
    edges, n = erdos_renyi_edges(N, m, seed=seed)
    return from_coo(edges[:, 0], edges[:, 1], n,
                    edge_capacity=len(edges) + cap_extra)


def _service(graph, monitor=None, flush_size=4, **engine_kw):
    metrics = ServeMetrics()
    ingest = IngestQueue(flush_size=flush_size, flush_interval=0.0)
    store = RankStore()
    engine = ServeEngine(graph, ingest, store, metrics=metrics,
                         method="frontier_prune", monitor=monitor,
                         **engine_kw)
    return ingest, store, engine, metrics


def _feed(ingest, engine, num_batches, flush_size=4, seed=0):
    """Submit random insert events and drain them batch by batch."""
    rng = np.random.default_rng(seed)
    for _ in range(num_batches * flush_size):
        u, v = rng.integers(0, N, size=2)
        while u == v:
            u, v = rng.integers(0, N, size=2)
        ingest.submit_insert(int(u), int(v))
    return engine.drain()


# ---------------------------------------------------------------------------
# rank digest
# ---------------------------------------------------------------------------

def test_rank_digest_is_bit_sensitive():
    g = _graph()
    r = np.asarray(static_pagerank(g).ranks)
    d0 = rank_digest(jnp.asarray(r))
    assert rank_digest(jnp.asarray(r.copy())) == d0     # value-determined
    bumped = r.copy()
    bumped[7] = np.nextafter(bumped[7], 1.0)            # single-ULP flip
    assert rank_digest(jnp.asarray(bumped)) != d0
    swapped = r.copy()
    swapped[[0, 1]] = swapped[[1, 0]]                   # position-weighted
    assert rank_digest(jnp.asarray(swapped)) != d0


# ---------------------------------------------------------------------------
# invariant sentinel
# ---------------------------------------------------------------------------

def _good_ranks():
    r = np.full(8, 1.0 / 8)
    return jnp.asarray(r)


def _observe(sent, ranks, delta=1e-12, iterations=5, affected=10,
             fallback=False, gen=1):
    return sent.observe(generation=gen, last_seq=gen, ranks=ranks,
                        delta=delta, iterations=iterations,
                        affected=affected, fallback=fallback)


def test_sentinel_clean_batch_no_incidents():
    sent = InvariantSentinel(clock=FakeClock())
    digest, incs = _observe(sent, _good_ranks())
    assert incs == []
    assert digest == rank_digest(_good_ranks())
    assert sent.gauges["sentinel_rank_mass_err"] < 1e-12
    assert sent.gauges["sentinel_trips"] == 0.0


@pytest.mark.parametrize("mutate,kind", [
    (lambda r: r.at[0].multiply(3.0), "rank_mass"),
    (lambda r: r.at[0].set(-r[0]).at[1].add(2 * r[0]), "rank_negative"),
    (lambda r: r.at[0].set(jnp.nan), "rank_nonfinite"),
])
def test_sentinel_trips_on_invariant_violation(mutate, kind):
    sent = InvariantSentinel(clock=FakeClock())
    _, incs = _observe(sent, mutate(_good_ranks()))
    assert [i.kind for i in incs] == [kind]
    assert incs[0].severity == "error"
    assert incs[0].generation == 1
    d = incs[0].as_dict()           # JSON-able schema
    json.dumps(d)
    assert d["kind"] == kind


def test_sentinel_trips_on_unconverged_residual():
    sent = InvariantSentinel(SentinelConfig(residual_tol=1e-6),
                             clock=FakeClock())
    _, incs = _observe(sent, _good_ranks(), delta=1e-3)
    assert [i.kind for i in incs] == ["residual"]


def test_sentinel_anomaly_scores_after_warmup():
    cfg = SentinelConfig(anomaly_warmup=8, anomaly_z=6.0)
    sent = InvariantSentinel(cfg, clock=FakeClock())
    for i in range(12):     # stable regime: 5 iterations, 10 affected
        _, incs = _observe(sent, _good_ranks(), iterations=5,
                           affected=10, gen=i)
        assert incs == []
    # a wild batch after warmup -> warn-severity anomaly incidents
    _, incs = _observe(sent, _good_ranks(), iterations=500,
                       affected=100000, gen=99)
    kinds = {i.kind for i in incs}
    assert kinds == {"anomaly_iterations", "anomaly_affected"}
    assert all(i.severity == "warn" for i in incs)


def test_sentinel_fallback_batches_skip_anomaly_scoring():
    cfg = SentinelConfig(anomaly_warmup=2, anomaly_z=6.0)
    sent = InvariantSentinel(cfg, clock=FakeClock())
    for i in range(6):
        _observe(sent, _good_ranks(), iterations=5, gen=i)
    # fallback solves look nothing like the baseline, but must not trip
    _, incs = _observe(sent, _good_ranks(), iterations=10000,
                       affected=10**6, fallback=True, gen=7)
    assert incs == []
    assert sent.gauges["sentinel_anomaly_iterations_z"] == 0.0


# ---------------------------------------------------------------------------
# shadow verification
# ---------------------------------------------------------------------------

def test_shadow_sampling_cadence_and_clean_reports():
    g = _graph()
    ranks = static_pagerank(g).ranks
    sv = ShadowVerifier(every=4, background=False)
    taken = [sv.maybe_submit(i, i, g, ranks) for i in range(9)]
    assert taken == [True, False, False, False] * 2 + [True]
    assert sv.samples == 3
    assert sv.take_incidents() == []
    assert all(r.l1 < 1e-8 for r in sv.reports)
    assert sv.gauges()["shadow_samples"] == 3.0


def test_shadow_flags_divergent_snapshot():
    g = _graph()
    ranks = static_pagerank(g).ranks.at[0].multiply(2.0)
    sv = ShadowVerifier(every=1, background=False)
    sv.maybe_submit(5, 42, g, ranks)
    incs = sv.take_incidents()
    assert {i.kind for i in incs} == {"shadow_l1", "shadow_linf"}
    assert all(i.generation == 5 and i.last_seq == 42 for i in incs)
    assert sv.take_incidents() == []                   # drained


def test_shadow_background_latest_wins():
    g = _graph()
    ranks = static_pagerank(g).ranks
    sv = ShadowVerifier(every=1, background=True)
    gate = threading.Event()
    orig = sv._verify

    def slow_verify(job):
        assert gate.wait(10.0)
        return orig(job)

    sv._verify = slow_verify
    try:
        sv.maybe_submit(0, 0, g, ranks)
        deadline = time.time() + 10.0
        while not sv._busy and time.time() < deadline:
            time.sleep(0.001)                          # worker picks job 0
        assert sv._busy
        sv.maybe_submit(1, 1, g, ranks)                # pending
        sv.maybe_submit(2, 2, g, ranks)                # displaces gen 1
        gate.set()
        assert sv.flush(timeout=10.0)
    finally:
        gate.set()
        sv.stop()
    assert sv.samples == 2
    assert sv.skipped == 1
    assert [r.generation for r in sv.reports] == [0, 2]


# ---------------------------------------------------------------------------
# SLO burn rates
# ---------------------------------------------------------------------------

def test_slo_burn_rate_arithmetic():
    clk = FakeClock()
    t = SloTracker("latency", objective=0.9,       # budget = 0.1
                   windows=((120.0, 2.0),), min_events=4, clock=clk)
    for i in range(10):
        clk.t = float(i)
        t.record(good=(i % 2 == 0))                # 5 bad / 10 total
    assert t.counts(120.0) == (10, 5)
    assert t.burn_rate(120.0) == pytest.approx(5.0)  # 0.5 / 0.1
    # both windows hot -> alert with the measured burns
    alerts = t.evaluate()
    assert len(alerts) == 1
    a = alerts[0]
    assert a.long_window_s == 120.0 and a.short_window_s == 10.0
    assert a.burn_long == pytest.approx(5.0)
    assert a.burn_short >= a.threshold


def test_slo_short_window_resets_alert():
    clk = FakeClock()
    t = SloTracker("x", objective=0.9, windows=((120.0, 2.0),),
                   min_events=4, clock=clk)
    for i in range(8):
        clk.t = float(i)
        t.record(good=False)
    assert t.evaluate()                            # burning
    for i in range(20):                            # recover: all good
        clk.t = 8.0 + i
        t.record(good=True)
    # long window still remembers the bad burst, short window is clean
    assert t.burn_rate(120.0) > 2.0
    assert t.burn_rate(10.0) == 0.0
    assert t.evaluate() == []


def test_slo_min_events_significance_gate():
    clk = FakeClock()
    t = SloTracker("x", objective=0.99, windows=((60.0, 2.0),),
                   min_events=4, clock=clk)
    for i in range(3):
        clk.t = float(i)
        t.record(good=False)                       # burn huge, n tiny
    assert t.evaluate() == []                      # not significant yet
    clk.t = 3.0
    t.record(good=False)
    assert t.evaluate()                            # 4th sample arms it


def test_slo_set_alerts_are_edge_triggered():
    clk = FakeClock()
    s = SloSet.serving(windows=((60.0, 2.0),), min_events=4, clock=clk)
    for i in range(6):
        clk.t = float(i)
        s.record("latency", good=False)
        s.record("staleness", good=True)
    assert len(s.evaluate()) == 1                  # fires once...
    assert s.evaluate() == []                      # ...stays active, no re-fire
    g = s.gauges()
    assert g["slo_alerts_active"] == 1.0
    assert g["slo_latency_bad_total"] == 6.0
    assert g["slo_staleness_burn_60s"] == 0.0


# ---------------------------------------------------------------------------
# flight recorder: capture -> replay bit-for-bit
# ---------------------------------------------------------------------------

def _monitor(**over):
    kw = dict(shadow_every=0, anchor_every=4, recorder_capacity=64)
    kw.update(over)
    return CorrectnessMonitor(MonitorConfig(**kw))


@pytest.mark.parametrize("engine_kw", [
    dict(),
    dict(engine="kernel", kernel_opts=dict(use_kernel=False, be=32, vb=64)),
], ids=["xla", "kernel"])
def test_capture_then_replay_is_bitwise(engine_kw):
    mon = _monitor()
    ingest, store, engine, _ = _service(_graph(), monitor=mon, **engine_kw)
    engine.bootstrap()
    n = _feed(ingest, engine, num_batches=8)
    assert n == 8 and len(mon.recorder) == 8
    report = replay(mon.recorder)
    assert report.anchor_generation == 0
    assert len(report.steps) == 8
    assert report.ok and report.num_bitwise == 8
    assert "8/8 bit-for-bit" in report.describe()


def test_replay_window_end_gen_trims_tail():
    mon = _monitor()
    ingest, store, engine, _ = _service(_graph(), monitor=mon)
    engine.bootstrap()
    _feed(ingest, engine, num_batches=6)
    report = replay(mon.recorder, end_gen=3)
    assert [s.generation for s in report.steps] == [1, 2, 3]
    assert report.ok


def test_recorder_anchor_gc_keeps_replay_covered():
    mon = _monitor(recorder_capacity=6, anchor_every=2)
    ingest, store, engine, _ = _service(_graph(), monitor=mon)
    engine.bootstrap()
    _feed(ingest, engine, num_batches=12)
    rec = mon.recorder
    assert len(rec) == 6                           # ring trimmed
    oldest = rec.records[0].generation
    # every surviving anchor is useful; at least one covers the ring head
    assert min(rec.anchor_generations) <= oldest - 1
    assert replay(rec).ok                          # still replayable


def test_incident_bundle_roundtrip_with_injected_fault(tmp_path):
    idir = str(tmp_path / "incidents")
    mon = _monitor(incident_dir=idir, shadow_every=4,
                   shadow_background=False)
    ingest, store, engine, metrics = _service(_graph(), monitor=mon)
    engine.bootstrap()
    engine.inject_fault(3, kind="rank", vertex=0, scale=4.0)
    _feed(ingest, engine, num_batches=8)
    # the mass sentinel catches the corruption at the faulted generation
    # itself -- far inside the 64-batch acceptance window
    errors = [i for i in mon.incidents if i.severity == "error"]
    assert errors and errors[0].generation == 3
    assert errors[0].kind == "rank_mass"
    assert engine.faults_injected == 1
    assert metrics.as_dict()["faults_injected"] == 1.0
    # auto-dumped bundle replays bit-for-bit, fault re-applied
    assert mon.last_bundle == os.path.join(idir, "incident_gen00000003")
    cfg, a, state, a_seq, records, incident = load_bundle(mon.last_bundle)
    assert incident["kind"] == "rank_mass"
    assert any(r.fault for r in records)
    report = replay(mon.last_bundle)
    assert report.ok and report.num_bitwise == len(report.steps)
    # the CLI agrees (exit 0 on bitwise reproduction)
    from repro.launch.replay import main as replay_main
    out_json = str(tmp_path / "report.json")
    assert replay_main([mon.last_bundle, "--strict",
                        "--json", out_json]) == 0
    with open(out_json) as f:
        assert json.load(f)["ok"] is True


def test_replay_refuses_unanchored_configs(tmp_path):
    mon = _monitor()
    ingest, store, engine, _ = _service(_graph(), monitor=mon)
    engine.bootstrap()
    _feed(ingest, engine, num_batches=2)
    bundle = mon.recorder.dump(str(tmp_path / "b"))
    man_path = os.path.join(bundle, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    for key in ("mesh", "ppr"):
        man["config"][key] = True
        with open(man_path, "w") as f:
            json.dump(man, f)
        with pytest.raises(NotImplementedError):
            replay(bundle)
        man["config"][key] = False


def test_replay_with_ppr_index_is_bitwise(tmp_path):
    """Single-device PPR configs replay now that the identity is
    anchored: the replayed engine rebuilds the same walk index from the
    recorded (num_walks, max_len, alpha, key) and every step matches
    bit-for-bit — both from the live recorder and a dumped bundle."""
    from repro.ppr import IndexConfig
    mon = _monitor()
    ingest, store, engine, _ = _service(
        _graph(), monitor=mon,
        ppr_index=IndexConfig(num_walks=8, max_len=8, seed=3))
    engine.bootstrap()
    _feed(ingest, engine, num_batches=6)
    assert mon.recorder.config["ppr"]["key"] is not None
    report = replay(mon.recorder)
    assert report.ok and report.num_bitwise == 6
    bundle = mon.recorder.dump(str(tmp_path / "b"))   # JSON round-trip
    assert replay(bundle).ok


# ---------------------------------------------------------------------------
# monitor wiring: gauges + summary through the engine
# ---------------------------------------------------------------------------

def test_monitor_gauges_flow_into_serve_metrics():
    mon = _monitor(shadow_every=2, shadow_background=False)
    ingest, store, engine, metrics = _service(_graph(), monitor=mon)
    engine.bootstrap()
    _feed(ingest, engine, num_batches=5)
    mon.close()
    m = metrics.as_dict()
    for key in ("sentinel_rank_mass_err", "sentinel_trips",
                "shadow_samples", "shadow_l1", "slo_alerts_active",
                "slo_latency_bad_total", "incidents_total"):
        assert key in m, key
    assert m["shadow_samples"] == 3.0              # batches 0, 2, 4
    assert m["incidents_total"] == 0.0
    s = mon.summary()
    assert s["batches"] == 5 and s["incident_bundle"] is None
    # the Prometheus surface renders the whole correctness plane
    text = MetricsExporter(metrics).scrape()
    assert "repro_shadow_l1" in text and "repro_sentinel_trips" in text


# ---------------------------------------------------------------------------
# export hardening: JSONL rotation, exporter lifecycle
# ---------------------------------------------------------------------------

def test_jsonl_sink_rotates_at_size_cap(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    sink = JsonlSink(path, max_bytes=400, backups=2, clock=lambda: 1.0)
    for i in range(40):
        sink.write({"i": i, "pad": "x" * 32})
    sink.close()
    assert sink.rotations >= 2
    assert os.path.exists(path + ".1") and os.path.exists(path + ".2")
    assert not os.path.exists(path + ".3")         # backups capped
    for p in (path, path + ".1", path + ".2"):
        assert os.path.getsize(p) <= 400
        with open(p) as f:                         # every line intact JSON
            rows = [json.loads(line) for line in f]
        assert all("i" in r for r in rows)


def test_jsonl_sink_truncates_with_zero_backups(tmp_path):
    path = str(tmp_path / "t.jsonl")
    sink = JsonlSink(path, max_bytes=200, backups=0)
    for i in range(20):
        sink.write({"i": i})
    sink.close()
    assert sink.rotations >= 1
    assert not os.path.exists(path + ".1")
    sink.write({"late": True})                     # post-close: no-op
    sink.close()                                   # idempotent


def test_metrics_exporter_lifecycle():
    exp = MetricsExporter(ServeMetrics())
    port = exp.serve(port=0)
    assert port > 0 and exp.port == port
    with pytest.raises(RuntimeError):
        exp.serve(port=0)                          # double-serve refused
    exp.close()
    assert exp.port is None
    exp.close()                                    # idempotent
    with exp:                                      # context manager re-serves
        assert exp.serve(port=0) > 0
    assert exp.port is None
