"""CLI driver smoke tests (subprocess; tiny workloads)."""
import os
import subprocess
import sys

import pytest


_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, env=env, cwd=_REPO,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


@pytest.mark.slow
def test_pagerank_driver(tmp_path):
    out = _run(["-m", "repro.launch.pagerank", "--dataset",
                "sx-mathoverflow", "--method", "frontier_prune",
                "--batch-frac", "1e-3", "--batches", "3",
                "--ckpt-every", "2", "--ckpt-dir", str(tmp_path)])
    assert "stream complete" in out
    assert "batch   2" in out
    # checkpoint written
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


@pytest.mark.slow
def test_train_driver_restart(tmp_path):
    out1 = _run(["-m", "repro.launch.train", "--arch", "qwen2.5-3b",
                 "--smoke", "--steps", "12", "--batch", "4", "--seq", "32",
                 "--ckpt-every", "5", "--ckpt-dir", str(tmp_path),
                 "--log-every", "5"])
    assert "final loss" in out1
    out2 = _run(["-m", "repro.launch.train", "--arch", "qwen2.5-3b",
                 "--smoke", "--steps", "14", "--batch", "4", "--seq", "32",
                 "--ckpt-every", "5", "--ckpt-dir", str(tmp_path),
                 "--log-every", "5"])
    assert "restored checkpoint at step 10" in out2


def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert "frontier_prune" in out


@pytest.mark.slow
def test_serve_driver(tmp_path):
    out = _run(["-m", "repro.launch.serve", "--dataset", "sx-mathoverflow",
                "--events", "200", "--flush-size", "32",
                "--flush-interval-ms", "20", "--query-every", "50",
                "--min-queries", "1",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"])
    assert "serve complete" in out
    assert "queries served" in out
    # generations printed at each query burst are monotone non-decreasing
    gens = [int(line.split("gen=")[1].split()[0])
            for line in out.splitlines() if "gen=" in line]
    assert gens and gens == sorted(gens)
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))
    # restart resumes the event feed and the generation clock
    out2 = _run(["-m", "repro.launch.serve", "--dataset", "sx-mathoverflow",
                 "--events", "300", "--flush-size", "32",
                 "--flush-interval-ms", "20", "--query-every", "50",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"])
    assert "restored generation" in out2
    assert "serve complete" in out2
