"""Block-Gauss-Seidel variant: fixed-point equality + faster convergence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gauss_seidel import gauss_seidel_pagerank
from repro.core.kernel_engine import kernel_pagerank_loop
from repro.core.reference import l1_error, static_pagerank_ref
from repro.graph.generators import grid_edges, rmat_edges
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.ops import pack_blocks


@pytest.mark.parametrize("gen,seed", [("rmat", 23), ("grid", 0)])
def test_gs_fixed_point_and_sweep_count(gen, seed):
    if gen == "rmat":
        edges, n = rmat_edges(8, 8, seed=seed)
    else:
        edges, n = grid_edges(20)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 8)
    packed = pack_blocks(edges[:, 0], edges[:, 1],
                         np.ones(len(edges), bool), n, be=256, vb=128)
    init = jnp.full((n,), 1.0 / n, jnp.float32)
    gs = gauss_seidel_pagerank(g, packed, init, tol=1e-7)
    jac = kernel_pagerank_loop(g, packed, init, jnp.ones((n,), bool),
                               tol=1e-7, closed_form=True, expand=False,
                               use_kernel=False)
    ref, _ = static_pagerank_ref(edges[:, 0], edges[:, 1], n, tol=1e-12)
    assert l1_error(gs.ranks, ref) < 1e-4
    # the async-analogue must not be slower than Jacobi in sweeps
    assert int(gs.sweeps) <= int(jac.iterations)
