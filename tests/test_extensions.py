"""Beyond-paper extensions: personalised + weighted PageRank on DF-P."""
import jax.numpy as jnp
import numpy as np

from repro.core.extensions import personalized_pagerank, weighted_pagerank
from repro.core.pagerank import static_pagerank
from repro.core.reference import l1_error
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import random_batch_update, rmat_edges
from repro.graph.structure import from_coo


def _setup():
    edges, n = rmat_edges(8, 8, seed=17)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 32)
    return edges, n, g


def test_ppr_sums_to_one_and_concentrates_on_seeds():
    edges, n, g = _setup()
    seeds = jnp.zeros((n,), bool).at[jnp.asarray([3, 7])].set(True)
    res = personalized_pagerank(g, seeds)
    assert abs(float(jnp.sum(res.ranks)) - 1.0) < 1e-9
    uni = static_pagerank(g)
    # seed vertices get boosted relative to global PR
    r, u = np.asarray(res.ranks), np.asarray(uni.ranks)
    assert r[3] > u[3] and r[7] > u[7]


def test_uniform_ppr_equals_global_pagerank():
    edges, n, g = _setup()
    res_ppr = personalized_pagerank(g, jnp.ones((n,), bool))
    res_pr = static_pagerank(g)
    assert l1_error(res_ppr.ranks, res_pr.ranks) < 1e-7


def test_incremental_ppr_matches_static_ppr():
    edges, n, g = _setup()
    seeds = jnp.zeros((n,), bool).at[5].set(True)
    base = personalized_pagerank(g, seeds)
    dele, ins = random_batch_update(edges, n, 10, seed=18)
    upd = make_batch_update(dele, ins, 16, 16)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    inc = personalized_pagerank(g2, seeds, prev_ranks=base.ranks,
                                graph_prev=g, touched=touched)
    ref = personalized_pagerank(g2, seeds)
    assert l1_error(inc.ranks, ref.ranks) < 1e-4
    assert int(jnp.sum(inc.affected_ever)) < n      # skipped work


def test_unit_weights_match_unweighted():
    edges, n, g = _setup()
    w = jnp.ones((g.edge_capacity,), jnp.float64)
    res_w = weighted_pagerank(g, w)
    res_u = static_pagerank(g)
    assert l1_error(res_w.ranks, res_u.ranks) < 1e-8


def test_weighted_shifts_mass_toward_heavy_edges():
    edges, n, g = _setup()
    # boost all edges into vertex 0
    w = np.ones(g.edge_capacity)
    dst = np.asarray(g.dst)
    w[dst == 0] = 10.0
    res_w = weighted_pagerank(g, jnp.asarray(w))
    res_u = static_pagerank(g)
    assert float(res_w.ranks[0]) > float(res_u.ranks[0])
    assert abs(float(jnp.sum(res_w.ranks)) - 1.0) < 1e-8