"""Model-layer property tests: attention equivalences, RoPE, MoE caps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _naive_attention(q, k, v, window=None):
    b, s, h, d = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("s,chunk,window", [(16, 4, None), (32, 8, 8),
                                            (33, 8, None), (16, 16, 4)])
def test_chunked_attention_matches_naive(s, chunk, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, s, 3, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, s, 3, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, s, 3, 8)), jnp.float32)
    out = L.chunked_causal_attention(q, k, v, window=window, chunk=chunk)
    ref = _naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_chunked_attention_traced_window():
    """window as a traced scalar (the local/global scan trick)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 4)), jnp.float32)
    k, v = q + 0.1, q - 0.1

    def f(w):
        return L.chunked_causal_attention(q, k, v, window=w, chunk=8)

    out_local = jax.jit(f)(jnp.int32(4))
    ref = _naive_attention(q, k, v, 4)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_decode_attention_matches_naive_last_position():
    rng = np.random.default_rng(2)
    s = 12
    q_all = jnp.asarray(rng.standard_normal((2, s, 4, 8)), jnp.float32)
    kvh = 2
    k_all = jnp.asarray(rng.standard_normal((2, s, kvh, 8)), jnp.float32)
    v_all = jnp.asarray(rng.standard_normal((2, s, kvh, 8)), jnp.float32)
    ref = _naive_attention(q_all, L._expand_kv(k_all, 4),
                           L._expand_kv(v_all, 4))[:, -1:]
    # cache padded beyond length
    pad = 4
    kc = jnp.pad(k_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = L.decode_attention(q_all[:, -1:], kc, vc,
                             jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 6, 2, 8)), jnp.float32)
    pos = jnp.arange(6, dtype=jnp.int32)[None]
    out = L.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # dot products depend only on relative position
    q = L.apply_rope(x, pos)
    k = L.apply_rope(x, pos + 7)     # same shift everywhere
    d1 = jnp.einsum("bshd,bshd->bsh", q, q)
    d2 = jnp.einsum("bshd,bshd->bsh", k, k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4)


def test_moe_capacity_drops_overflow():
    from repro.models.moe import init_moe, moe_ffn
    p = init_moe(jax.random.PRNGKey(0), 8, 16, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8), jnp.float32)
    # capacity_factor tiny -> most tokens dropped -> output much smaller
    out_small, _ = moe_ffn(p, x, top_k=2, capacity_factor=0.1)
    out_big, _ = moe_ffn(p, x, top_k=2, capacity_factor=8.0)
    assert float(jnp.sum(jnp.abs(out_small))) < \
        float(jnp.sum(jnp.abs(out_big)))


def test_rms_norm_scale_and_dtype():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)),
                    jnp.bfloat16)
    out = L.rms_norm(x, jnp.zeros((16,), jnp.bfloat16))
    assert out.dtype == jnp.bfloat16
    rms = np.sqrt(np.mean(np.square(np.asarray(out, np.float32)), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=0.1)


def test_adamw_converges_quadratic():
    from repro.optim.adamw import adamw_update, init_adamw
    w = dict(a=jnp.asarray([3.0, -2.0]))
    st = init_adamw(w)
    for _ in range(300):
        g = jax.tree_util.tree_map(lambda p: 2 * p, w)   # d/dp p^2
        w, st = adamw_update(w, g, st, lr=5e-2, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(w["a"]))) < 1e-2


def test_adamw_factored_matches_direction():
    from repro.optim.adamw import adamw_update, init_adamw
    rng = np.random.default_rng(0)
    w = dict(m=jnp.asarray(rng.standard_normal((1 << 11, 1 << 10)),
                           jnp.float32))
    g = jax.tree_util.tree_map(lambda p: p * 0.1, w)
    st_f = init_adamw(w, factored=True)
    st_d = init_adamw(w, factored=False)
    wf, _ = adamw_update(dict(w), g, st_f, lr=1e-2, factored=True,
                         weight_decay=0.0)
    wd, _ = adamw_update(dict(w), g, st_d, lr=1e-2, factored=False,
                         weight_decay=0.0)
    # factored v is an approximation; updates should agree in sign and
    # roughly in magnitude
    a, b = np.asarray(wf["m"] - w["m"]), np.asarray(wd["m"] - w["m"])
    agree = np.mean(np.sign(a) == np.sign(b))
    assert agree > 0.99
    assert 0.5 < np.abs(a).mean() / np.abs(b).mean() < 2.0
