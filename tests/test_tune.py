"""Autotuner: model ranking sanity, cache hit/miss, persistence, and the
ServeEngine bootstrap wiring that consumes the tuned geometry."""
import json

import numpy as np
import pytest

import repro  # noqa: F401
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.tune import (CANDIDATE_GRID, KernelGeometry,
                                              TuneCache, candidate_costs,
                                              graph_signature,
                                              spill_for_stream,
                                              tune_geometry)


def _graph(scale=9, edge_factor=6, seed=11, extra=512):
    edges, n = rmat_edges(scale, edge_factor, seed=seed)
    return from_coo(edges[:, 0], edges[:, 1], n,
                    edge_capacity=len(edges) + extra)


# ---------------------------------------------------------------------------
# model ranking
# ---------------------------------------------------------------------------

def test_candidate_costs_covers_grid_and_ranks():
    g = _graph()
    dst = np.asarray(g.dst)[np.asarray(g.valid)]
    ranked = candidate_costs(dst, g.num_vertices, 0.05, 1024)
    assert len(ranked) == len(CANDIDATE_GRID)
    costs = [c for _, c in ranked]
    assert costs == sorted(costs)
    assert all(c > 0 for c in costs)
    geoms = {(geo.be, geo.vb) for geo, _ in ranked}
    assert geoms == set(CANDIDATE_GRID)


def test_model_prefers_wider_blocks_on_dense_frontier():
    # at frontier=1.0 every entry is active: traffic is fixed, so the
    # model must rank by grid-step overhead, which favours larger BE*VB
    g = _graph()
    dst = np.asarray(g.dst)[np.asarray(g.valid)]
    best, _ = candidate_costs(dst, g.num_vertices, 1.0, 0)[0]
    worst, _ = candidate_costs(dst, g.num_vertices, 1.0, 0)[-1]
    assert best.be * best.vb > worst.be * worst.vb


def test_spill_for_stream_bounds():
    assert spill_for_stream(100, 0, 512) == 16          # floor
    assert spill_for_stream(1, 10**9, 512) == 512       # ceil at BE
    s = spill_for_stream(64, 1024, 512)
    assert 16 <= s <= 512 and (s & (s - 1)) == 0        # pow2 in range


def test_graph_signature_buckets():
    a = graph_signature(1000, 8000, 0.05)
    assert a == graph_signature(1100, 8800, 0.06)       # same bucket
    assert a != graph_signature(4000, 8000, 0.05)       # V moved 2 octaves
    assert a != graph_signature(1000, 8000, 0.005)      # frontier decade


# ---------------------------------------------------------------------------
# cache: hit/miss + persistence roundtrip
# ---------------------------------------------------------------------------

def test_tune_cache_miss_then_hit(tmp_path):
    path = str(tmp_path / "tune.json")
    g = _graph()
    geom1, info1 = tune_geometry(g, cache_path=path)
    assert info1.source == "model" and not info1.cache_hit
    assert len(info1.candidates) == len(CANDIDATE_GRID)
    geom2, info2 = tune_geometry(g, cache_path=path)
    assert info2.source == "cache" and info2.cache_hit
    assert geom2 == geom1
    assert info2.key == info1.key


def test_tune_cache_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    cache = TuneCache(path)
    geom = KernelGeometry(be=1024, vb=256, spill_lanes_per_window=64)
    cache.put("k", geom)
    # fresh instance reads the same JSON back
    reloaded = TuneCache(path)
    assert len(reloaded) == 1
    assert reloaded.get("k") == geom
    # the file itself is plain {key: {be, vb, spill}} JSON
    with open(path) as f:
        raw = json.load(f)
    assert raw["k"]["be"] == 1024


def test_tune_cache_tolerates_corrupt_file(tmp_path):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        f.write("{not json")
    cache = TuneCache(path)
    assert len(cache) == 0
    cache.put("k", KernelGeometry(be=256, vb=128, spill_lanes_per_window=16))
    assert TuneCache(path).get("k") is not None


def test_tune_frontier_decade_changes_key(tmp_path):
    path = str(tmp_path / "tune.json")
    g = _graph()
    _, a = tune_geometry(g, frontier_frac=0.05, cache_path=path)
    _, b = tune_geometry(g, frontier_frac=0.005, cache_path=path)
    assert a.key != b.key and not b.cache_hit


def test_measured_search_times_top_candidates(tmp_path):
    path = str(tmp_path / "tune.json")
    g = _graph(scale=8)
    geom, info = tune_geometry(g, cache_path=path, measure=True,
                               measure_top=2, use_kernel=False)
    assert info.source == "measured"
    timed = [c for c in info.candidates if c[2] is not None]
    assert len(timed) == 2
    assert all(t > 0 for _, _, t in timed)
    assert geom == min(timed, key=lambda c: c[2])[0]


# ---------------------------------------------------------------------------
# ServeEngine consumes the tuned geometry at bootstrap
# ---------------------------------------------------------------------------

def _serve_parts(graph):
    from repro.serve import IngestQueue, RankStore
    return IngestQueue(flush_size=8, flush_interval=1e9,
                       max_pending=1024), RankStore()


def test_serve_bootstrap_tunes_and_logs_geometry(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    from repro.serve import ServeEngine
    g = _graph(scale=8)
    ingest, store = _serve_parts(g)
    eng = ServeEngine(g, ingest, store, method="frontier", engine="kernel",
                      kernel_opts=dict(use_kernel=False))
    eng.bootstrap()
    assert eng.kernel_geometry is not None
    assert eng.tune_info is not None and not eng.tune_info.cache_hit
    assert (eng.kernel_geometry.be, eng.kernel_geometry.vb) in CANDIDATE_GRID
    # second engine over the same-shaped graph hits the persisted cache
    ingest2, store2 = _serve_parts(g)
    eng2 = ServeEngine(g, ingest2, store2, method="frontier",
                       engine="kernel", kernel_opts=dict(use_kernel=False))
    eng2.bootstrap()
    assert eng2.tune_info.cache_hit
    assert eng2.kernel_geometry == eng.kernel_geometry


def test_serve_explicit_geometry_disables_tuning(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    from repro.serve import ServeEngine
    g = _graph(scale=8)
    ingest, store = _serve_parts(g)
    eng = ServeEngine(g, ingest, store, method="frontier", engine="kernel",
                      kernel_opts=dict(be=32, vb=16,
                                       spill_lanes_per_window=64,
                                       use_kernel=False))
    eng.bootstrap()
    assert eng.tune_info is None                        # no tuning ran
    assert eng.kernel_geometry.be == 32
    assert eng.kernel_geometry.vb == 16
    assert not (tmp_path / "tune.json").exists()
