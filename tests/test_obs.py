"""repro.obs: span tracer, frontier telemetry, exporters, and the
zero-overhead-when-off contract of the traced serving stack."""
import json
import threading
import urllib.request

import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro import obs
from repro.core import kernel_engine as ke
from repro.core import pagerank as pr
from repro.graph.generators import erdos_renyi_edges, rmat_edges
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.update import pack_graph
from repro.obs.frontier import FIELDS, NUM_FIELDS, FrontierTelemetry
from repro.obs.trace import Tracer, _NOP
from repro.serve import (IngestQueue, QueryClient, RankStore, ServeEngine,
                         ServeMetrics)


def _graph(seed=0, n_exp=9, ef=8, cap_extra=512):
    edges, n = rmat_edges(n_exp, ef, seed=seed)
    return from_coo(edges[:, 0], edges[:, 1], n,
                    edge_capacity=len(edges) + cap_extra)


def _service(graph, flush_size=8, **engine_kw):
    metrics = ServeMetrics()
    ingest = IngestQueue(flush_size=flush_size, flush_interval=0.0,
                         max_pending=4096)
    store = RankStore()
    engine = ServeEngine(graph, ingest, store, metrics=metrics,
                         method="frontier_prune", **engine_kw)
    return ingest, store, engine, metrics


def _feed(ingest, engine, n, events, rng):
    for _ in range(events):
        u, v = rng.integers(0, n, size=2)
        if u != v:
            ingest.submit_insert(int(u), int(v))
    engine.drain()


# ---------------------------------------------------------------------------
# timeit + tracer core
# ---------------------------------------------------------------------------

def test_timeit_measures_elapsed():
    fake = iter([10.0, 10.25])
    with obs.timeit(clock=lambda: next(fake)) as t:
        pass
    assert t.seconds == pytest.approx(0.25)


def test_tracer_records_spans_with_args():
    tr = Tracer()
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]
    outer = tr.spans("outer")[0]
    inner = tr.spans("inner")[0]
    assert outer.args == {"k": 1}
    # interval containment: inner nests inside outer on the same thread
    assert outer.tid == inner.tid
    assert outer.t0 <= inner.t0
    assert inner.t0 + inner.dur <= outer.t0 + outer.dur + 1e-9


def test_tracer_spans_nest_per_thread():
    tr = Tracer()
    barrier = threading.Barrier(2)

    def work(name):
        barrier.wait()
        with tr.span(name):
            pass

    threads = [threading.Thread(target=work, args=(f"t{i}",))
               for i in range(2)]
    with tr.span("main"):
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"main", "t0", "t1"}
    # each worker thread gets its own track
    assert spans["t0"].tid != spans["t1"].tid
    assert spans["t0"].tid != spans["main"].tid


def test_disabled_tracer_is_free_and_shared():
    tr = Tracer(enabled=False)
    assert tr.span("x") is _NOP          # shared no-op context manager
    with tr.span("x"):
        pass
    tr.record("y", 0.0, 1.0)
    tr.instant("z")
    assert len(tr) == 0
    # sync must not touch the device path at all when disabled
    tr.sync(object())


def test_tracer_ring_buffer_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.record(f"s{i}", float(i), 0.5)
    assert len(tr) == 4
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]
    assert tr.to_chrome()["otherData"]["dropped_events"] == 6


def test_chrome_trace_round_trips_through_json(tmp_path):
    tr = Tracer()
    with tr.span("phase", detail="abc"):
        pass
    tr.instant("marker", n=np.int64(3))
    tr.counter("frontier", affected=7)
    path = tr.write(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    assert "traceEvents" in doc
    complete = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(complete) == 1
    ev = complete[0]
    assert ev["name"] == "phase"
    assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
    assert ev["args"] == {"detail": "abc"}
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "C", "M"} <= phs
    # numpy scalar coerced to a plain int by _jsonable
    inst = [e for e in doc["traceEvents"] if e["ph"] == "i"][0]
    assert inst["args"] == {"n": 3}


def test_global_tracer_disabled_by_default_and_scoped():
    assert not obs.get_tracer().enabled
    assert obs.span("x") is _NOP
    with obs.tracing() as tr:
        assert obs.get_tracer() is tr and tr.enabled
        with obs.span("inside"):
            pass
        assert len(tr.spans("inside")) == 1
    assert not obs.get_tracer().enabled


def test_traced_decorator():
    calls = []

    @obs.traced("decorated")
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2                      # disabled: plain call
    with obs.tracing() as tr:
        assert fn(2) == 3
        assert len(tr.spans("decorated")) == 1
    assert calls == [1, 2]


# ---------------------------------------------------------------------------
# frontier telemetry: schema, loops, engine parity
# ---------------------------------------------------------------------------

def test_frontier_schema_helpers():
    rows = np.arange(2 * NUM_FIELDS, dtype=np.float64).reshape(2, NUM_FIELDS)
    ft = FrontierTelemetry(rows)
    assert ft.iterations == 2
    for i, name in enumerate(FIELDS):
        assert ft.column(name).tolist() == [float(i), float(i + NUM_FIELDS)]
    s = ft.summary()
    assert s["iterations"] == 2
    assert s["affected_initial"] == 0.0 and s["affected_final"] == 5.0
    assert len(ft.rows()) == 2 and set(ft.rows()[0]) == set(FIELDS)
    cat = FrontierTelemetry.concat(ft, FrontierTelemetry(rows[:1]))
    assert cat.iterations == 3
    assert FrontierTelemetry.concat().iterations == 0


def test_xla_loop_telemetry_matches_endpoint_scalars():
    g = _graph(seed=1)
    V = g.num_vertices
    ranks = jnp.full((V,), 1.0 / V, jnp.float64)
    touched = np.zeros(V, bool)
    touched[:4] = True
    aff = pr.initial_affected(g, g, jnp.asarray(touched))
    res = pr._pagerank_loop(g, ranks, aff, tol=1e-10, frontier_tol=1e-6,
                            prune_tol=1e-6, max_iter=200, expand=True,
                            prune=True, closed_form=True, telemetry=True)
    assert res.telemetry.shape == (200, NUM_FIELDS)   # padded device rows
    ft = FrontierTelemetry.from_padded(res.telemetry, res.iterations)
    assert ft.iterations == int(res.iterations)
    # first row's affected = the initial affected set, final row's
    # residual = the loop's final delta
    assert ft.column("affected")[0] == float(jnp.sum(aff))
    assert ft.column("residual")[-1] == pytest.approx(float(res.delta))
    # identical solve without telemetry: same ranks, same iterations
    base = pr._pagerank_loop(g, ranks, aff, tol=1e-10, frontier_tol=1e-6,
                             prune_tol=1e-6, max_iter=200, expand=True,
                             prune=True, closed_form=True)
    assert base.telemetry is None
    assert int(base.iterations) == int(res.iterations)
    np.testing.assert_allclose(np.asarray(base.ranks),
                               np.asarray(res.ranks), rtol=0, atol=0)


def test_kernel_vs_xla_telemetry_parity():
    g = _graph(seed=5)
    packed = pack_graph(g, be=256, vb=256)
    V = g.num_vertices
    ranks = jnp.full((V,), 1.0 / V, jnp.float64)
    touched = np.zeros(V, bool)
    touched[:8] = True
    aff = pr.initial_affected(g, g, jnp.asarray(touched))
    kw = dict(tol=1e-7, frontier_tol=1e-5, prune_tol=1e-5, max_iter=100,
              expand=True, prune=True, closed_form=True)
    x = pr._pagerank_loop(g, ranks, aff, telemetry=True, **kw)
    k = ke.kernel_pagerank_loop(g, packed, ranks, aff, use_kernel=False,
                                telemetry=True, **kw)
    tx = FrontierTelemetry.from_padded(x.telemetry, x.iterations)
    tk = FrontierTelemetry.from_padded(k.telemetry, k.iterations)
    m = min(10, tx.iterations, tk.iterations)
    assert m >= 3
    # the two engines walk the same frontier: affected counts exact,
    # residuals agree to f32 precision while far from convergence
    np.testing.assert_array_equal(tx.column("affected")[:m],
                                  tk.column("affected")[:m])
    np.testing.assert_allclose(tx.column("residual")[:m],
                               tk.column("residual")[:m], rtol=1e-3)


def test_hybrid_telemetry_concatenates_phases():
    g = _graph(seed=7)
    packed = pack_graph(g, be=256, vb=256)
    V = g.num_vertices
    ranks = jnp.full((V,), 1.0 / V, jnp.float64)
    touched = np.zeros(V, bool)
    touched[:8] = True
    aff = pr.initial_affected(g, g, jnp.asarray(touched))
    res = ke.hybrid_pagerank(g, packed, ranks, aff, use_kernel=False,
                             prune=True, closed_form=True, telemetry=True)
    # trimmed host rows: kernel phase + polish phase = total iterations
    assert isinstance(res.telemetry, np.ndarray)
    assert res.telemetry.shape == (int(res.iterations), NUM_FIELDS)
    base = ke.hybrid_pagerank(g, packed, ranks, aff, use_kernel=False,
                              prune=True, closed_form=True)
    assert base.telemetry is None
    np.testing.assert_allclose(np.asarray(base.ranks),
                               np.asarray(res.ranks), rtol=0, atol=1e-15)


# ---------------------------------------------------------------------------
# zero overhead when off: program counts and trace counters
# ---------------------------------------------------------------------------

def test_disabled_tracing_adds_no_device_programs():
    g = _graph(seed=2, cap_extra=2048)
    n = g.num_vertices
    ingest, _, engine, metrics = _service(
        g, engine="kernel",
        kernel_opts=dict(use_kernel=False, be=256, vb=256))
    engine.bootstrap()
    rng = np.random.default_rng(0)
    _feed(ingest, engine, n, 24, rng)
    fused0 = ke.TRACE_COUNTS["fused_update_loop"]
    progs0 = list(metrics.batch_device_programs)
    # more untraced batches: no retrace, same programs per batch
    _feed(ingest, engine, n, 24, rng)
    assert ke.TRACE_COUNTS["fused_update_loop"] == fused0
    assert set(metrics.batch_device_programs) == set(progs0)


def test_tracing_toggles_one_retrace_and_preserves_programs():
    g = _graph(seed=3, cap_extra=2048)
    n = g.num_vertices
    ingest, _, engine, metrics = _service(
        g, engine="kernel",
        kernel_opts=dict(use_kernel=False, be=256, vb=256))
    engine.bootstrap()
    rng = np.random.default_rng(1)
    _feed(ingest, engine, n, 24, rng)
    untraced = metrics.as_dict()["device_programs_per_batch"]
    fused0 = ke.TRACE_COUNTS["fused_update_loop"]
    with obs.tracing():
        _feed(ingest, engine, n, 24, rng)
    # telemetry=True is a static flag: exactly one extra trace of the
    # fused loop, and the per-batch device-program count is unchanged
    assert ke.TRACE_COUNTS["fused_update_loop"] == fused0 + 1
    assert metrics.as_dict()["device_programs_per_batch"] == untraced
    with obs.tracing():
        _feed(ingest, engine, n, 8, rng)
    assert ke.TRACE_COUNTS["fused_update_loop"] == fused0 + 1   # cached


# ---------------------------------------------------------------------------
# serve engine: span tree + telemetry capture + gauges
# ---------------------------------------------------------------------------

def test_serve_step_span_tree_and_telemetry(tmp_path):
    g = _graph(seed=4, n_exp=11, cap_extra=2048)
    n = g.num_vertices
    ingest, _, engine, metrics = _service(
        g, engine="kernel",
        kernel_opts=dict(use_kernel=False, be=256, vb=256))
    engine.bootstrap()
    sink_path = str(tmp_path / "frontier.jsonl")
    engine.telemetry_sink = obs.JsonlSink(sink_path)
    rng = np.random.default_rng(2)
    trace_path = str(tmp_path / "trace.json")
    with obs.tracing(trace_path) as tr:
        _feed(ingest, engine, n, 40, rng)
        names = {s.name for s in tr.spans()}
    engine.telemetry_sink.close()
    # the batch span tree: every phase of the fused kernel path
    assert {"serve.step", "ingest.coalesce", "route_update",
            "fused_update_loop", "polish.f64",
            "snapshot.publish"} <= names
    # each serve.step contains its phases by interval
    steps = tr.spans("serve.step")
    inner = tr.spans("fused_update_loop")
    assert steps and inner
    s0 = steps[0]
    assert any(s0.t0 <= sp.t0 and sp.t0 + sp.dur <= s0.t0 + s0.dur + 1e-9
               for sp in inner)
    # frontier telemetry captured and summarized
    assert engine.last_telemetry is not None
    assert engine.last_telemetry.data.shape[1] == NUM_FIELDS
    d = metrics.as_dict()
    assert d["frontier_batches"] >= 1
    assert d["frontier_iterations_mean"] > 0
    # the JSONL sink got one frontier record per traced batch
    lines = [json.loads(ln) for ln in open(sink_path)]
    assert len(lines) == d["frontier_batches"]
    assert lines[0]["kind"] == "frontier"
    assert set(lines[0]["rows"][0]) == set(FIELDS)
    # trace file is valid Chrome-trace JSON
    doc = json.loads(open(trace_path).read())
    evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in evs} >= {"serve.step", "fused_update_loop"}
    assert all("ts" in e and "dur" in e for e in evs)


def test_ppr_repair_span_recorded():
    edges, n = erdos_renyi_edges(64, 400, seed=0)
    g = from_coo(edges[:, 0], edges[:, 1], n,
                 edge_capacity=len(edges) + 512)
    from repro.ppr import IndexConfig
    ingest, _, engine, _ = _service(
        g, ppr_index=IndexConfig(num_walks=4, max_len=8, seed=0))
    engine.bootstrap()
    rng = np.random.default_rng(3)
    with obs.tracing() as tr:
        _feed(ingest, engine, n, 20, rng)
        spans = tr.spans("ppr.repair")
    assert spans
    assert all("stale" in (s.args or {}) for s in spans)


def test_engine_gauges_in_as_dict():
    g = _graph(seed=6, cap_extra=2048)
    n = g.num_vertices
    ingest, _, engine, metrics = _service(
        g, engine="kernel", telemetry=False,
        kernel_opts=dict(use_kernel=False, be=256, vb=256))
    engine.bootstrap()
    rng = np.random.default_rng(4)
    _feed(ingest, engine, n, 16, rng)
    d = metrics.as_dict()
    assert "staleness_in_events" in d
    # stable snake_case serving counters (the PR 4-6 set)
    for key in ("comm_bytes", "device_programs_per_batch",
                "packed_rebuilds", "packed_rebuilds_by_shard",
                "events_per_s", "walks_resampled"):
        assert key in d
    # gauges never shadow core counters
    metrics.set_gauge("events_per_s", -1.0)
    assert metrics.as_dict()["events_per_s"] != -1.0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_prometheus_text_format():
    text = obs.prometheus_text(dict(
        events_per_s=12.5, batches=3, skip_me="a string",
        packed_rebuilds_by_shard={"0": 2, "3": 1}))
    lines = text.strip().splitlines()
    assert "repro_events_per_s 12.5" in lines
    assert "repro_batches 3" in lines
    assert '# TYPE repro_packed_rebuilds_by_shard gauge' in lines
    assert 'repro_packed_rebuilds_by_shard{key="0"} 2' in lines
    assert 'repro_packed_rebuilds_by_shard{key="3"} 1' in lines
    assert not any("skip_me" in ln for ln in lines)


def test_jsonl_sink_appends_records(tmp_path):
    path = str(tmp_path / "records.jsonl")
    sink = obs.JsonlSink(path, clock=lambda: 42.0)
    sink.write(dict(a=1, arr=np.arange(3)), kind="test")
    sink.write(dict(b=np.float32(2.5)))
    sink.close()
    rows = [json.loads(ln) for ln in open(path)]
    assert rows[0] == {"a": 1, "arr": [0, 1, 2], "kind": "test", "t": 42.0}
    assert rows[1]["b"] == 2.5


def test_metrics_exporter_scrape_server():
    m = ServeMetrics()
    m.record_batch(0.01, 8, 2, affected=5, iterations=3, fallback=False)
    m.set_gauge("halo_occupancy", 0.5)
    exporter = obs.MetricsExporter(m, extra=lambda: dict(extra_gauge=7))
    try:
        port = exporter.serve(port=0)
        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics").read().decode()
        assert "repro_events_applied 8" in text
        assert "repro_halo_occupancy 0.5" in text
        assert "repro_extra_gauge 7" in text
        blob = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics.json").read()
        d = json.loads(blob)
        assert d["events_applied"] == 8 and d["extra_gauge"] == 7
        with pytest.raises(Exception):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope")
    finally:
        exporter.close()


def test_metrics_exporter_write(tmp_path):
    m = ServeMetrics()
    m.record_batch(0.02, 4, 0, affected=2, iterations=1, fallback=True)
    path = str(tmp_path / "metrics.prom")
    obs.MetricsExporter(m).write(path)
    text = open(path).read()
    assert "repro_static_fallbacks 1" in text
    assert text.endswith("\n")


def test_halo_occupancy_gauge():
    from repro.kernels.pagerank_spmv.shard import HaloSpec, halo_occupancy
    halo = HaloSpec(ids=jnp.zeros((2, 8), jnp.int32),
                    count=jnp.asarray([4, 2], jnp.int32))
    assert halo_occupancy(halo) == pytest.approx(6 / 16)
    empty = HaloSpec(ids=jnp.zeros((2, 0), jnp.int32),
                     count=jnp.zeros((2,), jnp.int32))
    assert halo_occupancy(empty) == 0.0
