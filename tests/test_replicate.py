"""Replication tier: delta streams, gap retry/backoff, anchor resync,
late join, graceful degradation, writer failover (serve/replicate.py).

Everything runs on the injected ``LogicalClock`` + ``FaultyTransport``,
so every retry, backoff expiry and failover decision is deterministic.
Parity assertions are *bitwise* (L∞ == 0): deltas carry the exact f64
values the writer published, so a correct replica is not merely close —
it is identical.
"""
import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro.ft.elastic import ReplicaRoster
from repro.graph.generators import erdos_renyi_edges
from repro.graph.structure import from_coo
from repro.ppr import IndexConfig, build_walk_index
from repro.serve import (FailoverController, FaultyTransport, IngestQueue,
                         LinkDown, LogicalClock, QueryClient, RankStore,
                         ReadReplica, ReplicaDegradedError,
                         ReplicaQueryClient, ReplicationWriter, ServeEngine,
                         ServeMetrics)

N = 64
DT = 0.01


def _graph(seed=0, m=300):
    edges, n = erdos_renyi_edges(N, m, seed=seed)
    return from_coo(edges[:, 0], edges[:, 1], n,
                    edge_capacity=len(edges) + 1024)


def _engine_factory(clock, base_graph, ckpt_dir=None, ckpt_every=1):
    def make(graph, last_seq, generation):
        ingest = IngestQueue(flush_size=8, flush_interval=0.0,
                             max_pending=1 << 16,
                             start_seq=last_seq + 1, clock=clock)
        store = (RankStore(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)
                 if ckpt_dir else RankStore())
        return ServeEngine(graph, ingest, store, metrics=ServeMetrics(),
                           method="frontier_prune", clock=clock)
    return make


def _writer(clock, transport, roster, anchor_every=4, ckpt_dir=None,
            **writer_kw):
    factory = _engine_factory(clock, None, ckpt_dir=ckpt_dir)
    engine = factory(_graph(), last_seq=-1, generation=0)
    engine.bootstrap()
    w = ReplicationWriter(engine, transport, anchor_every=anchor_every,
                          clock=clock, **writer_kw)
    w.attach()
    transport.set_writer(w)
    w.heartbeat(roster)
    return w, factory


def _replica(name, clock, transport, roster, **kw):
    kw.setdefault("max_retries", 3)
    kw.setdefault("backoff_base", 2 * DT)
    kw.setdefault("slo_windows", ((1.0, 1.0),))
    kw.setdefault("slo_min_events", 4)
    return ReadReplica(name, transport, N, roster=roster, seed=0,
                       clock=clock, **kw)


def _feed(writer, events, clock, roster, replicas=(), seed=1,
          step_every=8, hb_every=4, record=None):
    rng = np.random.default_rng(seed)
    for i in range(events):
        clock.advance(DT)
        u, v = (int(x) for x in rng.integers(0, N, size=2))
        if u != v:
            writer.engine.ingest.submit_insert(u, v)
            if record is not None:
                record.append((u, v))
        if (i + 1) % step_every == 0:
            writer.engine.step(force=True)
        if (i + 1) % hb_every == 0:
            writer.heartbeat(roster)
        for r in replicas:
            r.pump()
    writer.engine.drain()


def _settle(writer, replicas, clock, roster, rounds=60):
    """Advance past every backoff and pump until nothing is in flight."""
    for _ in range(rounds):
        clock.advance(0.1)
        writer.heartbeat(roster)
        for r in replicas:
            r.pump()


def _assert_parity(writer, replica):
    wgen = writer.engine.store.generation
    assert replica.epoch == writer.epoch
    assert replica.generation == wgen, (replica.generation, wgen)
    wr = np.asarray(writer.engine.store.snapshot().ranks)
    linf = float(np.max(np.abs(replica.ranks - wr)))
    assert linf == 0.0, f"replica diverged: L∞={linf:.3e} at gen {wgen}"


# ---------------------------------------------------------------------------
# clean stream: exact replication + query surface
# ---------------------------------------------------------------------------

def test_delta_stream_reaches_bitwise_parity():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster)
    r = _replica("r0", clock, transport, roster)
    assert r.bootstrap()
    _feed(w, 80, clock, roster, replicas=[r])
    _settle(w, [r], clock, roster)
    _assert_parity(w, r)
    assert r.deltas_applied > 0
    assert r.gaps_detected == 0 and r.resyncs == 1   # bootstrap only
    # the replica's query surface answers from its own snapshot store
    client = ReplicaQueryClient(r)
    wr = np.asarray(w.engine.store.snapshot().ranks)
    res = client.get_ranks([3, 1, 4])
    np.testing.assert_array_equal(res.ranks, wr[[3, 1, 4]])
    assert res.staleness_events == 0
    top = client.top_k(5)
    np.testing.assert_array_equal(np.asarray(top.ranks),
                                  np.sort(wr)[::-1][:5])


def test_duplicates_and_reorder_are_idempotent():
    clock = LogicalClock()
    transport = FaultyTransport(seed=3, dup_p=0.4, reorder_p=0.5,
                               reorder_window=4 * DT)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster)
    r = _replica("r0", clock, transport, roster)
    assert r.bootstrap()
    _feed(w, 120, clock, roster, replicas=[r])
    _settle(w, [r], clock, roster)
    _assert_parity(w, r)
    assert transport.duplicated > 0 and transport.reordered > 0
    assert r.duplicates > 0                # dups detected, applied once


# ---------------------------------------------------------------------------
# gap retry state machine
# ---------------------------------------------------------------------------

def test_dropped_deltas_recovered_by_retransmit():
    clock = LogicalClock()
    transport = FaultyTransport(seed=5, drop_p=0.3)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster)
    r = _replica("r0", clock, transport, roster)
    assert r.bootstrap()
    _feed(w, 120, clock, roster, replicas=[r])
    _settle(w, [r], clock, roster)
    _assert_parity(w, r)
    assert r.gaps_detected >= 1
    assert r.retries_sent >= 1
    assert w.retransmits >= 1


def test_gap_beyond_log_forces_anchor_resync():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    # tiny retransmit log: a long partition spill is only anchor-servable
    w, _ = _writer(clock, transport, roster, anchor_every=2,
                   log_capacity=2)
    r = _replica("r0", clock, transport, roster)
    assert r.bootstrap()
    _feed(w, 40, clock, roster, replicas=[r])
    transport.partition("r0")
    _feed(w, 80, clock, roster, replicas=[r])
    transport.heal("r0")
    _settle(w, [r], clock, roster)
    _assert_parity(w, r)
    assert r.resyncs >= 2                  # bootstrap + post-partition
    kinds = [i.kind for i in r.incidents]
    assert "replica_resync" in kinds


def test_late_joiner_bootstraps_from_anchor_and_tail():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster, anchor_every=8)
    _feed(w, 60, clock, roster)
    late = _replica("late", clock, transport, roster)
    assert late.bootstrap()                # anchor + replayed delta tail
    _assert_parity(w, late)


def test_unreachable_writer_fails_bootstrap_gracefully():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster)
    w.kill()
    r = _replica("r0", clock, transport, roster)
    assert not r.bootstrap()               # False, not an exception
    with pytest.raises(LinkDown):
        transport.writer_for("r0")


# ---------------------------------------------------------------------------
# graceful degradation ladder
# ---------------------------------------------------------------------------

def test_degraded_replica_sheds_topk_but_serves_points():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster)
    r = _replica("r0", clock, transport, roster, staleness_slo_events=4)
    assert r.bootstrap()
    _feed(w, 40, clock, roster, replicas=[r])
    _settle(w, [r], clock, roster)
    assert not r.degraded
    # blackhole the stream, then let one heartbeat reveal the lag
    transport.drop_p = 1.0
    _feed(w, 40, clock, roster, replicas=[r])
    transport.drop_p = 0.0
    clock.advance(DT)
    w.heartbeat(roster)
    r.pump()
    assert r.degraded and r.staleness > 4
    client = ReplicaQueryClient(r)
    res = client.get_ranks([0, 1])         # the ladder's floor holds
    assert res.staleness_events == r.staleness
    with pytest.raises(ReplicaDegradedError) as e:
        client.top_k(3)
    assert e.value.staleness_events == r.staleness
    with pytest.raises(ReplicaDegradedError):
        client.personalized_top_k([1], 3)
    kinds = [i.kind for i in r.incidents]
    assert "replica_degraded" in kinds
    # recovery: retransmit/resync catches up, shedding lifts
    _settle(w, [r], clock, roster)
    assert not r.degraded
    _assert_parity(w, r)
    assert "replica_recovered" in [i.kind for i in r.incidents]
    client.top_k(3)                        # shedding is over


def test_shed_disabled_keeps_answering_stale_topk():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=1e9)
    w, _ = _writer(clock, transport, roster)
    r = _replica("r0", clock, transport, roster, staleness_slo_events=4,
                 shed_on_degrade=False)
    assert r.bootstrap()
    transport.drop_p = 1.0
    _feed(w, 40, clock, roster, replicas=[r])
    transport.drop_p = 0.0
    clock.advance(DT)
    w.heartbeat(roster)
    r.pump()
    assert r.degraded
    res = ReplicaQueryClient(r).top_k(3)   # stale but answered
    assert res.staleness_events == r.staleness > 4


# ---------------------------------------------------------------------------
# heartbeat failover
# ---------------------------------------------------------------------------

def test_failover_promotes_freshest_replica_without_losing_generation():
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=0.5)
    w, factory = _writer(clock, transport, roster)
    r0 = _replica("r0", clock, transport, roster)
    r1 = _replica("r1", clock, transport, roster)
    assert r0.bootstrap() and r1.bootstrap()
    # r0 misses the second half of the stream: r1 is strictly fresher
    _feed(w, 40, clock, roster, replicas=[r0, r1])
    transport.partition("r0")
    _feed(w, 40, clock, roster, replicas=[r0, r1])
    transport.heal("r0")
    r1.pump()
    committed_gen = w.engine.store.generation
    committed_seq = w.engine.ingest.latest_seq
    w.kill()
    clock.advance(1.0)                     # writer heartbeat lapses...
    r0.pump()
    r1.pump()                              # ...but the replicas keep beating
    ctl = FailoverController(transport, roster, factory,
                             num_vertices=N, clock=clock)
    promoted = ctl.check(w, [r0, r1])
    assert promoted is not None
    new_w, promoted_replica = promoted
    assert promoted_replica is r1          # freshest by (gen, last_seq)
    assert new_w.epoch == w.epoch + 1
    assert new_w.engine.store.generation >= committed_gen
    assert new_w.engine.ingest.start_seq > \
        new_w.engine.store.snapshot().last_seq
    transport.unregister(r1.name)
    transport.set_writer(new_w)
    assert ctl.failovers == 1
    assert "writer_failover" in [i.kind for i in ctl.incidents]
    # the survivor converges on the new epoch and keeps replicating
    _feed(new_w, 40, clock, roster, replicas=[r0],
          seed=9)
    _settle(new_w, [r0], clock, roster)
    assert r0.epoch == new_w.epoch
    _assert_parity(new_w, r0)
    assert new_w.engine.ingest.latest_seq >= committed_seq


def _assert_ppr_parity(writer, replica):
    """Writer and replica hold the *same walks* (bitwise) and answer
    index-mode personalized top-k identically."""
    widx = writer.engine.store.snapshot().ppr_index
    assert widx is not None and replica.ppr is not None
    assert bool(jnp.all(replica.ppr.steps == widx.steps))
    wq = QueryClient(writer.engine.store, writer.engine.ingest)
    rq = ReplicaQueryClient(replica)
    for seeds in ([1], [5, 9]):
        a = rq.personalized_top_k(seeds, 5, mode="index")
        b = wq.personalized_top_k(seeds, 5, mode="index")
        assert a.vertices.tolist() == b.vertices.tolist()
        np.testing.assert_array_equal(np.asarray(a.ranks),
                                      np.asarray(b.ranks))


def test_ppr_chaos_heals_keep_bitwise_index_parity(monkeypatch):
    """Walk-index parity through the full chaos schedule — dropped
    deltas → retransmit, partition past the log → anchor resync,
    writer death → failover — with index-mode top-k identical after
    every heal.  The anchor resync must heal by *incremental repair*
    (anchors now carry the index identity), and failover must promote
    the replica's index into the new writer: zero ``build_walk_index``
    calls after bootstrap, on either side."""
    clock = LogicalClock()
    transport = FaultyTransport(seed=5)
    roster = ReplicaRoster(heartbeat_timeout=0.5)
    cfg = IndexConfig(num_walks=8, max_len=8, seed=5)

    def factory(graph, last_seq, generation):
        ingest = IngestQueue(flush_size=8, flush_interval=0.0,
                             max_pending=1 << 16,
                             start_seq=last_seq + 1, clock=clock)
        return ServeEngine(graph, ingest, RankStore(),
                           metrics=ServeMetrics(), method="frontier_prune",
                           clock=clock, ppr_index=cfg)

    engine = factory(_graph(), last_seq=-1, generation=0)
    engine.bootstrap()
    w = ReplicationWriter(engine, transport, anchor_every=2,
                          log_capacity=2, clock=clock)
    w.attach()
    transport.set_writer(w)
    w.heartbeat(roster)
    r = _replica("r0", clock, transport, roster, ppr_cfg=cfg)
    assert r.bootstrap()                   # builds the replica index once
    _assert_ppr_parity(w, r)

    # from here on, any from-scratch rebuild is a regression
    builds = []
    import repro.serve.engine as eng_mod
    import repro.serve.replicate as rep_mod
    for mod in (eng_mod, rep_mod):
        orig = mod.build_walk_index
        monkeypatch.setattr(
            mod, "build_walk_index",
            lambda *a, _o=orig, **k: (builds.append(1), _o(*a, **k))[1])

    # -- heal 1: dropped deltas -> gap -> retransmit --------------------
    transport.drop_p = 0.3
    _feed(w, 40, clock, roster, replicas=[r], seed=2)
    transport.drop_p = 0.0
    _settle(w, [r], clock, roster)
    _assert_parity(w, r)
    _assert_ppr_parity(w, r)

    # -- heal 2: partition beyond the 2-entry log -> anchor resync ------
    transport.partition("r0")
    _feed(w, 40, clock, roster, replicas=[r], seed=3)
    transport.heal("r0")
    _settle(w, [r], clock, roster)
    assert r.resyncs >= 2                  # bootstrap + post-partition
    _assert_parity(w, r)
    _assert_ppr_parity(w, r)
    assert builds == []                    # resynced by repair, no rebuild

    # -- heal 3: writer dies -> failover promotes the replica -----------
    committed_gen = w.engine.store.generation
    w.kill()
    clock.advance(1.0)
    r.pump()                               # replica keeps beating
    ctl = FailoverController(transport, roster, factory,
                             num_vertices=N, clock=clock)
    new_w, promoted = ctl.check(w, [r])
    assert promoted is r
    assert new_w.engine.store.generation >= committed_gen
    assert builds == []                    # index carried over, not rebuilt
    snap = new_w.engine.store.snapshot()
    fresh = build_walk_index(snap.graph, cfg)
    assert bool(jnp.all(snap.ppr_index.steps == fresh.steps))
    # the promoted writer keeps maintaining the carried index correctly
    transport.set_writer(new_w)
    _feed(new_w, 24, clock, roster, seed=11)
    snap = new_w.engine.store.snapshot()
    fresh = build_walk_index(snap.graph, cfg)
    assert bool(jnp.all(snap.ppr_index.steps == fresh.steps))
    assert builds == []


def test_failover_restores_checkpoint_when_replicas_lag(tmp_path):
    clock = LogicalClock()
    transport = FaultyTransport(seed=0)
    roster = ReplicaRoster(heartbeat_timeout=0.5)
    feed_log: list = []
    w, factory = _writer(clock, transport, roster,
                         ckpt_dir=str(tmp_path))
    base_graph = w.engine.store.snapshot().graph

    def rebuild_graph(last_seq):
        """The recorded feed is the graph's log (insert-only here)."""
        src = np.asarray(base_graph.src).copy()
        dst = np.asarray(base_graph.dst).copy()
        valid = np.asarray(base_graph.valid).copy()
        ne = int(np.asarray(base_graph.num_edges))
        live = set(zip(src[:ne][valid[:ne]].tolist(),
                       dst[:ne][valid[:ne]].tolist()))
        for u, v in feed_log[: last_seq + 1]:
            if (u, v) not in live:
                src[ne], dst[ne], valid[ne] = u, v, True
                live.add((u, v))
                ne += 1
        import dataclasses
        return dataclasses.replace(
            base_graph, src=jnp.asarray(src), dst=jnp.asarray(dst),
            valid=jnp.asarray(valid),
            num_edges=jnp.asarray(np.int32(ne)))

    r0 = _replica("r0", clock, transport, roster)
    assert r0.bootstrap()
    # the replica is partitioned for the WHOLE stream: every surviving
    # candidate is behind the last committed checkpoint
    transport.partition("r0")
    _feed(w, 40, clock, roster, replicas=[r0], record=feed_log)
    committed_gen = w.engine.store.generation
    committed_seq = int(w.engine.store.snapshot().last_seq)
    assert committed_gen > 0
    w.kill()
    clock.advance(1.0)
    # without the replay callback, promotion must refuse to lose the
    # committed generation rather than silently promote a stale replica
    bare = FailoverController(transport, roster, factory,
                              ckpt_dir=str(tmp_path), num_vertices=N,
                              rebuild_graph=None, clock=clock)
    with pytest.raises(RuntimeError, match="refusing"):
        bare.promote(w, [r0])
    ctl = FailoverController(transport, roster, factory,
                             ckpt_dir=str(tmp_path), num_vertices=N,
                             rebuild_graph=rebuild_graph, clock=clock)
    new_w, promoted_replica = ctl.promote(w, [r0])
    assert promoted_replica is None        # came from the checkpoint
    assert new_w.engine.store.generation == committed_gen
    assert int(new_w.engine.store.snapshot().last_seq) == committed_seq
    transport.set_writer(new_w)
    transport.heal("r0")
    # healed replica resyncs onto the promoted epoch at full parity
    _settle(new_w, [r0], clock, roster)
    assert r0.epoch == new_w.epoch == w.epoch + 1
    _assert_parity(new_w, r0)
