"""repro.serve: ingest coalescing, snapshot consistency, streaming
equivalence, static fallback, queries, checkpoint restart."""
import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.api import build_initial_state
from repro.core.pagerank import static_pagerank
from repro.core.reference import l1_error
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.generators import erdos_renyi_edges
from repro.graph.structure import from_coo
from repro.serve import (IngestQueue, QueryClient, RankStore, ServeEngine,
                         ServeMetrics)
from repro.serve.ingest import DELETE, INSERT, EdgeEvent, coalesce_events

N = 64


def _graph(seed=0, m=400, cap_extra=512):
    edges, n = erdos_renyi_edges(N, m, seed=seed)
    return from_coo(edges[:, 0], edges[:, 1], n,
                    edge_capacity=len(edges) + cap_extra), edges


def _service(graph, method="frontier_prune", flush_size=16,
             flush_interval=0.0, clock=None, **engine_kw):
    metrics = ServeMetrics()
    kw = dict(flush_size=flush_size, flush_interval=flush_interval)
    if clock is not None:
        kw["clock"] = clock
    ingest = IngestQueue(**kw)
    store = RankStore()
    engine = ServeEngine(graph, ingest, store, metrics=metrics,
                         method=method, **engine_kw)
    return ingest, store, engine, metrics


# ---------------------------------------------------------------------------
# ingest: flush policy, admission, coalescing
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_flush_on_size():
    q = IngestQueue(flush_size=4, flush_interval=1e9)
    for i in range(3):
        q.submit(INSERT, i, i + 1)
    assert q.poll() is None                       # below size, deadline far
    q.submit(INSERT, 9, 10)
    b = q.poll()
    assert b is not None and b.num_events == 4
    assert (b.first_seq, b.last_seq) == (0, 3)
    assert q.poll() is None


def test_flush_on_deadline():
    clk = FakeClock()
    q = IngestQueue(flush_size=100, flush_interval=0.5, clock=clk)
    q.submit(INSERT, 1, 2)
    assert q.poll() is None                       # deadline not reached
    clk.t = 0.6
    b = q.poll()
    assert b is not None and b.num_events == 1


def test_force_flush_and_empty():
    q = IngestQueue(flush_size=100, flush_interval=1e9)
    assert q.poll(force=True) is None
    q.submit(INSERT, 1, 2)
    assert q.poll(force=True).num_events == 1


def test_admission_control_sheds_load():
    q = IngestQueue(flush_size=4, flush_interval=1e9, max_pending=6)
    seqs = [q.submit(INSERT, i, i + 1) for i in range(10)]
    assert seqs[:6] == list(range(6))
    assert all(s is None for s in seqs[6:])
    assert q.rejected == 4
    assert q.latest_seq == 5                      # rejected events get no seq


def test_coalesce_net_effect_last_op_wins():
    evs = [EdgeEvent(INSERT, 1, 2, 0, 0.0),
           EdgeEvent(DELETE, 1, 2, 1, 0.0),      # cancels the insert
           EdgeEvent(DELETE, 3, 4, 2, 0.0),
           EdgeEvent(INSERT, 3, 4, 3, 0.0),      # delete→insert = insert
           EdgeEvent(INSERT, 5, 6, 4, 0.0)]
    b = coalesce_events(evs, 8, 8)
    assert b.num_events == 5 and b.num_coalesced == 2
    dels = set(zip(np.asarray(b.update.del_src)[
        np.asarray(b.update.del_mask)].tolist(),
        np.asarray(b.update.del_dst)[
        np.asarray(b.update.del_mask)].tolist()))
    ins = set(zip(np.asarray(b.update.ins_src)[
        np.asarray(b.update.ins_mask)].tolist(),
        np.asarray(b.update.ins_dst)[
        np.asarray(b.update.ins_mask)].tolist()))
    assert dels == {(1, 2)}
    assert ins == {(3, 4), (5, 6)}


@pytest.mark.parametrize("seed", range(4))
def test_coalesced_batches_match_per_event_application(seed):
    """Coalescing must be semantically invisible: applying the coalesced
    window equals applying the raw events one by one, in order."""
    rng = np.random.default_rng(seed)
    g, edges = _graph(seed=seed)
    live = [tuple(e) for e in edges.tolist()]
    evs = []
    for i in range(40):
        if live and rng.random() < 0.35:
            u, v = live[int(rng.integers(len(live)))]
            evs.append(EdgeEvent(DELETE, u, v, i, 0.0))
        else:
            u, v = rng.integers(0, N, 2)
            if u == v:
                v = (v + 1) % N
            evs.append(EdgeEvent(INSERT, int(u), int(v), i, 0.0))
    # one coalesced window
    g_co = apply_batch(g, coalesce_events(evs, 64, 64).update)
    # one singleton batch per event, in order
    g_seq = g
    for ev in evs:
        d = np.asarray([[ev.u, ev.v]] if ev.kind == DELETE else
                       np.zeros((0, 2)), np.int32).reshape(-1, 2)
        i_ = np.asarray([[ev.u, ev.v]] if ev.kind == INSERT else
                        np.zeros((0, 2)), np.int32).reshape(-1, 2)
        g_seq = apply_batch(g_seq, make_batch_update(d, i_, 8, 8))

    def eset(gg):
        s, d, va = (np.asarray(gg.src), np.asarray(gg.dst),
                    np.asarray(gg.valid))
        return set(zip(s[va].tolist(), d[va].tolist()))

    assert eset(g_co) == eset(g_seq)


# ---------------------------------------------------------------------------
# state: snapshot consistency + generation monotonicity
# ---------------------------------------------------------------------------

def test_generation_monotone_and_snapshot_consistent():
    g, _ = _graph()
    ingest, store, engine, _ = _service(g, flush_size=8)
    engine.bootstrap()
    rng = np.random.default_rng(1)
    gens = [store.snapshot().generation]
    for i in range(30):
        u, v = rng.integers(0, N, 2)
        if u != v:
            ingest.submit(INSERT, int(u), int(v))
        engine.step(force=(i % 3 == 0))
        snap = store.snapshot()
        # consistency: the published (graph, ranks) pair is a fixed point
        # of each other — |ranks| matches the graph and sums to ~1
        assert snap.ranks.shape == (snap.graph.num_vertices,)
        assert abs(float(jnp.sum(snap.ranks)) - 1.0) < 1e-4
        gens.append(snap.generation)
    assert gens == sorted(gens)                   # monotone, never reset
    assert gens[-1] > 0


def test_rankstore_checkpoint_restore(tmp_path):
    g, _ = _graph()
    store = RankStore(ckpt_dir=str(tmp_path), ckpt_every=2)
    r0 = jnp.full((N,), 1.0 / N, jnp.float64)
    store.publish(g, r0, last_seq=-1)             # gen 0: checkpointed
    store.publish(g, r0 * 2, last_seq=5)          # gen 1: not (every=2)
    store.publish(g, r0 * 3, last_seq=11)         # gen 2: checkpointed
    restored = RankStore(ckpt_dir=str(tmp_path),
                         ckpt_every=2).restore_latest(N)
    assert restored is not None
    ranks, gen, last_seq = restored
    assert gen == 2 and last_seq == 11
    np.testing.assert_allclose(np.asarray(ranks), np.asarray(r0) * 3)


def test_seed_generation_continues_after_restart():
    g, _ = _graph()
    store = RankStore()
    store.seed_generation(7)
    r = jnp.full((N,), 1.0 / N, jnp.float64)
    assert store.publish(g, r, last_seq=3) == 7
    assert store.publish(g, r, last_seq=4) == 8


# ---------------------------------------------------------------------------
# engine: streaming equivalence, fallback, background thread
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("method", ["frontier", "frontier_prune"])
@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_equivalence_property(method, seed):
    """N micro-batched serve-loop steps over a random insert/delete event
    stream reach the same fixed point as one-shot static PageRank on the
    final graph (L1 <= 1e-6)."""
    rng = np.random.default_rng(seed)
    g, edges = _graph(seed=seed)
    live = set(map(tuple, edges.tolist()))
    ingest, store, engine, metrics = _service(
        g, method=method, flush_size=16, flush_interval=1e9,
        # never fall back to static here — the point is the DF/DF-P path
        static_fallback_frac=2.0,
        # tolerance-bounded drift accumulates per micro-batch; tighten the
        # frontier thresholds so ~15 batches stay within the 1e-6 budget
        frontier_tol=1e-9, prune_tol=1e-9)
    engine.bootstrap()
    submitted = 0
    for i in range(200):
        if live and rng.random() < 0.3:
            u, v = sorted(live)[int(rng.integers(len(live)))]
            if ingest.submit(DELETE, u, v) is not None:
                live.discard((u, v))
                submitted += 1
        else:
            u, v = int(rng.integers(N)), int(rng.integers(N))
            if u != v and ingest.submit(INSERT, u, v) is not None:
                live.add((u, v))
                submitted += 1
        engine.step()
    engine.drain()
    snap = store.snapshot()
    # serve-loop graph realises exactly the event log's final edge set
    s, d, va = (np.asarray(snap.graph.src), np.asarray(snap.graph.dst),
                np.asarray(snap.graph.valid))
    assert set(zip(s[va].tolist(), d[va].tolist())) == live
    ref = static_pagerank(snap.graph)
    assert l1_error(snap.ranks, ref.ranks) <= 1e-6
    assert metrics.as_dict()["events_applied"] == submitted > 0


def test_static_fallback_triggers_and_stays_correct():
    g, _ = _graph()
    ingest, store, engine, metrics = _service(
        g, flush_size=32, static_fallback_frac=0.0)   # always falls back
    engine.bootstrap()
    rng = np.random.default_rng(3)
    for _ in range(32):
        u, v = rng.integers(0, N, 2)
        if u != v:
            ingest.submit(INSERT, int(u), int(v))
    engine.drain()
    m = metrics.as_dict()
    assert m["static_fallbacks"] == m["batches"] > 0
    snap = store.snapshot()
    ref = static_pagerank(snap.graph)
    assert l1_error(snap.ranks, ref.ranks) <= 1e-8


def test_background_engine_thread_drains_queue():
    g, _ = _graph()
    ingest, store, engine, metrics = _service(g, flush_size=8,
                                              flush_interval=0.005)
    engine.bootstrap()
    engine.start()
    rng = np.random.default_rng(5)
    try:
        for _ in range(40):
            u, v = rng.integers(0, N, 2)
            if u != v:
                ingest.submit(INSERT, int(u), int(v))
    finally:
        engine.stop(drain=True)
    assert ingest.pending() == 0
    assert store.snapshot().generation >= 1
    assert metrics.as_dict()["events_applied"] > 0


# ---------------------------------------------------------------------------
# iteration budget: capped solves, frontier carryover (ft/straggler.py)
# ---------------------------------------------------------------------------

def test_iteration_budget_caps_solves_and_carries_frontier():
    g, _ = _graph()
    # a 2-iteration cap cannot converge a frontier batch: the engine must
    # cap the solve, carry the unconverged frontier, and count it
    ingest, store, engine, metrics = _service(
        g, flush_size=16, static_fallback_frac=2.0, iteration_budget=2)
    engine.bootstrap()
    rng = np.random.default_rng(2)
    for _ in range(64):
        u, v = rng.integers(0, N, 2)
        if u != v:
            ingest.submit(INSERT, int(u), int(v))
        engine.step()
    engine.drain()
    m = metrics.as_dict()
    assert m["batches"] >= 2
    assert m["iterations_mean"] <= 2.0        # the cap held
    assert m["budget_carryover"] >= 1         # carried at least once
    snap = store.snapshot()
    assert abs(float(jnp.sum(snap.ranks)) - 1.0) < 1e-3   # still sane


def test_without_budget_no_carryover_counted():
    g, _ = _graph()
    ingest, store, engine, metrics = _service(g, flush_size=16)
    engine.bootstrap()
    rng = np.random.default_rng(2)
    for _ in range(32):
        u, v = rng.integers(0, N, 2)
        if u != v:
            ingest.submit(INSERT, int(u), int(v))
    engine.drain()
    assert metrics.as_dict()["budget_carryover"] == 0


# ---------------------------------------------------------------------------
# close(): the shadow thread is joined and its mailbox flushed
# ---------------------------------------------------------------------------

def test_engine_close_flushes_pending_shadow_divergence():
    from repro.obs import CorrectnessMonitor, MonitorConfig
    g, _ = _graph()
    mon = CorrectnessMonitor(MonitorConfig(
        shadow_every=1, latency_slo_ms=1e9, staleness_slo_events=10**9))
    ingest, store, engine, _ = _service(g, flush_size=8, monitor=mon)
    engine.bootstrap()
    # corrupt the NEXT generation's ranks: the shadow reference solve is
    # the detector, and it may still be pending when close() is called —
    # the flush-on-close contract says it must be reported anyway
    engine.inject_fault(store.generation + 1, kind="rank", vertex=0,
                        scale=4.0)
    rng = np.random.default_rng(4)
    for _ in range(8):
        u, v = rng.integers(0, N, 2)
        if u != v:
            ingest.submit(INSERT, int(u), int(v))
    engine.drain()
    engine.close()                            # joins + flushes the mailbox
    assert mon.shadow._thread is None         # actually joined
    kinds = {i.kind for i in mon.incidents}
    assert kinds & {"shadow_l1", "shadow_linf"}, kinds
    engine.close()                            # idempotent


def test_shadow_stop_verifies_pending_sample_before_join():
    from repro.obs.shadow import ShadowVerifier
    g, _ = _graph()
    ref_ranks = np.full(N, 1.0 / N)
    sv = ShadowVerifier(every=1, l1_budget=1e-6, background=True)
    # a wildly wrong rank vector, submitted and immediately stopped: the
    # worker must verify it (and record the incident) before the join
    sv.maybe_submit(0, -1, g, jnp.asarray(ref_ranks * 3.0))
    sv.stop()
    assert sv.samples == 1
    assert any(i.kind == "shadow_l1" for i in sv.take_incidents())
    sv.stop()                                 # idempotent


# ---------------------------------------------------------------------------
# query: top-k, point ranks, personalized, staleness accounting
# ---------------------------------------------------------------------------

def test_queries_match_snapshot_ranks():
    g, _ = _graph()
    ingest, store, engine, metrics = _service(g)
    engine.bootstrap()
    client = QueryClient(store, ingest, metrics)
    ranks = np.asarray(store.snapshot().ranks)

    r = client.get_ranks([3, 1, 4])
    np.testing.assert_allclose(r.ranks, ranks[[3, 1, 4]])
    assert r.generation == 0 and r.staleness_events == 0

    t = client.top_k(5)
    np.testing.assert_allclose(np.asarray(t.ranks),
                               np.sort(ranks)[::-1][:5])
    np.testing.assert_allclose(ranks[t.vertices], t.ranks)

    # unserved events show up as staleness
    ingest.submit(INSERT, 0, 9)
    ingest.submit(INSERT, 0, 10)
    assert client.top_k(3).staleness_events == 2
    assert metrics.as_dict()["queries_served"] == 3


def test_personalized_top_k_biases_to_seeds():
    g, _ = _graph()
    _, store, engine, _ = _service(g)
    engine.bootstrap()
    client = QueryClient(store)
    res = client.personalized_top_k(seeds=[7], k=8)
    assert 7 in res.vertices.tolist()             # seed holds teleport mass
    global_top = client.top_k(8)
    assert res.vertices.tolist() != global_top.vertices.tolist()


# ---------------------------------------------------------------------------
# the shared affected-set builder (core.api) — serve engine's contract
# ---------------------------------------------------------------------------

def test_build_initial_state_per_method():
    g, _ = _graph()
    upd = make_batch_update(np.zeros((0, 2)), np.array([[1, 2]]), 8, 8)
    g2 = apply_batch(g, upd)
    prev = jnp.full((N,), 1.0 / N, jnp.float64)

    r, a = build_initial_state(g, g2, upd, prev, "static")
    assert float(jnp.max(jnp.abs(r - 1.0 / N))) == 0 and bool(jnp.all(a))
    r, a = build_initial_state(g, g2, upd, prev, "naive")
    assert r is prev and bool(jnp.all(a))
    for m in ("traversal", "frontier", "frontier_prune"):
        r, a = build_initial_state(g, g2, upd, prev, m)
        assert r is prev
        assert bool(a[1])                         # update endpoint marked
        assert int(jnp.sum(a)) > 0
    # frontier marking is local (seeds + 1 hop), unlike DT reachability
    _, a = build_initial_state(g, g2, upd, prev, "frontier")
    assert 0 < int(jnp.sum(a)) < N
    with pytest.raises(ValueError):
        build_initial_state(g, g2, upd, None, "frontier")
    with pytest.raises(ValueError):
        build_initial_state(g, g2, None, prev, "frontier")
