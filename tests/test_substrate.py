"""Substrate tests: sampler, partition, incremental GNN, data pipeline,
compression, schedules, roofline formulas."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.incremental_gnn import incremental_refresh
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import rmat_edges, random_batch_update
from repro.graph.partition import partition_graph
from repro.graph.sampling import NeighborSampler
from repro.graph.structure import from_coo
from repro.optim.compression import compress_tree, quantize_int8


def _graph(scale=8, ef=8, seed=3):
    edges, n = rmat_edges(scale, ef, seed=seed)
    return edges, n, from_coo(edges[:, 0], edges[:, 1], n,
                              edge_capacity=len(edges) + 32)


def test_neighbor_sampler_shapes_and_validity():
    edges, n, g = _graph()
    indptr, indices = g.to_host_csr()
    s = NeighborSampler(indptr, indices, fanouts=(5, 3), seed=0)
    seeds = np.arange(16, dtype=np.int32)
    batch = s.sample(seeds)
    assert batch.blocks[0].nodes.shape == (16 * 5,)
    assert batch.blocks[1].nodes.shape == (16 * 5 * 3,)
    # every sampled node must be a real out-neighbour of its parent
    b0 = batch.blocks[0]
    for i in np.nonzero(b0.mask)[0]:
        parent = seeds[b0.parent[i]]
        nbrs = indices[indptr[parent]: indptr[parent + 1]]
        assert b0.nodes[i] in nbrs


def test_partition_covers_all_edges():
    edges, n, g = _graph()
    part = partition_graph(g, 4, 4)
    total = int(part.valid.sum())
    assert total == int(g.num_valid_edges())
    # dst ranges respected
    for m in range(4):
        d = part.dst_local[m][part.valid[m]]
        assert (d >= 0).all()
        assert (d < part.v_per_shard).all()


def test_incremental_gnn_exact_on_refreshed_nodes():
    from repro.configs.graphsage_reddit import SMOKE as cfg
    from repro.models.gnn import GraphBatch, init_sage, sage_forward
    edges, n, g = _graph(7, 6, seed=9)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((n, cfg.d_in)), jnp.float32)
    params = init_sage(cfg, jax.random.PRNGKey(0))

    def fwd(gg, x):
        gb = GraphBatch(node_feats=x, edge_src=gg.src, edge_dst=gg.dst,
                        edge_mask=gg.valid,
                        node_mask=jnp.ones((n,), bool))
        return sage_forward(cfg, params, gb)

    emb = fwd(g, feats)
    dele, ins = random_batch_update(edges, n, 6, seed=1)
    upd = make_batch_update(dele, ins, 16, 16)
    g2 = apply_batch(g, upd)
    touched = touched_vertices_mask(upd, n)
    res = incremental_refresh(g2, feats, emb, touched, layer_fn=fwd,
                              n_layers=cfg.n_layers, frontier_tol=0.0)
    exact = fwd(g2, feats)
    # with τ_f = 0 the refresh must be exact on the whole receptive field
    np.testing.assert_allclose(np.asarray(res.embeddings),
                               np.asarray(exact), atol=1e-5)
    assert int(res.nodes_recomputed) < n  # and still skipped work


def test_int8_compression_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(g))
    assert err.max() <= float(s) * 0.5 + 1e-7
    tree = dict(a=g, b=g * 10)
    out = compress_tree(tree, "int8")
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(tree)


def test_synthetic_corpus_is_learnable_structure():
    from repro.data.lm import SyntheticCorpus
    c = SyntheticCorpus(vocab=256, seed=0)
    a = c.sample(4, 64)
    assert a.shape == (4, 65)
    assert a.min() >= 0 and a.max() < 256


def test_roofline_model_flops_positive():
    from repro.configs.registry import all_cells
    from repro.roofline.analysis import model_flops
    for spec, cell in all_cells(include_pagerank=True):
        f = model_flops(spec, cell)
        assert f > 0, (spec.arch_id, cell.name)


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
      %ag = f32[128,64]{1,0} all-gather(%x), replica_groups={{0,1}}
      %ar.1 = (f32[16]{0}, f32[16]{0}) all-reduce(%a, %b), to_apply=%add
      %done = f32[8]{0} all-gather-done(%ag2)
      %start = f32[8]{0} all-gather-start(%y)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 128 * 64 * 4 + 8 * 4
    assert out["all-reduce"] == 2 * 16 * 4
