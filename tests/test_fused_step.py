"""Fused update+sweep: the serving step's maintenance + f32 loop as ONE
device program must be bitwise identical to the two-program path, must
compile once over a stream, and the ServeEngine must account exactly one
f32 program (+polish) per micro-batch."""
import numpy as np
import pytest
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import pagerank as pr
from repro.core.kernel_engine import (TRACE_COUNTS as LOOP_TRACES,
                                      fused_hybrid_pagerank,
                                      hybrid_pagerank)
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.update import (TRACE_COUNTS as UPD_TRACES,
                                                apply_batch_packed,
                                                pack_graph)
from repro.serve import IngestQueue, RankStore, ServeEngine

N = 48
_PACK = dict(be=32, vb=16, spill_lanes_per_window=64)
_FLAGS = dict(closed_form=True, prune=True, expand=True, use_kernel=False)


def _stream(seed, steps=6, n=N, m=130):
    rng = np.random.default_rng(seed)
    init = np.unique(rng.integers(0, n, size=(m, 2)), axis=0)
    init = init[init[:, 0] != init[:, 1]]
    g = from_coo(init[:, 0], init[:, 1], n, edge_capacity=len(init) + 256)
    batches = []
    for _ in range(steps):
        dels = rng.integers(0, n, size=(3, 2))
        ins = rng.integers(0, n, size=(6, 2))
        batches.append(make_batch_update(dels[dels[:, 0] != dels[:, 1]],
                                         ins[ins[:, 0] != ins[:, 1]],
                                         8, 16))
    return g, batches


def _assert_packed_equal(a, b):
    import dataclasses
    for name in (f.name for f in dataclasses.fields(a)):
        x, y = getattr(a, name), getattr(b, name)
        if hasattr(x, "shape"):
            assert np.array_equal(np.asarray(x), np.asarray(y)), name
        else:
            assert x == y, name


# ---------------------------------------------------------------------------
# bitwise parity vs the two-program path, across a mixed stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("polish", [True, False])
def test_fused_bitwise_matches_two_program_path(seed, polish):
    g, batches = _stream(seed)
    packed2 = packed1 = pack_graph(g, **_PACK)
    r2 = r1 = pr.static_pagerank(g).ranks

    for i, upd in enumerate(batches):
        g_new = apply_batch(g, upd)
        aff = pr.initial_affected(g, g_new, touched_vertices_mask(upd, N))

        # two programs: maintenance, then the loop
        packed2 = apply_batch_packed(packed2, upd)
        res2 = hybrid_pagerank(g_new, packed2, r2, aff, polish=polish,
                               **_FLAGS)
        # one program: fused maintenance + peeled first sweep + loop
        packed1, res1 = fused_hybrid_pagerank(g_new, packed1, upd, r1, aff,
                                              polish=polish, **_FLAGS)

        _assert_packed_equal(packed1, packed2)
        assert np.array_equal(np.asarray(res1.ranks),
                              np.asarray(res2.ranks)), i    # bitwise
        assert int(res1.iterations) == int(res2.iterations)
        assert int(res1.edges_processed) == int(res2.edges_processed)
        assert int(res1.vertices_processed) == int(res2.vertices_processed)
        assert np.array_equal(np.asarray(res1.affected_ever),
                              np.asarray(res2.affected_ever))
        g, r1, r2 = g_new, res1.ranks, res2.ranks


def test_fused_rerun_after_repack_is_idempotent():
    # overflow recovery re-invokes the SAME fused call on the repacked
    # structure: the update is already applied, so maintenance must
    # degenerate to a no-op and the solve must repeat exactly
    g, batches = _stream(7, steps=1)
    packed = pack_graph(g, **_PACK)
    ranks = pr.static_pagerank(g).ranks
    upd = batches[0]
    g_new = apply_batch(g, upd)
    aff = pr.initial_affected(g, g_new, touched_vertices_mask(upd, N))
    p1, res1 = fused_hybrid_pagerank(g_new, packed, upd, ranks, aff,
                                     **_FLAGS)
    p2, res2 = fused_hybrid_pagerank(g_new, p1, upd, ranks, aff, **_FLAGS)
    _assert_packed_equal(p1, p2)
    assert np.array_equal(np.asarray(res1.ranks), np.asarray(res2.ranks))


# ---------------------------------------------------------------------------
# serve path: one f32 program per micro-batch, compiled once
# ---------------------------------------------------------------------------

def test_serve_step_launches_one_fused_program_per_batch():
    g, batches = _stream(11, steps=8)
    ingest = IngestQueue(flush_size=64, flush_interval=1e9,
                         max_pending=4096)
    eng = ServeEngine(g, ingest, RankStore(), method="frontier",
                      engine="kernel", kernel_opts=dict(**_PACK,
                                                        use_kernel=False))
    eng.bootstrap()

    def one(upd):
        dm, im = np.asarray(upd.del_mask), np.asarray(upd.ins_mask)
        for u, v in zip(np.asarray(upd.del_src)[dm],
                        np.asarray(upd.del_dst)[dm]):
            ingest.submit_delete(int(u), int(v))
        for u, v in zip(np.asarray(upd.ins_src)[im],
                        np.asarray(upd.ins_dst)[im]):
            ingest.submit_insert(int(u), int(v))
        eng.step(force=True)

    one(batches[0])                         # compiles the fused program
    before = {k: LOOP_TRACES[k] for k in ("fused_update_loop",
                                          "kernel_pagerank_loop")}
    upd_before = UPD_TRACES["apply_batch_packed"]
    n0 = len(eng.metrics.batch_device_programs)
    for upd in batches[1:]:
        one(upd)

    # the stream rides the ONE already-compiled fused program: no
    # retrace of it, and the standalone maintenance / loop programs are
    # never even traced on the serving path
    assert LOOP_TRACES["fused_update_loop"] == before["fused_update_loop"]
    assert (LOOP_TRACES["kernel_pagerank_loop"]
            == before["kernel_pagerank_loop"])
    assert UPD_TRACES["apply_batch_packed"] == upd_before

    progs = eng.metrics.batch_device_programs[n0:]
    assert len(progs) == len(batches) - 1
    # one fused f32 program + the f64 polish — never the unfused 3
    assert all(p == 2 for p in progs), progs
    assert eng.metrics.as_dict()["device_programs_per_batch"] == 2.0
