"""Sharded kernel path: compilation invariants and delta-routing
negative paths.

The streaming contract mirrors the single-pod kernel engine
(tests/test_kernel_serving.py): a temporal stream compiles exactly one
delta route, one per-shard update step and one kernel loop — asserted
via ``kernels.pagerank_spmv.shard.TRACE_COUNTS`` over a 50-batch
stream — and overflow recovery (repack at pinned shapes) must not
retrace anything.  Routing overflow is a checked ``ShardCapacityError``
naming the shards, never silent truncation; a batch whose edges all
land on one shard still round-trips exactly.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from jax.sharding import Mesh

from repro.core import pagerank as pr
from repro.dist.pagerank_dist import ShardedKernelEngine
from repro.graph.dynamic import (apply_batch, make_batch_update,
                                 touched_vertices_mask)
from repro.graph.generators import update_stream
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.shard import (ShardCapacityError,
                                               TRACE_COUNTS,
                                               apply_batch_sharded_host,
                                               pack_shards, route_update,
                                               sharded_edge_set)
from repro.serve import IngestQueue, RankStore, ServeEngine, ServeMetrics

N = 48


def _graph(seed=0, n=N, m=150, extra=256):
    rng = np.random.default_rng(seed)
    init = np.unique(rng.integers(0, n, size=(m, 2)), axis=0)
    init = init[init[:, 0] != init[:, 1]]
    return from_coo(init[:, 0], init[:, 1], n,
                    edge_capacity=len(init) + extra)


def _one_shard_mesh():
    return Mesh(np.asarray(jax.devices()[:1]), ("model",))


# ---------------------------------------------------------------------------
# trace counters: 50-batch stream = one route + one update + one loop
# ---------------------------------------------------------------------------

def run_trace_stream(num_shards, num_batches=50, seed=21):
    """Shared by the in-process 1-way test and the 4-way subprocess in
    the differential harness: returns the TRACE_COUNTS delta over
    batches 2..num_batches (must be all zero)."""
    init, n, batches = update_stream(5, 4, regime="mixed",
                                     num_batches=num_batches,
                                     batch_size=12, seed=seed)
    # headroom for the stream's net insertions: the 50 batches must not
    # overflow a spill lane (this test asserts compile counts, the
    # overflow path is test_sharded_repack_fallback_no_retrace)
    cap = len(init) + num_batches * 32 + 64
    g = from_coo(init[:, 0], init[:, 1], n, edge_capacity=cap)
    mesh = Mesh(np.asarray(jax.devices()[:num_shards]), ("model",))
    eng = ShardedKernelEngine(
        mesh, g, pack_kw=dict(be=32, vb=16,
                              spill_lanes_per_window=num_batches * 16))
    ranks = pr.static_pagerank(g).ranks

    def one(dels, ins):
        nonlocal g, ranks
        upd = make_batch_update(dels, ins, 8, 16)
        g_new = apply_batch(g, upd)
        eng.apply_update(upd)
        aff = pr.initial_affected(g, g_new,
                                  touched_vertices_mask(upd, n))
        res = eng.solve(g_new, ranks, aff, closed_form=True, prune=True,
                        expand=True)
        g, ranks = g_new, res.ranks

    one(*batches[0])                       # batch 1 compiles everything
    before = dict(TRACE_COUNTS)
    for dels, ins in batches[1:]:
        one(dels, ins)
    return {k: TRACE_COUNTS[k] - before.get(k, 0)
            for k in ("route_update", "sharded_apply",
                      "sharded_kernel_loop")}


def test_fifty_batch_stream_compiles_once():
    delta = run_trace_stream(1, num_batches=50)
    assert delta == {"route_update": 0, "sharded_apply": 0,
                     "sharded_kernel_loop": 0}, delta


# ---------------------------------------------------------------------------
# repack-fallback keeps pinned shapes: recovery must not retrace
# ---------------------------------------------------------------------------

def test_sharded_repack_fallback_no_retrace():
    # tiny spill headroom + skewed growth (inserts pile into the upper
    # dst windows): lanes overflow, the engine repacks at the pinned
    # ShardSpec — serving stays correct with zero recompilation, and the
    # per-shard rebuild attribution lands in the metrics
    rng = np.random.default_rng(13)
    feed = []
    for _ in range(160):
        if rng.random() < 0.75:
            u, v = int(rng.integers(0, N)), int(rng.integers(32, N))
        else:
            u, v = int(rng.integers(0, N)), int(rng.integers(0, 32))
        if u != v:
            feed.append((u, v, "i" if rng.random() < 0.85 else "d"))

    def serve(engine_name, mesh=None, kernel_opts=None):
        ingest = IngestQueue(flush_size=16, flush_interval=0.0)
        store = RankStore()
        metrics = ServeMetrics()
        eng = ServeEngine(_graph(2, m=300), ingest, store,
                          metrics=metrics, method="frontier_prune",
                          engine=engine_name, mesh=mesh,
                          kernel_opts=kernel_opts,
                          static_fallback_frac=1.0)
        eng.bootstrap()
        for u, v, kind in feed:
            (ingest.submit_insert if kind == "i"
             else ingest.submit_delete)(u, v)
            eng.step()
        eng.drain()
        return store.snapshot(), metrics

    snap_x, _ = serve("xla")
    before = dict(TRACE_COUNTS)
    snap_s, m = serve("kernel", mesh=_one_shard_mesh(),
                      kernel_opts=dict(use_kernel=False, be=8, vb=16,
                                       spill_lanes_per_window=8))
    after = dict(TRACE_COUNTS)
    assert m.packed_rebuilds >= 1
    assert m.packed_rebuilds_by_shard.get(0, 0) >= 1
    linf = float(jnp.max(jnp.abs(snap_s.ranks - snap_x.ranks)))
    assert linf <= 1e-6, linf
    # pinned shapes/statics: at most the one initial trace per function,
    # overflow recovery must not retrace
    for k, v in after.items():
        assert v - before.get(k, 0) <= 1, (k, before, after)


# ---------------------------------------------------------------------------
# delta routing negative paths (mesh-free: routing is a pure function)
# ---------------------------------------------------------------------------

def test_route_budget_overflow_is_checked_error():
    g = _graph(0)
    sharded, spec = pack_shards(g, 4, be=16, vb=8,
                                spill_lanes_per_window=16)
    # 6 insertions all landing on shard 0's dst range, budget of 2
    ins = np.asarray([[i, 1] for i in range(2, 8)], np.int32)
    upd = make_batch_update(np.zeros((0, 2), np.int32), ins, 4, 8)
    with pytest.raises(ShardCapacityError,
                       match="per-shard delta budget") as e:
        route_update(upd, spec, ins_budget=2)
    assert e.value.shards == (0,)
    # deletions overflow independently of insertions
    live = sorted(sharded_edge_set(sharded, spec))
    vps = spec.vertices_per_shard
    s0 = [e for e in live if e[1] < vps][:4]
    upd = make_batch_update(np.asarray(s0, np.int32),
                            np.zeros((0, 2), np.int32), 8, 4)
    with pytest.raises(ShardCapacityError, match="delta budget"):
        route_update(upd, spec, del_budget=2)


def test_all_edges_one_shard_roundtrip():
    g = _graph(1)
    sharded, spec = pack_shards(g, 4, be=16, vb=8,
                                spill_lanes_per_window=16)
    vps = spec.vertices_per_shard
    want = sharded_edge_set(sharded, spec)
    # every edge of the batch lands on shard 2: dst in [2*vps, 3*vps)
    lo = 2 * vps
    ins = np.asarray([[u, lo + (u % vps)] for u in range(6)], np.int32)
    ins = ins[ins[:, 0] != ins[:, 1]]
    dels = np.asarray([e for e in sorted(want)
                       if lo <= e[1] < lo + vps][:2], np.int32)
    upd = make_batch_update(dels.reshape(-1, 2), ins, 8, 8)
    routed = route_update(upd, spec)
    kept_per_shard = np.asarray(jnp.sum(routed.ins_mask, axis=1))
    assert kept_per_shard[2] == len(ins) and kept_per_shard.sum() \
        == len(ins), kept_per_shard
    out = apply_batch_sharded_host(sharded, spec, upd)
    want = (want - {tuple(e) for e in dels.reshape(-1, 2).tolist()}) \
        | {tuple(e) for e in ins.tolist()}
    assert sharded_edge_set(out, spec) == want


def test_sharded_pack_requires_spill():
    g = _graph(0)
    with pytest.raises(ValueError, match="spill_lanes_per_window >= 1"):
        pack_shards(g, 2, be=16, vb=8, spill_lanes_per_window=0)


# ---------------------------------------------------------------------------
# public API: one-shot update_pagerank(engine="kernel", mesh=...)
# ---------------------------------------------------------------------------

def test_update_pagerank_sharded_kernel_one_shot():
    from repro.core.api import update_pagerank
    from repro.graph.generators import random_batch_update
    g = _graph(5, m=300)
    r0 = pr.static_pagerank(g).ranks
    live = np.stack([np.asarray(g.src), np.asarray(g.dst)], 1)[
        np.asarray(g.valid)]
    dele, ins = random_batch_update(live, N, 16, seed=6)
    upd = make_batch_update(dele, ins, 32, 32)
    g2 = apply_batch(g, upd)
    xla = update_pagerank(g, g2, upd, r0, "frontier_prune")
    shd = update_pagerank(g, g2, upd, r0, "frontier_prune",
                          mesh=_one_shard_mesh(), engine="kernel",
                          pack_kw=dict(be=32, vb=16))
    linf = float(jnp.max(jnp.abs(xla.ranks - shd.ranks)))
    assert linf <= 1e-6, linf
    assert shd.ranks.dtype == jnp.float64
    assert int(shd.edges_processed) > 0
    assert int(shd.vertices_processed) > 0
    # a single-pod packed= cannot seed the sharded path — rejecting it
    # beats silently discarding the caller's maintained structure
    from repro.kernels.pagerank_spmv.update import pack_graph
    with pytest.raises(ValueError, match="single-pod structure"):
        update_pagerank(g, g2, upd, r0, "frontier_prune",
                        mesh=_one_shard_mesh(), engine="kernel",
                        packed=pack_graph(g2, be=32, vb=16))
