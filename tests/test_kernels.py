"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pagerank as pr
from repro.core.kernel_engine import df_pagerank_kernel
from repro.core.reference import l1_error, static_pagerank_ref
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.generators import (erdos_renyi_edges, random_batch_update,
                                    rmat_edges)
from repro.graph.structure import from_coo
from repro.kernels.pagerank_spmv.ops import gated_contrib, pack_blocks
from repro.kernels.pagerank_spmv.ref import frontier_spmv_ref
from repro.kernels.segment_ops.ops import aggregate_features


def _dense_contrib(edges, n, rsc, awin, vb):
    dense = np.zeros(n, np.float32)
    np.add.at(dense, edges[:, 1], rsc[edges[:, 0]])
    return np.where(np.repeat(awin, vb)[:n], dense, 0)


@pytest.mark.parametrize("be,vb", [(128, 128), (256, 128), (512, 256),
                                   (1024, 512)])
@pytest.mark.parametrize("gen", ["rmat", "er"])
def test_spmv_kernel_shape_sweep(be, vb, gen):
    if gen == "rmat":
        edges, n = rmat_edges(8, 8, seed=be + vb)
    else:
        edges, n = erdos_renyi_edges(500, 4000, seed=be)
    packed = pack_blocks(edges[:, 0], edges[:, 1],
                         np.ones(len(edges), bool), n, be=be, vb=vb)
    rng = np.random.default_rng(be)
    ranks = jnp.asarray(rng.random(n))
    deg = np.zeros(n, np.int64)
    np.add.at(deg, edges[:, 0], 1)
    inv_deg = jnp.asarray(1.0 / (deg + 1))
    for frac in (1.0, 0.25, 0.0):
        aff = jnp.asarray(rng.random(n) < frac)
        out_k = gated_contrib(packed, ranks, inv_deg, aff, use_kernel=True)
        out_r = gated_contrib(packed, ranks, inv_deg, aff, use_kernel=False)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-4, atol=1e-6)
        nw = packed.num_windows
        affp = np.zeros(nw * vb, bool)
        affp[:n] = np.asarray(aff)
        awin = affp.reshape(nw, vb).any(1)
        rsc = np.asarray((ranks * inv_deg).astype(jnp.float32))
        dense = _dense_contrib(edges, n, rsc, awin, vb)
        np.testing.assert_allclose(np.asarray(out_k), dense,
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_kernel_dtype_sweep(dtype):
    edges, n = rmat_edges(7, 8, seed=11)
    packed = pack_blocks(edges[:, 0], edges[:, 1],
                         np.ones(len(edges), bool), n, be=128, vb=128)
    rng = np.random.default_rng(3)
    v_pad = packed.num_windows * packed.vb
    rsc = jnp.asarray(rng.random(v_pad), dtype)
    awin = jnp.ones((packed.num_windows,), bool)
    from repro.kernels.pagerank_spmv.pagerank_spmv import frontier_spmv
    out = frontier_spmv(packed, rsc, awin, interpret=True)
    ref = frontier_spmv_ref(packed.src, packed.dst_rel, packed.valid,
                            packed.window, rsc.astype(jnp.float32), awin,
                            n, packed.vb)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_spmv_empty_graph():
    packed = pack_blocks(np.zeros(0, np.int32), np.zeros(0, np.int32),
                         np.zeros(0, bool), 128, be=128, vb=128)
    out = gated_contrib(packed, jnp.ones(128), jnp.ones(128),
                        jnp.ones(128, bool), use_kernel=True)
    assert float(jnp.max(jnp.abs(out))) == 0.0


@pytest.mark.parametrize("d", [16, 64, 130])
def test_spmm_kernel_feature_dims(d):
    edges, n = rmat_edges(7, 6, seed=d)
    packed = pack_blocks(edges[:, 0], edges[:, 1],
                         np.ones(len(edges), bool), n, be=128, vb=128)
    rng = np.random.default_rng(d)
    feats = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    aff = jnp.asarray(rng.random(n) < 0.5)
    a = aggregate_features(packed, feats, aff, use_kernel=True)
    b = aggregate_features(packed, feats, aff, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_kernel_engine_df_matches_f64_engine():
    """End-to-end: Pallas-path DF fixed point ≈ XLA f64 DF fixed point."""
    edges, n = rmat_edges(8, 8, seed=21)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) * 2)
    res0 = pr.static_pagerank(g)
    dele, ins = random_batch_update(edges, n, 12, seed=22)
    upd = make_batch_update(dele, ins, 32, 32)
    g2 = apply_batch(g, upd)
    sv = np.asarray(g2.src)[np.asarray(g2.valid)]
    dv = np.asarray(g2.dst)[np.asarray(g2.valid)]
    packed = pack_blocks(sv, dv, np.ones(len(sv), bool), n, be=256, vb=128)
    from repro.graph.dynamic import touched_vertices_mask
    touched = touched_vertices_mask(upd, n)
    resk = df_pagerank_kernel(g, g2, packed, touched, res0.ranks,
                              tol=1e-7, frontier_tol=1e-5)
    ref, _ = static_pagerank_ref(sv, dv, n, tol=1e-14)
    assert l1_error(resk.ranks, ref) < 5e-5   # f32 path tolerance
