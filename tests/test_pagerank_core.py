"""Core engine tests: all five approaches vs the NumPy oracle + invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pagerank as pr
from repro.core.api import update_pagerank
from repro.core.reference import (df_pagerank_ref, l1_error,
                                  static_pagerank_ref)
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.generators import random_batch_update, rmat_edges
from repro.graph.structure import from_coo


def _setup(seed=1, scale=8, batch=16):
    edges, n = rmat_edges(scale, 8, seed=seed)
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) * 2)
    res0 = pr.static_pagerank(g)
    dele, ins = random_batch_update(edges, n, batch, seed=seed + 1)
    upd = make_batch_update(dele, ins, max(32, batch * 2),
                            max(32, batch * 2))
    g2 = apply_batch(g, upd)
    sv = np.asarray(g2.src)[np.asarray(g2.valid)]
    dv = np.asarray(g2.dst)[np.asarray(g2.valid)]
    ref, _ = static_pagerank_ref(sv, dv, n, tol=1e-14)
    return g, g2, upd, res0, ref, n, (sv, dv)


def test_static_matches_numpy_oracle(small_graph, small_rmat):
    edges, n = small_rmat
    res = pr.static_pagerank(small_graph)
    ref, it_ref = static_pagerank_ref(edges[:, 0], edges[:, 1], n)
    assert int(res.iterations) == it_ref
    np.testing.assert_allclose(np.asarray(res.ranks), ref, rtol=0, atol=1e-12)


def test_ranks_sum_to_one(small_graph):
    res = pr.static_pagerank(small_graph)
    assert abs(float(jnp.sum(res.ranks)) - 1.0) < 1e-9


@pytest.mark.parametrize("method", ["naive", "traversal", "frontier",
                                    "frontier_prune"])
def test_dynamic_methods_reach_fixed_point(method):
    g, g2, upd, res0, ref, n, _ = _setup()
    res = update_pagerank(g, g2, upd, res0.ranks, method)
    err = l1_error(res.ranks, ref)
    # paper: dynamic-method error stays at/below static-at-τ error scale
    budget = 1e-8 if method != "frontier_prune" else 1e-4
    assert err < budget, f"{method}: L1 {err}"


def test_df_error_below_static_error():
    """Paper claim: DF at τ_f=1e-6 yields LOWER error than Static at τ."""
    g, g2, upd, res0, ref, n, _ = _setup()
    err_st = l1_error(update_pagerank(g, g2, None, None, "static").ranks, ref)
    err_df = l1_error(
        update_pagerank(g, g2, upd, res0.ranks, "frontier").ranks, ref)
    assert err_df <= err_st * 2.0   # small-graph slack; trend holds


def test_dfp_processes_fewer_edges_than_df():
    g, g2, upd, res0, *_ = _setup()
    df = update_pagerank(g, g2, upd, res0.ranks, "frontier")
    dfp = update_pagerank(g, g2, upd, res0.ranks, "frontier_prune")
    assert int(dfp.edges_processed) < int(df.edges_processed)


def test_df_affected_subset_of_dt_reachable():
    """DF's ever-affected set can never exceed DT's reachable set (+seeds)."""
    g, g2, upd, res0, *_ = _setup()
    df = update_pagerank(g, g2, upd, res0.ranks, "frontier")
    dt = update_pagerank(g, g2, upd, res0.ranks, "traversal")
    df_set = np.asarray(df.affected_ever)
    dt_set = np.asarray(dt.affected_ever)
    assert not np.any(df_set & ~dt_set)


def test_df_matches_async_oracle_fixed_point():
    g, g2, upd, res0, ref, n, (sv, dv) = _setup()
    edges_prev_s = np.asarray(g.src)[np.asarray(g.valid)]
    edges_prev_d = np.asarray(g.dst)[np.asarray(g.valid)]
    touched = np.zeros(n, bool)
    tm = np.asarray(upd.del_src)[np.asarray(upd.del_mask)]
    ti = np.asarray(upd.ins_src)[np.asarray(upd.ins_mask)]
    touched[np.unique(np.concatenate([tm, ti]))] = True
    r_ref, _, _ = df_pagerank_ref(edges_prev_s, edges_prev_d, sv, dv, n,
                                  np.asarray(res0.ranks), touched)
    df = update_pagerank(g, g2, upd, res0.ranks, "frontier")
    # schedules differ (Jacobi vs async) — fixed points must agree
    assert l1_error(df.ranks, r_ref) < 1e-7


def test_no_update_is_noop():
    """Empty batch -> initial frontier empty -> 0 iterations of real work."""
    g, g2, upd, res0, *_ = _setup()
    empty = make_batch_update(np.zeros((0, 2)), np.zeros((0, 2)), 8, 8)
    res = update_pagerank(g, g, empty, res0.ranks, "frontier")
    assert l1_error(res.ranks, res0.ranks) < 1e-12
    assert int(jnp.sum(res.affected_ever)) == 0


def test_deletion_only_and_insertion_only():
    g, g2, upd, res0, ref, n, _ = _setup()
    edges = np.stack([np.asarray(g.src)[np.asarray(g.valid)],
                      np.asarray(g.dst)[np.asarray(g.valid)]], 1)
    for dele, ins in [(edges[:5], np.zeros((0, 2))),
                      (np.zeros((0, 2)), np.array([[1, 7], [3, 9]]))]:
        u = make_batch_update(dele, ins, 16, 16)
        gb = apply_batch(g, u)
        sv = np.asarray(gb.src)[np.asarray(gb.valid)]
        dv = np.asarray(gb.dst)[np.asarray(gb.valid)]
        refb, _ = static_pagerank_ref(sv, dv, n, tol=1e-14)
        res = update_pagerank(g, gb, u, res0.ranks, "frontier")
        assert l1_error(res.ranks, refb) < 1e-8


def test_closed_form_equals_recursive_fixed_point(small_graph):
    """Paper Eq.2: closed-form update has the same fixed point as Eq.1."""
    res_a = pr._pagerank_loop(
        small_graph, jnp.full((small_graph.num_vertices,),
                              1.0 / small_graph.num_vertices),
        jnp.ones((small_graph.num_vertices,), bool), closed_form=False)
    res_b = pr._pagerank_loop(
        small_graph, jnp.full((small_graph.num_vertices,),
                              1.0 / small_graph.num_vertices),
        jnp.ones((small_graph.num_vertices,), bool), closed_form=True)
    # both converged to L∞ ≤ τ=1e-10; L1 may accumulate ~|V|·τ
    assert l1_error(res_a.ranks, res_b.ranks) < 1e-7
    # closed form converges in FEWER iterations (self-loop series resolved)
    assert int(res_b.iterations) <= int(res_a.iterations)
