"""Regression: pin exactly which endpoints seed the DF/DF-P frontier.

The paper (§3/§4.1, Alg.1 lines 4-6) seeds the initial marking from the
**source endpoint u** of every edge (u, v) in Δ — for insertions AND
deletions — because only u's out-degree changes, so only u's outgoing
contributions R[u]/d_u are perturbed; v is then reached as a member of
out(u).  ``touched_vertices_mask``'s docstring promises exactly that
("u-endpoints of every edge in Δ"); this pins the behaviour to a
hand-computed example so a refactor can't silently flip it to both
endpoints (over-marking: correct but paper-unfaithful work inflation)
or to destinations (under-marking: WRONG ranks).
"""
import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core.pagerank import initial_affected
from repro.graph.dynamic import apply_batch, make_batch_update, \
    touched_vertices_mask
from repro.graph.structure import from_coo

# hand example: the chain 0→1→2→3→4 with an isolated vertex 5.
#   Δ⁻ = {(1, 2)}   (deletion)      Δ⁺ = {(4, 5)}   (insertion)
V = 6


def _setup():
    e = np.array([[0, 1], [1, 2], [2, 3], [3, 4]], np.int32)
    g = from_coo(e[:, 0], e[:, 1], V, edge_capacity=16)
    upd = make_batch_update(np.array([[1, 2]], np.int32),
                            np.array([[4, 5]], np.int32), 8, 8)
    return g, apply_batch(g, upd), upd


def test_touched_mask_is_source_endpoints_only():
    _, _, upd = _setup()
    got = np.asarray(touched_vertices_mask(upd, V))
    #                     0      1      2      3      4      5
    want = np.array([False,  True, False, False,  True, False])
    np.testing.assert_array_equal(got, want)


def test_touched_mask_deletion_seeds_deleted_source():
    """A pure deletion batch seeds u (=1), not the lost target v (=2)."""
    upd = make_batch_update(np.array([[1, 2]], np.int32),
                            np.zeros((0, 2), np.int32), 8, 8)
    got = np.asarray(touched_vertices_mask(upd, V))
    want = np.array([False,  True, False, False, False, False])
    np.testing.assert_array_equal(got, want)


def test_touched_mask_insertion_seeds_inserting_source():
    """A pure insertion batch seeds u (=4), not the new target v (=5)."""
    upd = make_batch_update(np.zeros((0, 2), np.int32),
                            np.array([[4, 5]], np.int32), 8, 8)
    got = np.asarray(touched_vertices_mask(upd, V))
    want = np.array([False, False, False, False,  True, False])
    np.testing.assert_array_equal(got, want)


def test_initial_affected_hand_computed():
    """Alg.1 lines 4-6 on the chain example:

    seeds {1, 4} expand to their out-neighbours in Gᵗ⁻¹ ∪ Gᵗ:
    out(1) = {2} (Gᵗ⁻¹; gone in Gᵗ), out(4) = {5} (Gᵗ only), plus the
    seeds themselves (every vertex's implicit self-loop puts u ∈ out(u),
    and u's own rank depends on its changed out-degree).
    """
    g_prev, g_new, upd = _setup()
    touched = touched_vertices_mask(upd, V)
    got = np.asarray(initial_affected(g_prev, g_new, touched))
    #                     0      1      2      3      4      5
    want = np.array([False,  True,  True, False,  True,  True])
    np.testing.assert_array_equal(got, want)


def test_ranks_converge_from_pinned_seeds():
    """End check: DF-P from exactly these seeds reproduces the static
    fixed point of Gᵗ — i.e. the pinned seed set is *sufficient*."""
    from repro.core.api import update_pagerank
    from repro.core.reference import l1_error

    g_prev, g_new, upd = _setup()
    prev = update_pagerank(g_prev, g_prev, None, None, "static").ranks
    res = update_pagerank(g_prev, g_new, upd, prev, "frontier_prune")
    ref = update_pagerank(g_new, g_new, None, None, "static")
    assert l1_error(res.ranks, ref.ranks) <= 1e-8
    affected = np.asarray(res.affected_ever)
    assert affected[1] and affected[4]            # seeds were processed
    assert not affected[0]                        # upstream never marked
