"""Low-precision collective primitive: int8_psum (subprocess, 8 devices)."""
import os
import subprocess
import sys
import textwrap


def test_int8_psum_bound_and_wire_dtype():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, re
        import repro
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import int8_psum
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (8, 4096)), jnp.float32)
        smq = jax.jit(jax.shard_map(lambda v: int8_psum(v[0], "pod"),
                      mesh=mesh, in_specs=(P("pod", None),), out_specs=P(),
                      check_vma=False))
        smf = jax.jit(jax.shard_map(lambda v: jax.lax.psum(v[0], "pod"),
                      mesh=mesh, in_specs=(P("pod", None),), out_specs=P(),
                      check_vma=False))
        err = float(jnp.max(jnp.abs(smq(x) - smf(x))))
        bound = 8 * float(jnp.max(jnp.abs(x))) / 127 / 2 * 1.01
        assert err <= bound, (err, bound)
        hlo = smq.lower(x).compile().as_text()
        assert any(re.search(r"= s16\\[.*all-reduce", l)
                   for l in hlo.splitlines()), "no s16 all-reduce"
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=repo, timeout=420)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
