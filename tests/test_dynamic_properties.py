"""Property-style tests for graph.dynamic.apply_batch against a host-side
set-of-edges oracle (no hypothesis dependency — seeded numpy generators).

Each trial replays a chain of interleaved insert/delete batches that
deliberately include duplicate inserts (within a batch and of live edges)
and deletes of absent edges; after every batch the device graph's
``valid``/``num_edges`` must realise exactly (E \\ del) | ins as a set.
"""
import numpy as np
import pytest

from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.structure import from_coo

N = 32


def _edge_set(g):
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    return set(zip(src[valid].tolist(), dst[valid].tolist()))


def _random_edges(rng, k):
    e = rng.integers(0, N, size=(k, 2))
    return e[e[:, 0] != e[:, 1]]          # self-loops are implicit


@pytest.mark.parametrize("seed", range(8))
def test_apply_batch_chain_matches_set_oracle(seed):
    rng = np.random.default_rng(seed)
    init = np.unique(_random_edges(rng, 40), axis=0)
    g = from_coo(init[:, 0], init[:, 1], N, edge_capacity=len(init) + 64)
    oracle = set(map(tuple, init.tolist()))

    for step in range(6):
        live = np.asarray(sorted(oracle), np.int32).reshape(-1, 2)
        n_del = int(rng.integers(0, 5))
        dels = []
        if len(live) and n_del:
            picks = rng.choice(len(live), size=min(n_del, len(live)),
                               replace=False)
            dels.extend(map(tuple, live[picks].tolist()))
        # deletes of absent edges must be no-ops
        dels.extend(map(tuple, _random_edges(rng, 2).tolist()))
        ins = list(map(tuple, _random_edges(rng, 6).tolist()))
        # duplicate inserts: repeat within the batch and re-insert live edges
        if ins:
            ins.append(ins[0])
        if len(live):
            ins.append(tuple(live[int(rng.integers(len(live)))].tolist()))

        dels_a = np.asarray(dels, np.int32).reshape(-1, 2)
        ins_a = np.asarray(ins, np.int32).reshape(-1, 2)
        upd = make_batch_update(dels_a, ins_a, max(8, len(dels_a)),
                                max(8, len(ins_a)))
        g = apply_batch(g, upd)
        oracle = (oracle - set(dels)) | set(ins)

        got = _edge_set(g)
        assert got == oracle, (step, got ^ oracle)
        assert int(np.asarray(g.num_edges)) == len(oracle)
        assert int(np.asarray(g.valid).sum()) == len(oracle)


def test_apply_batch_duplicate_insert_within_batch_claims_one_slot():
    g = from_coo(np.array([0]), np.array([1]), N, edge_capacity=8)
    upd = make_batch_update(np.zeros((0, 2), np.int32),
                            np.array([[2, 3], [2, 3], [2, 3]], np.int32),
                            4, 4)
    g2 = apply_batch(g, upd)
    assert _edge_set(g2) == {(0, 1), (2, 3)}
    assert int(np.asarray(g2.num_edges)) == 2


def test_apply_batch_delete_then_reinsert_reuses_capacity():
    e = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    g = from_coo(e[:, 0], e[:, 1], N, edge_capacity=4)  # only 1 free slot
    for _ in range(5):                     # would overflow without slot reuse
        g = apply_batch(g, make_batch_update(
            np.array([[1, 2]], np.int32), np.zeros((0, 2), np.int32), 4, 4))
        g = apply_batch(g, make_batch_update(
            np.zeros((0, 2), np.int32), np.array([[1, 2]], np.int32), 4, 4))
    assert _edge_set(g) == {(0, 1), (1, 2), (2, 3)}
    assert int(np.asarray(g.num_edges)) == 3
