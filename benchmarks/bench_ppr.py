"""PPR walk-index benchmark: query latency vs the exact DF-P solve, and
per-micro-batch walk repair vs a full index rebuild.

Default shape is the acceptance scenario: a ~100k-vertex (2^17) RMAT
graph at paper-scale R.  Query seeds are drawn from the population the
index actually serves — seeds whose effective sample deg·R clears the
``mode="auto"`` routing floor (thin/cold seeds route to the exact
solver in production, so they are not part of the index-latency claim).

Emitted rows (µs per call + derived):

    ppr/build_index    one-off full build; derived = R/L/MB
    ppr/query_index    index-backed personalized top-10, median seed
    ppr/query_exact    the same queries via the exact DF-P solve;
                       derived = speedup and tie-tolerant precision@10
                       of the index answers against this oracle
    ppr/repair         walk repair for one coalesced micro-batch;
                       derived = walks resampled (== stale count —
                       the resample-count invariant is asserted),
                       full-rebuild µs and the repair speedup
    ppr/repair_shardS  the same micro-batch repaired on an S-way
                       range-sharded index (ppr/shard.py); the
                       repaired shards must unshard bitwise to the
                       single-device repair.  The companion
                       ``_modeled`` row carries the critical-path
                       scaling ratio total_stale / max_per_shard_stale
                       — stale-mass balance is a pure function of the
                       (seeded) graph and batch, so the ratio is
                       hardware-stable and safe for the nightly
                       regression gate (wall-clock on forced host
                       devices is not).  S is clipped to the visible
                       device count; on CPU set
                       XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.extensions import personalized_pagerank
from repro.graph.dynamic import apply_batch, make_batch_update, \
    touched_vertices_mask
from benchmarks.common import cached_rmat
from repro.graph.generators import random_batch_update
from repro.graph.structure import from_coo
from repro.ppr import (DEFAULT_MIN_EFFECTIVE_WALKS, IndexConfig,
                       build_walk_index, ppr_top_k, precision_at_k,
                       repair_walk_index, repair_walk_index_sharded,
                       shard_stale_counts, shard_walk_index, stale_walks,
                       unshard_walk_index)


def _timed(fn, repeats=3):
    out = fn()
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(scale=17, edge_factor=8, num_walks=64, max_len=16, num_queries=4,
        batch_size=256, topk=10, seed=0, shard_counts=(2, 4, 8)):
    edges, n = cached_rmat(scale, edge_factor, seed=1)
    graph = from_coo(edges[:, 0], edges[:, 1], n,
                     edge_capacity=int(len(edges) * 1.2))
    cfg = IndexConfig(num_walks=num_walks, max_len=max_len, seed=seed)

    t0 = time.perf_counter()
    index = build_walk_index(graph, cfg)
    jax.block_until_ready(index.steps)
    t_build = time.perf_counter() - t0
    emit("ppr/build_index", t_build,
         f"R={num_walks};L={max_len};MB={index.nbytes()/1e6:.0f}")

    # ---- query latency + accuracy vs the exact oracle --------------------
    deg = np.asarray(index.csr.deg)
    min_deg = -(-DEFAULT_MIN_EFFECTIVE_WALKS // num_walks)  # ceil division
    rng = np.random.default_rng(seed)
    seeds = rng.choice(np.flatnonzero(deg >= min_deg), num_queries,
                       replace=False)
    t_idx, t_exact, precisions = [], [], []
    for s in seeds:
        t, (ap_idx, _) = _timed(lambda s=s: ppr_top_k(index, [int(s)], topk))
        t_idx.append(t)
        mask = jnp.zeros((n,), bool).at[int(s)].set(True)
        t, res = _timed(
            lambda m=mask: personalized_pagerank(graph, m), repeats=1)
        t_exact.append(t)
        precisions.append(precision_at_k(np.asarray(ap_idx),
                                         np.asarray(res.ranks), topk))
    q_idx, q_exact = float(np.median(t_idx)), float(np.median(t_exact))
    emit("ppr/query_index", q_idx,
         f"p_at_{topk}={float(np.mean(precisions)):.2f}")
    emit("ppr/query_exact", q_exact,
         f"speedup={q_exact / q_idx:.0f}x;"
         f"p_at_{topk}={float(np.mean(precisions)):.2f}")

    # ---- incremental repair vs full rebuild ------------------------------
    dele, ins = random_batch_update(edges, n, batch_size, seed=seed + 1)
    upd = make_batch_update(dele, ins, max(8, len(dele)), max(8, len(ins)))
    graph2 = apply_batch(graph, upd)
    touched = touched_vertices_mask(upd, n)
    num_stale = int(jnp.sum(stale_walks(index.steps, touched)[0]))

    def do_repair():
        out, resampled = repair_walk_index(index, graph2, touched)
        # the resample-count invariant: ONLY walks intersecting touched
        # vertices are resampled, every one of them exactly once
        assert resampled == num_stale, (resampled, num_stale)
        return out.steps

    t_repair, _ = _timed(do_repair)
    t_rebuild, _ = _timed(lambda: build_walk_index(graph2, cfg).steps,
                          repeats=1)
    emit("ppr/repair", t_repair,
         f"resampled={num_stale}/{n * num_walks};"
         f"rebuild_us={t_rebuild*1e6:.0f};"
         f"speedup={t_rebuild / t_repair:.0f}x")

    # ---- sharded repair scaling ------------------------------------------
    repaired_single, _ = repair_walk_index(index, graph2, touched)
    for s in shard_counts:
        if len(jax.devices()) < s:
            print(f"# skipping ppr/repair_shard{s}: needs {s} devices, "
                  f"{len(jax.devices())} visible")
            continue
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:s]), ("model",))
        sidx = shard_walk_index(index, s, mesh=mesh)
        counts = shard_stale_counts(sidx, touched)
        assert int(counts.sum()) == num_stale, (counts, num_stale)

        def do_sharded(si=sidx):
            out, resampled = repair_walk_index_sharded(si, graph2, touched)
            assert resampled == num_stale, (resampled, num_stale)
            return out.steps

        t_shard, _ = _timed(do_sharded)
        out, _ = repair_walk_index_sharded(sidx, graph2, touched)
        assert bool(jnp.all(
            unshard_walk_index(out).steps == repaired_single.steps)), \
            f"sharded repair (S={s}) diverged from single-device repair"
        peak = int(counts.max())
        ratio = num_stale / max(peak, 1)
        emit(f"ppr/repair_shard{s}", t_shard,
             f"resampled={num_stale};peak_shard={peak};shards={s}")
        emit(f"ppr/repair_shard{s}_modeled", t_shard,
             f"events_per_s_ratio={ratio:.2f};shards={s}")


if __name__ == "__main__":
    run()
