"""Paper Figure 2: frontier-expansion metric sweep (Δr, Δr/d, Δr/r) —
speedup vs Static and rank error for a range of τ_f.

The engine's production metric is Δr/r (the paper's winner); for this
sweep we run a generalised loop supporting all three metrics.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, geomean, reference_ranks, setup_stream,
                               time_fn)
from repro.core import pagerank as pr
from repro.core.api import update_pagerank
from repro.core.reference import l1_error
from repro.data.snap import all_paper_datasets
from repro.graph.dynamic import apply_batch, touched_vertices_mask


@partial(jax.jit, static_argnames=("metric", "max_iter"))
def df_metric_loop(graph, init_ranks, init_affected, *, metric="rel",
                   frontier_tol=1e-6, alpha=0.85, tol=1e-10, max_iter=500):
    """DF loop with selectable expansion metric (paper §4.2)."""
    V = graph.num_vertices
    deg = graph.out_degree(True)
    inv_deg = 1.0 / deg.astype(jnp.float64)
    c0 = (1.0 - alpha) / V

    def body(state):
        ranks, affected, _, it = state
        contrib = pr._contrib(graph, ranks, inv_deg)
        r_new_all = c0 + alpha * (contrib + ranks * inv_deg)
        r_new = jnp.where(affected, r_new_all, ranks)
        dr = jnp.abs(r_new - ranks)
        if metric == "abs":            # Δr
            meas = dr
        elif metric == "contrib":      # Δr/d
            meas = dr * inv_deg
        else:                          # Δr/r (paper optimum)
            meas = dr / jnp.maximum(jnp.maximum(r_new, ranks), 1e-300)
        delta = jnp.max(jnp.where(affected, dr, 0.0))
        big = affected & (meas > frontier_tol)
        affected = affected | graph.push_or(big) | big
        return (r_new, affected, delta, it + 1)

    out = jax.lax.while_loop(
        lambda s: (s[2] > tol) & (s[3] < max_iter), body,
        (init_ranks.astype(jnp.float64), init_affected,
         jnp.asarray(jnp.inf, jnp.float64), jnp.asarray(0, jnp.int32)))
    return out[0], out[3]


def run(batch_frac=1e-3, num_batches=2):
    ds_list = all_paper_datasets()[:2]
    tol_grid = {
        "abs": [1e-10, 1e-12, 1e-14],
        "contrib": [1e-10, 1e-12, 1e-14],
        "rel": [1e-2, 1e-4, 1e-6],
    }
    for metric, tols in tol_grid.items():
        for tf in tols:
            times, errs = [], []
            for ds in ds_list:
                graph, updates, _ = setup_stream(ds, batch_frac, num_batches)
                res0 = update_pagerank(graph, graph, None, None, "static")
                g = graph
                for upd in updates:
                    g2 = apply_batch(g, upd)
                    touched = touched_vertices_mask(upd, ds.num_vertices)
                    aff0 = pr.initial_affected(g, g2, touched)
                    dt, (ranks, its) = time_fn(
                        lambda: df_metric_loop(g2, res0.ranks, aff0,
                                               metric=metric,
                                               frontier_tol=tf),
                        repeats=1)
                    ref = reference_ranks(g2, ds.num_vertices)
                    times.append(dt)
                    errs.append(l1_error(ranks, ref))
                    g = g2
            emit(f"fig2/{metric}/tf_{tf:g}", geomean(times),
                 f"err={geomean(errs):.2e}")


if __name__ == "__main__":
    run()
