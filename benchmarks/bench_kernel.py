"""Kernel-path benchmark: frontier-gated SpMV work-skipping — blocks
DMA'd vs total as the affected fraction shrinks (the TPU analogue of the
paper's 'process only affected vertices')."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import cached_rmat, emit, time_fn
from repro.kernels.pagerank_spmv.ops import gated_contrib, pack_blocks


def run():
    edges, n = cached_rmat(10, 10, seed=7)
    packed = pack_blocks(edges[:, 0], edges[:, 1],
                         np.ones(len(edges), bool), n, be=512, vb=256)
    rng = np.random.default_rng(0)
    ranks = jnp.asarray(rng.random(n))
    deg = np.zeros(n, np.int64)
    np.add.at(deg, edges[:, 0], 1)
    inv = jnp.asarray(1.0 / (deg + 1))
    nw, vb = packed.num_windows, packed.vb
    for kind in ("clustered", "random"):
        for frac in (1.0, 0.25, 0.05, 0.01):
            if kind == "clustered":
                # real-world DF frontiers are clustered (paper §5.2.3) —
                # window gating gets its full win here
                aff_np = np.zeros(n, bool)
                aff_np[: max(1, int(frac * n))] = True
            else:
                # uniformly random frontier = adversarial for gating
                aff_np = rng.random(n) < frac
            aff = jnp.asarray(aff_np)
            affp = np.zeros(nw * vb, bool)
            affp[:n] = aff_np
            active = affp.reshape(nw, vb).any(1)
            entry_active = int(np.asarray(active)[np.asarray(packed.window)]
                               .sum())
            dt, _ = time_fn(lambda: gated_contrib(packed, ranks, inv, aff),
                            repeats=2)
            emit(f"kernel/gated_spmv/{kind}/frac_{frac:g}", dt,
                 f"entries={entry_active}/{packed.num_entries}")

    # incremental PackedGraph maintenance vs full host repack: the
    # serving hot path applies micro-batches on device; a host rebuild
    # is the failure mode it exists to avoid.  Measured on a larger
    # graph than the SpMV sweep — the device update's fixed dispatch
    # cost only amortises once the repack's O(E log E) bites
    from repro.graph.dynamic import make_batch_update
    from repro.graph.structure import from_coo as _from_coo
    from repro.kernels.pagerank_spmv.update import apply_batch_packed, \
        pack_graph
    edges_u, n_u = cached_rmat(14, 8, seed=3)
    gg = _from_coo(edges_u[:, 0], edges_u[:, 1], n_u,
                   edge_capacity=len(edges_u) + 4096)
    pk = pack_graph(gg, be=512, vb=256, spill_lanes_per_window=256)
    dels = edges_u[rng.choice(len(edges_u), size=32, replace=False)]
    ins = np.stack([rng.integers(0, n_u, 64), rng.integers(0, n_u, 64)], 1)
    upd = make_batch_update(dels, ins, 64, 64)
    t_upd, _ = time_fn(apply_batch_packed, pk, upd, check=False)
    t_pack, _ = time_fn(pack_graph, gg, be=512, vb=256,
                        spill_lanes_per_window=256)
    emit("kernel/packed_update/incremental", t_upd,
         f"entries={pk.num_entries};M={pk.max_entries_per_window}")
    emit("kernel/packed_update/rebuild", t_pack, "")
    emit("kernel/packed_update/speedup", 0.0,
         f"rebuild_over_update={t_pack / max(t_upd, 1e-12):.1f}")

    # beyond-paper: window-sequential Gauss-Seidel (async analogue)
    import jax.numpy as _j
    from repro.core.gauss_seidel import gauss_seidel_pagerank
    from repro.core.kernel_engine import kernel_pagerank_loop
    from repro.graph.structure import from_coo
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 8)
    init = _j.full((n,), 1.0 / n, _j.float32)
    gs = gauss_seidel_pagerank(g, packed, init, tol=1e-7)
    jac = kernel_pagerank_loop(g, packed, init, _j.ones((n,), bool),
                               tol=1e-7, closed_form=True, expand=False,
                               use_kernel=False)
    emit("kernel/gauss_seidel_vs_jacobi", 0.0,
         f"sweeps={int(gs.sweeps)};jacobi_iters={int(jac.iterations)}")


if __name__ == "__main__":
    run()
