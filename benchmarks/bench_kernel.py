"""Kernel-path benchmark: frontier-gated SpMV work-skipping — blocks
DMA'd vs total as the affected fraction shrinks (the TPU analogue of the
paper's 'process only affected vertices')."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.graph.generators import rmat_edges
from repro.kernels.pagerank_spmv.ops import gated_contrib, pack_blocks


def run():
    edges, n = rmat_edges(10, 10, seed=7)
    packed = pack_blocks(edges[:, 0], edges[:, 1],
                         np.ones(len(edges), bool), n, be=512, vb=256)
    rng = np.random.default_rng(0)
    ranks = jnp.asarray(rng.random(n))
    deg = np.zeros(n, np.int64)
    np.add.at(deg, edges[:, 0], 1)
    inv = jnp.asarray(1.0 / (deg + 1))
    nw, vb = packed.num_windows, packed.vb
    for kind in ("clustered", "random"):
        for frac in (1.0, 0.25, 0.05, 0.01):
            if kind == "clustered":
                # real-world DF frontiers are clustered (paper §5.2.3) —
                # window gating gets its full win here
                aff_np = np.zeros(n, bool)
                aff_np[: max(1, int(frac * n))] = True
            else:
                # uniformly random frontier = adversarial for gating
                aff_np = rng.random(n) < frac
            aff = jnp.asarray(aff_np)
            affp = np.zeros(nw * vb, bool)
            affp[:n] = aff_np
            active = affp.reshape(nw, vb).any(1)
            entry_active = int(np.asarray(active)[np.asarray(packed.window)]
                               .sum())
            dt, _ = time_fn(lambda: gated_contrib(packed, ranks, inv, aff),
                            repeats=2)
            emit(f"kernel/gated_spmv/{kind}/frac_{frac:g}", dt,
                 f"entries={entry_active}/{packed.num_entries}")

    # beyond-paper: window-sequential Gauss-Seidel (async analogue)
    import jax.numpy as _j
    from repro.core.gauss_seidel import gauss_seidel_pagerank
    from repro.core.kernel_engine import kernel_pagerank_loop
    from repro.graph.structure import from_coo
    g = from_coo(edges[:, 0], edges[:, 1], n, edge_capacity=len(edges) + 8)
    init = _j.full((n,), 1.0 / n, _j.float32)
    gs = gauss_seidel_pagerank(g, packed, init, tol=1e-7)
    jac = kernel_pagerank_loop(g, packed, init, _j.ones((n,), bool),
                               tol=1e-7, closed_form=True, expand=False,
                               use_kernel=False)
    emit("kernel/gauss_seidel_vs_jacobi", 0.0,
         f"sweeps={int(gs.sweeps)};jacobi_iters={int(jac.iterations)}")


if __name__ == "__main__":
    run()
