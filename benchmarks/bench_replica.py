"""Replica-tier benchmark: open-loop Poisson query load under faults.

One ``ServeEngine`` writer feeds N ``ReadReplica``s over a seeded
``FaultyTransport`` that drops and reorders deltas, with a partition
spell on one replica mid-run — the steady-state fault regime the
replication tier is built for (serve/replicate.py).  Query traffic is
**open-loop**: the number of queries arriving at each event offset is
drawn up front from a seeded Poisson (it does not adapt to service
latency, so the tail percentiles are honest), and each query is one of
the three serve classes — point ranks, global top-k, personalized
top-k — drawn from a fixed mix and round-robined across the replicas.

Emitted rows (all registered with ``run.py --json``):

    replica/<class>      p99.9 wall latency per query (the row value);
                         p50/p99, sample count
    replica/staleness    staleness-in-events percentiles (p50/p99/
                         p99.9/max) over answered queries — answers
                         carry staleness as metadata — plus the shed
                         count from degraded replicas
    replica/tier         us per event end-to-end; events/s, deltas
                         applied, gaps/retries/resyncs, transport
                         drop/reorder counters

Shed queries (``ReplicaDegradedError`` while a replica is outside its
staleness SLO with top-k/PPR shed) are *not* latency samples — the tier
answered them instantly with a typed refusal carrying the staleness —
so they are counted separately rather than polluting the percentiles.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.ft.elastic import ReplicaRoster
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.serve import FaultyTransport, IngestQueue, LogicalClock, \
    RankStore, ReadReplica, ReplicaDegradedError, ReplicaQueryClient, \
    ReplicationWriter, ServeEngine, ServeMetrics

# traffic mix: mostly point lookups, some top-k, a little exact PPR
MIX = (("point", 0.6), ("top_k", 0.3), ("ppr", 0.1))


def _pctl(xs, q) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _run_tier(events: int = 480, num_replicas: int = 2, scale: int = 10,
              edge_factor: int = 8, queries_per_event: float = 2.0,
              drop_p: float = 0.05, reorder_p: float = 0.10,
              staleness_slo_events: int = 64, flush_size: int = 16,
              step_every: int = 16, hb_every: int = 8, dt: float = 0.01,
              topk: int = 10, seed: int = 7) -> dict:
    clock = LogicalClock()
    transport = FaultyTransport(seed=seed + 1, drop_p=drop_p,
                                reorder_p=reorder_p, reorder_window=4 * dt)
    edges, n = rmat_edges(scale, edge_factor, seed=seed)
    graph = from_coo(edges[:, 0], edges[:, 1], n,
                     edge_capacity=len(edges) + 4 * events)
    ingest = IngestQueue(flush_size=flush_size, flush_interval=0.0,
                         max_pending=1 << 20, clock=clock)
    engine = ServeEngine(graph, ingest, RankStore(), metrics=ServeMetrics(),
                         method="frontier_prune", clock=clock)
    engine.bootstrap()
    writer = ReplicationWriter(engine, transport, anchor_every=8,
                               clock=clock)
    writer.attach()
    transport.set_writer(writer)
    roster = ReplicaRoster(heartbeat_timeout=64 * dt)
    writer.heartbeat(roster)
    replicas = [ReadReplica(f"r{i}", transport, n, roster=roster,
                            staleness_slo_events=staleness_slo_events,
                            max_retries=3, backoff_base=2 * dt,
                            slo_windows=((2.0, 2.0),), slo_min_events=8,
                            seed=seed, clock=clock)
                for i in range(num_replicas)]
    for r in replicas:
        assert r.bootstrap(), "bootstrap against a healthy writer"
    clients = [ReplicaQueryClient(r) for r in replicas]

    rng = np.random.default_rng(seed)
    # open-loop arrival schedule, fixed before the run starts
    arrivals = rng.poisson(queries_per_event, size=events)
    kinds = rng.choice([k for k, _ in MIX], size=int(arrivals.sum()),
                       p=[p for _, p in MIX])
    # partition one replica for the middle sixth of the feed: the tier
    # keeps serving through it and the healed replica resyncs back
    part_open, part_close = events // 3, events // 3 + events // 6

    lat: dict = {k: [] for k, _ in MIX}
    stale_samples: list = []
    shed = 0
    qi = 0
    t0 = time.perf_counter()
    for i in range(events):
        clock.advance(dt)
        if i == part_open:
            transport.partition(replicas[-1].name)
        elif i == part_close:
            transport.heal(replicas[-1].name)
        u, v = (int(x) for x in rng.integers(0, n, size=2))
        if u != v:
            ingest.submit_insert(u, v)
        if (i + 1) % step_every == 0:
            engine.step(force=True)
        if (i + 1) % hb_every == 0:
            writer.heartbeat(roster)
        for r in replicas:
            r.pump()
        for _ in range(int(arrivals[i])):
            kind = str(kinds[qi])
            client = clients[qi % len(clients)]
            qi += 1
            tq = time.perf_counter()
            try:
                if kind == "point":
                    res = client.get_ranks(rng.integers(0, n, size=4))
                elif kind == "top_k":
                    res = client.top_k(topk)
                else:
                    seeds = [int(x) for x in rng.integers(0, n, size=3)]
                    res = client.personalized_top_k(seeds, topk)
            except ReplicaDegradedError as e:
                shed += 1
                stale_samples.append(e.staleness_events)
                continue
            lat[kind].append(time.perf_counter() - tq)
            stale_samples.append(res.staleness_events)
    engine.drain()
    wall = time.perf_counter() - t0
    for r in replicas:
        r.pump()
    return dict(
        wall=wall, events=events, lat=lat, stale=stale_samples, shed=shed,
        deltas_applied=sum(r.deltas_applied for r in replicas),
        gaps=sum(r.gaps_detected for r in replicas),
        retries=sum(r.retries_sent for r in replicas),
        resyncs=sum(r.resyncs for r in replicas),
        dropped=transport.dropped, reordered=transport.reordered,
        delivered=transport.delivered)


def run(events: int = 480, num_replicas: int = 2,
        queries_per_event: float = 2.0, drop_p: float = 0.05,
        reorder_p: float = 0.10, seed: int = 7):
    # warm pass compiles the step + query paths so the measured run's
    # tail percentiles are steady-state service latency, not jit
    _run_tier(events=64, num_replicas=num_replicas, drop_p=0.0,
              reorder_p=0.0, queries_per_event=queries_per_event,
              seed=seed)
    r = _run_tier(events=events, num_replicas=num_replicas,
                  queries_per_event=queries_per_event, drop_p=drop_p,
                  reorder_p=reorder_p, seed=seed)
    for kind, _ in MIX:
        xs = r["lat"][kind]
        emit(f"replica/{kind}", _pctl(xs, 99.9),
             f"p50_us={_pctl(xs, 50) * 1e6:.1f};"
             f"p99_us={_pctl(xs, 99) * 1e6:.1f};n={len(xs)}")
    st = r["stale"]
    # staleness is measured in events, not seconds: value column is 0
    emit("replica/staleness", 0.0,
         f"p50_ev={_pctl(st, 50):.0f};p99_ev={_pctl(st, 99):.0f};"
         f"p999_ev={_pctl(st, 99.9):.0f};"
         f"max_ev={max(st) if st else 0};shed={r['shed']}")
    emit("replica/tier", r["wall"] / max(1, r["events"]),
         f"events_per_s={r['events'] / r['wall']:.1f};"
         f"replicas={num_replicas};queries={len(st) + r['shed']};"
         f"deltas_applied={r['deltas_applied']};gaps={r['gaps']};"
         f"retries={r['retries']};resyncs={r['resyncs']};"
         f"dropped={r['dropped']};reordered={r['reordered']};"
         f"delivered={r['delivered']}")


if __name__ == "__main__":
    run()
