"""Benchmark driver: one module per paper table/figure (+ serving).
Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` also writes
the full result set as ``{name: {us_per_call, derived}}`` so the perf
trajectory is recorded machine-readably (e.g. BENCH_serving.json).

    PYTHONPATH=src:. python benchmarks/run.py [filter] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on the module table names")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON {name: {us_per_call, "
                         "derived}} to this path")
    args = ap.parse_args(argv)

    t0 = time.time()
    from benchmarks import (bench_affected, bench_dynamic_stream,
                            bench_frontier_tolerance, bench_kernel,
                            bench_ppr, bench_prune_tolerance,
                            bench_random_updates, bench_replica,
                            bench_scaling, bench_serving, common)
    print("name,us_per_call,derived")
    mods = [
        ("fig2_frontier_tolerance", bench_frontier_tolerance),
        ("fig3_prune_tolerance", bench_prune_tolerance),
        ("fig4_dynamic_stream", bench_dynamic_stream),
        ("fig5_affected", bench_affected),
        ("fig6_scaling", bench_scaling),
        ("fig12_random_updates", bench_random_updates),
        ("kernel_gated_spmv", bench_kernel),
        ("bench_serving", bench_serving),
        ("bench_ppr", bench_ppr),
        ("bench_replica", bench_replica),
    ]
    for name, mod in mods:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        mod.run()
    print(f"# total {time.time()-t0:.0f}s")

    if args.json_path:
        out = {r["name"]: dict(us_per_call=r["us_per_call"],
                               derived=r["derived"])
               for r in common.RESULTS}
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"# wrote {len(out)} results to {args.json_path}")


if __name__ == "__main__":
    main()
