"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import (bench_affected, bench_dynamic_stream,
                            bench_frontier_tolerance, bench_kernel,
                            bench_prune_tolerance, bench_random_updates,
                            bench_scaling)
    print("name,us_per_call,derived")
    mods = [
        ("fig2_frontier_tolerance", bench_frontier_tolerance),
        ("fig3_prune_tolerance", bench_prune_tolerance),
        ("fig4_dynamic_stream", bench_dynamic_stream),
        ("fig5_affected", bench_affected),
        ("fig6_scaling", bench_scaling),
        ("fig12_random_updates", bench_random_updates),
        ("kernel_gated_spmv", bench_kernel),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, mod in mods:
        if only and only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        mod.run()
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
