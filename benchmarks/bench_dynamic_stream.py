"""Paper Figure 4 (+7-11): runtime & rank error of Static/ND/DT/DF/DF-P on
real-world-like dynamic graphs over batch sizes 1e-5..1e-3 |E_T|."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (emit, geomean, reference_ranks, setup_stream,
                               time_fn)
from repro.core.api import update_pagerank
from repro.core.reference import l1_error
from repro.data.snap import all_paper_datasets
from repro.graph.dynamic import apply_batch

METHODS = ("static", "naive", "traversal", "frontier", "frontier_prune")


def run(batch_fracs=(1e-4, 1e-3, 1e-2), num_batches=3, datasets=None):
    datasets = datasets or all_paper_datasets()[:3]
    for frac in batch_fracs:
        times = {m: [] for m in METHODS}
        errs = {m: [] for m in METHODS}
        its = {m: [] for m in METHODS}
        work = {m: [] for m in METHODS}
        for ds in datasets:
            graph, updates, _ = setup_stream(ds, frac, num_batches)
            res0 = update_pagerank(graph, graph, None, None, "static")
            prev_ranks = res0.ranks
            g = graph
            for upd in updates:
                g2 = apply_batch(g, upd)
                ref = reference_ranks(g2, ds.num_vertices)
                for m in METHODS:
                    dt, res = time_fn(
                        lambda gm=m: update_pagerank(g, g2, upd, prev_ranks,
                                                     gm),
                        repeats=1)
                    times[m].append(dt)
                    errs[m].append(l1_error(res.ranks, ref))
                    its[m].append(int(res.iterations))
                    work[m].append(max(1, int(res.edges_processed)))
                    if m == "frontier_prune":
                        prev_ranks = res.ranks
                g = g2
        for m in METHODS:
            emit(f"fig4/{m}/batch_{frac:g}", geomean(times[m]),
                 f"err={geomean(errs[m]):.2e};iters={np.mean(its[m]):.0f};"
                 f"edgework={geomean(work[m]):.3g}")
        st = geomean(times["static"])
        sw = geomean(work["static"])
        for m in ("frontier", "frontier_prune"):
            sp = st / geomean(times[m]) if geomean(times[m]) else 0
            emit(f"fig4/speedup_vs_static/{m}/batch_{frac:g}", 0.0,
                 f"wall={sp:.2f}x;work={sw/geomean(work[m]):.2f}x")


if __name__ == "__main__":
    run()
