"""Paper Figure 6 analogue: strong scaling.  Threads don't exist on TPU;
the scaling axis is work partitions — we measure (a) the kernel path's
edges-processed reduction from frontier gating (the work the scaling
serves), and (b) shard_map weak-scaling collective budget from the
dry-run (EXPERIMENTS.md §Roofline covers the 256→512 chip step)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, geomean, setup_stream, time_fn
from repro.core.api import update_pagerank
from repro.data.snap import all_paper_datasets
from repro.graph.dynamic import apply_batch


def run(batch_frac=1e-3, num_batches=2):
    ds_list = all_paper_datasets()[:3]
    for m in ("frontier", "frontier_prune"):
        fracs = []
        for ds in ds_list:
            graph, updates, _ = setup_stream(ds, batch_frac, num_batches)
            res0 = update_pagerank(graph, graph, None, None, "static")
            g = graph
            for upd in updates:
                g2 = apply_batch(g, upd)
                res = update_pagerank(g, g2, upd, res0.ranks, m)
                full = update_pagerank(g, g2, upd, res0.ranks, "naive")
                fracs.append(float(res.edges_processed)
                             / max(1.0, float(full.edges_processed)))
                g = g2
        emit(f"fig6/work_fraction/{m}", 0.0,
             f"{100*geomean(fracs):.2f}% of ND edge work")


if __name__ == "__main__":
    run()
