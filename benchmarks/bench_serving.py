"""Serving benchmark: sustained event throughput + query staleness per
method — the paper's update-cost comparison restated in service units.

For each method the same synthetic temporal feed (one dataset, fixed
event count, fixed flush policy) is driven through the full serve path
(ingest → coalesce → apply_batch → rank update → publish) with a query
burst every ``query_every`` events.  Emitted rows:

    serving/<method>            us per *event* end-to-end, derived =
                                events/s, p99 update latency, p99
                                query staleness (events), mean
                                |affected|, static fallbacks

The 131k-vertex RMAT section (graph via the seeded ``common`` cache,
built once for the whole suite) compares the XLA f64 engine, the kernel
engine (autotuned geometry, fused update+sweep, incremental PackedGraph
maintenance + hybrid-precision ladder) and the **sharded** kernel engine
(window-range shards + routed deltas + boundary-halo exchange over a
``model`` mesh spanning every visible device — force more with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) on the same
stream, emits the events/s deltas per method plus each engine's
``comm_bytes`` / ``device_programs_per_batch`` counters and the tuned
geometry, and times one incremental ``apply_batch_packed`` against a
full host ``pack_blocks`` rebuild — all registered in ``run.py --json``.

Wall-clock on a CPU host does not show the TPU win, so the kernel-vs-XLA
comparison is ALSO emitted **roofline-normalized** (the ``*_modeled``
rows): device seconds modeled from each engine's recorded work counters
via ``roofline.analysis`` — the XLA f64 engine re-streams the full edge
list every iteration with random-access gather/scatter (sector-
inflated, ``dense_spmv_iteration_cost``), the kernel engine streams
only the gated windows' packed f32 lanes at element width plus the
replicated rank block, and its cross-shard halo bytes ride the
interconnect.  The modeled ratio is the number the ≥3x acceptance gate
and the CI regression check read.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, rmat_dataset, time_fn
from repro.data.snap import load_temporal
from repro.obs import timeit
from repro.serve import IngestQueue, QueryClient, RankStore, ServeEngine, \
    ServeMetrics, preload_graph_and_feed

METHODS = ("traversal", "frontier", "frontier_prune")
RMAT_METHODS = ("frontier", "frontier_prune")

# packed lane traffic per gated edge: src id 4B + inv-degree 4B +
# rank 4B, streamed contiguously (no sector inflation)
KERNEL_LANE_BYTES = 12.0


def _modeled_seconds(m, num_edges, num_vertices, engine):
    """Roofline device time for one serve run from its recorded work
    counters (see module docstring; model in roofline.analysis)."""
    from repro.roofline.analysis import (HBM_BW, LINK_BW,
                                         dense_spmv_iteration_cost)
    iters = m["iterations_mean"] * m["batches"]
    if engine == "xla":
        return iters * dense_spmv_iteration_cost(
            num_edges=num_edges, num_vertices=num_vertices)["total_s"]
    # gated path: only DMA'd window entries + gated output windows hit
    # HBM at f32 element width, plus the replicated rank-source block
    # per sweep; halo bytes ride the interconnect (single-pod comm = 0)
    hbm = (m["edges_processed"] * KERNEL_LANE_BYTES
           + m["vertices_processed"] * 4.0
           + iters * num_vertices * 4.0)
    return hbm / HBM_BW + m["comm_bytes"] / LINK_BW


def _mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()), ("model",))


def _serve_once(ds, events, method, flush_size=64, query_every=100,
                topk=10, seed=0, engine="xla", kernel_opts=None,
                mesh=None, monitor=None):
    graph, feed = preload_graph_and_feed(ds, events)
    # short deadline: while the engine is busy, pending events coalesce
    # into full flush_size batches (the adaptive micro-batching regime)
    ingest = IngestQueue(flush_size=flush_size, flush_interval=5e-3,
                         max_pending=max(events, 8 * flush_size))
    store = RankStore()
    engine = ServeEngine(graph, ingest, store, method=method,
                         engine=engine, kernel_opts=kernel_opts,
                         mesh=mesh, monitor=monitor)
    engine.bootstrap()
    rng = np.random.default_rng(seed)
    # warm the compiled step so the timed run measures steady state
    u, v = int(feed[0, 0]), int(feed[0, 1])
    ingest.submit_insert(u, v)
    engine.drain()

    # fresh metrics AFTER warm-up: the reported p50/p99 must be
    # steady-state serving latency, not the one-time compile
    metrics = ServeMetrics()
    engine.metrics = metrics
    client = QueryClient(store, ingest, metrics)

    with timeit() as t:
        for i in range(1, len(feed)):
            ingest.submit_insert(int(feed[i, 0]), int(feed[i, 1]))
            engine.step()
            if (i + 1) % query_every == 0:
                client.get_ranks(rng.integers(0, ds.num_vertices, size=4))
                client.top_k(topk)
        engine.drain()
    return t.seconds, len(feed) - 1, metrics.as_dict(), engine


def run(dataset="sx-mathoverflow", events=600, flush_size=64,
        query_every=100, rmat_events=320, monitor_events=4096):
    ds = load_temporal(dataset)
    for method in METHODS:
        wall, n, m, _ = _serve_once(ds, events, method, flush_size,
                                    query_every)
        emit(f"serving/{method}", wall / max(1, n),
             f"events_per_s={n / wall:.1f};"
             f"p99_update_ms={m['update_latency_p99_ms']:.1f};"
             f"p99_staleness_ev={m['staleness_p99_events']:.0f};"
             f"affected={m['affected_mean']:.0f};"
             f"fallbacks={m['static_fallbacks']}")

    # ---- correctness-monitor overhead (sentinels + recorder on every
    # batch, background shadow verification sampling 1/64) ---------------
    # long enough that the timed window spans many multiples of the
    # shadow period, so the sampled reference solves land inside it and
    # the ratio is an honest steady-state cost, not a lucky miss.  The
    # acceptance bar is <=5% events/s overhead (check_regression gates
    # rows named monitor_overhead at an absolute floor, no baseline
    # needed).
    from repro.obs import CorrectnessMonitor, MonitorConfig
    wall0, n0, _, _ = _serve_once(ds, monitor_events, "frontier_prune",
                                  flush_size, query_every)
    # latency/staleness SLOs are meaningless for a firehose feed on a
    # CPU bench host, so park them out of reach: the incidents count in
    # the row then reflects correctness violations only
    mon = CorrectnessMonitor(MonitorConfig(
        shadow_every=64, latency_slo_ms=1e9, staleness_slo_events=10**9))
    wall1, n1, mm, _ = _serve_once(ds, monitor_events, "frontier_prune",
                                   flush_size, query_every, monitor=mon)
    mon.close()
    rate0, rate1 = n0 / wall0, n1 / wall1
    emit(f"serving/{ds.name}/monitor_overhead", 0.0,
         f"events_per_s_ratio={rate1 / rate0:.3f};shadow_every=64;"
         f"shadow_samples={int(mm.get('shadow_samples', 0))};"
         f"incidents={int(mm.get('incidents_total', 0))};"
         f"events_per_s_plain={rate0:.1f};"
         f"events_per_s_monitored={rate1:.1f}")

    # ---- xla vs kernel vs sharded-kernel, 131k-vertex RMAT stream ------
    rmat = rmat_dataset()
    mesh = _mesh()
    shards = int(mesh.shape["model"])
    graph0, _ = preload_graph_and_feed(rmat, rmat_events)
    num_edges = int(graph0.num_valid_edges()) + rmat_events
    geometry_emitted = False
    for method in RMAT_METHODS:
        rate, modeled = {}, {}
        for eng, m_arg in (("xla", None), ("kernel", None),
                           ("sharded_kernel", mesh)):
            wall, n, m, serve = _serve_once(rmat, rmat_events, method,
                                            flush_size, query_every,
                                            engine=eng.split("_")[-1],
                                            mesh=m_arg)
            rate[eng] = n / wall
            modeled[eng] = n / max(1e-12,
                                   _modeled_seconds(m, num_edges,
                                                    rmat.num_vertices,
                                                    eng))
            extra = f";shards={shards}" if m_arg is not None else ""
            emit(f"serving/{rmat.name}/{method}/{eng}", wall / max(1, n),
                 f"events_per_s={rate[eng]:.1f};"
                 f"p99_update_ms={m['update_latency_p99_ms']:.1f};"
                 f"affected={m['affected_mean']:.0f};"
                 f"rebuilds={m['packed_rebuilds']};"
                 f"progs_per_batch={m['device_programs_per_batch']:.1f};"
                 f"comm_bytes={m['comm_bytes']}{extra}")
            if eng == "kernel" and not geometry_emitted and \
                    serve.kernel_geometry is not None:
                geometry_emitted = True
                info = serve.tune_info
                emit(f"serving/{rmat.name}/tuned_geometry",
                     info.tune_time_s if info else 0.0,
                     serve.kernel_geometry.describe()
                     + (f";source={info.source};key={info.key}" if info
                        else ";source=explicit"))
            if eng == "sharded_kernel":
                sh = serve._sharded
                ci = getattr(sh, "last_comm_info", {}) or {}
                v_pad = sh.spec.padded_vertices
                slots = ci.get("halo_slots", 0)
                emit(f"serving/{rmat.name}/{method}/halo",
                     0.0,
                     f"halo_slots={slots};v_pad={v_pad};"
                     f"slots_over_v={slots / max(1, v_pad):.4f};"
                     f"shards={shards}")
        emit(f"serving/{rmat.name}/{method}/kernel_vs_xla", 0.0,
             f"events_per_s_ratio={rate['kernel'] / rate['xla']:.2f}")
        emit(f"serving/{rmat.name}/{method}/sharded_kernel_vs_xla", 0.0,
             f"events_per_s_ratio="
             f"{rate['sharded_kernel'] / rate['xla']:.2f};shards={shards}")
        # roofline-normalized ratios: the acceptance-gate numbers (the
        # CPU host can't show the TPU memory-hierarchy win in wall time)
        emit(f"serving/{rmat.name}/{method}/kernel_vs_xla_modeled", 0.0,
             f"events_per_s_ratio="
             f"{modeled['kernel'] / modeled['xla']:.2f}")
        emit(f"serving/{rmat.name}/{method}/sharded_kernel_vs_xla_modeled",
             0.0, f"events_per_s_ratio="
             f"{modeled['sharded_kernel'] / modeled['xla']:.2f};"
             f"shards={shards}")

    # ---- incremental PackedGraph update vs full host repack ------------
    from repro.graph.dynamic import make_batch_update
    from repro.kernels.pagerank_spmv.update import apply_batch_packed, \
        pack_graph
    from repro.serve.engine import KERNEL_PACK_DEFAULTS
    graph, feed = preload_graph_and_feed(rmat, rmat_events)
    packed = pack_graph(graph, **KERNEL_PACK_DEFAULTS)
    upd = make_batch_update(np.zeros((0, 2), np.int32),
                            feed[:flush_size], 8, max(8, flush_size))
    t_upd, _ = time_fn(apply_batch_packed, packed, upd, check=False)
    t_pack, _ = time_fn(pack_graph, graph, **KERNEL_PACK_DEFAULTS)
    emit(f"serving/{rmat.name}/pack_update/incremental", t_upd,
         f"entries={packed.num_entries}")
    emit(f"serving/{rmat.name}/pack_update/rebuild", t_pack, "")
    emit(f"serving/{rmat.name}/pack_update/speedup", 0.0,
         f"rebuild_over_update={t_pack / max(t_upd, 1e-12):.1f}")


# span taxonomy the phase-breakdown mode reports (DESIGN.md §11); names
# absent from a run (e.g. kernel-only phases on the xla engine) are
# skipped rather than emitted as zeros
PHASES = ("serve.step", "ingest.coalesce", "route_update", "solve",
          "fused_update_loop", "kernel_loop.f32", "polish.f64",
          "snapshot.publish", "ppr.repair")


def run_traced(dataset="sx-mathoverflow", events=600, flush_size=64,
               trace_path=None, engine="xla"):
    """Phase-breakdown pass: the same serve run with the obs tracer on,
    emitting mean span duration per phase as ``serving/<ds>/phase/<name>``
    rows (+ the batch frontier-telemetry digest), and writing the
    Chrome-trace JSON to ``trace_path`` for the nightly artifact."""
    from repro import obs

    ds = load_temporal(dataset)
    with obs.tracing(trace_path) as tr:
        wall, n, m, _ = _serve_once(ds, events, "frontier_prune",
                                    flush_size, engine=engine)
        for name in PHASES:
            spans = tr.spans(name)
            if not spans:
                continue
            emit(f"serving/{ds.name}/phase/{name}",
                 float(np.mean([s.dur for s in spans])),
                 f"count={len(spans)};"
                 f"total_ms={sum(s.dur for s in spans) * 1e3:.1f}")
    emit(f"serving/{ds.name}/phase/traced_overhead", wall / max(1, n),
         f"events_per_s_traced={n / wall:.1f};"
         f"frontier_batches={m.get('frontier_batches', 0)};"
         f"frontier_iters_mean={m.get('frontier_iterations_mean', 0.0):.1f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="",
                    help="run the traced phase-breakdown pass and write "
                         "the Chrome-trace JSON here (skips the full "
                         "untraced suite)")
    ap.add_argument("--engine", default="xla", choices=["xla", "kernel"])
    a = ap.parse_args()
    if a.trace:
        run_traced(trace_path=a.trace, engine=a.engine)
    else:
        run()
