"""Serving benchmark: sustained event throughput + query staleness per
method — the paper's update-cost comparison restated in service units.

For each method the same synthetic temporal feed (one dataset, fixed
event count, fixed flush policy) is driven through the full serve path
(ingest → coalesce → apply_batch → rank update → publish) with a query
burst every ``query_every`` events.  Emitted rows:

    serving/<method>            us per *event* end-to-end, derived =
                                events/s, p99 update latency, p99
                                query staleness (events), mean
                                |affected|, static fallbacks
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.data.snap import load_temporal
from repro.serve import IngestQueue, QueryClient, RankStore, ServeEngine, \
    ServeMetrics, preload_graph_and_feed

METHODS = ("traversal", "frontier", "frontier_prune")


def _serve_once(ds, events, method, flush_size=64, query_every=100,
                topk=10, seed=0):
    import time

    graph, feed = preload_graph_and_feed(ds, events)
    # short deadline: while the engine is busy, pending events coalesce
    # into full flush_size batches (the adaptive micro-batching regime)
    ingest = IngestQueue(flush_size=flush_size, flush_interval=5e-3,
                         max_pending=max(events, 8 * flush_size))
    store = RankStore()
    engine = ServeEngine(graph, ingest, store, method=method)
    engine.bootstrap()
    rng = np.random.default_rng(seed)
    # warm the compiled step so the timed run measures steady state
    u, v = int(feed[0, 0]), int(feed[0, 1])
    ingest.submit_insert(u, v)
    engine.drain()

    # fresh metrics AFTER warm-up: the reported p50/p99 must be
    # steady-state serving latency, not the one-time compile
    metrics = ServeMetrics()
    engine.metrics = metrics
    client = QueryClient(store, ingest, metrics)

    t0 = time.perf_counter()
    for i in range(1, len(feed)):
        ingest.submit_insert(int(feed[i, 0]), int(feed[i, 1]))
        engine.step()
        if (i + 1) % query_every == 0:
            client.get_ranks(rng.integers(0, ds.num_vertices, size=4))
            client.top_k(topk)
    engine.drain()
    wall = time.perf_counter() - t0
    return wall, len(feed) - 1, metrics.as_dict()


def run(dataset="sx-mathoverflow", events=600, flush_size=64,
        query_every=100):
    ds = load_temporal(dataset)
    for method in METHODS:
        wall, n, m = _serve_once(ds, events, method, flush_size,
                                 query_every)
        emit(f"serving/{method}", wall / max(1, n),
             f"events_per_s={n / wall:.1f};"
             f"p99_update_ms={m['update_latency_p99_ms']:.1f};"
             f"p99_staleness_ev={m['staleness_p99_events']:.0f};"
             f"affected={m['affected_mean']:.0f};"
             f"fallbacks={m['static_fallbacks']}")


if __name__ == "__main__":
    run()
