"""Shared benchmark harness: timing, CSV output, stream setup, and the
seeded RMAT cache every bench draws from (one generation per
(scale, edge_factor, seed) across the whole ``run.py`` suite — the full
run is reproducible run-to-run and no registered bench regenerates a
graph another bench already built)."""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

import repro  # noqa: F401
from repro.core.reference import static_pagerank_ref
from repro.obs import timeit
from repro.graph.dynamic import make_batch_update
from repro.graph.generators import TemporalStream
from repro.graph.structure import from_coo


# seeded generation cache: every bench that wants an RMAT graph (or the
# serving event-stream view of one) goes through here, so `run.py`
# builds each (scale, edge_factor, seed) exactly once per suite run and
# identical seeds always reproduce identical graphs
_RMAT_CACHE: dict = {}


def cached_rmat(scale: int, edge_factor: int, seed: int):
    """(edges (m,2) int, n) — memoized ``rmat_edges``.  Callers must not
    mutate the returned array."""
    from repro.graph.generators import rmat_edges
    key = (scale, edge_factor, seed)
    if key not in _RMAT_CACHE:
        _RMAT_CACHE[key] = rmat_edges(scale, edge_factor, seed=seed)
    return _RMAT_CACHE[key]


def rmat_dataset(scale: int = 17, edge_factor: int = 4, seed: int = 7):
    """131k-vertex (scale 17) R-MAT power-law digraph as an arrival-order
    event stream (deduplicated, shuffled) — the shared serving workload.
    Memoized like ``cached_rmat`` (the dedup+shuffle at scale 17 is the
    expensive part the serving benches would otherwise redo per engine).
    """
    from repro.data.snap import TemporalDataset
    key = ("dataset", scale, edge_factor, seed)
    if key not in _RMAT_CACHE:
        edges, n = cached_rmat(scale, edge_factor, seed)
        edges = np.unique(edges, axis=0)
        edges = edges[edges[:, 0] != edges[:, 1]]
        rng = np.random.default_rng(seed)
        edges = edges[rng.permutation(len(edges))]
        _RMAT_CACHE[key] = TemporalDataset(f"rmat{n}",
                                           edges.astype(np.int32), n, True)
    return _RMAT_CACHE[key]


def time_fn(fn: Callable, *args, repeats: int = 3, **kw) -> tuple:
    """(min_seconds, last_result) with jit warmup + block_until_ready."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(repeats):
        with timeit() as t:
            out = fn(*args, **kw)
            jax.block_until_ready(out)
        best = min(best, t.seconds)
    return best, out


# every emit() is also recorded here so run.py --json can write the full
# result set machine-readably (perf-trajectory tracking across PRs)
RESULTS: list = []


def emit(name: str, seconds: float, derived: str = ""):
    RESULTS.append(dict(name=name, us_per_call=seconds * 1e6,
                        derived=derived))
    print(f"{name},{seconds*1e6:.1f},{derived}")


def reference_ranks(graph, n):
    sv = np.asarray(graph.src)[np.asarray(graph.valid)]
    dv = np.asarray(graph.dst)[np.asarray(graph.valid)]
    ref, _ = static_pagerank_ref(sv, dv, n, tol=1e-14)
    return ref


def setup_stream(dataset, batch_frac: float, num_batches: int = 10):
    """Build G⁰ (90% preload) + list of padded insertion batches
    (paper §5.1.4: load 90%, replay B-edge batches)."""
    stream = TemporalStream(dataset.edges, dataset.num_vertices, batch_frac,
                            num_batches)
    pre = stream.preload_edges()
    cap_extra = stream.batch_size * stream.num_batches + 64
    graph = from_coo(pre[:, 0], pre[:, 1], dataset.num_vertices,
                     edge_capacity=len(pre) + cap_extra)
    ins_cap = max(64, stream.batch_size)
    updates = [make_batch_update(np.zeros((0, 2)), stream.batch(i), 8,
                                 ins_cap)
               for i in range(stream.num_batches)]
    return graph, updates, stream


def geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=np.float64)
    if len(xs) == 0:
        return 0.0
    return float(np.exp(np.mean(np.log(xs))))
