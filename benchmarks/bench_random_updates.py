"""Paper Figures 12/13: large static graphs + uniformly random batch
updates (80% ins / 20% del), batch sizes 1e-7..1e-1 |E| — runtime + error.

Graph classes mirror Table 2: web-like (RMAT power-law), social (BA),
road (ER low degree) — CPU-scaled.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (cached_rmat, emit, geomean,
                               reference_ranks, time_fn)
from repro.core.api import update_pagerank
from repro.core.reference import l1_error
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.generators import (barabasi_albert_edges, erdos_renyi_edges,
                                    grid_edges, random_batch_update)
from repro.graph.structure import from_coo

METHODS = ("static", "naive", "traversal", "frontier", "frontier_prune")


def graphs():
    # sized so edge work dominates dispatch (≥100k edges each);
    # grid = the high-diameter road-network class where the paper sees
    # its biggest frontier wins
    e1, n1 = cached_rmat(14, 12, seed=3)       # web-like power law
    e2, n2 = barabasi_albert_edges(15_000, 8, seed=4)     # social
    e3, n3 = grid_edges(260)                  # road-like lattice
    return [("web_rmat", e1, n1), ("social_ba", e2, n2),
            ("road_grid", e3, n3)]


def run(batch_fracs=(1e-4, 1e-3, 1e-2)):
    gs = graphs()
    for frac in batch_fracs:
        times = {m: [] for m in METHODS}
        errs = {m: [] for m in METHODS}
        work = {m: [] for m in METHODS}
        for name, edges, n in gs:
            bsz = max(2, int(frac * len(edges)))
            g = from_coo(edges[:, 0], edges[:, 1], n,
                         edge_capacity=len(edges) + 2 * bsz + 64)
            res0 = update_pagerank(g, g, None, None, "static")
            dele, ins = random_batch_update(edges, n, bsz, seed=9)
            upd = make_batch_update(dele, ins, max(8, len(dele) + 4),
                                    max(8, len(ins) + 4))
            g2 = apply_batch(g, upd)
            ref = reference_ranks(g2, n)
            for m in METHODS:
                dt, res = time_fn(
                    lambda mm=m: update_pagerank(g, g2, upd, res0.ranks,
                                                 mm), repeats=1)
                times[m].append(dt)
                errs[m].append(l1_error(res.ranks, ref))
                work[m].append(max(1, int(res.edges_processed)))
        for m in METHODS:
            emit(f"fig12/{m}/batch_{frac:g}", geomean(times[m]),
                 f"err={geomean(errs[m]):.2e};edgework={geomean(work[m]):.3g}")
        st = geomean(times["static"])
        sw = geomean(work["static"])
        for m in ("naive", "traversal", "frontier", "frontier_prune"):
            emit(f"fig12/speedup/{m}/batch_{frac:g}", 0.0,
                 f"wall={st/geomean(times[m]):.2f}x;"
                 f"work={sw/geomean(work[m]):.2f}x")


if __name__ == "__main__":
    run()
