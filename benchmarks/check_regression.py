"""Bench regression gate: compare a fresh ``run.py --json`` result set
against the last committed ``BENCH_*.json`` and fail (exit 1) if any
kernel-vs-XLA events/s ratio fell more than ``--tolerance`` (default
10%) below its committed value.

Only the ``*_modeled`` ratio rows gate by default — they are
roofline-normalized from the engines' work counters, so they are stable
across host hardware (the wall-clock ratios on a shared CI runner are
not).  ``--all-ratios`` widens the gate to every ``events_per_s_ratio``
row for local use; ``--filter SUBSTR`` restricts the gate to rows whose
name contains SUBSTR (so e.g. the nightly serving run gates
``serving/`` rows and a separate bench_ppr run gates ``ppr/`` rows,
each against the same committed baseline).

    PYTHONPATH=src:. python benchmarks/run.py --json /tmp/bench.json
    python benchmarks/check_regression.py /tmp/bench.json

The baseline is auto-discovered as the lexicographically newest
``BENCH_*.json`` in the repo root (the dated filenames sort by date), or
passed explicitly with ``--baseline``.

``monitor_overhead`` rows gate differently: they are an *absolute*
floor, not a baseline delta.  The serving acceptance bar is that the
correctness monitor (sentinels + flight recorder every batch, shadow
verification 1/64) costs at most ~5% events/s, so any
``events_per_s_ratio`` in a row whose name contains
``monitor_overhead`` must stay above ``--monitor-floor`` (default
0.95) — no committed baseline required.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

_RATIO = re.compile(r"events_per_s_ratio=([0-9.]+)")


def ratio_rows(results: dict, modeled_only: bool = True) -> dict:
    """{name: ratio} for every row whose derived carries a ratio."""
    out = {}
    for name, row in results.items():
        if modeled_only and not name.endswith("_modeled"):
            continue
        m = _RATIO.search(row.get("derived", "") or "")
        if m:
            out[name] = float(m.group(1))
    return out


def latest_baseline(repo_root: str) -> str | None:
    paths = sorted(glob.glob(os.path.join(repo_root, "BENCH_*.json")))
    return paths[-1] if paths else None


def check_monitor_floor(current_path: str, floor: float) -> int:
    """Gate monitor_overhead ratio rows at an absolute floor."""
    with open(current_path) as f:
        rows = ratio_rows(json.load(f), modeled_only=False)
    rows = {n: r for n, r in rows.items() if "monitor_overhead" in n}
    if not rows:
        return 0
    failures = []
    for name, cur in sorted(rows.items()):
        status = "FAIL" if cur < floor else "ok"
        print(f"{status}  {name}: {cur:.3f} vs absolute floor {floor:.2f}")
        if cur < floor:
            failures.append(f"{name}: {cur:.3f} < {floor:.2f} "
                            f"(monitor overhead above budget)")
    if failures:
        print(f"\n{len(failures)} monitor-overhead floor violation(s):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"all {len(rows)} monitor_overhead ratio(s) above "
          f"{floor:.2f} floor")
    return 0


def check(current_path: str, baseline_path: str, tolerance: float,
          modeled_only: bool = True, name_filter: str = "") -> int:
    with open(current_path) as f:
        current = ratio_rows(json.load(f), modeled_only)
    with open(baseline_path) as f:
        baseline = ratio_rows(json.load(f), modeled_only)
    if name_filter:
        current = {n: r for n, r in current.items() if name_filter in n}
        baseline = {n: r for n, r in baseline.items() if name_filter in n}
    if not baseline:
        print(f"no ratio rows in baseline {baseline_path}"
              + (f" matching filter {name_filter!r}" if name_filter else "")
              + "; nothing to gate")
        return 0
    failures = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run "
                            f"(baseline {base:.2f})")
            continue
        floor = base * (1.0 - tolerance)
        status = "FAIL" if cur < floor else "ok"
        print(f"{status}  {name}: {cur:.2f} vs baseline {base:.2f} "
              f"(floor {floor:.2f})")
        if cur < floor:
            failures.append(f"{name}: {cur:.2f} < {floor:.2f} "
                            f"({base:.2f} - {tolerance:.0%})")
    if failures:
        print(f"\n{len(failures)} ratio regression(s) beyond "
              f"{tolerance:.0%}:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"\nall {len(baseline)} gated ratios within {tolerance:.0%} "
          "of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh run.py --json output")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_*.json (default: newest in "
                         "the repo root)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional drop below baseline")
    ap.add_argument("--all-ratios", action="store_true",
                    help="gate wall-clock ratios too, not just modeled")
    ap.add_argument("--monitor-floor", type=float, default=0.95,
                    help="absolute events_per_s_ratio floor for "
                         "monitor_overhead rows (<=5%% overhead budget)")
    ap.add_argument("--filter", default="",
                    help="only gate rows whose name contains this "
                         "substring (applied to baseline AND current, so "
                         "separate benchmark runs — e.g. serving/ vs "
                         "ppr/ — can gate against one committed baseline "
                         "without tripping missing-row failures)")
    args = ap.parse_args(argv)
    rc = check_monitor_floor(args.current, args.monitor_floor)
    baseline = args.baseline or latest_baseline(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if baseline is None:
        print("no committed BENCH_*.json baseline found; nothing to gate")
        return rc
    print(f"baseline: {baseline}")
    return check(args.current, baseline, args.tolerance,
                 modeled_only=not args.all_ratios,
                 name_filter=args.filter) or rc


if __name__ == "__main__":
    sys.exit(main())
