"""Bench regression gate: compare a fresh ``run.py --json`` result set
against the last committed ``BENCH_*.json`` and fail (exit 1) if any
kernel-vs-XLA events/s ratio fell more than ``--tolerance`` (default
10%) below its committed value.

Only the ``*_modeled`` ratio rows gate by default — they are
roofline-normalized from the engines' work counters, so they are stable
across host hardware (the wall-clock ratios on a shared CI runner are
not).  ``--all-ratios`` widens the gate to every ``events_per_s_ratio``
row for local use.

    PYTHONPATH=src:. python benchmarks/run.py --json /tmp/bench.json
    python benchmarks/check_regression.py /tmp/bench.json

The baseline is auto-discovered as the lexicographically newest
``BENCH_*.json`` in the repo root (the dated filenames sort by date), or
passed explicitly with ``--baseline``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

_RATIO = re.compile(r"events_per_s_ratio=([0-9.]+)")


def ratio_rows(results: dict, modeled_only: bool = True) -> dict:
    """{name: ratio} for every row whose derived carries a ratio."""
    out = {}
    for name, row in results.items():
        if modeled_only and not name.endswith("_modeled"):
            continue
        m = _RATIO.search(row.get("derived", "") or "")
        if m:
            out[name] = float(m.group(1))
    return out


def latest_baseline(repo_root: str) -> str | None:
    paths = sorted(glob.glob(os.path.join(repo_root, "BENCH_*.json")))
    return paths[-1] if paths else None


def check(current_path: str, baseline_path: str, tolerance: float,
          modeled_only: bool = True) -> int:
    with open(current_path) as f:
        current = ratio_rows(json.load(f), modeled_only)
    with open(baseline_path) as f:
        baseline = ratio_rows(json.load(f), modeled_only)
    if not baseline:
        print(f"no ratio rows in baseline {baseline_path}; nothing to gate")
        return 0
    failures = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run "
                            f"(baseline {base:.2f})")
            continue
        floor = base * (1.0 - tolerance)
        status = "FAIL" if cur < floor else "ok"
        print(f"{status}  {name}: {cur:.2f} vs baseline {base:.2f} "
              f"(floor {floor:.2f})")
        if cur < floor:
            failures.append(f"{name}: {cur:.2f} < {floor:.2f} "
                            f"({base:.2f} - {tolerance:.0%})")
    if failures:
        print(f"\n{len(failures)} ratio regression(s) beyond "
              f"{tolerance:.0%}:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"\nall {len(baseline)} gated ratios within {tolerance:.0%} "
          "of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh run.py --json output")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_*.json (default: newest in "
                         "the repo root)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional drop below baseline")
    ap.add_argument("--all-ratios", action="store_true",
                    help="gate wall-clock ratios too, not just modeled")
    args = ap.parse_args(argv)
    baseline = args.baseline or latest_baseline(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if baseline is None:
        print("no committed BENCH_*.json baseline found; nothing to gate")
        return 0
    print(f"baseline: {baseline}")
    return check(args.current, baseline, args.tolerance,
                 modeled_only=not args.all_ratios)


if __name__ == "__main__":
    sys.exit(main())
