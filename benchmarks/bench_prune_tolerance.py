"""Paper Figure 3: prune-tolerance τ_p sweep for DF-P at τ_f ∈
{1e-6, 1e-7, 1e-8} (Δr/r expansion)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (emit, geomean, reference_ranks, setup_stream,
                               time_fn)
from repro.core import pagerank as pr
from repro.core.api import update_pagerank
from repro.core.reference import l1_error
from repro.data.snap import all_paper_datasets
from repro.graph.dynamic import apply_batch, touched_vertices_mask


def run(batch_frac=1e-3, num_batches=2):
    ds_list = all_paper_datasets()[:2]
    for tf in (1e-6, 1e-7, 1e-8):
        for ratio in (1.0, 1e-2, 1e-4):
            tp = tf * ratio
            times, errs = [], []
            for ds in ds_list:
                graph, updates, _ = setup_stream(ds, batch_frac, num_batches)
                res0 = update_pagerank(graph, graph, None, None, "static")
                g = graph
                for upd in updates:
                    g2 = apply_batch(g, upd)
                    dt, res = time_fn(
                        lambda: update_pagerank(
                            g, g2, upd, res0.ranks, "frontier_prune",
                            frontier_tol=tf, prune_tol=tp),
                        repeats=1)
                    ref = reference_ranks(g2, ds.num_vertices)
                    times.append(dt)
                    errs.append(l1_error(res.ranks, ref))
                    g = g2
            emit(f"fig3/tf_{tf:g}/tp_{tp:g}", geomean(times),
                 f"err={geomean(errs):.2e}")


if __name__ == "__main__":
    run()
