"""Paper Figure 5: % of vertices ever marked affected — DT vs DF vs DF-P."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, geomean, setup_stream
from repro.core.api import update_pagerank
from repro.data.snap import all_paper_datasets
from repro.graph.dynamic import apply_batch


def run(batch_fracs=(1e-4, 1e-3, 1e-2), num_batches=2):
    ds_list = all_paper_datasets()[:3]
    for frac in batch_fracs:
        pct = {m: [] for m in ("traversal", "frontier", "frontier_prune")}
        for ds in ds_list:
            graph, updates, _ = setup_stream(ds, frac, num_batches)
            res0 = update_pagerank(graph, graph, None, None, "static")
            g = graph
            for upd in updates:
                g2 = apply_batch(g, upd)
                for m in pct:
                    res = update_pagerank(g, g2, upd, res0.ranks, m)
                    pct[m].append(100.0 * float(jnp.sum(res.affected_ever))
                                  / ds.num_vertices)
                g = g2
        for m, vals in pct.items():
            emit(f"fig5/{m}/batch_{frac:g}", 0.0,
                 f"affected={np.mean(vals):.2f}%")


if __name__ == "__main__":
    run()
