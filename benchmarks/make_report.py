"""Assemble EXPERIMENTS.md from dry-run results (baseline + optimized).

    PYTHONPATH=src python -m benchmarks.make_report
"""
from __future__ import annotations

import json
import os

from repro.roofline.analysis import (CHIPS, RooflineRow, build_table,
                                     to_markdown)

HEADER = """# EXPERIMENTS — DF\\* PageRank framework

All numbers in this file are produced by code in this repository:
dry-runs by ``repro.launch.dryrun`` (512 forced host devices), roofline
terms by ``repro.roofline.analysis``, paper-validation rows by
``python -m benchmarks.run`` (see bench_output.txt).  ``results/`` holds
the BASELINE sweep (paper-faithful first implementation), ``results_opt/``
the beyond-paper optimised sweep — §Perf documents every change between
them.

## §Method

* **Dry-run**: every (arch × shape × mesh) cell is
  ``jax.jit(step).lower(...).compile()`` against ShapeDtypeStructs on the
  production mesh (16×16 single-pod; 2×16×16 multi-pod), CPU host
  devices.  ``memory_analysis()`` proves per-device footprint;
  ``cost_analysis()`` + an HLO collective parser give roofline terms.
* **Counting-mode**: XLA counts while/scan bodies ONCE, so LM cells are
  *additionally* lowered unrolled at L=1 and L=2 and extrapolated
  (cost(L)=cost(1)+(L−1)·Δ — exact for homogeneous stacks; gemma3's
  local/global layers share one HLO because the window is a traced
  scalar).  GNN/recsys models are Python-unrolled already; the PageRank
  while_loop is intentionally counted per-iteration.
* **Hardware constants** (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
  50 GB/s/link ICI.  cost_analysis FLOPs/bytes are per-device-program, so
  terms are computed without dividing by chip count.
* **CPU-lowering caveat**: XLA:CPU legalises bf16 arithmetic to f32;
  byte-based terms are ≤2× upper bounds for bf16 tensors.  Both sweeps
  share the pipeline, so §Perf deltas are unaffected.
* **Skipped cells**: long_500k for the four pure full-attention archs
  (assignment rule); gemma3-12b (5:1 local:global hybrid) runs it.
"""

PAPER_VALIDATION = """
## §Paper-validation (paper's own claims, CPU-scaled)

From ``bench_output.txt`` (synthetic stand-ins sized to CPU; |E_T|/|V|
ratios preserved; trends are the claim — absolute speedups need the
paper's 64-core machine / our TPU target):

| paper claim | our measurement | verdict |
|---|---|---|
| DF/DF-P error stays below Static-at-τ error (Fig 2/4b) | quickstart + fig4: DF L1 ≈ ND/DT L1 < Static L1 (e.g. 1.06e-9 vs 6.76e-9); DF-P higher (≈1e-6) but bounded, exactly the paper's DF-P trade-off | ✓ |
| Δr/r at τ_f=1e-6 is the best frontier metric (Fig 2) | fig2 sweep: Δr/r best speedup-at-equal-error among {Δr, Δr/d, Δr/r} | ✓ |
| τ_p = τ_f optimal for DF-P (Fig 3) | fig3 sweep: error degrades for τ_p ≫ τ_f with no further work win | ✓ |
| DF/DF-P mark fewer vertices than DT at small batches, comparable at large (Fig 5) | fig5: DF 47% vs DT 78% at 1e-4|E_T|; converging at 1e-2 | ✓ |
| DF-P ≫ DF ≫ Static work reduction on small batches (Fig 4) | fig4: DF-P **16.6×** edge-work reduction at 1e-4|E_T| on real-world-like streams (DF 1.44×); fig12 random: DF-P 6.85×, DF 1.66× | ✓ |
| DT ≤ ND on random updates (reachability saturates) (§5.2.2) | fig12: DT edge-work ≈ ND on all random-update graphs | ✓ |
| road/k-mer graphs (low degree, high diameter) benefit most (Fig 12) | grid lattice shows the largest DF gains (5.6× ad-hoc probe) vs power-law (≈1×) | ✓ |
| speedup decays as batch grows (Fig 4a) | fig4: DF-P work ratio 16.6× → 5.65× → 3.49× from 1e-4 to 1e-2 |E_T| | ✓ |
| async ordering converges in fewer sweeps (paper §4.4 impl) | block-Gauss-Seidel (beyond-paper, deterministic): 32 vs 39 Jacobi sweeps at equal τ | ✓ |

Wall-clock on XLA-CPU does not reproduce the paper's ratios for DF
(dense-masked execution pays O(E) per iteration regardless of the
frontier + ~2× op count for frontier bookkeeping); the *work* metrics —
which the frontier-gated TPU kernel turns into time (bench kernel rows:
DMA'd entries scale with active windows) — do.  DF-P's closed form shows
up even in CPU wall-clock (iterations 86→25 in quickstart).
"""


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dominant_summary(rows):
    from collections import Counter
    c = Counter(r.dominant for r in rows if r.status == "OK")
    return ", ".join(f"{k}: {v}" for k, v in c.most_common())


def dryrun_section(results_dir, title):
    lines = [f"\n## §Dry-run — {title}\n"]
    for mesh in ("single", "multi"):
        path = os.path.join(results_dir, f"dryrun_{mesh}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            records = json.load(f)
        ok = [r for r in records if r["status"] == "OK"]
        sk = [r for r in records if r["status"] == "SKIP"]
        fail = [r for r in records if r["status"] == "FAIL"]
        lines.append(
            f"**mesh {mesh}** ({CHIPS[mesh]} chips): {len(ok)} OK, "
            f"{len(sk)} SKIP, {len(fail)} FAIL\n")
        lines.append("| arch | shape | peak GiB/dev | HLO flops/dev | "
                     "coll GiB/dev | collective op counts |")
        lines.append("|---|---|---|---|---|---|")
        for r in ok:
            cc = r.get("collectives_counting") or r["collectives"]
            counts = (r["collectives"].get("op_counts") or {})
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in counts.items() if v)
            flops = (r.get("cost_counting") or r["cost"]).get("flops", 0)
            lines.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{_fmt_bytes(r['memory'].get('peak_per_device_bytes', 0))}"
                f" | {flops:.3g} | {_fmt_bytes(cc.get('total', 0))} | "
                f"{cstr} |")
        for r in sk:
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | "
                         f"{r.get('skip_reason', '')[:70]} |")
        for r in fail:
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | "
                         f"{r.get('error', '')[:70]} |")
    return "\n".join(lines)


PERF = """
## §Perf — hypothesis → change → measure log

Baseline = first paper-faithful implementation (``results/``); optimised
= ``results_opt/``.  Three cells were hillclimbed per the brief — most
collective-bound (**qwen3-moe-30b-a3b/train_4k**), worst roofline
fraction & memory (**graphcast/ogb_products**, with arctic-480b's memory
chain as supporting iterations), most representative of the paper
(**df-pagerank/web_sk2005**) — plus the cross-cutting sharding fixes that
the baselines exposed.  All single-pod numbers, per device.

| # | cell | hypothesis (napkin math) | change | before → after | verdict |
|---|---|---|---|---|---|
| 1 | qwen2.5-3b/train_4k | loss backward all-gathers full-batch logits because embed's D dim is FSDP-sharded; ≈300 GiB/step | embed P('model', None); one-hot gold-logit contraction instead of take_along_axis over sharded vocab | coll 3.82 TiB → 0.50 TiB; peak 773→253 GiB (8-dev probe) | **confirmed** (7.6×) |
| 2 | all LM train | GSPMD satisfies FSDP-dim contractions by all-reducing activations (O(B·S·F)) instead of all-gathering weights (O(D·F)); predicted ~1000× per-matmul collective ratio | MaxText-style activation sharding constraints (dist/constraints.py) in attention/FFN/MoE/loss | included in #1's measurement; HLO shows weight all-gathers replacing activation all-reduces | **confirmed** |
| 3 | arctic-480b/train_4k | [T·k,E] one-hot cumsum for MoE dispatch rank is a ~1 TiB temp | sort-based ranking, O(T·k) | peak 299 → 65 GiB | **confirmed** |
| 4 | arctic-480b/train_4k | optimizer m+v at f32 cannot fit 480B×256 dev; bf16 moments + factored (Adafactor) v + bf16 grad-accum save ~12 GiB | MOMENT_DTYPE/FACTORED_V/ACCUM_DTYPE | 65 → 57 → 41 GiB (with #5) | **confirmed** |
| 5 | arctic-480b/train_4k | scan-of-scans attention bwd materialises full S×S probabilities (≈12 GiB) | jax.checkpoint on both chunk-scan bodies | 41.8 → 40.5 GiB only | **partially refuted** — XLA liveness already reused most of it |
| 6 | qwen3-moe/train_4k | global cross-shard sort in dispatch drives the 48 GiB collectives | shard-local ranking (per-shard capacity) | coll 48.8 → 48.8 GiB | **refuted** — sort was already local under GSPMD |
| 7 | qwen3-moe/train_4k | attribution (top-collective dump): ``buf.at[slot].set(x[tok])`` scatter materialises u32[T·k, D] index operand → 64 GiB all-gather ×2/layer | inverse-permutation dispatch: scatter int32 token ids ([E·C]·4 B), gather rows | peak 67.4 → **14.9 GiB (fits)**; counting-coll 4841 → 1341 GiB; prefill_32k 134.9 → 13.8 GiB / 3590 → 546 GiB; arctic train 40.5 → 29.2 GiB / 4871 → 2544 GiB | **confirmed** (3.6-9.8×) |
| 8 | LM prefill cells | serving needs only last-position logits; full [B,S,V] projection ≈640 GB global at 32k×152k vocab | prefill projects x[:, -1] only | part of #7's prefill before/after | **confirmed** |
| 9 | GNN ogb_products | divisibility guard in sharding rules silently REPLICATED all odd-sized node/edge arrays (2,449,029 % 512 ≠ 0) → whole graph per device | allow uneven sharding (XLA pads); pad graph buffers to 512-multiples; sharding constraints on gathers/segment-sums/MLPs | graphcast 4221 → 80 GiB; nequip 742 → 24; pna 496 → 24; graphsage 38 → 4.3 | **confirmed** (53×) |
| 10 | graphcast/ogb_products | bwd saves 16 rounds of edge messages; per-round remat should cut memory ~16× for +33% flops | jax.checkpoint per processor round | 80 → 100 GiB, coll +33% | **refuted & reverted** — recompute repeats the hm all-gathers; XLA already freed the messages |
| 11 | df-pagerank/web_sk2005 | per-iteration V·4B rank all-gather dominates (433 MiB/iter of 459); ranks only change in affected windows ⇒ re-broadcast changed windows only (exactness invariant), bit-pack expansion flags | frontier-compressed collective schedule (persistent gathered buffer + CAP-bounded window refresh + packed flags) | in-loop coll 265.8 → 54.5 MiB/iter (4.9×), frontier-proportional from there; peak 0.44 → 0.41 GiB; flops/iter 1.5e8 → 3.1e8 (pack/scatter overhead, compute term stays 1e-4× of collective) | **confirmed** (flagship — the paper's insight applied to the collective layer) |

| 12 | qwen2.5-3b/train_4k (multi) | int8 quantise→dequantise around grads should cut the pod-axis all-reduce 4× | `grad_compression='int8'` in train_step | coll 4.18 GiB → 4.18 GiB (unchanged) | **refuted as a pjit hook** — XLA keeps the all-reduce on f32.  Follow-up delivered: `dist/collectives.int8_psum`, a shard_map primitive whose all-reduce genuinely runs on an s16 payload (verified in HLO) with provable error bound ≤ shards·scale/2 (tests/test_collectives.py) — 2× wire bytes today, 4× with an int8-safe reduction tree.  Wiring it under the pjit train step requires shard_map-ing the gradient sync (future work) |

Stopping rule: after #11, remaining ideas on the three target cells
(sequence-parallel reduce-scatter for TP, dst-aligned GNN edge
partitioning, bf16 GNN features) were napkin-mathed below the 5%-of-
dominant-term threshold or require the next engineering block
(documented in DESIGN.md as future work); three consecutive <5% changes
were observed on arctic memory (#5 and two unlogged remat policy
variations), closing that chain.

### Final baseline → optimised deltas (single-pod sweep, per device)

| cell | peak GiB | counting-collective GiB/step |
|---|---|---|
| qwen3-moe-30b-a3b/train_4k | 67.4 → **14.9** (4.5×, fits) | 4841 → 1341 (3.6×) |
| qwen3-moe-30b-a3b/prefill_32k | 134.9 → **13.8** (9.8×, fits) | 3590 → 546 (6.6×) |
| arctic-480b/train_4k | 299 (pre-sweep) → 40.5 → **29.2** | 4871 → 2544 (1.9×) |
| graphcast/ogb_products | 4221.6 → **79.8** (53×) | 0 (replicated!) → 114.5 (real dist.) |
| df-pagerank/web_sk2005 | 0.44 → 0.41 | in-loop 265.8 → 54.5 MiB/iter (4.9×, frontier-proportional) |
| gemma3-12b/train_4k | 19.4 → 19.4 (untouched control) | 339 → 339 |

TPU-projection note: remaining arctic/gemma/graphcast overshoots are
dominated by XLA:CPU's f32 copies of bf16 weights/caches (attributed via
buffer dump — e.g. arctic decode_32k: 14.4 GiB temp of which ≥9 GiB are
legalisation copies that do not exist on TPU; projected ≈7 GiB, fits).

### Kernel-level work-skipping (single-pod perf path)

``bench_kernel`` rows (gated SpMV, interpret-mode timing, DMA-entry
counts are the TPU-meaningful metric): with a clustered frontier (the
paper's real-world case) DMA'd entries drop 19 → 9 of 19 as the affected
fraction shrinks to one window — the surviving 9 are the RMAT hub
window's edge share (power-law in-degree concentrates edges exactly
where frontiers live; on the road-grid class the active share is
proportional to the frontier).  A uniformly random frontier is the
documented adversarial case (every window stays hot at ≥5% density,
entries 19 → 17 only at 1%).

### Beyond-paper features shipped alongside the hillclimb

* **block-Gauss-Seidel sweeps** (core/gauss_seidel.py): the paper's
  asynchronous-convergence advantage, deterministic at window
  granularity over the dst-sorted PackedGraph — fewer sweeps than Jacobi
  at equal tolerance (bench row kernel/gauss_seidel_vs_jacobi), same
  fixed point, and the schedule maps onto the Pallas grid on hardware.
* **personalised + weighted PageRank** (core/extensions.py): the DF-P
  frontier is teleport/weight-agnostic, so incremental PPR and weighted
  PR on dynamic graphs reuse the whole engine (tests/test_extensions.py:
  incremental PPR matches from-scratch PPR while touching a fraction of
  the graph).
* **extra pool GNNs** (models/gnn_extra.py): GCN, GIN, GAT
  (SDDMM + segment-softmax) on the shared substrate.
"""


def main():
    parts = [HEADER, PAPER_VALIDATION]
    parts.append(dryrun_section("results", "baseline (paper-faithful)"))
    if os.path.exists("results_opt/dryrun_single.json"):
        parts.append(dryrun_section("results_opt", "optimised"))

    parts.append("\n## §Roofline — baseline (all 40 cells × 2 meshes)\n")
    rows = build_table("results")
    parts.append(to_markdown(rows))
    parts.append(f"\ndominant-term census: {dominant_summary(rows)}\n")
    if os.path.exists("results_opt/dryrun_single.json"):
        parts.append("\n## §Roofline — optimised\n")
        rows_o = build_table("results_opt")
        parts.append(to_markdown(rows_o))
        parts.append(
            f"\ndominant-term census: {dominant_summary(rows_o)}\n")
        parts.append(
            "\nReading the census shift: the optimised sweep has MORE\n"
            "collective-dominant cells than the baseline because the GNN\n"
            "big-graph cells moved from 'replicated, zero collectives,\n"
            "memory-catastrophic' to genuinely distributed — their memory\n"
            "collapsed 20-50× and honest gather traffic appeared.  No cell\n"
            "is compute-dominant on this CPU-lowered accounting: bf16\n"
            "legalisation doubles the byte terms and the counting-mode\n"
            "lowering omits remat, so dense-LM train cells (MODEL/HLO ≈\n"
            "0.78-0.84) sit just under the memory roof; on real TPU\n"
            "several would cross into compute-bound.  The per-cell\n"
            "roofline fraction (compute/max term) is the §Perf score —\n"
            "best optimised cells: arctic train 0.187, gemma3 train 0.148\n"
            "/ prefill 0.143, arctic prefill 0.126, glm4 train 0.115 of\n"
            "the bf16 peak on this conservative accounting (≈2× higher\n"
            "TPU-projected after halving the legalised byte terms, i.e.\n"
            "≈0.23-0.37 for the top cells).\n")
    parts.append(PERF)
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("EXPERIMENTS.md written,",
          sum(len(p) for p in parts), "chars")


if __name__ == "__main__":
    main()
