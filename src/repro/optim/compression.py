"""Gradient compression hooks (distributed-optimization, DESIGN.md §4).

MEASURED LIMITATION (EXPERIMENTS.md §Perf #12): wrapping gradients in
quantise→dequantise under pjit does NOT shrink the collective — XLA keeps
the all-reduce on the f32 values (4.18 GiB with and without, qwen2.5
multi-pod).  Actually moving the pod-axis reduction to int8 requires the
reduction to be explicit (shard_map over 'pod': quantise → psum int32
accumulation of int8 payloads → dequantise, with error feedback) — the
correct next implementation, kept out of the pjit train path here.  The
``bf16``/``int8`` modes therefore serve as *numerics* experiments
(gradient precision ablation), not bandwidth savings, and are documented
as such.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale


def compress_tree(grads, mode: str):
    """Simulate the compressed collective: quantise→dequantise the pytree.

    Under pjit the surrounding psum then carries the quantised values;
    XLA folds the cast into the collective when profitable.
    """
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)
    if mode == "int8":
        def qdq(g):
            q, s = quantize_int8(g.astype(jnp.float32))
            return dequantize_int8(q, s).astype(g.dtype)
        return jax.tree_util.tree_map(qdq, grads)
    raise ValueError(f"unknown compression mode {mode!r}")
