"""AdamW with configurable moment dtype (bf16 moments = the memory-scaling
trick that keeps arctic-480b's optimizer state inside 512×16GB HBM; see
DESIGN.md §4) and decoupled weight decay.  Pure pytree implementation —
optimizer state inherits the parameter PartitionSpec, i.e. ZeRO-style
sharding falls out of the param sharding rules for free."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object      # pytree like params
    v: object      # per-leaf: array, or (v_row, v_col) when factored


_FACTOR_MIN_SIZE = 1 << 20


def _is_factored(p, factored: bool) -> bool:
    return factored and p.ndim >= 2 and p.size >= _FACTOR_MIN_SIZE


def init_adamw(params, moment_dtype=jnp.float32,
               factored: bool = False) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)

    def vinit(p):
        if _is_factored(p, factored):
            # Adafactor row/col second moment: O(n+m) instead of O(nm) —
            # the trick that fits arctic-480b's optimizer inside 256×16GB
            return (jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return zeros(p)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(vinit, params))


def adamw_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, factored: bool = False):
    """Returns (new_params, new_state).  ``lr`` may be a schedule value."""
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        mhat = m_new / b1c
        if _is_factored(p, factored):
            vr, vc = v
            g2 = jnp.square(g32) + 1e-30
            vr_new = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
            vc_new = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
            vhat = (vr_new[..., :, None] * vc_new[..., None, :]
                    / jnp.maximum(
                        jnp.mean(vr_new, axis=-1, keepdims=True)[..., None],
                        1e-30)) / b2c
            v_out = (vr_new, vc_new)
        else:
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            vhat = v_new / b2c
            v_out = v_new.astype(v.dtype)
        delta = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype), v_out)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)
