"""DeepFM [arXiv:1703.04247] — sparse embeddings + FM + deep MLP.

JAX has no ``nn.EmbeddingBag`` — implemented here as gather
(``jnp.take``) + ``jax.ops.segment_sum`` (kernel_taxonomy §B.6), which IS
part of the system.  The per-field tables are stored as ONE
[total_rows, dim] array with per-field row offsets so the table shards
row-wise over the mesh 'model' axis.

Shapes served:
  * train_batch / serve_p99 / serve_bulk — pointwise scoring, batch B;
  * retrieval_cand — one query against 10⁶ candidate item embeddings as a
    single batched matmul (no loop).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 100_000
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    n_dense: int = 0

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.vocab_per_field

    def param_count(self) -> int:
        n = self.total_rows * (self.embed_dim + 1)
        d_in = self.n_sparse * self.embed_dim + self.n_dense
        dims = (d_in,) + self.mlp_dims + (1,)
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


class DeepFMParams(NamedTuple):
    table: jax.Array       # [total_rows, embed_dim]  factor embeddings
    table_w: jax.Array     # [total_rows, 1]          first-order weights
    mlp_ws: Tuple[jax.Array, ...]
    mlp_bs: Tuple[jax.Array, ...]
    bias: jax.Array


def init_deepfm(cfg: DeepFMConfig, key) -> DeepFMParams:
    key, kt, kw = jax.random.split(key, 3)
    d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    dims = (d_in,) + cfg.mlp_dims + (1,)
    ws, bs = [], []
    for a, b in zip(dims[:-1], dims[1:]):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (a, b), jnp.float32) * a ** -0.5)
        bs.append(jnp.zeros((b,), jnp.float32))
    return DeepFMParams(
        table=jax.random.normal(kt, (cfg.total_rows, cfg.embed_dim),
                                jnp.float32) * 0.01,
        table_w=jax.random.normal(kw, (cfg.total_rows, 1),
                                  jnp.float32) * 0.01,
        mlp_ws=tuple(ws), mlp_bs=tuple(bs),
        bias=jnp.zeros((), jnp.float32))


def embedding_bag(table: jax.Array, ids: jax.Array, bags: jax.Array,
                  n_bags: int, mode: str = "sum",
                  weights: jax.Array | None = None) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: gather + segment-reduce.

    ids: int32[NNZ] row ids; bags: int32[NNZ] bag assignment (sorted or
    not); returns [n_bags, dim].
    """
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, bags, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bags,
                                  num_segments=n_bags)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    elif mode == "max":
        out = jax.ops.segment_max(rows, bags, num_segments=n_bags)
    return out


def _field_ids(cfg: DeepFMConfig, sparse_ids: jax.Array) -> jax.Array:
    """[B, n_sparse] per-field local ids -> global row ids."""
    offs = jnp.arange(cfg.n_sparse, dtype=jnp.int32) * cfg.vocab_per_field
    return sparse_ids + offs[None, :]


def deepfm_forward(cfg: DeepFMConfig, params: DeepFMParams,
                   sparse_ids: jax.Array) -> jax.Array:
    """sparse_ids: int32[B, n_sparse] -> logits f32[B]."""
    b = sparse_ids.shape[0]
    rows = _field_ids(cfg, sparse_ids)                    # [B, F]
    emb = jnp.take(params.table, rows.reshape(-1), axis=0) \
        .reshape(b, cfg.n_sparse, cfg.embed_dim)          # [B, F, K]
    w1 = jnp.take(params.table_w, rows.reshape(-1), axis=0) \
        .reshape(b, cfg.n_sparse)                         # [B, F]

    # FM second order: ½((Σv)² − Σv²)
    sum_v = jnp.sum(emb, axis=1)                          # [B, K]
    sum_v2 = jnp.sum(jnp.square(emb), axis=1)             # [B, K]
    fm2 = 0.5 * jnp.sum(jnp.square(sum_v) - sum_v2, axis=-1)   # [B]
    fm1 = jnp.sum(w1, axis=1)

    # deep branch
    h = emb.reshape(b, cfg.n_sparse * cfg.embed_dim)
    for i, (w, bb) in enumerate(zip(params.mlp_ws, params.mlp_bs)):
        h = h @ w + bb
        if i < len(params.mlp_ws) - 1:
            h = jax.nn.relu(h)
    return params.bias + fm1 + fm2 + h[:, 0]


def deepfm_loss(cfg: DeepFMConfig, params: DeepFMParams,
                sparse_ids: jax.Array, labels: jax.Array) -> jax.Array:
    logits = deepfm_forward(cfg, params, sparse_ids)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_score(cfg: DeepFMConfig, params: DeepFMParams,
                    query_ids: jax.Array, cand_item_ids: jax.Array
                    ) -> jax.Array:
    """retrieval_cand shape: 1 query (its field ids) scored against
    n_candidates item rows — one batched dot, not a loop.

    query_ids: int32[1, n_sparse]; cand_item_ids: int32[NC] rows of field 0.
    """
    rows = _field_ids(cfg, query_ids)
    q = jnp.take(params.table, rows.reshape(-1), axis=0)
    q = jnp.sum(q, axis=0)                                # [K] pooled query
    cand = jnp.take(params.table, cand_item_ids, axis=0)  # [NC, K]
    return cand @ q                                       # [NC]
