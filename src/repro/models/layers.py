"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU.

All functions take explicit dtypes; compute runs in the param dtype (bf16
on TPU) with f32 softmax/normalisation accumulations.  Attention is
*chunked* (flash-style two-level scan with running max/denominator) so the
S×S score matrix is never materialised — required for the 32k-prefill
shapes to fit HBM, and the standard TPU-idiomatic formulation.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# norms / positional
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (
        jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, D]; positions: int32[..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _expand_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """[B,S,KVH,D] -> [B,S,QH,D] by group repeat (GQA)."""
    b, s, kvh, d = k.shape
    rep = n_q_heads // kvh
    return jnp.repeat(k, rep, axis=2)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             *, window=None,
                             chunk: int = 512) -> jax.Array:
    """Flash-style causal attention, O(S·chunk) memory.

    q,k,v: [B, S, H, D] (k/v already GQA-expanded).  ``window``: sliding
    window size for local layers — static int, traced i32 scalar (so one
    kernel serves interleaved local/global layers under scan), or None
    (full causal).
    """
    b, s, h, d = q.shape
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    sp = n_chunks * chunk
    # [N, B, C, H, D]
    qc = qp.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    kc = kp.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    pos = jnp.arange(sp, dtype=jnp.int32).reshape(n_chunks, chunk)

    # jax.checkpoint on both scan bodies: without it the backward saves
    # every (q-chunk × kv-chunk) probability block — the full S×S matrix
    # (measured 12+ GiB/device on arctic train_4k) — defeating the whole
    # point of flash-style chunking.  With it, bwd memory is O(S·chunk).
    @jax.checkpoint
    def q_block(carry, qi):
        qb, qpos = qi            # [B,C,H,D], [C]

        @jax.checkpoint
        def kv_block(acc, ki):
            kb, vb, kpos = ki
            m, l, o = acc
            logits = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                                preferred_element_type=jnp.float32) * scale
            mask = qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, -1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, -1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            o_new = o * corr.transpose(0, 2, 1)[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, h, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk), jnp.float32)
        o0 = jnp.zeros((b, chunk, h, d), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), (kc, vc, pos))
        out = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return carry, out.astype(qb.dtype)

    _, out = jax.lax.scan(q_block, None, (qc, pos))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, d)
    return out[:, :s]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array,
                     window=None) -> jax.Array:
    """Single-token decode over a (possibly seq-sharded) KV cache.

    q: [B, 1, H, D]; k_cache/v_cache: [B, S_max, KVH, D]; cache_len: i32[].
    Written as plain max/exp/sum reductions over the seq axis so GSPMD can
    shard S_max over the mesh 'data' axis and insert the log-sum-exp-style
    partial reductions automatically (flash-decoding analogue).
    """
    b, smax, kvh, d = k_cache.shape
    h = q.shape[2]
    kx = _expand_kv(k_cache, h)
    vx = _expand_kv(v_cache, h)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kx,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(smax, dtype=jnp.int32)
    mask = kpos[None, None, None, :] < cache_len
    if window is not None:
        mask &= kpos[None, None, None, :] >= (cache_len - window)
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", (p / l).astype(vx.dtype), vx,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    from repro.dist.constraints import constrain
    nb = x.ndim - 1
    spec = ("batch",) + (None,) * (nb - 1)
    g = constrain(jnp.einsum("...d,df->...f", x, w_gate), *spec, "tp")
    u = constrain(jnp.einsum("...d,df->...f", x, w_up), *spec, "tp")
    return constrain(jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u,
                                w_down), *spec, None)
