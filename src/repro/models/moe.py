"""Mixture-of-Experts FFN: top-k routing with sort-free capacity dispatch.

Scatter-based dispatch (not the GShard [T,E,C] one-hot einsum, which is
O(T·E·C) memory): each (token, k) pair computes its position within its
expert's capacity via a cumulative rank, then scatters into an [E, C, D]
buffer; expert FFNs run as one batched einsum; results gather back with
router weights.  Overflow beyond capacity is dropped (standard
capacity-factor semantics).  The [E, C, D] buffer shards E over the mesh
'model' axis — GSPMD turns scatter/gather across it into all-to-alls,
i.e. expert parallelism.

Supports:
  * qwen3-moe-30b-a3b: 128 experts, top-8, no shared expert;
  * arctic-480b: 128 experts, top-2, PLUS a dense residual MLP
    (``dense_residual=True`` — output = dense_mlp(x) + moe(x)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.constraints import constrain, data_shards
from repro.models.layers import swiglu


class MoEParams(NamedTuple):
    w_router: jax.Array    # [D, E]
    w_gate: jax.Array      # [E, D, F]
    w_up: jax.Array        # [E, D, F]
    w_down: jax.Array      # [E, F, D]


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.bfloat16) -> MoEParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return MoEParams(
        w_router=(jax.random.normal(k1, (d_model, n_experts), jnp.float32)
                  * s_in).astype(jnp.float32),
        w_gate=(jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32)
                * s_in).astype(dtype),
        w_up=(jax.random.normal(k3, (n_experts, d_model, d_ff), jnp.float32)
              * s_in).astype(dtype),
        w_down=(jax.random.normal(k4, (n_experts, d_ff, d_model), jnp.float32)
                * s_ff).astype(dtype),
    )


def moe_ffn(params: MoEParams, x: jax.Array, top_k: int,
            capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """x: [T, D] flat tokens -> ([T, D], aux_loss)."""
    t, d = x.shape
    e = params.w_router.shape[1]
    c = max(1, int(t * top_k * capacity_factor / e))

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params.w_router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)     # [T,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1),
        axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- dispatch: rank of each (token,k) within its expert -------------
    # sort-based ranking, NOT the one-hot cumsum: [T·k, E] cumsum is a
    # ~TB-scale temp at train_4k shapes (measured 299 GiB/device on
    # arctic-480b); the sort keeps dispatch memory O(T·k).
    #
    # The ranking is SHARD-LOCAL: tokens are reshaped to
    # [data_shards, T_local·k] and ranked within each row, so the sort
    # never crosses the batch-sharded axis (a global sort over 8.4M
    # sharded tokens was the dominant collective on qwen3-moe train_4k —
    # EXPERIMENTS.md §Perf).  Capacity becomes per-shard (c_local), the
    # standard expert-parallel semantics.
    flat_expert = expert_idx.reshape(-1)                    # [T*k]
    tk = flat_expert.shape[0]
    ds = data_shards()
    if tk % ds != 0:
        ds = 1
    tk_l = tk // ds
    c_local = max(1, c // ds)
    rows = flat_expert.reshape(ds, tk_l)
    order = jnp.argsort(rows, axis=1, stable=True)          # local sort
    sorted_e = jnp.take_along_axis(rows, order, axis=1)
    # start offset of each expert within each row
    start = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(e, dtype=row.dtype)))(sorted_e)     # [ds, E]
    pos_sorted = jnp.arange(tk_l, dtype=jnp.int32)[None, :] - \
        jnp.take_along_axis(start, sorted_e.astype(jnp.int32),
                            axis=1).astype(jnp.int32)
    pos = jnp.zeros((ds, tk_l), jnp.int32).at[
        jnp.arange(ds, dtype=jnp.int32)[:, None], order].set(pos_sorted)
    shard_id = jnp.repeat(jnp.arange(ds, dtype=jnp.int32), tk_l)
    pos = pos.reshape(-1)
    keep = pos < c_local
    c_eff = c_local * ds
    slot = flat_expert * c_eff + shard_id * c_local + pos    # [T*k]
    slot = jnp.where(keep, slot, e * c_eff)                  # drop slot
    c = c_eff

    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    # Dispatch as an INVERSE-PERMUTATION GATHER, not a row scatter:
    # ``buf.at[slot].set(x[tok_idx])`` lowers to a scatter whose index
    # operand XLA materialises per-element — measured as a 64 GiB
    # u32[T·k, D] all-gather per layer on qwen3-moe train_4k
    # (EXPERIMENTS.md §Perf).  Scattering only the int32 token ids
    # ([E·C], 4 B each) and gathering rows keeps index traffic negligible
    # and turns the data motion into the expected dispatch all-to-all.
    inv = jnp.full((e * c,), t, jnp.int32)
    inv = inv.at[slot].set(tok_idx, mode="drop")             # [E*C] ids
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])  # sentinel row
    buf = jnp.take(x_pad, inv, axis=0)                       # [E*C, D]
    # expert-parallel layout: E over 'model' — the gather above becomes
    # the dispatch all-to-all under GSPMD instead of a replicated buffer
    buf = constrain(buf.reshape(e, c, d), "tp", None, None)

    # ---- expert computation (batched einsum over E) --------------------
    g = constrain(jnp.einsum("ecd,edf->ecf", buf, params.w_gate),
                  "tp", None, None)
    u = constrain(jnp.einsum("ecd,edf->ecf", buf, params.w_up),
                  "tp", None, None)
    y = constrain(jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                             params.w_down), "tp", None, None)
    y = y.reshape(e * c, d)

    # ---- combine --------------------------------------------------------
    gathered = jnp.where(keep[:, None], y.at[slot, :].get(mode="fill",
                                                          fill_value=0), 0)
    weighted = gathered.astype(jnp.float32) * \
        gate_vals.reshape(-1)[:, None]
    out = jax.ops.segment_sum(weighted, tok_idx, num_segments=t)
    return out.astype(x.dtype), aux
