"""Extra pool architectures beyond the 10 assigned (kernel_taxonomy §B.3):

* **GCN**  [arXiv:1609.02907] — symmetric-normalised SpMM: Ã·X·W
* **GIN**  [arXiv:1810.00826] — sum aggregation + (1+ε) self + MLP
* **GAT**  [arXiv:1710.10903] — SDDMM edge scores → segment-softmax → SpMM
  (the edge-softmax is the distinct kernel regime: segment_max for
  numerical stability, exp, segment_sum normalisation — all on the same
  substrate primitives)

All run on the GraphBatch substrate and are selectable via the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn import (GraphBatch, _degree, _edge_gather, _init_mlp,
                              _mlp, _seg_sum)


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_layers: int = 2
    d_hidden: int = 64
    d_in: int = 1433
    n_classes: int = 7


def init_gcn(cfg: GCNConfig, key):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    params = []
    for a, b in zip(dims[:-1], dims[1:]):
        key, k = jax.random.split(key)
        params.append(_init_mlp(k, (a, b))[0])
    return params


def gcn_forward(cfg: GCNConfig, params, g: GraphBatch) -> jax.Array:
    n = g.node_feats.shape[0]
    deg = _degree(g.edge_dst, g.edge_mask, n) + 1.0     # +self loop
    dinv = jax.lax.rsqrt(deg)
    h = g.node_feats
    for i, (w, b) in enumerate(params):
        hw = h @ w + b
        sent = _edge_gather(hw * dinv[:, None], g.edge_src)
        sent = jnp.where(g.edge_mask[:, None], sent, 0.0)
        agg = _seg_sum(sent, g.edge_dst, n) * dinv[:, None]
        h = agg + hw * (dinv * dinv)[:, None]           # self loop term
        if i < len(params) - 1:
            h = jax.nn.relu(h)
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return h


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin"
    n_layers: int = 3
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 10


def init_gin(cfg: GINConfig, key):
    params = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        key, k = jax.random.split(key)
        d_out = cfg.d_hidden if i < cfg.n_layers - 1 else cfg.n_classes
        params.append(dict(
            mlp=_init_mlp(k, (d_prev, cfg.d_hidden, d_out)),
            eps=jnp.zeros(())))
        d_prev = d_out
    return params


def gin_forward(cfg: GINConfig, params, g: GraphBatch) -> jax.Array:
    n = g.node_feats.shape[0]
    h = g.node_feats
    for i, lp in enumerate(params):
        sent = _edge_gather(h, g.edge_src)
        sent = jnp.where(g.edge_mask[:, None], sent, 0.0)
        agg = _seg_sum(sent, g.edge_dst, n)
        h = _mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg)
        if i < len(params) - 1:
            h = jax.nn.relu(h)
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return h


# ---------------------------------------------------------------------------
# GAT — SDDMM + segment-softmax
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_hidden: int = 64
    n_heads: int = 4
    d_in: int = 1433
    n_classes: int = 7


def init_gat(cfg: GATConfig, key):
    params = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        params.append(dict(
            w=(jax.random.normal(k1, (d_prev, cfg.n_heads, d_out))
               * d_prev ** -0.5),
            a_src=jax.random.normal(k2, (cfg.n_heads, d_out)) * 0.1,
            a_dst=jax.random.normal(k3, (cfg.n_heads, d_out)) * 0.1))
        d_prev = d_out if last else d_out * cfg.n_heads
    return params


def segment_softmax(scores: jax.Array, seg: jax.Array, mask: jax.Array,
                    n: int) -> jax.Array:
    """softmax over edges grouped by destination (numerically stable)."""
    neg = jnp.full_like(scores, -1e30)
    s = jnp.where(mask[:, None], scores, neg)
    mx = jax.ops.segment_max(s, seg, num_segments=n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(s - mx[seg]) * mask[:, None]
    z = jax.ops.segment_sum(ex, seg, num_segments=n)
    return ex / jnp.maximum(z[seg], 1e-30)


def gat_forward(cfg: GATConfig, params, g: GraphBatch) -> jax.Array:
    n = g.node_feats.shape[0]
    h = g.node_feats
    for i, lp in enumerate(params):
        last = i == len(params) - 1
        hw = jnp.einsum("nd,dhk->nhk", h, lp["w"])      # [N, H, K]
        e_src = jnp.einsum("nhk,hk->nh", hw, lp["a_src"])
        e_dst = jnp.einsum("nhk,hk->nh", hw, lp["a_dst"])
        # SDDMM: score per edge (LeakyReLU(a_s·h_u + a_d·h_v))
        scores = jax.nn.leaky_relu(
            _edge_gather(e_src, g.edge_src) +
            _edge_gather(e_dst, g.edge_dst), 0.2)       # [E, H]
        attn = segment_softmax(scores, g.edge_dst, g.edge_mask, n)
        sent = _edge_gather(hw, g.edge_src) * attn[..., None]
        agg = _seg_sum(sent.reshape(sent.shape[0], -1), g.edge_dst,
                       n).reshape(n, cfg.n_heads, -1)
        h = jnp.mean(agg, axis=1) if last else \
            jax.nn.elu(agg).reshape(n, -1)
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return h
