"""Decoder-only LM supporting all five assigned architectures.

One parameterised stack covers:
  * gemma3-12b   — GQA(16/8), 5:1 local(1024):global attention, vocab 262144
  * qwen2.5-3b   — GQA(16/2), QKV bias, full attention
  * glm4-9b      — GQA(32/2), RoPE, full attention
  * qwen3-moe    — GQA(32/4) + 128-expert top-8 MoE FFN
  * arctic-480b  — GQA(56/8) + 128-expert top-2 MoE + dense-residual FFN

Layers are **stacked** ([L, ...] params) and executed with ``lax.scan`` +
``jax.checkpoint`` (remat): the compiled HLO stays one-layer-sized, which
keeps the 512-device dry-run compile tractable and implements the standard
activation-recompute memory policy.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.constraints import constrain
from repro.models import layers as L
from repro.models.moe import MoEParams, init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # attention pattern: every `global_every`-th layer is global, others
    # local with `window`; None = all global (full causal)
    window: Optional[int] = None
    global_every: int = 1
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False   # arctic: dense MLP + MoE in parallel
    dtype: str = "bfloat16"
    # counting mode (roofline): unrolled layer loop + plain attention +
    # full-logit loss — FLOP-identical math without inner scans, so
    # cost_analysis / HLO collective parsing see the WHOLE program
    # (XLA counts while bodies once; see launch/dryrun.py).
    counting: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            if self.dense_residual:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.hd * d
        ffn = self.top_k * 3 * d * self.moe_d_ff + d * self.n_experts
        if self.dense_residual:
            ffn += 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d


class LayerParams(NamedTuple):
    """One decoder layer; every leaf stacked [L, ...] for scan."""
    ln1: jax.Array          # [D]
    wq: jax.Array           # [D, H*hd]
    wk: jax.Array           # [D, KVH*hd]
    wv: jax.Array           # [D, KVH*hd]
    bq: jax.Array           # [H*hd]   (zeros when qkv_bias=False)
    bk: jax.Array
    bv: jax.Array
    wo: jax.Array           # [H*hd, D]
    ln2: jax.Array          # [D]
    w_gate: jax.Array       # [D, F] (dense FFN or arctic residual; may be 0-size)
    w_up: jax.Array
    w_down: jax.Array       # [F, D]
    moe: Optional[MoEParams]


class LMParams(NamedTuple):
    embed: jax.Array        # [V, D]
    layers: LayerParams     # stacked [L, ...]
    ln_f: jax.Array         # [D]


def init_lm(cfg: LMConfig, key: jax.Array) -> LMParams:
    dt = cfg.jdtype
    d, hd, h, kvh = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    lkeys = jax.random.split(key, 8)
    s = d ** -0.5

    def w(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    ldim = cfg.n_layers
    f = cfg.d_ff if (not cfg.is_moe or cfg.dense_residual) else 0
    layer = LayerParams(
        ln1=jnp.zeros((ldim, d), dt),
        wq=w(lkeys[0], (ldim, d, h * hd), s),
        wk=w(lkeys[1], (ldim, d, kvh * hd), s),
        wv=w(lkeys[2], (ldim, d, kvh * hd), s),
        bq=jnp.zeros((ldim, h * hd), dt),
        bk=jnp.zeros((ldim, kvh * hd), dt),
        bv=jnp.zeros((ldim, kvh * hd), dt),
        wo=w(lkeys[3], (ldim, h * hd, d), (h * hd) ** -0.5),
        ln2=jnp.zeros((ldim, d), dt),
        w_gate=w(lkeys[4], (ldim, d, f), s) if f else
        jnp.zeros((ldim, d, 0), dt),
        w_up=w(lkeys[5], (ldim, d, f), s) if f else
        jnp.zeros((ldim, d, 0), dt),
        w_down=w(lkeys[6], (ldim, f, d), max(f, 1) ** -0.5) if f else
        jnp.zeros((ldim, 0, d), dt),
        moe=jax.vmap(lambda k: init_moe(k, d, cfg.moe_d_ff, cfg.n_experts,
                                        dt))(
            jax.random.split(lkeys[7], ldim)) if cfg.is_moe else None,
    )
    ke, _ = jax.random.split(key)
    return LMParams(
        embed=w(ke, (cfg.vocab, d), 1.0),
        layers=layer,
        ln_f=jnp.zeros((d,), dt),
    )


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def layer_locality(cfg: LMConfig) -> jax.Array:
    """i32[L]: 1 for sliding-window layers (config-derived, not a param)."""
    if cfg.window is None:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    return (jnp.arange(cfg.n_layers, dtype=jnp.int32) % cfg.global_every
            != cfg.global_every - 1).astype(jnp.int32)


def _attn_block(cfg: LMConfig, p: LayerParams, x, positions, is_local):
    b, s_len, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = L.rms_norm(x, p.ln1)
    q = constrain(jnp.einsum("bsd,dk->bsk", xn, p.wq) + p.bq,
                  "batch", None, "tp").reshape(b, s_len, h, hd)
    k = constrain(jnp.einsum("bsd,dk->bsk", xn, p.wk) + p.bk,
                  "batch", None, "tp").reshape(b, s_len, kvh, hd)
    v = constrain(jnp.einsum("bsd,dk->bsk", xn, p.wv) + p.bv,
                  "batch", None, "tp").reshape(b, s_len, kvh, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    kx = L._expand_kv(k, h)
    vx = L._expand_kv(v, h)
    # counting mode: chunk = full seq -> the kv/q scans have length 1 and
    # XLA's count-body-once cost analysis is exact (FLOP-identical math)
    chunk = s_len if cfg.counting else 512
    if cfg.window is not None:
        # one kernel for interleaved local/global layers: effective window
        # is a traced scalar selected by the per-layer flag
        w_eff = jnp.where(is_local.astype(bool),
                          jnp.int32(cfg.window), jnp.int32(s_len + 1))
        out = L.chunked_causal_attention(q, kx, vx, window=w_eff,
                                         chunk=chunk)
    else:
        out = L.chunked_causal_attention(q, kx, vx, window=None, chunk=chunk)
    out = out.reshape(b, s_len, h * hd)
    return x + constrain(jnp.einsum("bsk,kd->bsd", out, p.wo),
                         "batch", None, None)


def _ffn_block(cfg: LMConfig, p: LayerParams, x):
    b, s_len, d = x.shape
    xn = L.rms_norm(x, p.ln2)
    aux = jnp.zeros((), jnp.float32)
    out = jnp.zeros_like(x)
    if cfg.is_moe:
        flat = xn.reshape(-1, d)
        moe_out, aux = moe_ffn(p.moe, flat, cfg.top_k)
        out = out + moe_out.reshape(b, s_len, d)
        if cfg.dense_residual:
            out = out + L.swiglu(xn, p.w_gate, p.w_up, p.w_down)
    else:
        out = L.swiglu(xn, p.w_gate, p.w_up, p.w_down)
    return x + out, aux


def backbone(cfg: LMConfig, params: LMParams, tokens: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """tokens: int32[B, S] -> (hidden f[B, S, D], aux_loss)."""
    b, s_len = tokens.shape
    x = constrain(params.embed[tokens].astype(cfg.jdtype),
                  "batch", None, None)
    positions = jnp.broadcast_to(
        jnp.arange(s_len, dtype=jnp.int32)[None], (b, s_len))

    locality = layer_locality(cfg)
    if cfg.counting:
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params.layers)
            x = _attn_block(cfg, lp, x, positions, locality[i])
            x, aux = _ffn_block(cfg, lp, x)
            aux_total = aux_total + aux
        return L.rms_norm(x, params.ln_f), aux_total

    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.nothing_saveable)
    def one_layer(x, lp, is_local):
        x = _attn_block(cfg, lp, x, positions, is_local)
        x, aux = _ffn_block(cfg, lp, x)
        return x, aux

    def scan_body(x, scanned):
        lp, is_local = scanned
        return one_layer(x, lp, is_local)

    x, auxes = jax.lax.scan(scan_body, x, (params.layers, locality))
    return L.rms_norm(x, params.ln_f), jnp.sum(auxes)


def forward(cfg: LMConfig, params: LMParams, tokens: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens: int32[B, S] -> (logits f32[B, S, V], aux_loss)."""
    x, aux = backbone(cfg, params, tokens)
    logits = jnp.einsum("bsd,vd->bsv", x, params.embed,
                        preferred_element_type=jnp.float32)
    return logits, aux


def lm_loss(cfg: LMConfig, params: LMParams, tokens: jax.Array,
            labels: jax.Array, *, seq_chunk: int = 512,
            aux_weight: float = 0.01) -> jax.Array:
    """Next-token cross entropy with **seq-chunked logits**: the [B,S,V]
    logits tensor (would be TBs for gemma3 train_4k) is never materialised;
    each chunk's logits live only inside one rematerialised scan step."""
    b, s_len = tokens.shape
    x, aux = backbone(cfg, params, tokens)
    vocab = params.embed.shape[0]

    def xent(xch, lch):
        logits = constrain(
            jnp.einsum("bsd,vd->bsv", xch, params.embed,
                       preferred_element_type=jnp.float32),
            "batch", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot contraction, NOT take_along_axis: the
        # vocab dim is model-sharded and a gather across it would force
        # GSPMD to all-gather the full logits (measured: the dominant
        # collective before this change); the one-hot reduce keeps the
        # reduction local + one scalar-field all-reduce.
        onehot = jax.nn.one_hot(lch, vocab, dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        return jnp.sum(logz - gold)

    if cfg.counting:
        total = xent(x, labels)
        return total / (b * s_len) + aux_weight * aux

    seq_chunk = min(seq_chunk, s_len)
    n_chunks = s_len // seq_chunk
    xc = x[:, : n_chunks * seq_chunk].reshape(
        b, n_chunks, seq_chunk, -1).transpose(1, 0, 2, 3)
    lc = labels[:, : n_chunks * seq_chunk].reshape(
        b, n_chunks, seq_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        xch, lch = inp
        return carry + xent(xch, lch), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * n_chunks * seq_chunk) + aux_weight * aux


# ---------------------------------------------------------------------------
# decode (serving) — KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array           # [L, B, S_max, KVH, hd]
    v: jax.Array
    length: jax.Array      # i32[]


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               length: int = 0) -> KVCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, cfg.jdtype), jnp.zeros(shape, cfg.jdtype),
                   jnp.asarray(length, jnp.int32))


def decode_step(cfg: LMConfig, params: LMParams, cache: KVCache,
                tokens: jax.Array) -> Tuple[jax.Array, KVCache]:
    """One decode step.  tokens: int32[B, 1] -> (logits [B,1,V], cache)."""
    b = tokens.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = params.embed[tokens].astype(cfg.jdtype)
    pos = jnp.full((b, 1), cache.length, jnp.int32)
    zero = jnp.asarray(0, cache.length.dtype)

    def body(x, scanned):
        lp, is_local, kc, vc = scanned
        xn = L.rms_norm(x, lp.ln1)
        q = (jnp.einsum("bsd,dk->bsk", xn, lp.wq) + lp.bq
             ).reshape(b, 1, h, hd)
        k = (jnp.einsum("bsd,dk->bsk", xn, lp.wk) + lp.bk
             ).reshape(b, 1, kvh, hd)
        v = (jnp.einsum("bsd,dk->bsk", xn, lp.wv) + lp.bv
             ).reshape(b, 1, kvh, hd)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(
            kc, k.astype(kc.dtype), (zero, cache.length, zero, zero))
        vc = jax.lax.dynamic_update_slice(
            vc, v.astype(vc.dtype), (zero, cache.length, zero, zero))
        if cfg.window is not None:
            smax = kc.shape[1]
            w_eff = jnp.where(is_local.astype(bool),
                              jnp.int32(cfg.window), jnp.int32(smax + 1))
            out = L.decode_attention(q, kc, vc, cache.length + 1,
                                     window=w_eff)
        else:
            out = L.decode_attention(q, kc, vc, cache.length + 1,
                                     window=None)
        x = x + jnp.einsum("bsk,kd->bsd", out.reshape(b, 1, h * hd), lp.wo)
        x, _ = _ffn_block(cfg, lp, x)
        return x, (kc, vc)

    if cfg.counting:
        locality = layer_locality(cfg)
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params.layers)
            x, (kc, vc) = body(x, (lp, locality[i], cache.k[i], cache.v[i]))
            ks.append(kc)
            vs.append(vc)
        knew = jnp.stack(ks)
        vnew = jnp.stack(vs)
    else:
        x, (knew, vnew) = jax.lax.scan(
            body, x, (params.layers, layer_locality(cfg), cache.k, cache.v))
    x = L.rms_norm(x, params.ln_f)
    logits = jnp.einsum("bsd,vd->bsv", x, params.embed,
                        preferred_element_type=jnp.float32)
    return logits, KVCache(knew, vnew, cache.length + 1)
