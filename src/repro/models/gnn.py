"""GNN zoo: GraphSAGE, PNA, NequIP-lite, GraphCast-style EPD.

All message passing uses the system's segment-op substrate
(gather by edge src → ``jax.ops.segment_sum/max`` by edge dst) — JAX has no
sparse message-passing primitive, so this IS part of the framework
(kernel_taxonomy §B.3/§B.11).  Full-graph layers can optionally route the
sum-aggregation through the frontier-gated Pallas SpMM
(kernels/segment_ops) when an affected-mask is supplied — that is the
paper's DF technique applied to incremental GNN refresh
(core/incremental_gnn.py).

Graphs arrive as a ``GraphBatch``: flat edge arrays + node features with
static (padded) shapes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist.constraints import constrain


class GraphBatch(NamedTuple):
    node_feats: jax.Array    # f32[N, F]  (or positions for nequip)
    edge_src: jax.Array      # int32[E]
    edge_dst: jax.Array      # int32[E]
    edge_mask: jax.Array     # bool[E]
    node_mask: jax.Array     # bool[N]
    # molecular/equivariant extras
    positions: Optional[jax.Array] = None     # f32[N, 3]
    # graphcast extras: second node set + two bipartite edge sets
    mesh_feats: Optional[jax.Array] = None    # f32[M, Fm]
    g2m_src: Optional[jax.Array] = None
    g2m_dst: Optional[jax.Array] = None
    m2g_src: Optional[jax.Array] = None
    m2g_dst: Optional[jax.Array] = None


def _seg_sum(vals, idx, n):
    # keep the scattered result node-sharded: without the constraint GSPMD
    # replicates segment outputs, and every downstream gather/MLP runs on
    # the FULL graph per device (measured 4.2 TiB/device on
    # graphcast/ogb_products; EXPERIMENTS.md §Perf)
    out = jax.ops.segment_sum(vals, idx, num_segments=n)
    return constrain(out, "full", *((None,) * (out.ndim - 1)))


def _seg_max(vals, idx, n):
    return jax.ops.segment_max(vals, idx, num_segments=n)


def _seg_min(vals, idx, n):
    return -jax.ops.segment_max(-vals, idx, num_segments=n)


def _gather_send(feats, src, mask):
    out = jnp.where(mask[:, None], feats[src], 0.0)
    return constrain(out, "full", None)       # edge-sharded messages


def _degree(dst, mask, n):
    return _seg_sum(mask.astype(jnp.float32), dst, n)


def _mlp(params, x, act=jax.nn.relu):
    for i, (w, b) in enumerate(params):
        x = jnp.einsum("...d,df->...f", x, w) + b
        if i < len(params) - 1:
            x = act(x)
        if x.ndim == 2:      # keep node/edge tables sharded through MLPs
            x = constrain(x, "full", None)
    return x


def _edge_gather(feats, idx):
    """Gather node rows to edges, keeping the edge dim sharded."""
    out = feats[idx]
    return constrain(out, "full", *((None,) * (out.ndim - 1)))


def _init_mlp(key, dims, dtype=jnp.float32):
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params.append((
            (jax.random.normal(k, (a, b), jnp.float32) * a ** -0.5
             ).astype(dtype),
            jnp.zeros((b,), dtype)))
    return params


# ===========================================================================
# GraphSAGE  [arXiv:1706.02216]  — 2 layers, d=128, mean aggregator
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    n_classes: int = 41
    fanouts: Tuple[int, ...] = (25, 10)


def init_sage(cfg: SAGEConfig, key):
    params = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        d_out = cfg.d_hidden if i < cfg.n_layers - 1 else cfg.n_classes
        params.append(dict(
            w_self=_init_mlp(k1, (d_prev, d_out))[0],
            w_neigh=_init_mlp(k2, (d_prev, d_out))[0]))
        d_prev = d_out
    return params


def sage_forward(cfg: SAGEConfig, params, g: GraphBatch,
                 affected: Optional[jax.Array] = None) -> jax.Array:
    """Full-graph forward.  ``affected`` routes aggregation through the
    frontier-gated path (incremental refresh)."""
    n = g.node_feats.shape[0]
    h = g.node_feats
    for i, lp in enumerate(params):
        sent = _gather_send(h, g.edge_src, g.edge_mask)
        agg = _seg_sum(sent, g.edge_dst, n)
        deg = _degree(g.edge_dst, g.edge_mask, n)[:, None]
        mean = agg / jnp.maximum(deg, 1.0)
        w_s, b_s = lp["w_self"]
        w_n, b_n = lp["w_neigh"]
        h = h @ w_s + mean @ w_n + b_s + b_n
        if i < len(params) - 1:
            h = jax.nn.relu(h)
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return h


def sage_block_forward(cfg: SAGEConfig, params, feats_per_layer,
                       parents_per_layer, masks_per_layer) -> jax.Array:
    """Minibatch (sampled-block) forward for ``minibatch_lg``.

    feats_per_layer[l]: f32[B_l, F] RAW features of level-l block nodes,
    innermost hop first (last entry = seeds).  parents_per_layer[i] maps
    rows of level i to rows of level i+1.

    Standard multi-level evaluation: layer j produces hidden states for
    every level except the (current) deepest, consuming one level per
    layer; after L layers only the seed representations remain.
    """
    reps = list(feats_per_layer)          # level L ... level 0 (seeds)
    for j, lp in enumerate(params):
        w_s, b_s = lp["w_self"]
        w_n, b_n = lp["w_neigh"]
        new_reps = []
        for i in range(len(reps) - 1):
            child = reps[i]
            parent_self = reps[i + 1]
            parent_map = parents_per_layer[i + j]
            mask = masks_per_layer[i + j]
            nb_parents = parent_self.shape[0]
            sent = jnp.where(mask[:, None], child, 0.0)
            agg = _seg_sum(sent, parent_map, nb_parents)
            cnt = _seg_sum(mask.astype(jnp.float32), parent_map, nb_parents)
            mean = agg / jnp.maximum(cnt[:, None], 1.0)
            h = parent_self @ w_s + mean @ w_n + b_s + b_n
            if j < len(params) - 1:
                h = jax.nn.relu(h)
            new_reps.append(h)
        reps = new_reps
    return reps[0]


# ===========================================================================
# PNA  [arXiv:2004.05718] — mean/max/min/std aggregators × id/amp/atten
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 16
    n_classes: int = 10
    avg_degree: float = 4.0


def init_pna(cfg: PNAConfig, key):
    params = []
    key, k0 = jax.random.split(key)
    params.append(dict(encode=_init_mlp(k0, (cfg.d_in, cfg.d_hidden))))
    for _ in range(cfg.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        params.append(dict(
            pre=_init_mlp(k1, (2 * cfg.d_hidden, cfg.d_hidden)),
            post=_init_mlp(k2, (13 * cfg.d_hidden, cfg.d_hidden)),
        ))
    key, kf = jax.random.split(key)
    params.append(dict(decode=_init_mlp(kf, (cfg.d_hidden, cfg.n_classes))))
    return params


def pna_forward(cfg: PNAConfig, params, g: GraphBatch) -> jax.Array:
    n = g.node_feats.shape[0]
    h = _mlp(params[0]["encode"], g.node_feats)
    deg = _degree(g.edge_dst, g.edge_mask, n)
    log_deg = jnp.log1p(deg)[:, None]
    delta = jnp.log1p(cfg.avg_degree)
    for lp in params[1:-1]:
        msg_in = jnp.concatenate(
            [_edge_gather(h, g.edge_src), _edge_gather(h, g.edge_dst)],
            axis=-1)
        msg = _mlp(lp["pre"], msg_in)
        msg = jnp.where(g.edge_mask[:, None], msg, 0.0)
        s = _seg_sum(msg, g.edge_dst, n)
        cnt = jnp.maximum(deg, 1.0)[:, None]
        mean = s / cnt
        mx = jnp.where(
            deg[:, None] > 0,
            _seg_max(jnp.where(g.edge_mask[:, None], msg, -1e30),
                     g.edge_dst, n), 0.0)
        mn = jnp.where(
            deg[:, None] > 0,
            _seg_min(jnp.where(g.edge_mask[:, None], msg, 1e30),
                     g.edge_dst, n), 0.0)
        sq = _seg_sum(jnp.square(msg), g.edge_dst, n)
        std = jnp.sqrt(jnp.maximum(sq / cnt - jnp.square(mean), 0.0))
        aggs = [mean, mx, mn, std]
        scaled = []
        for a in aggs:
            scaled += [a, a * log_deg / delta,
                       a * delta / jnp.maximum(log_deg, 1e-6)]
        hcat = jnp.concatenate([h] + scaled, axis=-1)
        h = h + _mlp(lp["post"], hcat)
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return _mlp(params[-1]["decode"], h)


# ===========================================================================
# NequIP-lite [arXiv:2101.03164] — E(3)-equivariant, l_max=2 restricted TP
# ===========================================================================
# Features per node: scalars s[N, C], vectors V[N, 3, C], rank-2 traceless
# T[N, 5, C].  Restricted tensor-product paths (DESIGN.md documents the
# simplification vs full Clebsch-Gordan):
#   0⊗0→0, 0⊗1→1, 0⊗2→2  (radial-scalar gating of each irrep)
#   1⊗1→0 (dot), 1⊗1→1 (cross), 1⊗1→2 (traceless sym outer)

@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 4


def _bessel_rbf(r, n_rbf, cutoff):
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rc = jnp.clip(r, 1e-6, cutoff)
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * rc[..., None] / cutoff)
    rb = rb / rc[..., None]
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r, 0, cutoff) / cutoff) + 1.0)
    return rb * env[..., None]


def _sym_traceless(v):
    """v: [..., 3] -> 5 components of traceless symmetric outer product."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    return jnp.stack([x * y, y * z, x * z,
                      0.5 * (x * x - y * y),
                      (2 * z * z - x * x - y * y) / jnp.sqrt(12.0)], -1)


def init_nequip(cfg: NequIPConfig, key):
    c = cfg.channels
    params = dict(embed=None, layers=[], readout=None)
    key, ke = jax.random.split(key)
    params["embed"] = (jax.random.normal(ke, (cfg.n_species, c)) * 0.5)
    for _ in range(cfg.n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        params["layers"].append(dict(
            radial=_init_mlp(k1, (cfg.n_rbf, 32, 6 * c)),   # 6 TP paths
            mix_s=_init_mlp(k2, (2 * c, c)),
            mix_v=(jax.random.normal(k3, (2 * c, c)) * (2 * c) ** -0.5),
        ))
    key, kr = jax.random.split(key)
    params["readout"] = _init_mlp(kr, (c, 16, 1))
    return params


def nequip_forward(cfg: NequIPConfig, params, species: jax.Array,
                   positions: jax.Array, edge_src, edge_dst, edge_mask
                   ) -> jax.Array:
    """Per-graph energy.  species: int32[N]; positions: f32[N,3]."""
    n = species.shape[0]
    c = cfg.channels
    s = params["embed"][species]                       # [N, C]
    v = jnp.zeros((n, 3, c))
    t = jnp.zeros((n, 5, c))
    rel = _edge_gather(positions, edge_dst) - \
        _edge_gather(positions, edge_src)              # [E, 3]
    r = jnp.linalg.norm(rel + 1e-12, axis=-1)
    rhat = rel / jnp.maximum(r[:, None], 1e-6)
    rbf = _bessel_rbf(r, cfg.n_rbf, cfg.cutoff)        # [E, n_rbf]
    y1 = rhat                                          # [E, 3]   l=1 SH
    y2 = _sym_traceless(rhat)                          # [E, 5]   l=2 SH

    def one_layer(carry, lp):
        # (per-layer remat tried and refuted — same re-gather cost as
        # graphcast; EXPERIMENTS.md §Perf)
        s, v, t = carry
        w = _mlp(lp["radial"], rbf)                    # [E, 6C]
        w = w * edge_mask[:, None]
        w0, w1, w2, w11_0, w11_1, w11_2 = jnp.split(w, 6, axis=-1)
        s_src = _edge_gather(s, edge_src)              # [E, C]
        v_src = _edge_gather(v, edge_src)              # [E, 3, C]
        # path 0⊗0→0, 0⊗1→1, 0⊗2→2: scalar × geometry
        m0 = w0 * s_src                                        # [E, C]
        m1 = w1[:, None, :] * s_src[:, None, :] * y1[:, :, None]
        m2 = w2[:, None, :] * s_src[:, None, :] * y2[:, :, None]
        # paths 1⊗1→{0,1,2}: vector features × edge direction
        dot = jnp.einsum("eic,ei->ec", v_src, y1)
        m0 = m0 + w11_0 * dot
        cross = jnp.cross(v_src.transpose(0, 2, 1),
                          jnp.broadcast_to(y1[:, None, :], v_src.transpose(
                              0, 2, 1).shape)).transpose(0, 2, 1)
        m1 = m1 + w11_1[:, None, :] * cross
        outer = _sym_traceless_pair(v_src, y1)
        m2 = m2 + w11_2[:, None, :] * outer

        agg_s = _seg_sum(m0, edge_dst, n)
        agg_v = _seg_sum(m1, edge_dst, n)
        agg_t = _seg_sum(m2, edge_dst, n)
        s = _mlp(lp["mix_s"], jnp.concatenate([s, agg_s], -1))
        v = jnp.einsum("nic,cd->nid",
                       jnp.concatenate([v, agg_v], -1), lp["mix_v"])
        t = t + agg_t
        # invariant gate keeps equivariance: scale v/t by σ(s)
        gate = jax.nn.sigmoid(s)[:, None, :]
        v = v * gate
        t = t * gate
        return (s, v, t)

    for lp in params["layers"]:
        s, v, t = one_layer((s, v, t), lp)

    e_atom = _mlp(params["readout"], s)[:, 0]
    return jnp.sum(e_atom)


def _sym_traceless_pair(v, y):
    """v: [E,3,C], y: [E,3] -> traceless sym product [E,5,C]."""
    vx, vy, vz = v[:, 0], v[:, 1], v[:, 2]
    yx, yy, yz = y[:, 0:1], y[:, 1:2], y[:, 2:3]
    xy = 0.5 * (vx * yy + vy * yx)
    yz_ = 0.5 * (vy * yz + vz * yy)
    xz = 0.5 * (vx * yz + vz * yx)
    xx_yy = 0.5 * (vx * yx - vy * yy)
    zz = (2 * vz * yz - vx * yx - vy * yy) / jnp.sqrt(12.0)
    return jnp.stack([xy, yz_, xz, xx_yy, zz], axis=1)


# ===========================================================================
# GraphCast-style encoder-processor-decoder [arXiv:2212.12794]
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6


def init_graphcast(cfg: GraphCastConfig, key):
    d = cfg.d_hidden
    key, k1, k2, k3, k4, k5 = jax.random.split(key, 6)
    params = dict(
        grid_enc=_init_mlp(k1, (cfg.n_vars, d)),
        g2m=_init_mlp(k2, (2 * d, d)),
        proc=[],
        m2g=_init_mlp(k3, (2 * d, d)),
        grid_dec=_init_mlp(k4, (2 * d, d, cfg.n_vars)),
        mesh_enc=_init_mlp(k5, (3, d)),
    )
    for _ in range(cfg.n_layers):
        key, ka, kb = jax.random.split(key, 3)
        params["proc"].append(dict(
            edge=_init_mlp(ka, (2 * d, d)),
            node=_init_mlp(kb, (2 * d, d))))
    return params


def graphcast_forward(cfg: GraphCastConfig, params, g: GraphBatch
                      ) -> jax.Array:
    """grid feats [G, n_vars] + mesh feats [M, 3] -> next-step grid vars."""
    d = cfg.d_hidden
    n_grid = g.node_feats.shape[0]
    n_mesh = g.mesh_feats.shape[0]
    hg = _mlp(params["grid_enc"], g.node_feats)
    hm = _mlp(params["mesh_enc"], g.mesh_feats)
    # encoder: grid -> mesh
    msg = _mlp(params["g2m"], jnp.concatenate(
        [_edge_gather(hg, g.g2m_src), _edge_gather(hm, g.g2m_dst)], -1))
    hm = hm + _seg_sum(msg, g.g2m_dst, n_mesh)

    # processor: 16 interaction-network rounds on the mesh graph.
    # (NOTE: per-round jax.checkpoint was tried and REFUTED — it grew peak
    # memory 80→100 GiB and collectives +33% on ogb_products because the
    # recomputation repeats the hm all-gathers; see EXPERIMENTS.md §Perf.)
    def one_round(hm, lp):
        em = _mlp(lp["edge"], jnp.concatenate(
            [_edge_gather(hm, g.edge_src), _edge_gather(hm, g.edge_dst)],
            -1))
        em = jnp.where(g.edge_mask[:, None], em, 0.0)
        agg = _seg_sum(em, g.edge_dst, n_mesh)
        return hm + _mlp(lp["node"], jnp.concatenate([hm, agg], -1))

    for lp in params["proc"]:
        hm = one_round(hm, lp)
    # decoder: mesh -> grid
    msg = _mlp(params["m2g"], jnp.concatenate(
        [_edge_gather(hm, g.m2g_src), _edge_gather(hg, g.m2g_dst)], -1))
    hg_upd = hg + _seg_sum(msg, g.m2g_dst, n_grid)
    return _mlp(params["grid_dec"],
                jnp.concatenate([hg_upd, hg], -1))
