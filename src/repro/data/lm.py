"""Synthetic-corpus token pipeline with background prefetch.

Offline container → deterministic synthetic corpus (mixture of Zipfian
unigrams + repeated n-gram motifs so a real LM loss curve is learnable);
the pipeline shape (iterator → host staging → double-buffered device
prefetch) is the production structure.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class SyntheticCorpus:
    """Zipf unigrams + motif phrases; next-token predictable structure."""

    def __init__(self, vocab: int, seed: int = 0, n_motifs: int = 64,
                 motif_len: int = 8):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(2, vocab, size=(n_motifs, motif_len))
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self.p = p / p.sum()
        self.rng = rng

    def sample(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int32)
        for b in range(batch):
            toks = []
            while len(toks) < seq + 1:
                if self.rng.random() < 0.5:
                    toks.extend(self.motifs[
                        self.rng.integers(len(self.motifs))])
                else:
                    toks.extend(self.rng.choice(
                        self.vocab, size=8, p=self.p))
            out[b] = toks[: seq + 1]
        return out


def batches(vocab: int, batch: int, seq: int, seed: int = 0
            ) -> Iterator[dict]:
    corpus = SyntheticCorpus(vocab, seed)
    while True:
        chunk = corpus.sample(batch, seq)
        yield dict(tokens=chunk[:, :-1], labels=chunk[:, 1:])


class Prefetcher:
    """Double-buffered host->device prefetch (overlap input with step)."""

    def __init__(self, it: Iterator[dict], depth: int = 2,
                 sharding=None):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.sharding = sharding
        self._stop = False

        def worker():
            for item in it:
                if self._stop:
                    return
                dev = {k: (jax.device_put(v, self.sharding)
                           if self.sharding is not None
                           else jax.device_put(v))
                       for k, v in item.items()}
                self.q.put(dev)

        self.thread = threading.Thread(target=worker, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop = True
