"""SNAP temporal-network loading with deterministic synthetic stand-ins.

The paper evaluates on five SNAP temporal graphs (Table 1).  This container
is offline, so for each named dataset we provide:
  * a real loader for the SNAP text format (``u v t`` per line) if a file is
    present under ``$REPRO_DATA`` or ``data/``;
  * otherwise a *scaled-down synthetic stand-in* generated with the same
    qualitative structure (localised temporal updates, power-law degrees)
    and the same |E_T|/|E| duplication ratio, so every benchmark in
    benchmarks/ runs end-to-end offline.
"""
from __future__ import annotations

import functools
import os
import zlib
from dataclasses import dataclass

import numpy as np

from repro.graph.generators import temporal_stream_edges

# name -> (|V|, |E_T|, |E|) from paper Table 1, and the synthetic scale we
# use on CPU (|V|_synth).  Ratios |E_T|/|V| and |E_T|/|E| are preserved.
PAPER_TABLE1 = {
    "sx-mathoverflow":      (24_818, 506_550, 239_978),
    "sx-askubuntu":         (159_316, 964_437, 596_933),
    "sx-superuser":         (194_085, 1_443_339, 924_886),
    "wiki-talk-temporal":   (1_140_149, 7_833_140, 3_309_592),
    "sx-stackoverflow":     (2_601_977, 63_497_050, 36_233_450),
}
_SYNTH_SCALE_V = {
    # sized so per-iteration edge work dominates XLA-CPU dispatch overhead
    "sx-mathoverflow": 12_000,
    "sx-askubuntu": 16_000,
    "sx-superuser": 20_000,
    "wiki-talk-temporal": 30_000,
    "sx-stackoverflow": 40_000,
}


@dataclass
class TemporalDataset:
    name: str
    edges: np.ndarray        # int32[(T,2)] timestamp-ordered (u, v)
    num_vertices: int
    synthetic: bool


def _find_file(name: str):
    for root in (os.environ.get("REPRO_DATA", ""), "data", "/root/data"):
        if not root:
            continue
        for ext in (".txt", ".csv", ""):
            p = os.path.join(root, name + ext)
            if os.path.exists(p):
                return p
    return None


@functools.lru_cache(maxsize=8)
def load_temporal(name: str, seed: int = 0) -> TemporalDataset:
    if name not in PAPER_TABLE1:
        raise KeyError(f"unknown dataset {name}; options {list(PAPER_TABLE1)}")
    path = _find_file(name)
    if path is not None:
        raw = np.loadtxt(path, dtype=np.int64, comments=("#", "%"))
        order = np.argsort(raw[:, 2], kind="stable")
        edges = raw[order, :2]
        ids = np.unique(edges)
        remap = {int(v): i for i, v in enumerate(ids)}
        edges = np.vectorize(lambda v: remap[int(v)])(edges)
        return TemporalDataset(name, edges.astype(np.int32), len(ids), False)

    v_full, et_full, _ = PAPER_TABLE1[name]
    n = _SYNTH_SCALE_V[name]
    m = max(1000, int(et_full / v_full * n))      # preserve |E_T|/|V|
    # process-stable name hash: builtin hash() is randomized per process,
    # which regenerated a DIFFERENT synthetic graph on restart and broke
    # checkpoint-resume (restored ranks belonged to another graph)
    name_h = zlib.crc32(name.encode()) % 1000
    edges = temporal_stream_edges(n, m, seed=seed + name_h)
    return TemporalDataset(name, edges, n, True)


def all_paper_datasets(seed: int = 0):
    return [load_temporal(name, seed) for name in PAPER_TABLE1]
