"""shard_map DF/DF-P PageRank over the 2-D/3-D production mesh.

Layout (DESIGN.md §4, graph/partition.py): the ``model`` axis owns
contiguous dst ranges — vertex state (ranks, inv out-degree, frontier
mask) lives model-sharded, replicated across the data axes; the ``data``
(+``pod``) axes stripe the edges *within* each dst range.

One iteration on a device (m, p):
  1. all_gather across ``model`` of the rank/degree product PACKED with
     the previous sweep's above-tau_f mask (one [V/M, 2] gather — the
     {0,1} mask rides the float lanes exactly; expansion marks are
     consumed one sweep later, which only reassociates the affected-set
     union);
  2. gather per-edge contributions for the local stripe, segment-sum into
     the local dst range;
  3. psum partials across the data axes → exact pull-step contributions;
  4. DF / DF-P rank update + frontier expansion (and pruning): the
     per-stripe ``push_or`` marks are OR-combined across the data axes over
     the int8-compressed wire (collectives.bool_or_psum — exact for {0,1}).

The returned step is a single jit-able function whose while_loop carries
only model-shard-local state, so per-iteration wire traffic is one
packed [V/M, 2] all_gather + one contribution psum + one compressed mask
exchange — independent of |E|.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core.pagerank import (ALPHA, FRONTIER_TOL, MAX_ITER, PRUNE_TOL,
                                 TOL)
from repro.dist.collectives import bool_or_psum
from repro.dist.sharding import data_axes as _data_axes
from repro.graph.partition import (edges_per_device, partition_graph,
                                   vertices_per_shard)

from jax.sharding import NamedSharding, PartitionSpec as P


def _mesh_dims(mesh):
    if "model" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
    dax = _data_axes(mesh)
    sizes = dict(mesh.shape)
    m = sizes["model"]
    p = int(math.prod(sizes[a] for a in dax) or 1)
    return m, p, dax


def _edge_pspec(dax) -> P:
    stripe = dax[0] if len(dax) == 1 else dax
    return P("model", stripe, None)


def distributed_in_shardings(mesh):
    """NamedShardings for the 6 step args:
    (src, dst_local, valid, ranks, inv_out_deg, affected)."""
    dax = _data_axes(mesh)
    es = NamedSharding(mesh, _edge_pspec(dax))
    vs = NamedSharding(mesh, P("model"))
    return (es, es, es, vs, vs, vs)


def distributed_input_specs(mesh, n_vertices: int, edge_capacity: int,
                            dtype=jnp.float32):
    """Abstract (ShapeDtypeStruct) inputs for ``jit(...).lower`` — the
    balanced-stripe shapes of partition_graph for this mesh."""
    m, p, _ = _mesh_dims(mesh)
    v_pad = vertices_per_shard(n_vertices, m) * m
    e_dev = edges_per_device(edge_capacity, m, p)
    sds = jax.ShapeDtypeStruct
    return (sds((m, p, e_dev), jnp.int32),
            sds((m, p, e_dev), jnp.int32),
            sds((m, p, e_dev), jnp.bool_),
            sds((v_pad,), dtype),
            sds((v_pad,), dtype),
            sds((v_pad,), jnp.bool_))


class _DistState(NamedTuple):
    ranks: jax.Array          # local [V/M]
    base: jax.Array           # local bool[V/M]: affected, pre-expansion
    big: jax.Array            # local bool[V/M]: above tau_f last sweep
    ever: jax.Array           # local bool[V/M]
    delta: jax.Array          # replicated scalar
    it: jax.Array
    edges: jax.Array
    verts: jax.Array


def build_distributed_step(mesh, n_vertices: int, *,
                           alpha: float = ALPHA, tol: float = TOL,
                           frontier_tol: float = FRONTIER_TOL,
                           prune_tol: float = PRUNE_TOL,
                           max_iter: int = MAX_ITER,
                           prune: bool = False,
                           closed_form: Optional[bool] = None,
                           int8_frontier: bool = True,
                           full_result: bool = False):
    """DF (default) / DF-P (``prune=True``) iteration as one shard_map step.

    Returns ``fn(src, dst_local, valid, ranks, inv_out_deg, affected)``
    over partition_graph's layout: edge arrays [M, P, E_dev], vertex
    arrays [v_per·M] (padded; pad slots must be unaffected with
    inv_out_deg 0).  ``fn`` → (ranks, iterations, delta), plus
    (affected_ever, edges_processed, vertices_processed) when
    ``full_result``.  The fixed point matches core.pagerank — pruning,
    expansion and the DF-P closed form are applied per Jacobi iteration
    exactly as Algorithm 1 lines 9-26.
    """
    if closed_form is None:
        closed_form = prune
    _, _, dax = _mesh_dims(mesh)
    c0_val = (1.0 - alpha) / n_vertices

    def psum_data(x):
        return jax.lax.psum(x, dax) if dax else x

    def or_data(flags):
        if not dax:
            return flags
        if int8_frontier:
            return bool_or_psum(flags, dax)
        return jax.lax.psum(flags.astype(jnp.int32), dax) > 0

    def step(src, dst, valid, ranks, inv_deg, affected):
        src, dst, valid = src[0, 0], dst[0, 0], valid[0, 0]
        cdt = ranks.dtype
        ranks = ranks.astype(jnp.float64) \
            if jax.config.jax_enable_x64 else ranks
        inv = inv_deg.astype(ranks.dtype)
        v_per = ranks.shape[0]
        c0 = jnp.asarray(c0_val, ranks.dtype)
        tiny = jnp.asarray(jnp.finfo(ranks.dtype).tiny, ranks.dtype)
        in_deg = psum_data(jax.ops.segment_sum(
            valid.astype(jnp.int64), dst, num_segments=v_per))

        def push_marks(big_full):
            """Alg.1 line 22 marks for the local stripe: out-neighbours of
            the gathered above-tau_f set, OR-combined across stripes."""
            hit = valid & big_full[src]
            return or_data(jax.ops.segment_max(
                hit.astype(jnp.int32), dst, num_segments=v_per) > 0)

        def body(st: _DistState) -> _DistState:
            r = st.ranks
            # ONE [V/M, 2] all_gather per iteration: the R/d pull view
            # packed with last sweep's above-tau_f mask ({0,1} rides the
            # float lanes exactly), so expansion costs no extra gather —
            # its marks are simply consumed one sweep later, which only
            # reassociates the affected-set union, never changes it.
            packed = jnp.stack([r * inv, st.big.astype(r.dtype)], axis=1)
            full = jax.lax.all_gather(packed, "model", tiled=True)
            w_full = full[:, 0]
            marks = push_marks(full[:, 1] > 0)
            aff = st.base | st.big | marks

            w = jnp.where(valid, w_full[src], 0.0)
            contrib = psum_data(
                jax.ops.segment_sum(w, dst, num_segments=v_per))
            if closed_form:                       # DF-P (paper Eq. 2)
                r_all = (c0 + alpha * contrib) / (1.0 - alpha * inv)
            else:                                 # DF: self-loop as a term
                r_all = c0 + alpha * (contrib + r * inv)
            r_new = jnp.where(aff, r_all, r)
            dr = jnp.abs(r_new - r)
            rel = dr / jnp.maximum(jnp.maximum(r_new, r), tiny)
            delta = jax.lax.pmax(
                jnp.max(jnp.where(aff, dr, 0.0)), ("model",) + dax)

            base = aff
            if prune:                             # Alg.1 line 19
                base = base & ~(aff & (rel <= prune_tol))
            big = aff & (rel > frontier_tol)

            edges = st.edges + jax.lax.psum(
                jnp.sum(jnp.where(aff, in_deg, 0)), "model")
            verts = st.verts + jax.lax.psum(
                jnp.sum(aff.astype(jnp.int64)), "model")
            return _DistState(r_new, base, big, st.ever | aff, delta,
                              st.it + 1, edges, verts)

        def cond(st: _DistState):
            return (st.delta > tol) & (st.it < max_iter)

        st0 = _DistState(
            ranks=ranks, base=affected,
            big=jnp.zeros_like(affected), ever=affected,
            delta=jnp.asarray(jnp.inf, ranks.dtype),
            it=jnp.asarray(0, jnp.int32),
            edges=jnp.asarray(0, jnp.int64),
            verts=jnp.asarray(0, jnp.int64))
        out = jax.lax.while_loop(cond, body, st0)
        res = (out.ranks.astype(cdt), out.it, out.delta)
        if full_result:
            # fold in the final sweep's unexpanded marks so affected_ever
            # matches the single-device engine exactly
            last = jax.lax.all_gather(out.big, "model", tiled=True)
            res += (out.ever | push_marks(last), out.edges, out.verts)
        return res

    es = _edge_pspec(dax)
    vs = P("model")
    out_specs = (vs, P(), P())
    if full_result:
        out_specs += (vs, P(), P())
    return shard_map(step, mesh=mesh,
                     in_specs=(es, es, es, vs, vs, vs),
                     out_specs=out_specs, check_vma=False)


# ---------------------------------------------------------------------------
# streaming engine: host partitioning + a cached compiled step
# ---------------------------------------------------------------------------

class DistributedEngine:
    """Replays a dynamic-graph stream on a mesh with one compiled step.

    Pre-sizes the per-device edge capacity from the graph's (static)
    edge_capacity so the partition shape — and hence the compiled
    shard_map program — is stable across stream batches; a heavily skewed
    dst range can still grow e_dev, costing one retrace.
    """

    def __init__(self, mesh, n_vertices: int, edge_capacity: int, **opts):
        import numpy as np
        self._np = np
        self.mesh = mesh
        self.m, self.p, _ = _mesh_dims(mesh)
        self.n_vertices = n_vertices
        self.v_per = vertices_per_shard(n_vertices, self.m)
        self.v_pad = self.v_per * self.m
        self.e_dev = edges_per_device(edge_capacity, self.m, self.p)
        self._fn = jax.jit(build_distributed_step(
            mesh, n_vertices, full_result=True, **opts))
        self._shardings = distributed_in_shardings(mesh)

    def _pad(self, host_vec, dtype):
        np = self._np
        out = np.zeros((self.v_pad,), dtype)
        out[: self.n_vertices] = host_vec
        return out

    def run(self, graph, ranks, affected):
        """graph: EdgeListGraph; ranks f[V]; affected bool[V] →
        (ranks f[V], iterations, delta, affected_ever bool[V],
        edges_processed, vertices_processed)."""
        np = self._np
        part = partition_graph(graph, self.m, self.p,
                               min_edges_per_device=self.e_dev)
        self.e_dev = part.src.shape[2]            # sticky growth on skew
        deg = np.asarray(graph.out_degree(include_self_loop=True))
        inv = self._pad(1.0 / deg.astype(np.float64), np.float64)
        args = (jnp.asarray(part.src), jnp.asarray(part.dst_local),
                jnp.asarray(part.valid),
                jnp.asarray(self._pad(np.asarray(ranks), np.float64)),
                jnp.asarray(inv),
                jnp.asarray(self._pad(np.asarray(affected), bool)))
        args = tuple(jax.device_put(a, s)
                     for a, s in zip(args, self._shardings))
        r, it, delta, ever, edges, verts = self._fn(*args)
        return (r[: self.n_vertices], it, delta,
                ever[: self.n_vertices], edges, verts)
