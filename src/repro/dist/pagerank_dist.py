"""shard_map DF/DF-P PageRank over the 2-D/3-D production mesh.

Two engines live here:

  * the **XLA engine** (``build_distributed_step`` /
    ``DistributedEngine``): f64 segment_sum contributions over
    model-sharded vertex ranges and data-striped edges — the original
    distributed path, described below;
  * the **kernel engine** (``sharded_kernel_pagerank`` /
    ``ShardedKernelEngine``): the Pallas frontier-gated SpMV over a
    window-range-sharded ``PackedGraph`` (kernels.pagerank_spmv.shard),
    f32 iterations with a replicated rank vector maintained by one
    ``psum`` of shard-local contributions per iteration, then the same
    f32→f64 hybrid polish as the single-pod kernel engine
    (core.kernel_engine) over the union of shard affected_ever masks.
    This makes the fast path and the scale path the same path
    (DESIGN.md §9).

Layout (DESIGN.md §4, graph/partition.py): the ``model`` axis owns
contiguous dst ranges — vertex state (ranks, inv out-degree, frontier
mask) lives model-sharded, replicated across the data axes; the ``data``
(+``pod``) axes stripe the edges *within* each dst range.  The kernel
engine reuses the same dst-range ownership at window granularity.

One iteration on a device (m, p):
  1. all_gather across ``model`` of the rank/degree product PACKED with
     the previous sweep's above-tau_f mask (one [V/M, 2] gather — the
     {0,1} mask rides the float lanes exactly; expansion marks are
     consumed one sweep later, which only reassociates the affected-set
     union);
  2. gather per-edge contributions for the local stripe, segment-sum into
     the local dst range;
  3. psum partials across the data axes → exact pull-step contributions;
  4. DF / DF-P rank update + frontier expansion (and pruning): the
     per-stripe ``push_or`` marks are OR-combined across the data axes over
     the int8-compressed wire (collectives.bool_or_psum — exact for {0,1}).

The returned step is a single jit-able function whose while_loop carries
only model-shard-local state, so per-iteration wire traffic is one
packed [V/M, 2] all_gather + one contribution psum + one compressed mask
exchange — independent of |E|.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core import pagerank as pr
from repro.core.pagerank import (ALPHA, FRONTIER_TOL, MAX_ITER, PRUNE_TOL,
                                 TOL)
from repro.dist.collectives import bool_or_psum
from repro.dist.sharding import data_axes as _data_axes
from repro.obs import trace as obs_trace
from repro.obs.frontier import FrontierTelemetry
from repro.graph.partition import (edges_per_device, partition_graph,
                                   vertices_per_shard)

from jax.sharding import NamedSharding, PartitionSpec as P


def _mesh_dims(mesh):
    if "model" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
    dax = _data_axes(mesh)
    sizes = dict(mesh.shape)
    m = sizes["model"]
    p = int(math.prod(sizes[a] for a in dax) or 1)
    return m, p, dax


def _edge_pspec(dax) -> P:
    stripe = dax[0] if len(dax) == 1 else dax
    return P("model", stripe, None)


def distributed_in_shardings(mesh):
    """NamedShardings for the 6 step args:
    (src, dst_local, valid, ranks, inv_out_deg, affected)."""
    dax = _data_axes(mesh)
    es = NamedSharding(mesh, _edge_pspec(dax))
    vs = NamedSharding(mesh, P("model"))
    return (es, es, es, vs, vs, vs)


def distributed_input_specs(mesh, n_vertices: int, edge_capacity: int,
                            dtype=jnp.float32):
    """Abstract (ShapeDtypeStruct) inputs for ``jit(...).lower`` — the
    balanced-stripe shapes of partition_graph for this mesh."""
    m, p, _ = _mesh_dims(mesh)
    v_pad = vertices_per_shard(n_vertices, m) * m
    e_dev = edges_per_device(edge_capacity, m, p)
    sds = jax.ShapeDtypeStruct
    return (sds((m, p, e_dev), jnp.int32),
            sds((m, p, e_dev), jnp.int32),
            sds((m, p, e_dev), jnp.bool_),
            sds((v_pad,), dtype),
            sds((v_pad,), dtype),
            sds((v_pad,), jnp.bool_))


class _DistState(NamedTuple):
    ranks: jax.Array          # local [V/M]
    base: jax.Array           # local bool[V/M]: affected, pre-expansion
    big: jax.Array            # local bool[V/M]: above tau_f last sweep
    ever: jax.Array           # local bool[V/M]
    delta: jax.Array          # replicated scalar
    it: jax.Array
    edges: jax.Array
    verts: jax.Array


def build_distributed_step(mesh, n_vertices: int, *,
                           alpha: float = ALPHA, tol: float = TOL,
                           frontier_tol: float = FRONTIER_TOL,
                           prune_tol: float = PRUNE_TOL,
                           max_iter: int = MAX_ITER,
                           prune: bool = False,
                           closed_form: Optional[bool] = None,
                           int8_frontier: bool = True,
                           full_result: bool = False):
    """DF (default) / DF-P (``prune=True``) iteration as one shard_map step.

    Returns ``fn(src, dst_local, valid, ranks, inv_out_deg, affected)``
    over partition_graph's layout: edge arrays [M, P, E_dev], vertex
    arrays [v_per·M] (padded; pad slots must be unaffected with
    inv_out_deg 0).  ``fn`` → (ranks, iterations, delta), plus
    (affected_ever, edges_processed, vertices_processed) when
    ``full_result``.  The fixed point matches core.pagerank — pruning,
    expansion and the DF-P closed form are applied per Jacobi iteration
    exactly as Algorithm 1 lines 9-26.
    """
    if closed_form is None:
        closed_form = prune
    _, _, dax = _mesh_dims(mesh)
    c0_val = (1.0 - alpha) / n_vertices

    def psum_data(x):
        return jax.lax.psum(x, dax) if dax else x

    def or_data(flags):
        if not dax:
            return flags
        if int8_frontier:
            return bool_or_psum(flags, dax)
        return jax.lax.psum(flags.astype(jnp.int32), dax) > 0

    def step(src, dst, valid, ranks, inv_deg, affected):
        src, dst, valid = src[0, 0], dst[0, 0], valid[0, 0]
        cdt = ranks.dtype
        ranks = ranks.astype(jnp.float64) \
            if jax.config.jax_enable_x64 else ranks
        inv = inv_deg.astype(ranks.dtype)
        v_per = ranks.shape[0]
        c0 = jnp.asarray(c0_val, ranks.dtype)
        tiny = jnp.asarray(jnp.finfo(ranks.dtype).tiny, ranks.dtype)
        in_deg = psum_data(jax.ops.segment_sum(
            valid.astype(jnp.int64), dst, num_segments=v_per))

        def push_marks(big_full):
            """Alg.1 line 22 marks for the local stripe: out-neighbours of
            the gathered above-tau_f set, OR-combined across stripes."""
            hit = valid & big_full[src]
            return or_data(jax.ops.segment_max(
                hit.astype(jnp.int32), dst, num_segments=v_per) > 0)

        def body(st: _DistState) -> _DistState:
            r = st.ranks
            # ONE [V/M, 2] all_gather per iteration: the R/d pull view
            # packed with last sweep's above-tau_f mask ({0,1} rides the
            # float lanes exactly), so expansion costs no extra gather —
            # its marks are simply consumed one sweep later, which only
            # reassociates the affected-set union, never changes it.
            packed = jnp.stack([r * inv, st.big.astype(r.dtype)], axis=1)
            full = jax.lax.all_gather(packed, "model", tiled=True)
            w_full = full[:, 0]
            marks = push_marks(full[:, 1] > 0)
            aff = st.base | st.big | marks

            w = jnp.where(valid, w_full[src], 0.0)
            contrib = psum_data(
                jax.ops.segment_sum(w, dst, num_segments=v_per))
            if closed_form:                       # DF-P (paper Eq. 2)
                r_all = (c0 + alpha * contrib) / (1.0 - alpha * inv)
            else:                                 # DF: self-loop as a term
                r_all = c0 + alpha * (contrib + r * inv)
            r_new = jnp.where(aff, r_all, r)
            dr = jnp.abs(r_new - r)
            rel = dr / jnp.maximum(jnp.maximum(r_new, r), tiny)
            delta = jax.lax.pmax(
                jnp.max(jnp.where(aff, dr, 0.0)), ("model",) + dax)

            base = aff
            if prune:                             # Alg.1 line 19
                base = base & ~(aff & (rel <= prune_tol))
            big = aff & (rel > frontier_tol)

            edges = st.edges + jax.lax.psum(
                jnp.sum(jnp.where(aff, in_deg, 0)), "model")
            verts = st.verts + jax.lax.psum(
                jnp.sum(aff.astype(jnp.int64)), "model")
            return _DistState(r_new, base, big, st.ever | aff, delta,
                              st.it + 1, edges, verts)

        def cond(st: _DistState):
            return (st.delta > tol) & (st.it < max_iter)

        st0 = _DistState(
            ranks=ranks, base=affected,
            big=jnp.zeros_like(affected), ever=affected,
            delta=jnp.asarray(jnp.inf, ranks.dtype),
            it=jnp.asarray(0, jnp.int32),
            edges=jnp.asarray(0, jnp.int64),
            verts=jnp.asarray(0, jnp.int64))
        out = jax.lax.while_loop(cond, body, st0)
        res = (out.ranks.astype(cdt), out.it, out.delta)
        if full_result:
            # fold in the final sweep's unexpanded marks so affected_ever
            # matches the single-device engine exactly
            last = jax.lax.all_gather(out.big, "model", tiled=True)
            res += (out.ever | push_marks(last), out.edges, out.verts)
        return res

    es = _edge_pspec(dax)
    vs = P("model")
    out_specs = (vs, P(), P())
    if full_result:
        out_specs += (vs, P(), P())
    return shard_map(step, mesh=mesh,
                     in_specs=(es, es, es, vs, vs, vs),
                     out_specs=out_specs, check_vma=False)


# ---------------------------------------------------------------------------
# streaming engine: host partitioning + a cached compiled step
# ---------------------------------------------------------------------------

class DistributedEngine:
    """Replays a dynamic-graph stream on a mesh with one compiled step.

    Pre-sizes the per-device edge capacity from the graph's (static)
    edge_capacity so the partition shape — and hence the compiled
    shard_map program — is stable across stream batches; a heavily skewed
    dst range can still grow e_dev, costing one retrace.
    """

    def __init__(self, mesh, n_vertices: int, edge_capacity: int, **opts):
        import numpy as np
        self._np = np
        self.mesh = mesh
        self.m, self.p, _ = _mesh_dims(mesh)
        self.n_vertices = n_vertices
        self.v_per = vertices_per_shard(n_vertices, self.m)
        self.v_pad = self.v_per * self.m
        self.e_dev = edges_per_device(edge_capacity, self.m, self.p)
        self._fn = jax.jit(build_distributed_step(
            mesh, n_vertices, full_result=True, **opts))
        self._shardings = distributed_in_shardings(mesh)

    def _pad(self, host_vec, dtype):
        np = self._np
        out = np.zeros((self.v_pad,), dtype)
        out[: self.n_vertices] = host_vec
        return out

    def run(self, graph, ranks, affected):
        """graph: EdgeListGraph; ranks f[V]; affected bool[V] →
        (ranks f[V], iterations, delta, affected_ever bool[V],
        edges_processed, vertices_processed)."""
        np = self._np
        part = partition_graph(graph, self.m, self.p,
                               min_edges_per_device=self.e_dev)
        self.e_dev = part.src.shape[2]            # sticky growth on skew
        deg = np.asarray(graph.out_degree(include_self_loop=True))
        inv = self._pad(1.0 / deg.astype(np.float64), np.float64)
        args = (jnp.asarray(part.src), jnp.asarray(part.dst_local),
                jnp.asarray(part.valid),
                jnp.asarray(self._pad(np.asarray(ranks), np.float64)),
                jnp.asarray(inv),
                jnp.asarray(self._pad(np.asarray(affected), bool)))
        args = tuple(jax.device_put(a, s)
                     for a, s in zip(args, self._shardings))
        r, it, delta, ever, edges, verts = self._fn(*args)
        return (r[: self.n_vertices], it, delta,
                ever[: self.n_vertices], edges, verts)


# ---------------------------------------------------------------------------
# kernel engine on the mesh: window-range-sharded frontier-gated SpMV
# ---------------------------------------------------------------------------

# compiled sharded kernel loops, keyed by (mesh, spec, solver statics);
# FIFO-bounded like the XLA engine cache
_SHARDED_LOOPS: dict = {}
_SHARDED_LOOPS_MAX = 8


def _get_sharded_loop(mesh, spec, *, alpha: float, tol: float,
                      frontier_tol: float, prune_tol: float, max_iter: int,
                      closed_form: bool, prune: bool, expand: bool,
                      use_kernel: bool):
    """One compiled shard_map'd f32 kernel loop per (mesh, spec, flags).

    Mirrors ``core.kernel_engine.kernel_pagerank_loop`` with two
    distributed moves per iteration: the shard-local gated SpMV over the
    shard's windows, and one ``psum`` over ``model`` that reassembles the
    full contribution vector (per-shard supports are disjoint — shard s
    owns all in-edges of its dst windows — so the sum is exact, not an
    approximation).  Rank state, frontier masks and expansion
    (``graph.push_or``) stay replicated: every device runs the identical
    O(V)/O(E) mask math, only the O(active edges) SpMV is sharded.
    """
    from repro.kernels.pagerank_spmv import shard as _sh

    key = (mesh, spec, alpha, tol, frontier_tol, prune_tol, max_iter,
           closed_form, prune, expand, use_kernel)
    fn = _SHARDED_LOOPS.get(key)
    if fn is not None:
        return fn
    S, wps, vb = spec.num_shards, spec.windows_per_shard, spec.vb
    vps = spec.vertices_per_shard
    v_pad = spec.padded_vertices
    V = spec.num_vertices

    def step(sharded, graph, ranks_pad, inv_deg_pad, affected):
        _sh.TRACE_COUNTS["sharded_kernel_loop"] += 1   # trace-time only
        packed = _sh._local_packed(sharded, spec, index=0)
        idx = jax.lax.axis_index("model")
        entry_edges = jnp.sum((packed.valid > 0), axis=1).astype(jnp.int64)
        c0 = jnp.float32((1.0 - alpha) / V)
        a32 = jnp.float32(alpha)

        def body(state):
            r_pad, aff, ever, _, it, edges, verts = state
            aff_pad = jnp.pad(aff, (0, v_pad - V))
            active = jnp.any(aff_pad.reshape(S * wps, vb), axis=1)
            active_l = jax.lax.dynamic_slice(active, (idx * wps,), (wps,))
            rsc = r_pad * inv_deg_pad
            contrib_l = _sh.gated_contrib_shard(packed, rsc, active_l,
                                                use_kernel=use_kernel)
            contrib = jax.lax.psum(
                jax.lax.dynamic_update_slice(
                    jnp.zeros((v_pad,), jnp.float32), contrib_l,
                    (idx * vps,)), "model")
            if closed_form:
                r_new_all = (c0 + a32 * contrib) / (1.0 - a32 * inv_deg_pad)
            else:
                r_new_all = c0 + a32 * (contrib + r_pad * inv_deg_pad)
            r_new = jnp.where(aff_pad, r_new_all, r_pad)
            dr = jnp.abs(r_new - r_pad)[:V]
            rel = dr / jnp.maximum(jnp.maximum(r_new[:V], r_pad[:V]), 1e-30)
            delta = jnp.max(jnp.where(aff, dr, 0.0))
            new_aff = aff
            if prune:
                new_aff = new_aff & ~(aff & (rel <= prune_tol))
            if expand:
                big = aff & (rel > frontier_tol)
                new_aff = new_aff | graph.push_or(big) | big
            edges = edges + jax.lax.psum(jnp.sum(
                jnp.where(active_l[packed.window], entry_edges, 0)),
                "model")
            verts = verts + jax.lax.psum(
                jnp.sum(active_l.astype(jnp.int64)) * vb, "model")
            return (r_new, new_aff, ever | new_aff, delta, it + 1,
                    edges, verts)

        def cond(state):
            return (state[3] > tol) & (state[4] < max_iter)

        state0 = (ranks_pad, affected, affected,
                  jnp.asarray(jnp.inf, jnp.float32),
                  jnp.asarray(0, jnp.int32),
                  jnp.asarray(0, jnp.int64), jnp.asarray(0, jnp.int64))
        r_out, _, ever, delta, it, edges, verts = jax.lax.while_loop(
            cond, body, state0)
        return r_out, it, delta, ever, edges, verts

    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P("model"), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P()), check_vma=False))
    while len(_SHARDED_LOOPS) >= _SHARDED_LOOPS_MAX:
        _SHARDED_LOOPS.pop(next(iter(_SHARDED_LOOPS)))
    _SHARDED_LOOPS[key] = fn
    return fn


def _get_halo_loop(mesh, spec, halo_h: int, *, alpha: float, tol: float,
                   frontier_tol: float, prune_tol: float, max_iter: int,
                   closed_form: bool, prune: bool, expand: bool,
                   use_kernel: bool, wire: str):
    """Boundary-only sharded loop: rank stays SHARD-RESIDENT and each
    iteration exchanges just the halo table — O(boundary) wire, not O(V).

    Replaces ``_get_sharded_loop``'s replicated-rank recipe (full-rank
    ``psum`` every iteration) with the dist-engine exchange contract at
    window granularity:

      1. ONE ``[S, H, 2]`` psum per iteration carries every shard's
         owned (rank/deg, above-tau_f flag) values for every halo slot —
         each slot has exactly one owner, the rest contribute zeros, so
         the sum reconstructs the table exactly.  ``wire="quantized"``
         sends the {0,1} flags over the int8/s16 wire
         (collectives.bool_or_psum, exact) and only the f32 ranks at
         full width; ``wire="packed"`` rides both in f32 lanes.
      2. Each shard scatters its row into a local full-width rsc/flag
         buffer (own range + halo; all other slots are zero and by
         construction unread: every src in the shard's lanes is either
         owned or in its halo), runs the gated SpMV over its OWN windows
         only, and updates its local rank slice in place.
      3. Frontier expansion marks come from the shard's own packed lanes
         (``valid & big[src]`` segment-max into local windows) — the
         replicated ``graph.push_or`` is gone.  Like the XLA dist
         engine, expansion marks are consumed ONE SWEEP LATER (the
         ``[.., flag]`` lane carries the previous sweep's mask), which
         only reassociates the affected-set union; the final sweep's
         marks are folded in after the loop with one extra exchange.

    The full rank vector is reassembled (out_spec ``P("model")`` concat)
    only once, at convergence.
    """
    from repro.kernels.pagerank_spmv import shard as _sh

    key = (mesh, spec, halo_h, wire, alpha, tol, frontier_tol, prune_tol,
           max_iter, closed_form, prune, expand, use_kernel)
    fn = _SHARDED_LOOPS.get(key)
    if fn is not None:
        return fn
    S, wps, vb = spec.num_shards, spec.windows_per_shard, spec.vb
    vps = spec.vertices_per_shard
    v_pad = spec.padded_vertices
    V = spec.num_vertices

    def step(sharded, halo_ids, r_loc, inv_loc, aff_loc):
        _sh.TRACE_COUNTS["sharded_kernel_loop"] += 1   # trace-time only
        packed = _sh._local_packed(sharded, spec, index=0)
        me = jax.lax.axis_index("model")
        lo = me * vps
        entry_edges = jnp.sum((packed.valid > 0), axis=1).astype(jnp.int64)
        c0 = jnp.float32((1.0 - alpha) / V)
        a32 = jnp.float32(alpha)
        src_flat = packed.src.reshape(-1)
        valid_flat = packed.valid.reshape(-1) > 0
        dst_local = (packed.window[:, None] * vb
                     + packed.dst_rel).reshape(-1)
        owned = (halo_ids >= lo) & (halo_ids < lo + vps)      # [S, H]
        lid = jnp.clip(halo_ids - lo, 0, vps - 1)

        def exchange(rsc_loc, big_loc):
            """halo table in, (rsc_full, big_full) local buffers out."""
            vals = jnp.where(owned, rsc_loc[lid], 0.0)
            fl = jnp.where(owned, big_loc[lid], False)
            if wire == "quantized":
                vals = jax.lax.psum(vals, "model")
                fl = bool_or_psum(fl, "model")
            else:
                both = jax.lax.psum(
                    jnp.stack([vals, fl.astype(jnp.float32)], axis=-1),
                    "model")
                vals, fl = both[..., 0], both[..., 1] > 0
            my_ids = halo_ids[me]
            rsc_full = jax.lax.dynamic_update_slice(
                jnp.zeros((v_pad,), jnp.float32), rsc_loc, (lo,))
            rsc_full = rsc_full.at[my_ids].set(vals[me], mode="drop")
            big_full = jax.lax.dynamic_update_slice(
                jnp.zeros((v_pad,), bool), big_loc, (lo,))
            big_full = big_full.at[my_ids].set(fl[me], mode="drop")
            return rsc_full, big_full

        def marks_from(big_full):
            hit = valid_flat & big_full[src_flat]
            return jax.ops.segment_max(hit.astype(jnp.int32), dst_local,
                                       num_segments=vps) > 0

        def body(state):
            r, base, big, ever, _, it, edges, verts = state
            rsc_full, big_full = exchange(r * inv_loc, big)
            aff = base | big
            if expand:
                aff = aff | marks_from(big_full)
            active_l = jnp.any(aff.reshape(wps, vb), axis=1)
            contrib_l = _sh.gated_contrib_shard(packed, rsc_full, active_l,
                                                use_kernel=use_kernel)
            if closed_form:
                r_all = (c0 + a32 * contrib_l) / (1.0 - a32 * inv_loc)
            else:
                r_all = c0 + a32 * (contrib_l + r * inv_loc)
            r_new = jnp.where(aff, r_all, r)
            dr = jnp.abs(r_new - r)
            rel = dr / jnp.maximum(jnp.maximum(r_new, r), 1e-30)
            delta = jax.lax.pmax(jnp.max(jnp.where(aff, dr, 0.0)), "model")
            new_base = aff
            if prune:
                new_base = new_base & ~(aff & (rel <= prune_tol))
            new_big = (aff & (rel > frontier_tol)) if expand \
                else jnp.zeros_like(aff)
            edges = edges + jax.lax.psum(jnp.sum(
                jnp.where(active_l[packed.window], entry_edges, 0)),
                "model")
            verts = verts + jax.lax.psum(
                jnp.sum(active_l.astype(jnp.int64)) * vb, "model")
            return (r_new, new_base, new_big, ever | aff, delta, it + 1,
                    edges, verts)

        def cond(state):
            return (state[4] > tol) & (state[5] < max_iter)

        state0 = (r_loc, aff_loc, jnp.zeros_like(aff_loc), aff_loc,
                  jnp.asarray(jnp.inf, jnp.float32),
                  jnp.asarray(0, jnp.int32),
                  jnp.asarray(0, jnp.int64), jnp.asarray(0, jnp.int64))
        r_out, _, big, ever, delta, it, edges, verts = jax.lax.while_loop(
            cond, body, state0)
        if expand:
            # fold in the final sweep's unconsumed marks (one extra
            # exchange), matching the XLA dist engine's full_result
            _, big_full = exchange(r_out * inv_loc, big)
            ever = ever | marks_from(big_full)
        return r_out, it, delta, ever, edges, verts

    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P("model"), P(), P("model"), P("model"), P("model")),
        out_specs=(P("model"), P(), P(), P("model"), P(), P()),
        check_vma=False))
    while len(_SHARDED_LOOPS) >= _SHARDED_LOOPS_MAX:
        _SHARDED_LOOPS.pop(next(iter(_SHARDED_LOOPS)))
    _SHARDED_LOOPS[key] = fn
    return fn


# nominal per-link ICI bandwidth used ONLY to give the modeled
# ``halo.exchange`` trace span a plausible duration — never for decisions
_LINK_BW_BYTES_PER_S = 25e9


def halo_comm_bytes(halo, iterations: int, *, wire: str = "packed",
                    expand: bool = True) -> int:
    """Wire bytes of one solve's halo exchanges (per device): each
    iteration moves the [S, H] rank lanes (f32) plus the flag lanes (f32
    packed, or s16 over the quantized wire), and the final fold-in is
    one more exchange.  Sublinear in V: proportional to S·H, the padded
    boundary size."""
    from repro.kernels.pagerank_spmv.shard import halo_slots

    slots = halo_slots(halo)
    per_iter = slots * (4 + (2 if wire == "quantized" else 4))
    return (int(iterations) + (1 if expand else 0)) * per_iter


def sharded_hybrid_pagerank(mesh, sharded, spec, graph, init_ranks,
                            init_affected, *, alpha: float = ALPHA,
                            tol: float = TOL, tol_f32: float = 1e-7,
                            frontier_tol: float = FRONTIER_TOL,
                            prune_tol: float = PRUNE_TOL,
                            kernel_frontier_tol: float = 1e-5,
                            kernel_prune_tol: float = 1e-5,
                            max_iter: int = MAX_ITER,
                            closed_form: bool = False, prune: bool = False,
                            expand: bool = True, polish: bool = True,
                            use_kernel: bool = False, halo=None,
                            wire: str = "packed",
                            comm_info: Optional[dict] = None,
                            telemetry: bool = False
                            ) -> pr.PageRankResult:
    """The sharded precision ladder: f32 kernel iterations on the mesh to
    ``tol_f32``, then the f64 XLA polish on the default device seeded
    with the union of shard ``affected_ever`` masks — same fixed point
    and ``PageRankResult`` contract as ``core.kernel_engine
    .hybrid_pagerank`` and the f64 engine (L∞ ≤ 1e-6, DESIGN.md §8-§9).

    ``halo`` (a ``shard.HaloSpec``) switches the f32 phase to the
    boundary-only exchange loop — shard-resident ranks, per-iteration
    wire ∝ halo size instead of V (``wire="quantized"`` compresses the
    flag lanes over the int8/s16 wire; the f64 polish stays exact
    either way).  ``comm_info`` (a dict, mutated) receives the solve's
    ``comm_bytes`` / ``halo_slots`` / ``f32_iterations`` accounting.

    ``telemetry=True`` records per-iteration obs.frontier rows in the
    polish phase (the sharded f32 loops expose only their endpoint
    scalars — per-iteration rows would ride the wire every sweep, so the
    f32 phase is summarized in ``comm_info`` instead); the tracer gets a
    span per mesh program and a modeled ``halo.exchange`` span from the
    wire accounting (the exchange runs inside the compiled loop and
    cannot be host-timed; ``args["modeled"]`` marks it).
    """
    import numpy as np

    tr = obs_trace.get_tracer()
    V = spec.num_vertices
    v_pad = spec.padded_vertices
    deg = graph.out_degree(include_self_loop=True)
    inv_pad = jnp.pad((1.0 / deg).astype(jnp.float32), (0, v_pad - V))
    r_pad = jnp.pad(init_ranks.astype(jnp.float32), (0, v_pad - V))
    s0 = tr.now()
    if halo is not None:
        loop = _get_halo_loop(mesh, spec, halo.ids.shape[1], alpha=alpha,
                              tol=tol_f32,
                              frontier_tol=kernel_frontier_tol,
                              prune_tol=kernel_prune_tol,
                              max_iter=max_iter, closed_form=closed_form,
                              prune=prune, expand=expand,
                              use_kernel=use_kernel, wire=wire)
        aff_pad = jnp.pad(init_affected, (0, v_pad - V))
        r_out, it, delta, ever, edges, verts = loop(
            sharded, halo.ids, r_pad, inv_pad, aff_pad)
        ever = ever[:V]
        if comm_info is not None:
            from repro.kernels.pagerank_spmv.shard import halo_slots
            comm_info["f32_iterations"] = int(it)
            comm_info["halo_slots"] = halo_slots(halo)
            comm_info["comm_bytes"] = halo_comm_bytes(
                halo, int(it), wire=wire, expand=expand)
    else:
        loop = _get_sharded_loop(mesh, spec, alpha=alpha, tol=tol_f32,
                                 frontier_tol=kernel_frontier_tol,
                                 prune_tol=kernel_prune_tol,
                                 max_iter=max_iter, closed_form=closed_form,
                                 prune=prune, expand=expand,
                                 use_kernel=use_kernel)
        r_out, it, delta, ever, edges, verts = loop(sharded, graph, r_pad,
                                                    inv_pad, init_affected)
        if comm_info is not None:
            # replicated-rank recipe: one full-rank [v_pad] f32 psum per
            # iteration on every device — the O(V) cost the halo removes
            comm_info["f32_iterations"] = int(it)
            comm_info["halo_slots"] = 0
            comm_info["comm_bytes"] = int(it) * v_pad * 4
    if tr.enabled:
        tr.sync(r_out)
        tr.record("sharded_f32_loop", s0, tr.now() - s0,
                  exchange="halo" if halo is not None else "psum",
                  iterations=int(it))
        cb = (comm_info or {}).get("comm_bytes")
        if cb is None:
            cb = halo_comm_bytes(halo, int(it), wire=wire, expand=expand) \
                if halo is not None else int(it) * v_pad * 4
        # the exchange lives inside the compiled loop — model its span
        # from the wire accounting instead of pretending to host-time it
        tr.record("halo.exchange", s0, cb / _LINK_BW_BYTES_PER_S,
                  comm_bytes=int(cb), modeled=True,
                  wire=wire if halo is not None else "psum")
    # hop the replicated results off the mesh so the f64 polish runs as a
    # plain single-device jit (mixing committed mesh arrays into it would
    # be a device mismatch)
    k_ranks = jnp.asarray(np.asarray(r_out[:V]))
    ever = jnp.asarray(np.asarray(ever))
    it = jnp.asarray(np.asarray(it))
    edges = jnp.asarray(np.asarray(edges))
    verts = jnp.asarray(np.asarray(verts))
    if not polish:
        return pr.PageRankResult(k_ranks.astype(jnp.float64), it,
                                 jnp.asarray(np.asarray(delta),
                                             jnp.float64),
                                 ever, edges, verts)
    with tr.span("polish.f64", program="xla_polish"):
        p = pr._pagerank_loop(graph, k_ranks.astype(jnp.float64), ever,
                              alpha=alpha, tol=tol,
                              frontier_tol=frontier_tol,
                              prune_tol=prune_tol, max_iter=max_iter,
                              closed_form=closed_form, prune=prune,
                              expand=expand, telemetry=telemetry)
        tr.sync(p.ranks)
    tel = None
    if telemetry and p.telemetry is not None:
        tel = FrontierTelemetry.from_padded(p.telemetry, p.iterations).data
    return pr.PageRankResult(p.ranks, it + p.iterations, p.delta,
                             ever | p.affected_ever,
                             edges + p.edges_processed,
                             verts + p.vertices_processed,
                             telemetry=tel)


def sharded_kernel_pagerank(graph, init_ranks, init_affected, mesh, *,
                            sharded=None, spec=None, pack_kw=None,
                            **kw) -> pr.PageRankResult:
    """One-shot ``engine="kernel"`` on a mesh: pack (unless the caller
    maintains the sharded structure incrementally — see
    ``ShardedKernelEngine``) and run the sharded hybrid ladder."""
    from repro.kernels.pagerank_spmv.shard import build_halo, pack_shards

    if "model" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
    if sharded is None:
        sharded, spec = pack_shards(graph, int(mesh.shape["model"]),
                                    **(pack_kw or {}))
    if kw.pop("exchange", "halo") == "halo" and "halo" not in kw:
        kw["halo"] = build_halo(sharded, spec)
    return sharded_hybrid_pagerank(mesh, sharded, spec, graph, init_ranks,
                                   init_affected, **kw)


class ShardedKernelEngine:
    """Streaming owner of the sharded kernel path: one sharded pack per
    bootstrap, per-batch delta routing + shard_map'd incremental update,
    one compiled kernel loop — the mesh analogue of the ``ServeEngine``'s
    single-pod kernel path.

    All pack statics are pinned at construction (entry capacity, the
    per-window entry bound, overlay size), so overflow ``repack``s never
    change the ``ShardSpec`` and therefore never retrace the compiled
    update or loop.  ``delta_budget`` bounds the routed per-shard rows of
    each micro-batch (None = the full batch capacity — any batch fits);
    overflowing it, a window's spill lanes or the locator overlay raises
    ``ShardCapacityError`` naming the shards, which stream owners resolve
    by ``repack`` (the serve engine counts these per shard).

    ``exchange="halo"`` (the default) keeps ranks shard-resident and
    exchanges only the cross-shard boundary each f32 iteration: the halo
    table is built at bootstrap, extended on-device as routed insertions
    land (capacity-checked like every other structure; a repack rebuilds
    it exactly, shedding deletion-stale slots), and its pinned capacity
    keeps the compiled loop's shapes static.  ``exchange="psum"`` is the
    replicated-rank full-psum recipe (the PR-5 baseline, kept for
    differentials).  After each solve, ``last_comm_info`` /
    ``last_comm_bytes`` expose the per-solve wire accounting.
    """

    def __init__(self, mesh, graph, *, pack_kw=None, delta_budget=None,
                 use_kernel: bool = False, exchange: str = "halo",
                 wire: str = "packed", halo_capacity=None, **loop_kw):
        from repro.kernels.pagerank_spmv.shard import (build_halo,
                                                       build_sharded_apply,
                                                       pack_shards)

        if "model" not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
        if exchange not in ("halo", "psum"):
            raise ValueError(f"exchange must be 'halo' or 'psum', "
                             f"got {exchange!r}")
        self.mesh = mesh
        self.num_shards = int(mesh.shape["model"])
        pack_kw = dict(pack_kw or {})
        pack_kw.setdefault("spill_lanes_per_window", 1)
        self.sharded, spec = pack_shards(graph, self.num_shards, **pack_kw)
        # pin every static: repacks must not change any shape or static
        # field (max_entries_per_window at the trivially safe bound —
        # a repack may redistribute entries to windows that grew)
        self.spec = spec._replace(max_entries_per_window=spec.num_entries)
        pack_kw["num_entries"] = self.spec.num_entries
        pack_kw["max_entries_per_window"] = self.spec.num_entries
        pack_kw["overlay_capacity"] = self.spec.overlay_capacity
        pack_kw.pop("extra_entries", None)
        self._pack_kw = pack_kw
        self.delta_budget = delta_budget
        self.use_kernel = use_kernel
        self.exchange = exchange
        self.wire = wire
        self.halo = None
        if exchange == "halo":
            self.halo = build_halo(self.sharded, self.spec,
                                   capacity=halo_capacity)
            self._halo_capacity = int(self.halo.ids.shape[1])
        self.last_comm_info: dict = {}
        self.last_comm_bytes = 0
        self.loop_kw = loop_kw
        self._apply = build_sharded_apply(mesh, self.spec)

    def apply_update(self, update):
        """Route Δ to its owning shards, apply under shard_map, extend
        the halo with any inserted boundary srcs.  Raises
        ``ShardCapacityError`` (budget/spill/overlay/halo) unchanged —
        the structures are only replaced on success, atomically."""
        import numpy as np

        from repro.kernels.pagerank_spmv.shard import (ShardCapacityError,
                                                       extend_halo,
                                                       route_update)

        routed = route_update(update, self.spec,
                              del_budget=self.delta_budget,
                              ins_budget=self.delta_budget)
        new, dropped = self._apply(self.sharded, routed)
        d = np.asarray(dropped)
        if d.sum():
            bad = tuple(int(s) for s in np.flatnonzero(d))
            raise ShardCapacityError(
                f"{int(d.sum())} insertions exceed spill capacity of "
                f"their dst windows or the locator overlay on shards "
                f"{bad}; repack with pack_shards (capacity sizing: "
                "DESIGN.md §8-§9)", shards=bad)
        new_halo = None
        if self.halo is not None:
            new_halo = extend_halo(self.halo, routed, self.spec)
        self.sharded = new
        if new_halo is not None:
            self.halo = new_halo

    def repack(self, graph):
        """Rebuild the sharded pack from ``graph`` at the pinned shapes,
        degrading the spill guarantee to the sharded minimum (1 lane) if
        regrown windows no longer fit it — same recovery contract as the
        single-pod serve path.  The halo is rebuilt exactly (stale slots
        dropped); if the boundary outgrew its pinned capacity the table
        grows, costing the one loop recompile the growth forces."""
        from repro.kernels.pagerank_spmv.shard import (ShardCapacityError,
                                                       build_halo,
                                                       pack_shards)

        try:
            sharded, spec = pack_shards(graph, self.num_shards,
                                        **self._pack_kw)
        except ValueError:
            sharded, spec = pack_shards(
                graph, self.num_shards,
                **{**self._pack_kw, "spill_lanes_per_window": 1})
        spec = spec._replace(max_entries_per_window=self.spec.num_entries)
        assert spec == self.spec, "repack changed pinned statics"
        self.sharded = sharded
        if self.halo is not None:
            try:
                self.halo = build_halo(self.sharded, self.spec,
                                       capacity=self._halo_capacity)
            except ShardCapacityError:
                self.halo = build_halo(self.sharded, self.spec)
                self._halo_capacity = int(self.halo.ids.shape[1])

    def solve(self, graph, init_ranks, init_affected,
              **flags) -> pr.PageRankResult:
        self.last_comm_info = {}
        res = sharded_hybrid_pagerank(
            self.mesh, self.sharded, self.spec, graph, init_ranks,
            init_affected, use_kernel=self.use_kernel, halo=self.halo,
            wire=self.wire, comm_info=self.last_comm_info,
            **{**self.loop_kw, **flags})
        self.last_comm_bytes = self.last_comm_info.get("comm_bytes", 0)
        return res
