"""Logical sharding hints for model code (DESIGN.md §4).

Model layers annotate activations with *logical* axis names and this module
resolves them against whatever mesh is active (``with jax.set_mesh(mesh)``);
with no active mesh every hint is a no-op, so the same model code runs in
single-device smoke tests and on the production mesh unchanged.

Logical axes:
  * ``"batch"`` — the data-parallel axes (``data``, plus ``pod`` when the
    mesh has one): batch/token dims of activations;
  * ``"tp"``    — the ``model`` axis: feature/vocab/expert dims;
  * ``"full"``  — every mesh axis combined: giant node/edge tables that
    should be flat-sharded over the whole slice (GNN scatter outputs);
  * ``None``    — replicated / no constraint for that dim.

A hint only applies when the dim size is divisible by the resolved axis
size — otherwise that dim silently stays unconstrained (GSPMD would pad,
and padded segment-sums corrupt masked graph reductions).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import active_mesh


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if hasattr(mesh, "devices") else dict(mesh.shape)


def _resolve(name, mesh) -> tuple:
    """Logical name -> tuple of mesh axis names present on this mesh."""
    if name is None:
        return ()
    names = _axis_sizes(mesh)
    if name == "tp":
        axes = ("model",)
    elif name == "batch":
        axes = ("pod", "data")
    elif name == "full":
        axes = ("pod", "data", "model")
    else:                                   # explicit mesh axis name
        axes = (name,)
    return tuple(a for a in axes if a in names)


def data_shards() -> int:
    """Number of shards on the data-parallel axes of the active mesh (1 when
    no mesh is active) — used by MoE dispatch for shard-local ranking."""
    mesh = active_mesh()
    if mesh is None:
        return 1
    sizes = _axis_sizes(mesh)
    return int(math.prod(sizes[a] for a in _resolve("batch", mesh)) or 1)


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """``with_sharding_constraint`` with logical names, one per dim of x.

    No-op when no mesh is active, when a named axis is absent from the
    mesh, or when the dim size is not divisible by the axis size.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    sizes = _axis_sizes(mesh)
    spec = []
    for dim, name in enumerate(logical_axes):
        axes = _resolve(name, mesh)
        n = math.prod(sizes[a] for a in axes) if axes else 0
        if axes and n > 0 and dim < x.ndim and x.shape[dim] % n == 0:
            spec.append(axes[0] if len(axes) == 1 else axes)
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
