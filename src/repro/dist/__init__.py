"""repro.dist — the multi-device layer (DESIGN.md §4).

Modules:
  * ``pagerank_dist``  — shard_map DF/DF-P PageRank over the 2-D/3-D mesh
    (XLA engine) plus the window-range-sharded kernel engine
    (``ShardedKernelEngine`` / ``sharded_kernel_pagerank``, DESIGN.md §9);
  * ``collectives``    — low-precision collective primitives (int8_psum);
  * ``constraints``    — logical sharding hints for the model zoo;
  * ``sharding``       — NamedSharding trees per arch family (dry-run).

Kept import-light: importing ``repro.dist`` must not touch jax device
state (launch/dryrun.py forces the device count *before* importing jax).
"""
from repro.dist import collectives, constraints, pagerank_dist, sharding

__all__ = ["collectives", "constraints", "pagerank_dist", "sharding"]
