"""Low-precision collective primitives (DESIGN.md §4.3).

``int8_psum`` is the cross-pod wire-compression trick: symmetric per-row
int8 quantization, an s16-widened all-reduce (8x less wire traffic than
f32 for the payload), dequantize.  The s16 wire dtype is the contract —
the sum of up to 256 int8 shards fits s16 exactly (256·127 = 32512 <
32767), so the reduction itself is lossless and the only error is the
per-shard rounding, bounded by ``n_shards · max|x| / 127 / 2``.

Used by the distributed PageRank step for the frontier-mask exchange,
where values are {0, 1}: with the shared scale ``1/127`` quantization is
EXACT, so frontier compression costs zero accuracy.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

AxisNames = Union[str, Tuple[str, ...]]

# sum of int8 lanes stays inside s16 up to this many shards
MAX_WIRE_SHARDS = 256


def int8_psum(x: jax.Array, axis: AxisNames) -> jax.Array:
    """psum(x, axis) over an int8-quantized wire with an s16 all-reduce.

    Per-row symmetric quantization: the scale is shared across the reduced
    axis (one extra scalar/row f32 all-reduce of the absmax), so the
    widened integer sum dequantizes consistently.  ``x``: any float array;
    rows are the leading dims, the quantization group is the last dim
    (whole array when 1-D).  Only valid inside shard_map/pmap where
    ``axis`` names are bound; axis size must be <= MAX_WIRE_SHARDS.
    """
    n_shards = jax.lax.psum(1, axis)           # static at trace time
    if n_shards > MAX_WIRE_SHARDS:
        raise ValueError(
            f"int8_psum over {n_shards} shards would overflow the s16 "
            f"wire (max {MAX_WIRE_SHARDS}); reduce hierarchically")
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if xf.ndim >= 2:
        amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)   # per row
    else:
        amax = jnp.max(jnp.abs(xf))                           # whole shard
    amax = jax.lax.pmax(amax, axis)            # shared scale across shards
    scale = jnp.maximum(amax, jnp.float32(1e-30)) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    wire = jax.lax.psum(q.astype(jnp.int16), axis)            # s16 wire
    return (wire.astype(jnp.float32) * scale).astype(dtype)


def bool_or_psum(flags: jax.Array, axis: AxisNames) -> jax.Array:
    """OR-reduce a boolean mask across ``axis`` over the int8 wire.

    {0,1} payloads quantize exactly (scale 1/127), so this is a lossless
    frontier exchange at 1/4 the wire bytes of an i32 psum.
    """
    count = int8_psum(flags.astype(jnp.float32), axis)
    return count > 0.5
