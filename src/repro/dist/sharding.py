"""NamedSharding trees per arch family for the dry-run cells (DESIGN.md §4).

The rules mirror the logical constraints the models annotate
(dist.constraints): LM/MoE weights shard their feature/vocab/expert dim
over ``model`` (tensor parallelism; expert parallelism for MoE stacks),
batches shard their leading dim over the data axes, GNN parameters are
small and replicated (their giant node/edge *activations* are
constraint-sharded instead), recsys embedding tables shard row-wise over
``model``.  Optimizer state inherits the parameter rules leaf-for-leaf —
ZeRO-style sharding falls out for free (optim/adamw.py).

Every rule is divisibility-guarded: a dim that doesn't divide evenly over
its axis stays replicated rather than letting GSPMD pad it.
"""
from __future__ import annotations

import math
from typing import Any, Optional

from jax.sharding import NamedSharding, PartitionSpec as P


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    sizes = dict(mesh.shape)
    return int(math.prod(sizes[a] for a in axes) or 1)


def _map_named(obj, fn, path=()):
    """tree_map that exposes NamedTuple/dict field names as the path —
    model classes are matched by field name, never imported (repro.dist
    sits below repro.models in the layering)."""
    if obj is None:
        return None
    if hasattr(obj, "_fields"):                 # NamedTuple
        return type(obj)(*[_map_named(getattr(obj, f), fn, path + (f,))
                           for f in obj._fields])
    if isinstance(obj, dict):
        return {k: _map_named(v, fn, path + (k,)) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(_map_named(v, fn, path + (i,))
                         for i, v in enumerate(obj))
    return fn(path, obj)


def _shard_dim(mesh, leaf, dim: Optional[int], axes=("model",)):
    """NS sharding ``dim`` over ``axes`` when present+divisible, else
    replicated."""
    shape = getattr(leaf, "shape", None)
    if shape is None:
        return None                             # python scalar in a batch
    axes = tuple(a for a in axes if a in mesh.axis_names)
    n = _axis_size(mesh, axes)
    if (dim is None or not axes or n <= 1 or dim >= len(shape)
            or shape[dim] % n != 0):
        return NamedSharding(mesh, P())
    spec = [None] * len(shape)
    spec[dim] = axes[0] if len(axes) == 1 else axes
    return NamedSharding(mesh, P(*spec))


# --- per-family parameter rules (leaf name -> dim sharded over 'model') ----

_LM_LAST = ("wq", "wk", "wv", "w_gate", "w_up", "bq", "bk", "bv",
            "w_router")
_LM_SECOND_LAST = ("wo", "w_down")


def _lm_rule(mesh, path, leaf):
    names = [p for p in path if isinstance(p, str)]
    name = names[-1] if names else ""
    ndim = len(getattr(leaf, "shape", ()))
    if name == "embed":
        return _shard_dim(mesh, leaf, 0)        # vocab rows over 'model'
    if "moe" in names and name in ("w_gate", "w_up", "w_down"):
        return _shard_dim(mesh, leaf, 1)        # [L, E, ...]: expert dim
    if name in _LM_LAST:
        return _shard_dim(mesh, leaf, ndim - 1)
    if name in _LM_SECOND_LAST:
        return _shard_dim(mesh, leaf, ndim - 2)
    return _shard_dim(mesh, leaf, None)         # norms, scalars


def _recsys_rule(mesh, path, leaf):
    names = [p for p in path if isinstance(p, str)]
    name = names[-1] if names else ""
    ndim = len(getattr(leaf, "shape", ()))
    if name in ("table", "table_w"):
        return _shard_dim(mesh, leaf, 0)        # embedding rows
    if name == "mlp_ws":
        return _shard_dim(mesh, leaf, ndim - 1)
    return _shard_dim(mesh, leaf, None)


def _gnn_rule(mesh, path, leaf):
    return _shard_dim(mesh, leaf, None)         # params small: replicate


_PARAM_RULES = {"lm": _lm_rule, "gnn": _gnn_rule, "recsys": _recsys_rule}


def _batch_rule(mesh, path, leaf):
    dax = data_axes(mesh)
    return _shard_dim(mesh, leaf, 0, dax)       # leading dim data-parallel


def family_shardings(family: str, mesh, params: Any, batch: Any,
                     opt: Any = None):
    """(param_shardings, batch_shardings, opt_shardings|None) trees for
    ``jit(in_shardings=...)`` over the family's train/serve steps."""
    rule = _PARAM_RULES[family]
    pspec = _map_named(params, lambda p, l: rule(mesh, p, l))
    bspec = _map_named(batch, lambda p, l: _batch_rule(mesh, p, l))
    ospec = None
    if opt is not None:
        # AdamWState mirrors params under 'm'/'v' so the name rules apply;
        # factored (v_row, v_col) leaves fall back per their own shapes.
        ospec = _map_named(opt, lambda p, l: rule(mesh, p, l))
    return pspec, bspec, ospec


def lm_cache_specs(mesh, cache, batch: int):
    """KV-cache shardings: batch over the data axes, KV heads over
    ``model`` (both divisibility-guarded); seq stays unsharded because the
    decode step dynamic-updates one position per step."""
    dax = data_axes(mesh)
    k = cache.k                                  # [L, B, S_max, KVH, hd]
    spec = [None] * 5
    if dax and batch % _axis_size(mesh, dax) == 0:
        spec[1] = dax[0] if len(dax) == 1 else dax
    if k.shape[3] % _axis_size(mesh, "model") == 0:
        spec[3] = "model"
    kv = NamedSharding(mesh, P(*spec))
    return type(cache)(k=kv, v=kv, length=NamedSharding(mesh, P()))
