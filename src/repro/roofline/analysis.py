"""Roofline: 3 terms per (arch × shape × mesh) from the dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory     = HLO_bytes_per_device / HBM_bw              [s]
    collective = collective_bytes_per_device / link_bw      [s]

HLO terms come from the **counting-mode** lowering for LM archs (layer
scans unrolled via the L=1/L=2 delta — launch/dryrun.py) and directly from
the compiled module otherwise; XLA cost_analysis is per-device-program, so
no ÷chips is applied.  The dominant term is the bottleneck the §Perf loop
attacks.  MODEL_FLOPS is the analytic useful-work count (6·N·D dense LMs,
6·N_active·D MoE, per-family formulas below); MODEL/HLO per device catches
remat/redundancy/dispatch waste.

CPU-lowering caveat (recorded per EXPERIMENTS.md §Method): XLA:CPU
legalises bf16 arithmetic to f32, so byte-based terms are ≤2× upper
bounds for bf16 tensors; comparisons between iterations share the
pipeline, so §Perf deltas are unaffected.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link
CHIPS = dict(single=256, multi=512)


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    peak_gib: float
    status: str
    note: str = ""

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_s / max-term: 1.0 = compute-bound at peak."""
        t = self.bound_time
        return self.compute_s / t if t > 0 else 0.0


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (useful work) per family
# ---------------------------------------------------------------------------

def lm_model_flops(spec, cell) -> float:
    cfg = spec.config
    d = cell.dims
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = d["batch"] * d["seq"]
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = d["batch"] * d["seq"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = d["batch"]
    attn = (2.0 * cfg.n_layers * d["batch"] * d["ctx"]
            * cfg.n_heads * cfg.hd * 2)
    return 2.0 * n_active * tokens + attn


def gnn_model_flops(spec, cell) -> float:
    cfg = spec.config
    d = cell.dims
    if cell.kind == "gnn_minibatch":
        n = d["batch_nodes"] * (1 + d["fanout0"]
                                + d["fanout0"] * d["fanout1"])
        e = d["batch_nodes"] * d["fanout0"] * (1 + d["fanout1"])
    elif cell.kind == "gnn_molecule":
        n = d["n_nodes"] * d["batch"]
        e = d["n_edges"] * d["batch"]
    else:
        n, e = d["n_nodes"], d["n_edges"]
    a = spec.arch_id
    if a == "graphsage-reddit":
        din = d.get("d_feat", cfg.d_in)
        f = 2.0 * n * din * cfg.d_hidden * 2 + 2.0 * e * din
    elif a == "pna":
        f = cfg.n_layers * (2.0 * e * 2 * cfg.d_hidden * cfg.d_hidden
                            + 13 * 2.0 * n * cfg.d_hidden * cfg.d_hidden)
    elif a == "nequip":
        c = cfg.channels
        f = cfg.n_layers * e * (2.0 * cfg.n_rbf * 32 + 2.0 * 32 * 6 * c
                                + 30.0 * c)
    else:  # graphcast
        dh = cfg.d_hidden
        f = (2.0 * n * cfg.n_vars * dh
             + cfg.n_layers * (2.0 * e * 2 * dh * dh
                               + 2.0 * n // 4 * 2 * dh * dh)
             + 2.0 * n * 2 * dh * cfg.n_vars)
    return 3.0 * f if cell.kind != "serve" else f   # fwd+bwd ≈ 3× fwd


def recsys_model_flops(spec, cell) -> float:
    cfg = spec.config
    d = cell.dims
    if cell.kind == "recsys_retrieval":
        return 2.0 * d["n_candidates"] * cfg.embed_dim
    b = d["batch"]
    d_in = cfg.n_sparse * cfg.embed_dim
    dims = (d_in,) + cfg.mlp_dims + (1,)
    mlp = sum(2.0 * a * bb for a, bb in zip(dims[:-1], dims[1:]))
    fm = 4.0 * cfg.n_sparse * cfg.embed_dim
    per = mlp + fm
    return b * per * (3.0 if cell.kind == "recsys_train" else 1.0)


def pagerank_model_flops(spec, cell) -> float:
    d = cell.dims
    # per iteration: one multiply-add per edge + ~5 flops per vertex
    return 2.0 * d["edge_capacity"] + 5.0 * d["n_vertices"]


# ---------------------------------------------------------------------------
# gated-SpMV geometry model (consumed by kernels.pagerank_spmv.tune)
# ---------------------------------------------------------------------------

# fixed cost of one grid step of the frontier-gated SpMV beyond its MXU
# contraction: DMA issue, scalar-prefetch reads, revisit bookkeeping.  The
# grid is STATIC (= total entries) — excess steps stay VMEM-resident but
# still run the one-hot matmul with a zeroed payload, so per-step cost is
# paid for every entry, active or not.
SPMV_STEP_OVERHEAD_S = 1e-6

# random-access HBM traffic moves whole sectors regardless of element
# width: a gather/scatter of one f64 still transfers a 32B sector.  The
# dense XLA engine pays this on every edge (gather r/d by src, scatter-
# add by dst); the packed kernel streams contiguous lanes at element
# width — that gap, not FLOPs, is the kernel path's headroom.
GATHER_SECTOR_BYTES = 32


def dense_spmv_iteration_cost(*, num_edges: int, num_vertices: int,
                              index_bytes: float = 8.0,
                              value_bytes: float = 8.0,
                              hbm_bw: float = HBM_BW) -> dict:
    """Roofline terms for ONE dense XLA segment-sum PageRank iteration
    (the f64 engine's step): per edge, a random gather of the source
    contribution (one sector), the scatter-add's read+write (two
    sectors) and the sequential src/dst index stream; per vertex, ~6
    streamed f64 vectors (old/new ranks, inverse degree, frontier/prune
    masks, delta).  All traffic is charged at streaming bandwidth —
    sector inflation already accounts for the random-access penalty."""
    edge_bytes = num_edges * (3.0 * GATHER_SECTOR_BYTES + index_bytes)
    vertex_bytes = num_vertices * value_bytes * 6.0
    memory_s = (edge_bytes + vertex_bytes) / hbm_bw
    return dict(memory_s=memory_s, edge_bytes=edge_bytes,
                vertex_bytes=vertex_bytes, total_s=memory_s)


def gated_spmv_iteration_cost(*, total_entries: int, active_entries: float,
                              active_windows: float, be: int, vb: int,
                              v_rsc: int, peak_flops: float = PEAK_FLOPS,
                              hbm_bw: float = HBM_BW) -> dict:
    """Roofline terms for ONE gated-SpMV iteration at a given geometry.

    The asymmetry that makes geometry worth tuning: **memory traffic is
    gated** (only active entries are DMA'd from HBM; the replicated rsc
    block and the active output windows ride along), but **compute is
    not** — the grid is static at ``total_entries`` steps and every step
    runs the ``[1,BE]@[BE,VB]`` one-hot contraction (inactive steps with
    a zeroed payload).  Large BE trims total entries (fewer wasted MXU
    steps + less per-step overhead); small VB sharpens window gating
    (fewer bytes per active frontier vertex) but multiplies the window
    count and hence the entry count.  The tuner ranks candidate
    geometries by ``total_s = max(compute_s, memory_s)``.
    """
    lane_bytes = active_entries * be * (4 + 4 + 4)      # src, dst_rel, valid
    out_bytes = active_windows * vb * 4.0
    rsc_bytes = float(v_rsc) * 4.0
    memory_s = (lane_bytes + out_bytes + rsc_bytes) / hbm_bw
    compute_s = total_entries * (2.0 * be * vb / peak_flops
                                 + SPMV_STEP_OVERHEAD_S)
    return dict(compute_s=compute_s, memory_s=memory_s,
                total_s=max(compute_s, memory_s))


def model_flops(spec, cell) -> float:
    return dict(lm=lm_model_flops, gnn=gnn_model_flops,
                recsys=recsys_model_flops,
                pagerank=pagerank_model_flops)[spec.family](spec, cell)


# ---------------------------------------------------------------------------
# table builder
# ---------------------------------------------------------------------------

def _whatif(spec, rec) -> str:
    """One sentence: what would move the dominant term down."""
    hints = {
        ("lm", "compute"): "raise MXU utilisation: fuse GQA head padding "
                           "(heads % 16), larger per-device microbatch",
        ("lm", "memory"): "bf16 end-to-end + fused attention kernel to cut "
                          "HBM traffic; re-check remat policy",
        ("lm", "collective"): "sequence-parallel reduce-scatter instead of "
                              "TP all-reduce; overlap with compute via "
                              "async collectives",
        ("gnn", "memory"): "frontier-gated SpMM kernel (kernels/segment_ops)"
                           " + cache blocking of node features",
        ("gnn", "collective"): "partition by dst-range (2D) to turn gather "
                               "all-reduces into model-axis all-gathers",
        ("gnn", "compute"): "segment-matmul (MXU scatter) instead of "
                            "scalar segment-sum",
        ("recsys", "memory"): "row-sharded embedding gather is HBM-bound: "
                              "pack multi-field lookups into one gather",
        ("recsys", "collective"): "shard batch over all axes; keep tables "
                                  "model-sharded to avoid replication",
        ("recsys", "compute"): "batch small MLP GEMMs",
        ("pagerank", "collective"): "all-gather only ACTIVE dst-window "
                                    "slices of R (frontier-compressed "
                                    "gather)",
        ("pagerank", "memory"): "block-gated SpMV skips inactive windows "
                                "(kernels/pagerank_spmv)",
        ("pagerank", "compute"): "closed-form DF-P update trims iterations",
    }
    return hints.get((spec.family, rec), "")


def build_table(results_dir: str = "results") -> list[RooflineRow]:
    from repro.configs.registry import REGISTRY
    rows = []
    for mesh_name in ("single", "multi"):
        path = os.path.join(results_dir, f"dryrun_{mesh_name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            records = json.load(f)
        for r in records:
            spec = REGISTRY[r["arch"]]
            cell = spec.shapes[r["shape"]]
            if r["status"] != "OK":
                rows.append(RooflineRow(
                    r["arch"], r["shape"], mesh_name, 0, 0, 0, "-", 0, 0, 0,
                    0, r["status"], r.get("skip_reason",
                                          r.get("error", ""))[:90]))
                continue
            cost = r.get("cost_counting") or r.get("cost", {})
            coll = r.get("collectives_counting") or r.get("collectives", {})
            flops = float(cost.get("flops", 0.0))
            byts = float(cost.get("bytes accessed", 0.0))
            cbytes = float(coll.get("total", 0.0))
            comp = flops / PEAK_FLOPS
            mem = byts / HBM_BW
            col = cbytes / LINK_BW
            dom = max((comp, "compute"), (mem, "memory"),
                      (col, "collective"))[1]
            mf = model_flops(spec, cell) / CHIPS[mesh_name]
            rows.append(RooflineRow(
                r["arch"], r["shape"], mesh_name, comp, mem, col, dom, mf,
                flops, (mf / flops if flops else 0.0),
                r.get("memory", {}).get("peak_per_device_bytes", 0) / 2**30,
                "OK", _whatif(spec, dom)))
    return rows


def to_markdown(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | peak GiB | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.status != "OK":
            lines.append(f"| {r.arch} | {r.shape} | {r.mesh} | - | - | - | "
                         f"{r.status} | - | - | {r.note} |")
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.peak_gib:.2f} | {r.note} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = build_table()
    print(to_markdown(rows))
