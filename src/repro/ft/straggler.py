"""Straggler mitigation for the frontier workload.

The DF/DF-P frontier makes work per edge shard inherently skewed: most
iterations touch a small, clustered set of dst windows, so a naive static
edge stripe leaves most devices idle while one grinds.  Mitigations:

1. **Active-first re-striping** (``rebalance``): between batch updates,
   re-stripe each dst-range's edges so edges whose dst was recently
   affected interleave round-robin across the 'data' axis
   (graph/partition.py already supports ``balance_by_active``) — every
   stripe carries ~equal active work.

2. **Bounded iterations** (``IterationBudget``): a slow/failed device
   can stall a synchronous while_loop indefinitely; drivers cap each
   batch at ``max_iter`` and carry the still-unconverged frontier into
   the next batch's seed set (correct: DF re-marks until Δ ≤ τ).

3. **Skew telemetry** (``stripe_skew``): max/mean active-edges per
   stripe, logged by the driver; >2 triggers a rebalance.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.partition import PartitionedGraph, partition_graph
from repro.graph.structure import EdgeListGraph


def active_edge_mask(graph: EdgeListGraph, affected: np.ndarray
                     ) -> np.ndarray:
    """bool[E_cap]: live edges whose dst is affected (= edges that do work)."""
    dst = np.asarray(graph.dst)
    valid = np.asarray(graph.valid)
    return valid & affected[dst]


def stripe_skew(part: PartitionedGraph, affected: np.ndarray) -> float:
    """max/mean active edges across edge stripes (1.0 = perfectly even)."""
    # dst_local + vtx_starts -> global dst; count active per [m, p] stripe.
    # v_per_shard is window-rounded, so pad the mask to the padded range.
    aff_pad = np.zeros(part.model_shards * part.v_per_shard, bool)
    aff_pad[: len(affected)] = affected
    act = aff_pad[part.dst_local + part.vtx_starts[:, None, None]] \
        & part.valid
    per_stripe = act.sum(axis=2).astype(np.float64)     # [M, P]
    mean = per_stripe.mean()
    if mean == 0:
        return 1.0
    return float(per_stripe.max() / mean)


def rebalance(graph: EdgeListGraph, affected: np.ndarray,
              model_shards: int, edge_shards: int) -> PartitionedGraph:
    """Re-stripe edges with recently-active edges spread round-robin."""
    mask = active_edge_mask(graph, affected)
    return partition_graph(graph, model_shards, edge_shards,
                           balance_by_active=mask)


class IterationBudget:
    """Caps per-batch iterations; carries unconverged frontier forward."""

    def __init__(self, max_iter_per_batch: int = 100):
        self.max_iter = max_iter_per_batch
        self.carried_frontier: Optional[np.ndarray] = None

    def seeds_for_batch(self, fresh_seeds: np.ndarray) -> np.ndarray:
        if self.carried_frontier is None:
            return fresh_seeds
        return fresh_seeds | self.carried_frontier

    def after_batch(self, converged: bool, frontier: np.ndarray):
        self.carried_frontier = None if converged else frontier.copy()
