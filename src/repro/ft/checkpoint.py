"""Sharded checkpoint manager — save/restore any pytree, reshard on load.

Design (no external deps):
  * one ``.npy`` per leaf under ``<dir>/step_<N>.tmp/``, atomically renamed
    to ``step_<N>/`` after a manifest with the tree structure, shapes and
    dtypes is fsync'd — a torn write can never look like a checkpoint;
  * every leaf's manifest entry carries a **content digest** (crc32 of the
    raw bytes); ``restore`` recomputes and verifies it, so a leaf torn or
    bit-flipped *after* the atomic rename (disk corruption, partial copy
    of a checkpoint directory) raises a structured
    ``CheckpointCorruptError`` instead of loading silently;
  * restore takes an *abstract* target pytree (+ optional sharding tree)
    and ``device_put``s each leaf, so a checkpoint written on one mesh
    restores onto ANY other mesh/device-count (elastic rescale,
    ft/elastic.py);
  * ``keep_last`` garbage collection; ``restore_latest_valid`` walks the
    retained steps newest-first and falls back past corrupt ones, so a
    single bad checkpoint degrades recovery by one ``every`` interval
    rather than killing the restart;
  * for the PageRank stream the state is (ranks, batch_index, rng_state) —
    restart replays the temporal stream from the last committed batch.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint leaf failed its integrity check on restore.

    ``step`` is the checkpoint step, ``leaf`` the manifest key of the
    offending leaf (None when the manifest itself is unreadable).
    """

    def __init__(self, message: str, *, step: Optional[int] = None,
                 leaf: Optional[str] = None):
        super().__init__(message)
        self.step = step
        self.leaf = leaf


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


def save(directory: str, step: int, state: Any, keep_last: int = 3) -> str:
    """Write checkpoint; returns the final path.  Atomic."""
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(_leaf_paths(state)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            dict(key=name, file=fname, shape=list(arr.shape),
                 dtype=str(arr.dtype),
                 crc32=zlib.crc32(np.ascontiguousarray(arr).tobytes())))
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, _MANIFEST))]
    return max(steps) if steps else None


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Load into the structure of ``target`` (abstract or concrete pytree).

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with them (reshard-on-restore).  Shapes must match; dtypes
    are cast to the target's (e.g. f64 CPU ranks -> f32 TPU engine).

    Every leaf whose manifest entry carries a ``crc32`` digest (all
    checkpoints written by this module do) is verified against it before
    anything is device_put; a mismatch, an unreadable ``.npy`` or an
    unreadable manifest raises ``CheckpointCorruptError``.
    """
    path = os.path.join(directory, f"step_{step:010d}")
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"step {step}: unreadable manifest ({e})", step=step) from e
    leaves, treedef = jax.tree_util.tree_flatten(target)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target has "
            f"{len(leaves)}")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for leaf, rec, sh in zip(leaves, manifest["leaves"], shard_leaves):
        try:
            arr = np.load(os.path.join(path, rec["file"]))
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"step {step} leaf {rec['key']}: unreadable "
                f"({rec['file']}: {e})", step=step, leaf=rec["key"]) from e
        if "crc32" in rec:
            got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got != rec["crc32"]:
                raise CheckpointCorruptError(
                    f"step {step} leaf {rec['key']}: content digest "
                    f"{got:#010x} != manifest {rec['crc32']:#010x} "
                    f"(torn or corrupt {rec['file']})",
                    step=step, leaf=rec["key"])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {rec['key']}: checkpoint shape {arr.shape} != "
                f"target {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest_valid(directory: str, target: Any, shardings: Any = None
                         ) -> tuple:
    """(step, state) of the newest restorable checkpoint, or (None, None).

    Walks the retained steps newest-first; a ``CheckpointCorruptError``
    falls back to the previous ``keep_last`` step instead of propagating,
    so one torn/corrupt checkpoint costs one save interval of progress
    rather than the whole restart.  Raises only when every retained step
    is corrupt — at that point there is genuinely nothing to restore
    from, and silently cold-starting would hide the corruption.
    """
    if not os.path.isdir(directory):
        return None, None
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(directory)
                    if d.startswith("step_") and not d.endswith(".tmp")
                    and os.path.exists(os.path.join(directory, d,
                                                    _MANIFEST))),
                   reverse=True)
    last_err: Optional[CheckpointCorruptError] = None
    for step in steps:
        try:
            return step, restore(directory, step, target, shardings)
        except CheckpointCorruptError as e:
            last_err = e
    if last_err is not None:
        raise last_err
    return None, None


class CheckpointManager:
    """Periodic checkpointing + restart bookkeeping for drivers."""

    def __init__(self, directory: str, every: int = 10, keep_last: int = 3):
        self.directory = directory
        self.every = every
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, state: Any) -> Optional[str]:
        if step % self.every == 0:
            return save(self.directory, step, state, self.keep_last)
        return None

    def restore_latest(self, target: Any, shardings: Any = None):
        """Newest restorable (step, state); corrupt steps fall back to
        the previous retained one (``restore_latest_valid``)."""
        return restore_latest_valid(self.directory, target, shardings)
