"""Elastic rescaling + replica membership.

Two ingredients make rescale a pure data movement, no retraining logic:
  * checkpoints are mesh-agnostic host arrays (ft/checkpoint.py);
  * the PageRank graph partition is a pure function of
    (V, E_cap, mesh shape) (graph/partition.py), so a new mesh just means
    re-running ``partition_graph`` and ``device_put``-ing the same ranks.

``rescale_pagerank_state`` is the paper-workload path; ``rescale_state``
is the generic (LM/GNN/recsys) path used by launch/train.py on restart;
``rescale_serving_state`` restores the serving checkpoint layout written
by ``serve.state.RankStore`` (ranks, generation, last_seq) onto any mesh.

``ReplicaRoster`` is the membership half of elasticity for the
read-replica serving tier (serve/replicate.py): replicas join and leave
at any time, liveness is heartbeat-based against an injected clock, and
the roster answers "who is alive right now" for retransmission fan-out
and writer-failover candidate selection.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.ft import checkpoint as ckpt
from repro.graph.partition import partition_graph
from repro.graph.structure import EdgeListGraph
from repro.launch.mesh import data_axes


def rescale_state(directory: str, target_abstract: Any,
                  new_shardings: Any) -> tuple[Optional[int], Any]:
    """Restore the latest checkpoint resharded onto a new mesh."""
    step = ckpt.latest_step(directory)
    if step is None:
        return None, None
    state = ckpt.restore(directory, step, target_abstract, new_shardings)
    return step, state


def rescale_pagerank_state(directory: str, graph: EdgeListGraph, mesh,
                           dtype=np.float32):
    """Restore (ranks, batch_idx) and repartition the graph for ``mesh``.

    Returns (batch_idx, ranks_host, partitioned_graph) or (None, ...) when
    no checkpoint exists.  The caller device_puts with
    ``dist.pagerank_dist.distributed_in_shardings(mesh)``.
    """
    step = ckpt.latest_step(directory)
    m = mesh.shape["model"]
    p = 1
    for a in data_axes(mesh):
        p *= mesh.shape[a]
    part = partition_graph(graph, m, p)
    if step is None:
        return None, None, part
    target = dict(
        ranks=jax.ShapeDtypeStruct((graph.num_vertices,), dtype),
        batch_idx=jax.ShapeDtypeStruct((), np.int64),
    )
    state = ckpt.restore(directory, step, target)
    return int(state["batch_idx"]), np.asarray(state["ranks"]), part


def rescale_serving_state(directory: str, num_vertices: int,
                          dtype=np.float64):
    """Restore a ``RankStore`` checkpoint onto any device count.

    The serving checkpoint layout is (ranks f64[V], generation, last_seq)
    — mesh-agnostic host arrays, so "rescale" is just restoring them and
    re-bootstrapping a ``ServeEngine`` on whatever mesh the new process
    has (the packed/sharded device state is rebuilt from the replayed
    graph at bootstrap, same as a restart on the original mesh).

    Returns (generation, last_seq, ranks_host) or (None, None, None)
    when no restorable checkpoint exists.  Corrupt checkpoints fall back
    to the previous retained step (``ckpt.restore_latest_valid``).
    """
    target = dict(
        ranks=jax.ShapeDtypeStruct((num_vertices,), dtype),
        generation=jax.ShapeDtypeStruct((), np.int64),
        last_seq=jax.ShapeDtypeStruct((), np.int64))
    step, state = ckpt.restore_latest_valid(directory, target)
    if state is None:
        return None, None, None
    return (int(state["generation"]), int(state["last_seq"]),
            np.asarray(state["ranks"]))


class ReplicaRoster:
    """Heartbeat-based membership for the read-replica tier.

    Thread-safe: replicas join/leave/beat from their own pump threads
    while the failover controller reads liveness.  Time is an injected
    monotone clock reading passed by the caller, so the chaos harness
    can drive membership on a logical clock deterministically.
    """

    def __init__(self, heartbeat_timeout: float = 1.0):
        self.heartbeat_timeout = heartbeat_timeout
        self._lock = threading.Lock()
        self._last_beat: Dict[str, float] = {}
        self.joins = 0
        self.leaves = 0

    def join(self, name: str, now: float) -> None:
        with self._lock:
            if name not in self._last_beat:
                self.joins += 1
            self._last_beat[name] = now

    def leave(self, name: str) -> None:
        with self._lock:
            if self._last_beat.pop(name, None) is not None:
                self.leaves += 1

    def beat(self, name: str, now: float) -> None:
        with self._lock:
            if name not in self._last_beat:
                self.joins += 1
            self._last_beat[name] = now

    def members(self) -> List[str]:
        with self._lock:
            return sorted(self._last_beat)

    def alive(self, now: float) -> List[str]:
        """Members whose last beat is within the heartbeat timeout."""
        with self._lock:
            return sorted(n for n, t in self._last_beat.items()
                          if now - t <= self.heartbeat_timeout)

    def is_alive(self, name: str, now: float) -> bool:
        with self._lock:
            t = self._last_beat.get(name)
        return t is not None and now - t <= self.heartbeat_timeout
