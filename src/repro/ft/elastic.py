"""Elastic rescaling: resume any checkpoint onto a different mesh.

Two ingredients make rescale a pure data movement, no retraining logic:
  * checkpoints are mesh-agnostic host arrays (ft/checkpoint.py);
  * the PageRank graph partition is a pure function of
    (V, E_cap, mesh shape) (graph/partition.py), so a new mesh just means
    re-running ``partition_graph`` and ``device_put``-ing the same ranks.

``rescale_pagerank_state`` is the paper-workload path; ``rescale_state``
is the generic (LM/GNN/recsys) path used by launch/train.py on restart.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.ft import checkpoint as ckpt
from repro.graph.partition import partition_graph
from repro.graph.structure import EdgeListGraph
from repro.launch.mesh import data_axes


def rescale_state(directory: str, target_abstract: Any,
                  new_shardings: Any) -> tuple[Optional[int], Any]:
    """Restore the latest checkpoint resharded onto a new mesh."""
    step = ckpt.latest_step(directory)
    if step is None:
        return None, None
    state = ckpt.restore(directory, step, target_abstract, new_shardings)
    return step, state


def rescale_pagerank_state(directory: str, graph: EdgeListGraph, mesh,
                           dtype=np.float32):
    """Restore (ranks, batch_idx) and repartition the graph for ``mesh``.

    Returns (batch_idx, ranks_host, partitioned_graph) or (None, ...) when
    no checkpoint exists.  The caller device_puts with
    ``dist.pagerank_dist.distributed_in_shardings(mesh)``.
    """
    step = ckpt.latest_step(directory)
    m = mesh.shape["model"]
    p = 1
    for a in data_axes(mesh):
        p *= mesh.shape[a]
    part = partition_graph(graph, m, p)
    if step is None:
        return None, None, part
    target = dict(
        ranks=jax.ShapeDtypeStruct((graph.num_vertices,), dtype),
        batch_idx=jax.ShapeDtypeStruct((), np.int64),
    )
    state = ckpt.restore(directory, step, target)
    return int(state["batch_idx"]), np.asarray(state["ranks"]), part
