"""Window-range sharding of ``PackedGraph`` across the device mesh.

The single-pod kernel engine (core.kernel_engine) runs the frontier-gated
SpMV over one ``PackedGraph`` on one device.  This module partitions that
blocked structure by **contiguous destination-window ranges** — shard *s*
of *S* owns global windows ``[s·wps, (s+1)·wps)`` (``wps`` windows per
shard, the global window count padded up to ``S·wps``) — which is the
blocked analogue of the dst-range ownership the XLA distributed engine
already uses (``graph/partition.py``): all in-edges of a vertex live on
exactly one shard, so per-shard SpMV partials have **disjoint support**
and a single ``psum`` reassembles the full contribution vector exactly.

Representation: a ``ShardedPacked`` pytree stacks S equally-shaped
per-shard ``PackedGraph``s along a leading shard axis (placed on the
mesh's ``model`` axis under ``shard_map``).  Each per-shard structure is
a *bona fide* ``PackedGraph`` over the shard's local vertex range
(``num_vertices = wps·vb``, window ids and ``dst`` rebased to the shard)
except that ``src`` stays **global** — sources are gathered from the
replicated rank vector, destinations are shard-local.  Because
``pack_blocks`` and ``update.apply_batch_packed`` key edges as
``src·num_vertices + dst``, the global-src/local-dst convention keeps
keys injective and the *unmodified* incremental update correct per
shard.

Micro-batch deltas are routed to their owning shard by dst
(``route_update``): per shard, matching rows are stably compacted into a
static per-shard budget (default: the full batch capacity, so any batch
fits even when every edge lands on one shard).  Overflowing a smaller
budget is a **checked capacity error** (``ShardCapacityError``), never a
silent truncation — the same contract as lane/overlay exhaustion.  The
per-shard update then runs under ``shard_map`` (``build_sharded_apply``)
so the one-compiled-update-per-stream invariant survives sharding: all
shapes are static, ``TRACE_COUNTS`` asserts no retraces.

``frontier_spmv_shard`` is the kernel entry for one shard: identical to
``frontier_spmv_padded`` except the rank-scale input spans the *full*
replicated padded vertex range (src is global) while the output spans
only the shard's ``wps`` windows.  DESIGN.md §9 has the layout diagram,
budget model and psum cost analysis.
"""
from __future__ import annotations

import collections
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.graph.dynamic import BatchUpdate
from repro.graph.structure import EdgeListGraph
from repro.kernels.pagerank_spmv.pagerank_spmv import (
    DEFAULT_BE, DEFAULT_VB, PackedGraph, frontier_spmv_padded, pack_blocks)
from repro.kernels.pagerank_spmv.ref import frontier_spmv_ref_padded
from repro.kernels.pagerank_spmv.update import _apply_batch_packed

__all__ = ["ShardSpec", "ShardedPacked", "ShardCapacityError", "HaloSpec",
           "pack_shards", "route_update", "build_sharded_apply",
           "apply_batch_sharded_host", "frontier_spmv_shard",
           "gated_contrib_shard", "shard_graph", "sharded_edge_set",
           "build_halo", "extend_halo", "halo_slots", "TRACE_COUNTS"]

# retracing telemetry for the sharded path (same contract as
# kernels.pagerank_spmv.update.TRACE_COUNTS): one compiled route, one
# compiled per-shard update and one compiled kernel loop per stream
TRACE_COUNTS: collections.Counter = collections.Counter()


class ShardCapacityError(ValueError):
    """A checked sharded-capacity overflow (delta budget, spill lanes or
    locator overlay).  ``shards`` names the shards that overflowed."""

    def __init__(self, message: str, shards: tuple = ()):
        super().__init__(message)
        self.shards = tuple(shards)


class ShardSpec(NamedTuple):
    """Static geometry of a sharded pack (hashable: jit/cache key).

    Shard *s* owns global windows ``[s·wps, (s+1)·wps)``, i.e. global
    vertices ``[s·wps·vb, (s+1)·wps·vb)``.
    """

    num_shards: int
    windows_per_shard: int
    vb: int
    be: int
    num_vertices: int            # global V (<= num_shards·wps·vb)
    num_entries: int             # per-shard entry capacity (equal shapes)
    max_entries_per_window: int
    overlay_capacity: int

    @property
    def vertices_per_shard(self) -> int:
        return self.windows_per_shard * self.vb

    @property
    def padded_vertices(self) -> int:
        return self.num_shards * self.vertices_per_shard


class ShardedPacked(NamedTuple):
    """S per-shard ``PackedGraph``s stacked on a leading shard axis.

    Field semantics match ``PackedGraph`` per shard; ``window`` ids and
    ``dst_rel`` windows are shard-local, ``src`` is global.
    """

    src: jax.Array          # int32[S, NE, BE]   global sources
    dst_rel: jax.Array      # int32[S, NE, BE]
    valid: jax.Array        # f32[S, NE, BE]
    window: jax.Array       # int32[S, NE]       local window ids
    entry_start: jax.Array  # int32[S, WPS+1]
    sorted_key: jax.Array   # int64[S, NE*BE]
    sorted_lane: jax.Array  # int32[S, NE*BE]
    ovl_key: jax.Array      # int64[S, K]
    ovl_lane: jax.Array     # int32[S, K]


def _local_packed(sharded: ShardedPacked, spec: ShardSpec,
                  index=0) -> PackedGraph:
    """One shard's arrays -> a shard-local PackedGraph (spec statics)."""
    return PackedGraph(
        src=sharded.src[index], dst_rel=sharded.dst_rel[index],
        valid=sharded.valid[index], window=sharded.window[index],
        entry_start=sharded.entry_start[index],
        sorted_key=sharded.sorted_key[index],
        sorted_lane=sharded.sorted_lane[index],
        ovl_key=sharded.ovl_key[index], ovl_lane=sharded.ovl_lane[index],
        num_vertices=spec.vertices_per_shard, vb=spec.vb, be=spec.be,
        max_entries_per_window=spec.max_entries_per_window)


def shard_graph(sharded: ShardedPacked, spec: ShardSpec,
                s: int) -> PackedGraph:
    """Host-side extraction of shard ``s`` (tests, oracles)."""
    return _local_packed(jax.tree_util.tree_map(np.asarray, sharded),
                         spec, s)


def sharded_edge_set(sharded: ShardedPacked, spec: ShardSpec) -> set:
    """Global live (src, dst) pairs across all shards — the parity oracle
    against ``update.packed_edge_set`` / the edge-list graph."""
    out: set = set()
    vps = spec.vertices_per_shard
    for s in range(spec.num_shards):
        src = np.asarray(sharded.src[s])
        dst = (np.asarray(sharded.window[s])[:, None] * spec.vb
               + np.asarray(sharded.dst_rel[s]) + s * vps)
        live = np.asarray(sharded.valid[s]) > 0
        out |= set(zip(src[live].tolist(), dst[live].tolist()))
    return out


# ---------------------------------------------------------------------------
# host-side pack
# ---------------------------------------------------------------------------

def pack_shards(graph: EdgeListGraph, num_shards: int, *,
                be: int = DEFAULT_BE, vb: int = DEFAULT_VB,
                spill_lanes_per_window: int = 1,
                num_entries: int | None = None,
                extra_entries: int = 0,
                overlay_capacity: int = 1024,
                max_entries_per_window: int | None = None
                ) -> tuple[ShardedPacked, ShardSpec]:
    """Partition ``graph`` into S window-range shards, each packed with
    ``pack_blocks`` at one shared per-shard entry capacity.

    ``num_entries`` pins the per-shard capacity (repacks mid-stream must
    pass the bootstrap value or the compiled update/kernel retrace);
    otherwise the capacity is the widest shard's requirement plus
    ``extra_entries`` **total** headroom spread evenly across shards.
    ``spill_lanes_per_window >= 1`` is required: every owned window must
    hold at least one entry so active windows always have a block the
    kernel writes (same invariant as the single-device pack).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if spill_lanes_per_window < 1:
        raise ValueError("sharded packs need spill_lanes_per_window >= 1 "
                         "(every owned window must hold an entry)")
    V = graph.num_vertices
    nw = -(-V // vb)
    wps = -(-nw // num_shards)
    vps = wps * vb
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    valid = np.asarray(graph.valid)
    shard_of = dst // vps

    if num_entries is None:
        # per-shard entry requirement, mirroring pack_blocks' sizing
        need_cap = 0
        for s in range(num_shards):
            m = valid & (shard_of == s)
            counts = np.bincount(dst[m] // vb - s * wps,
                                 minlength=wps).astype(np.int64)
            n_base = -(-counts // be)
            slack = n_base * be - counts
            need = np.maximum(0, spill_lanes_per_window - slack)
            need_cap = max(need_cap, int(np.sum(n_base + -(-need // be))))
        num_entries = need_cap + -(-max(0, extra_entries) // num_shards)

    packs = []
    for s in range(num_shards):
        m = valid & (shard_of == s)
        packs.append(pack_blocks(
            src[m], dst[m] - s * vps, np.ones(int(m.sum()), bool), vps,
            be=be, vb=vb, num_entries=num_entries,
            spill_lanes_per_window=spill_lanes_per_window,
            overlay_capacity=overlay_capacity,
            max_entries_per_window=None))
    widest = max(p.max_entries_per_window for p in packs)
    if max_entries_per_window is None:
        max_entries_per_window = widest
    elif widest > max_entries_per_window:
        raise ValueError(
            f"{widest} entries in one window exceed the pinned "
            f"max_entries_per_window {max_entries_per_window}")
    stack = lambda f: jnp.stack([getattr(p, f) for p in packs])
    sharded = ShardedPacked(
        src=stack("src"), dst_rel=stack("dst_rel"), valid=stack("valid"),
        window=stack("window"), entry_start=stack("entry_start"),
        sorted_key=stack("sorted_key"), sorted_lane=stack("sorted_lane"),
        ovl_key=stack("ovl_key"), ovl_lane=stack("ovl_lane"))
    spec = ShardSpec(num_shards=num_shards, windows_per_shard=wps, vb=vb,
                     be=be, num_vertices=V, num_entries=num_entries,
                     max_entries_per_window=max_entries_per_window,
                     overlay_capacity=overlay_capacity)
    return sharded, spec


# ---------------------------------------------------------------------------
# delta routing
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("spec", "del_budget", "ins_budget"))
def _route_update(update: BatchUpdate, spec: ShardSpec,
                  del_budget: int, ins_budget: int):
    TRACE_COUNTS["route_update"] += 1                  # trace-time only
    vps = spec.vertices_per_shard
    # int32 shard ids: routed endpoint arrays must keep BatchUpdate's
    # int32 dtype (int64 would be unsafely cast back in the lane scatter)
    sids = jnp.arange(spec.num_shards, dtype=jnp.int32)

    def side(srcs, dsts, mask, budget):
        shard = dsts // vps

        def per_shard(s):
            m = mask & (shard == s)
            order = jnp.argsort(~m, stable=True)[:budget]
            kept = m[order]
            # masked rows get in-range sentinels so downstream window /
            # locator indexing never reads out of bounds
            return (jnp.where(kept, srcs[order], 0),
                    jnp.where(kept, dsts[order] - s * vps, 0),
                    kept,
                    jnp.sum(m.astype(jnp.int32))
                    - jnp.sum(kept.astype(jnp.int32)))

        return jax.vmap(per_shard)(sids)

    d_src, d_dst, d_mask, d_drop = side(update.del_src, update.del_dst,
                                        update.del_mask, del_budget)
    i_src, i_dst, i_mask, i_drop = side(update.ins_src, update.ins_dst,
                                        update.ins_mask, ins_budget)
    routed = BatchUpdate(del_src=d_src, del_dst=d_dst, del_mask=d_mask,
                         ins_src=i_src, ins_dst=i_dst, ins_mask=i_mask)
    return routed, d_drop, i_drop


def route_update(update: BatchUpdate, spec: ShardSpec, *,
                 del_budget: int | None = None,
                 ins_budget: int | None = None,
                 check: bool = True) -> BatchUpdate:
    """Δ -> per-shard Δ: rows land on the shard owning their dst window,
    stably compacted into ``[S, budget]`` arrays with dst rebased to the
    shard.  Budgets default to the full batch capacity (any batch fits,
    even one whose edges all hit one shard); a smaller budget that
    overflows raises ``ShardCapacityError`` — never silent truncation.
    """
    if del_budget is None:
        del_budget = update.del_src.shape[0]
    if ins_budget is None:
        ins_budget = update.ins_src.shape[0]
    routed, d_drop, i_drop = _route_update(update, spec, del_budget,
                                           ins_budget)
    if check:
        d = np.asarray(d_drop)
        i = np.asarray(i_drop)
        if d.sum() or i.sum():
            bad = tuple(int(s) for s in np.flatnonzero(d + i))
            raise ShardCapacityError(
                f"{int(d.sum())} deletions / {int(i.sum())} insertions "
                f"exceed the per-shard delta budget "
                f"(del={del_budget}, ins={ins_budget}) on shards {bad}; "
                "raise the budget (delta routing model: DESIGN.md §9)",
                shards=bad)
    return routed


# ---------------------------------------------------------------------------
# per-shard incremental update under shard_map
# ---------------------------------------------------------------------------

_APPLY_CACHE: dict = {}


def build_sharded_apply(mesh, spec: ShardSpec):
    """Compiled ``(ShardedPacked, routed Δ) -> (ShardedPacked, dropped[S])``
    running ``update.apply_batch_packed``'s body per shard under
    shard_map.  Cached per (mesh, spec) so a stream compiles once."""
    key = (mesh, spec)
    fn = _APPLY_CACHE.get(key)
    if fn is not None:
        return fn

    def step(sharded, routed):
        TRACE_COUNTS["sharded_apply"] += 1             # trace-time only
        packed = _local_packed(sharded, spec, index=0)
        upd = BatchUpdate(*[x[0] for x in routed])
        new, dropped = _apply_batch_packed(packed, upd)
        return (ShardedPacked(
            src=new.src[None], dst_rel=new.dst_rel[None],
            valid=new.valid[None], window=new.window[None],
            entry_start=new.entry_start[None],
            sorted_key=new.sorted_key[None],
            sorted_lane=new.sorted_lane[None],
            ovl_key=new.ovl_key[None], ovl_lane=new.ovl_lane[None]),
            dropped[None])

    fn = jax.jit(shard_map(step, mesh=mesh,
                           in_specs=(P("model"), P("model")),
                           out_specs=(P("model"), P("model")),
                           check_vma=False))
    while len(_APPLY_CACHE) >= 8:
        _APPLY_CACHE.pop(next(iter(_APPLY_CACHE)))
    _APPLY_CACHE[key] = fn
    return fn


def apply_batch_sharded_host(sharded: ShardedPacked, spec: ShardSpec,
                             update: BatchUpdate, *,
                             del_budget: int | None = None,
                             ins_budget: int | None = None,
                             check: bool = True) -> ShardedPacked:
    """Mesh-free reference: route + apply each shard sequentially on the
    default device.  Same result as the shard_map path — used by tests
    and as the oracle for the differential harness."""
    routed = route_update(update, spec, del_budget=del_budget,
                          ins_budget=ins_budget, check=check)
    outs, dropped = [], []
    for s in range(spec.num_shards):
        local = _local_packed(sharded, spec, s)
        upd = BatchUpdate(*[x[s] for x in routed])
        new, drop = _apply_batch_packed(local, upd)
        outs.append(new)
        dropped.append(int(drop))
    if check and any(dropped):
        bad = tuple(s for s, d in enumerate(dropped) if d)
        raise ShardCapacityError(
            f"{sum(dropped)} insertions exceed spill/overlay capacity on "
            f"shards {bad}; repack with pack_shards (sizing: DESIGN.md "
            "§8-§9)", shards=bad)
    stack = lambda f: jnp.stack([getattr(p, f) for p in outs])
    return ShardedPacked(
        src=stack("src"), dst_rel=stack("dst_rel"), valid=stack("valid"),
        window=stack("window"), entry_start=stack("entry_start"),
        sorted_key=stack("sorted_key"), sorted_lane=stack("sorted_lane"),
        ovl_key=stack("ovl_key"), ovl_lane=stack("ovl_lane"))


# ---------------------------------------------------------------------------
# shard-local frontier-gated SpMV
# ---------------------------------------------------------------------------

def frontier_spmv_shard(packed: PackedGraph, rsc_full: jax.Array,
                        active_window: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """``frontier_spmv_padded`` for one shard: gathers from the FULL
    replicated scaled-rank vector (``src`` is global) and scatters into
    this shard's ``wps`` local windows.  Returns f32[wps·vb]; windows
    inactive (or unowned — by construction absent) are zero.

    The base kernel already accepts an rsc longer than its own padded
    window range, so this is pure delegation — there is exactly one
    compaction/pinning/first-write implementation to maintain.
    """
    return frontier_spmv_padded(packed, rsc_full, active_window,
                                interpret=interpret)


def gated_contrib_shard(packed: PackedGraph, rsc_full: jax.Array,
                        active_window: jax.Array, *,
                        use_kernel: bool = True) -> jax.Array:
    """Shard-local contributions for the active local windows.

    ``use_kernel=True`` runs the compiled Pallas kernel **on TPU only**.
    Off-TPU the jnp oracle is used even when the kernel is requested:
    interpret-mode Pallas is not SPMD-safe under shard_map on the pinned
    jax 0.4.x when the scalar-prefetch values diverge across devices
    (which per-shard frontier gating inherently does) — revisited output
    blocks read uninitialized memory on some shards.  A six-entry
    minimal repro and the full caveat live in DESIGN.md §9; the oracle
    computes the identical gated contributions (same f32 math, XLA
    segment_sum instead of the MXU one-hot scatter), so CPU CI exercises
    the same semantics.  ``frontier_spmv_shard`` itself stays correct in
    any single-device context (tests compare it against the oracle).
    """
    if use_kernel and jax.default_backend() == "tpu":
        return frontier_spmv_shard(packed, rsc_full, active_window,
                                   interpret=False)
    return frontier_spmv_ref_padded(packed.src, packed.dst_rel,
                                    packed.valid, packed.window, rsc_full,
                                    active_window, packed.vb)


# ---------------------------------------------------------------------------
# halo: the cross-shard source boundary (dist boundary-only exchange)
# ---------------------------------------------------------------------------

class HaloSpec(NamedTuple):
    """Each shard's boundary-in set: the global src vertices whose rank
    the shard must RECEIVE each iteration because they feed its dst
    windows but live on another shard.

    ``ids[s]`` holds shard s's halo as an int32 row of capacity H; live
    entries occupy the ``count[s]``-long prefix, the tail is the
    out-of-range sentinel ``S·vps`` (scatters with ``mode="drop"``
    ignore it, the ownership test inside the exchange zeroes it).  The
    table is small — Σ|halo| is the number of distinct cut srcs, the
    graph's edge-cut boundary — and replicated on every device, which is
    what turns the per-iteration full-rank ``psum`` (O(V) wire) into one
    ``[S, H]`` exchange (O(boundary) wire).  Deletions leave stale
    entries behind (a few extra exchanged floats, never wrong values);
    repacks rebuild the table exactly.
    """

    ids: jax.Array      # int32[S, H] global src ids, sentinel-padded
    count: jax.Array    # int32[S] live prefix length


def halo_slots(halo: HaloSpec) -> int:
    """Total exchanged slots per iteration (the comm-volume unit)."""
    return int(halo.ids.shape[0] * halo.ids.shape[1])


def halo_occupancy(halo: HaloSpec) -> float:
    """Live fraction of the pinned halo table (obs gauge): 1.0 means the
    next boundary-crossing insertion forces a capacity repack."""
    slots = halo_slots(halo)
    if slots == 0:
        return 0.0
    return float(np.asarray(halo.count).sum()) / slots


def build_halo(sharded: ShardedPacked, spec: ShardSpec, *,
               capacity: int | None = None,
               min_capacity: int = 8) -> HaloSpec:
    """Host-side halo construction from the live sharded pack.

    Per shard: the unique live srcs outside its own vertex range.
    ``capacity`` pins H (streaming repacks must keep the compiled loop's
    shapes); by default H is the widest shard's halo plus 25% + 64 slots
    of insert headroom, rounded to a multiple of 64.  A pinned capacity
    smaller than a shard's rebuilt halo is a ``ShardCapacityError``.
    """
    vps = spec.vertices_per_shard
    rows = []
    for s in range(spec.num_shards):
        src = np.asarray(sharded.src[s]).reshape(-1)
        live = np.asarray(sharded.valid[s]).reshape(-1) > 0
        remote = np.unique(src[live & ((src < s * vps)
                                       | (src >= (s + 1) * vps))])
        rows.append(remote.astype(np.int32))
    widest = max((len(r) for r in rows), default=0)
    if capacity is None:
        capacity = max(min_capacity, -(-int(widest * 1.25 + 64) // 64) * 64)
    elif widest > capacity:
        bad = tuple(s for s, r in enumerate(rows) if len(r) > capacity)
        raise ShardCapacityError(
            f"shard halo of {widest} srcs exceeds the pinned halo "
            f"capacity {capacity} on shards {bad}; grow the halo "
            "(comm-volume model: DESIGN.md §10)", shards=bad)
    sentinel = spec.padded_vertices
    ids = np.full((spec.num_shards, capacity), sentinel, np.int32)
    for s, r in enumerate(rows):
        ids[s, : len(r)] = r
    return HaloSpec(ids=jnp.asarray(ids),
                    count=jnp.asarray([len(r) for r in rows], jnp.int32))


@partial(jax.jit, static_argnames=("vps",))
def _extend_halo(ids: jax.Array, count: jax.Array, ins_src: jax.Array,
                 ins_mask: jax.Array, vps: int):
    """Append each routed insertion's src to its shard's halo row.

    ``ins_src``/``ins_mask`` are ``route_update``'s [S, B] per-shard
    views (replicated host arrays, NOT under shard_map), so every row
    extends independently via vmap.  Skips own-range srcs and srcs
    already present; in-batch duplicates collapse to their first
    occurrence (same argsort scheme as the packed-lane update).  Returns
    ``(ids, count, dropped[S])`` — dropped > 0 means the pinned capacity
    overflowed and the caller repacks/regrows.
    """
    TRACE_COUNTS["extend_halo"] += 1                   # trace-time only
    S, H = ids.shape

    def row(s, row_ids, row_count, srcs, mask):
        cand = mask & ((srcs < s * vps) | (srcs >= (s + 1) * vps))
        present = jnp.any(srcs[:, None] == row_ids[None, :], axis=1)
        keep = cand & ~present
        key = jnp.where(keep, srcs, -1)
        sorted_key = jnp.sort(key)
        first = jnp.concatenate(
            [jnp.array([True]), sorted_key[1:] != sorted_key[:-1]])
        order = jnp.argsort(key)
        keep = keep & jnp.zeros_like(keep).at[order].set(
            first & (sorted_key >= 0))
        pos = row_count + jnp.cumsum(keep.astype(jnp.int32)) - 1
        ok = keep & (pos < H)
        slot = jnp.where(ok, pos, H)
        return (row_ids.at[slot].set(srcs, mode="drop"),
                (row_count
                 + jnp.sum(ok.astype(jnp.int32))).astype(jnp.int32),
                jnp.sum((keep & ~ok).astype(jnp.int32)))

    sids = jnp.arange(S, dtype=jnp.int32)
    return jax.vmap(row)(sids, ids, count, ins_src, ins_mask)


def extend_halo(halo: HaloSpec, routed: BatchUpdate, spec: ShardSpec, *,
                check: bool = True) -> HaloSpec:
    """Halo maintenance for one routed micro-batch (insertions only —
    deletions just leave stale slots).  Capacity overflow is the usual
    checked ``ShardCapacityError``; the stream owner repacks, which
    rebuilds the halo exactly (dropping any stale slots too)."""
    ids, count, dropped = _extend_halo(halo.ids, halo.count,
                                       routed.ins_src, routed.ins_mask,
                                       spec.vertices_per_shard)
    if check:
        d = np.asarray(dropped)
        if d.sum():
            bad = tuple(int(s) for s in np.flatnonzero(d))
            raise ShardCapacityError(
                f"{int(d.sum())} inserted boundary srcs exceed the halo "
                f"capacity {halo.ids.shape[1]} on shards {bad}; repack "
                "with a larger halo (comm model: DESIGN.md §10)",
                shards=bad)
    return HaloSpec(ids=ids, count=count)
