"""Autotuned kernel geometry: per-graph (BE, VB, spill) instead of fixed
pack constants.

The frontier-gated SpMV's cost profile is asymmetric (see
``roofline.analysis.gated_spmv_iteration_cost``): HBM traffic is gated to
active entries, but the static grid runs every entry's MXU step, so the
right (BE, VB) depends on the graph — its size, its *dst in-degree
distribution* (which fixes how many entries each candidate geometry
packs, including padding waste on skewed windows) and the frontier
fraction serving actually sees.  This module derives the geometry in two
stages:

  1. **model ranking** — for each candidate on the (BE, VB) grid, compute
     the exact per-window entry counts from the graph's dst histogram
     (degree distribution, not a uniform-fill guess) and rank by the
     roofline iteration cost at the expected frontier fraction;
  2. **measured search (fallback)** — time the top ``measure_top``
     candidates on one representative gated contribution (pack + SpMV on
     a clustered frontier of the expected fraction) and keep the winner.

Winners are cached keyed by ``(device kind, graph-shape signature,
frontier bucket)`` and the cache persists as JSON
(``~/.cache/repro/kernel_tune.json`` or ``$REPRO_TUNE_CACHE``), so a
serving restart — or any later stream over a same-shaped graph — skips
the search entirely.  ``ServeEngine`` bootstrap, ``pack_graph`` /
``pack_blocks`` (via ``KernelGeometry.pack_kw``) and
``dist.ShardedKernelEngine`` (``pack_shards``) all consume the result;
``launch/serve.py`` logs what was picked.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Optional, Sequence

import numpy as np

from repro.roofline.analysis import gated_spmv_iteration_cost

__all__ = ["KernelGeometry", "TuneCache", "TuneInfo", "candidate_costs",
           "default_cache_path", "graph_signature", "tune_geometry",
           "CANDIDATE_GRID"]

# (be, vb) candidates: VB stays a multiple of 128 lanes (the TPU lane
# width constraint the default 256 = 2x128 encodes), BE spans the
# paper's OpenMP chunk (2048) down to serving-fine entries
CANDIDATE_GRID: tuple = tuple(
    (be, vb) for be in (256, 512, 1024, 2048) for vb in (128, 256, 512))


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """One pack geometry: entry width, window width, spill reservation."""

    be: int
    vb: int
    spill_lanes_per_window: int

    def pack_kw(self) -> dict:
        """kwargs for pack_blocks / pack_graph / pack_shards."""
        return dict(be=self.be, vb=self.vb,
                    spill_lanes_per_window=self.spill_lanes_per_window)

    def describe(self) -> str:
        return (f"be={self.be} vb={self.vb} "
                f"spill={self.spill_lanes_per_window}")


@dataclasses.dataclass(frozen=True)
class TuneInfo:
    """How a geometry was picked (logged by launch/serve, benched)."""

    source: str                      # "cache" | "model" | "measured"
    cache_hit: bool
    tune_time_s: float
    key: str
    # (geometry, predicted_s, measured_s|None) per candidate considered
    candidates: tuple = ()


def _pow2_ceil(x: int) -> int:
    return 1 << max(0, int(math.ceil(math.log2(max(1, x)))))


def spill_for_stream(num_windows: int, expected_inserts: int,
                     be: int) -> int:
    """Spill lanes per window sized to absorb ``expected_inserts`` net
    insertions between repacks with 4x skew headroom, clamped to [16, BE]
    (a window never reserves more than one extra entry of slack)."""
    per_window = -(-4 * max(0, expected_inserts) // max(1, num_windows))
    return int(min(be, max(16, _pow2_ceil(per_window))))


def graph_signature(num_vertices: int, num_edges: int,
                    frontier_frac: float) -> str:
    """Bucketed shape key: graphs within ~2x in V/E and the same frontier
    decade share a tuned geometry (re-tuning inside a bucket would churn
    the cache for sub-model-resolution differences)."""
    lv = int(round(math.log2(max(2, num_vertices))))
    le = int(round(math.log2(max(2, num_edges))))
    lf = int(round(math.log10(max(1e-6, min(1.0, frontier_frac)))))
    return f"v2^{lv}-e2^{le}-f1e{lf}"


def device_kind() -> str:
    import jax
    try:
        return jax.devices()[0].device_kind.replace(" ", "_")
    except Exception:                                  # pragma: no cover
        return jax.default_backend()


def default_cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "kernel_tune.json")


class TuneCache:
    """Persistent {key: geometry} store (JSON, atomic rewrite).

    Tolerant by construction: a missing, corrupt or wrong-schema file is
    an empty cache, never an error — tuning must not take serving down.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data: dict = {}
        try:
            with open(self.path) as f:
                raw = json.load(f)
            for k, v in raw.items():
                self._data[k] = KernelGeometry(
                    be=int(v["be"]), vb=int(v["vb"]),
                    spill_lanes_per_window=int(v["spill_lanes_per_window"]))
        except (OSError, ValueError, KeyError, TypeError):
            self._data = {}

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: str) -> Optional[KernelGeometry]:
        return self._data.get(key)

    def put(self, key: str, geom: KernelGeometry) -> None:
        self._data[key] = geom
        self.save()

    def save(self) -> None:
        try:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({k: dataclasses.asdict(g)
                           for k, g in self._data.items()}, f, indent=2,
                          sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:                                # pragma: no cover
            pass                                       # cache is best-effort


# ---------------------------------------------------------------------------
# model ranking
# ---------------------------------------------------------------------------

def _geometry_cost(dst: np.ndarray, num_vertices: int, be: int, vb: int,
                   spill: int, frontier_frac: float) -> float:
    """Roofline iteration cost of (be, vb, spill) on THIS graph: entry
    counts come from the actual dst histogram (pack_blocks' exact sizing
    arithmetic), active work from the expected frontier fraction."""
    nw = -(-num_vertices // vb)
    counts = np.bincount(dst // vb, minlength=nw).astype(np.int64)
    n_base = -(-counts // be)
    slack = n_base * be - counts
    need = np.maximum(0, spill - slack)
    n_w = n_base + -(-need // be)                      # entries per window
    total_entries = int(np.sum(n_w))
    # clustered frontier of fraction f: ~f of the windows are active and
    # (sampling windows proportionally) carry ~f of the entries
    f = min(1.0, max(frontier_frac, 1.0 / max(1, nw)))
    active_windows = max(1.0, f * nw)
    active_entries = max(1.0, f * total_entries)
    return gated_spmv_iteration_cost(
        total_entries=total_entries, active_entries=active_entries,
        active_windows=active_windows, be=be, vb=vb,
        v_rsc=nw * vb)["total_s"]


def candidate_costs(dst: np.ndarray, num_vertices: int,
                    frontier_frac: float, expected_inserts: int,
                    grid: Sequence = CANDIDATE_GRID) -> list:
    """[(KernelGeometry, predicted_s)] ranked ascending by model cost."""
    dst = np.asarray(dst)
    out = []
    for be, vb in grid:
        if vb > max(128, _pow2_ceil(num_vertices)):
            continue                  # window wider than the whole graph
        nw = -(-num_vertices // vb)
        spill = spill_for_stream(nw, expected_inserts, be)
        geom = KernelGeometry(be=be, vb=vb, spill_lanes_per_window=spill)
        out.append((geom, _geometry_cost(dst, num_vertices, be, vb, spill,
                                         frontier_frac)))
    out.sort(key=lambda t: t[1])
    return out


# ---------------------------------------------------------------------------
# measured search
# ---------------------------------------------------------------------------

def _measure(graph, geom: KernelGeometry, frontier_frac: float,
             use_kernel: bool, repeats: int = 2) -> float:
    """Seconds for one gated contribution at ``geom`` on a clustered
    frontier of the expected fraction (pack time excluded — packing is
    per-repack, the SpMV is per-iteration)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.pagerank_spmv.ops import gated_contrib
    from repro.kernels.pagerank_spmv.update import pack_graph

    n = graph.num_vertices
    packed = pack_graph(graph, **geom.pack_kw())
    aff = np.zeros(n, bool)
    aff[: max(1, int(frontier_frac * n))] = True
    aff = jnp.asarray(aff)
    ranks = jnp.full((n,), 1.0 / n, jnp.float32)
    inv = (1.0 / graph.out_degree(include_self_loop=True)).astype(
        jnp.float32)
    out = gated_contrib(packed, ranks, inv, aff, use_kernel=use_kernel)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = gated_contrib(packed, ranks, inv, aff, use_kernel=use_kernel)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def tune_geometry(graph, *, frontier_frac: float = 0.05,
                  expected_inserts: int = 1024,
                  measure: bool = False, measure_top: int = 3,
                  use_kernel: Optional[bool] = None,
                  cache: Optional[TuneCache] = None,
                  cache_path: Optional[str] = None,
                  grid: Sequence = CANDIDATE_GRID
                  ) -> tuple[KernelGeometry, TuneInfo]:
    """Pick (BE, VB, spill) for ``graph``.

    Order of attack: persistent cache (keyed by device kind + bucketed
    graph shape + frontier decade) → roofline model ranking over the
    candidate grid → optional measured search over the model's top
    ``measure_top`` (the 2-3-candidate first-batch timing fallback).
    The winner is written back to the cache either way, so restarts and
    same-shaped streams skip straight to the cache hit.
    """
    t0 = time.perf_counter()
    n = graph.num_vertices
    e = int(graph.num_valid_edges())
    key = f"{device_kind()}/{graph_signature(n, e, frontier_frac)}"
    if cache is None:
        cache = TuneCache(cache_path)
    hit = cache.get(key)
    if hit is not None:
        return hit, TuneInfo(source="cache", cache_hit=True,
                             tune_time_s=time.perf_counter() - t0, key=key)

    dst = np.asarray(graph.dst)[np.asarray(graph.valid)]
    ranked = candidate_costs(dst, n, frontier_frac, expected_inserts,
                             grid=grid)
    source = "model"
    cands = [(g, p, None) for g, p in ranked]
    best = ranked[0][0]
    if measure and len(ranked) > 1:
        import jax
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        timed = []
        for geom, pred in ranked[: max(2, measure_top)]:
            timed.append((geom, pred,
                          _measure(graph, geom, frontier_frac, use_kernel)))
        timed.sort(key=lambda t: t[2])
        best = timed[0][0]
        cands = timed + cands[len(timed):]
        source = "measured"
    cache.put(key, best)
    return best, TuneInfo(source=source, cache_hit=False,
                          tune_time_s=time.perf_counter() - t0, key=key,
                          candidates=tuple(cands))
