"""Frontier-block-gated SpMV — the paper's work-skipping, TPU-native.

The paper skips *vertices* that are not affected (OpenMP dynamic schedule).
A TPU cannot branch per vertex, but it can skip whole VMEM tiles.  We
therefore translate "process only affected vertices" into "DMA + compute
only **active dst windows**":

  * edges are dst-sorted and packed into entries of BE edges, each entry
    belonging to one dst *window* of VB consecutive vertices
    (``pack_blocks``, host-side, done once per batch update);
  * a window is *active* iff any of its VB vertices is affected;
  * the grid visits a **compacted list of active entries** delivered via
    scalar prefetch; the BlockSpec index_map reads the entry id from SMEM,
    so inactive entries are never DMA'd from HBM at all — memory traffic is
    O(active_edges), matching the CPU algorithm's O(affected work);
  * excess grid steps (grid is static = NE) re-map to the last active entry
    — its block stays VMEM-resident, so they cost no HBM traffic; their
    contribution is zeroed via the ``i < n_active`` predicate;
  * the scatter within a window is a one-hot matmul
    ``w[1,BE] @ onehot[BE,VB]`` — an MXU contraction, the canonical TPU
    scatter idiom (VB=256 keeps the lane dim a multiple of 128, BE=2048
    mirrors the paper's OpenMP chunk size);
  * per-window accumulation across an entry run uses the Pallas revisit
    pattern: first entry of a run overwrites, the rest accumulate.

dtypes: f32 (primary) and bf16 (with f32 MXU accumulation).  f64 stays on
the XLA path — the TPU MXU has no f64; DESIGN.md §3 records the trade-off.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BE = 2048     # edges per entry (paper's OpenMP chunk size)
DEFAULT_VB = 256      # vertices per dst window (2 × 128 lanes)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedGraph:
    """Host-packed blocked edge structure (rebuilt per batch update)."""

    src: jax.Array        # int32[NE, BE]
    dst_rel: jax.Array    # int32[NE, BE]   dst - window*VB
    valid: jax.Array      # f32[NE, BE]     1.0 live / 0.0 pad
    window: jax.Array     # int32[NE]       window id per entry
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    vb: int = dataclasses.field(metadata=dict(static=True))
    be: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_entries(self) -> int:
        return self.src.shape[0]

    @property
    def num_windows(self) -> int:
        return -(-self.num_vertices // self.vb)


def pack_blocks(src: np.ndarray, dst: np.ndarray, valid: np.ndarray,
                num_vertices: int, be: int = DEFAULT_BE,
                vb: int = DEFAULT_VB, num_entries: int | None = None
                ) -> PackedGraph:
    """Group live edges by dst window, split each group into BE-edge entries.

    ``num_entries`` pins the entry capacity so a temporal stream keeps one
    compiled kernel across batches (pad with empty entries).
    """
    src = np.asarray(src)[np.asarray(valid)]
    dst = np.asarray(dst)[np.asarray(valid)]
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    win = dst // vb
    nw = -(-num_vertices // vb)

    entries_src, entries_dst, entries_val, entries_win = [], [], [], []
    for w in range(nw):
        lo, hi = np.searchsorted(win, w), np.searchsorted(win, w + 1)
        for off in range(lo, hi, be):
            chunk = slice(off, min(off + be, hi))
            n = chunk.stop - chunk.start
            s = np.zeros(be, np.int32)
            d = np.zeros(be, np.int32)
            v = np.zeros(be, np.float32)
            s[:n] = src[chunk]
            d[:n] = dst[chunk] - w * vb
            v[:n] = 1.0
            entries_src.append(s)
            entries_dst.append(d)
            entries_val.append(v)
            entries_win.append(w)
    ne = len(entries_src)
    cap = num_entries if num_entries is not None else max(ne, 1)
    if ne > cap:
        raise ValueError(f"{ne} entries exceed capacity {cap}")
    for _ in range(cap - ne):
        entries_src.append(np.zeros(be, np.int32))
        entries_dst.append(np.zeros(be, np.int32))
        entries_val.append(np.zeros(be, np.float32))
        entries_win.append(0)
    return PackedGraph(
        src=jnp.asarray(np.stack(entries_src)),
        dst_rel=jnp.asarray(np.stack(entries_dst)),
        valid=jnp.asarray(np.stack(entries_val)),
        window=jnp.asarray(np.asarray(entries_win, np.int32)),
        num_vertices=num_vertices, vb=vb, be=be)


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------

def _kernel(sel_ref, win_ref, first_ref, nact_ref,     # scalar prefetch
            src_ref, dstrel_ref, valid_ref, rsc_ref,   # tensor in
            out_ref):                                   # tensor out
    i = pl.program_id(0)
    active = (i < nact_ref[0]).astype(jnp.float32)
    be, vb = src_ref.shape[1], out_ref.shape[1]
    src = src_ref[0, :]
    w = jnp.take(rsc_ref[:], src, axis=0).astype(jnp.float32)
    w = w * valid_ref[0, :] * active                     # [BE]
    dst_rel = dstrel_ref[0, :]
    onehot = (dst_rel[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (be, vb), 1)
              ).astype(jnp.float32)
    part = jax.lax.dot_general(
        w[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [1, VB]

    @pl.when(first_ref[i] == 1)
    def _write():
        out_ref[0, :] = part[0]

    @pl.when(first_ref[i] == 0)
    def _accum():
        out_ref[0, :] += part[0]


@partial(jax.jit, static_argnames=("interpret",))
def frontier_spmv(packed: PackedGraph, rsc: jax.Array,
                  active_window: jax.Array, *, interpret: bool = False
                  ) -> jax.Array:
    """Gated blocked SpMV.  Returns f32[num_vertices] contributions.

    rsc: f32/bf16[V_pad] scaled ranks R/d (V_pad = NW*VB);
    active_window: bool[NW].
    """
    ne, be = packed.src.shape
    vb = packed.vb
    nw = packed.num_windows
    v_pad = nw * vb
    if rsc.shape[0] != v_pad:
        rsc = jnp.pad(rsc, (0, v_pad - rsc.shape[0]))

    # --- device-side active-entry compaction (stable order) ---------------
    entry_active = active_window[packed.window]
    # stable argsort: active entries first, original order preserved
    order = jnp.argsort(~entry_active, stable=True)
    sel = order.astype(jnp.int32)
    nact = jnp.sum(entry_active.astype(jnp.int32)).astype(jnp.int32)
    win_sel = packed.window[sel]
    # windows of excess steps are pinned to the last active entry's window
    last = jnp.maximum(nact - 1, 0)
    pin = win_sel[last]
    idx = jnp.arange(ne, dtype=jnp.int32)
    win_eff = jnp.where(idx < nact, win_sel, pin)
    sel_eff = jnp.where(idx < nact, sel, sel[last])
    first = jnp.where(
        idx < nact,
        jnp.concatenate([jnp.ones((1,), jnp.int32),
                         (win_eff[1:] != win_eff[:-1]).astype(jnp.int32)]),
        0)
    # i==0 must write even when nact==0 (zeros) so block 0 is defined
    first = first.at[0].set(1)
    nact_arr = jnp.asarray([nact], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((v_pad,), lambda i, sel, win, first, nact: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (1, vb), lambda i, sel, win, first, nact: (win[i], 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nw, vb), jnp.float32),
        interpret=interpret,
    )(sel_eff, win_eff, first, nact_arr,
      packed.src, packed.dst_rel, packed.valid, rsc)
    contrib = out.reshape(-1)[: packed.num_vertices]
    # inactive windows are never visited -> their blocks are undefined;
    # the contract (and the engine) wants zeros there.
    vmask = jnp.repeat(active_window, vb)[: packed.num_vertices]
    return jnp.where(vmask, contrib, 0.0)
