"""Frontier-block-gated SpMV — the paper's work-skipping, TPU-native.

The paper skips *vertices* that are not affected (OpenMP dynamic schedule).
A TPU cannot branch per vertex, but it can skip whole VMEM tiles.  We
therefore translate "process only affected vertices" into "DMA + compute
only **active dst windows**":

  * edges are dst-sorted and packed into entries of BE edges, each entry
    belonging to one dst *window* of VB consecutive vertices
    (``pack_blocks``, host-side, done once per batch update);
  * a window is *active* iff any of its VB vertices is affected;
  * the grid visits a **compacted list of active entries** delivered via
    scalar prefetch; the BlockSpec index_map reads the entry id from SMEM,
    so inactive entries are never DMA'd from HBM at all — memory traffic is
    O(active_edges), matching the CPU algorithm's O(affected work);
  * excess grid steps (grid is static = NE) re-map to the last active entry
    — its block stays VMEM-resident, so they cost no HBM traffic; their
    contribution is zeroed via the ``i < n_active`` predicate;
  * the scatter within a window is a one-hot matmul
    ``w[1,BE] @ onehot[BE,VB]`` — an MXU contraction, the canonical TPU
    scatter idiom (VB=256 keeps the lane dim a multiple of 128, BE=2048
    mirrors the paper's OpenMP chunk size);
  * per-window accumulation across an entry run uses the Pallas revisit
    pattern: first entry of a run overwrites, the rest accumulate.

dtypes: f32 (primary) and bf16 (with f32 MXU accumulation).  f64 stays on
the XLA path — the TPU MXU has no f64; DESIGN.md §3 records the trade-off.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BE = 2048     # edges per entry (paper's OpenMP chunk size)
DEFAULT_VB = 256      # vertices per dst window (2 × 128 lanes)


LANE_SENTINEL = np.iinfo(np.int64).max   # key of a never-live lane


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedGraph:
    """Blocked edge structure: host-packed bootstrap, device-maintained.

    Entries stay sorted by window (same-window entries are contiguous in
    flat order — the kernel's overwrite/accumulate run detection depends
    on it) and ``window``/``entry_start`` never change after packing;
    incremental updates (``update.apply_batch_packed``) only flip lanes.

    The last four arrays form the *edge locator* the incremental update
    searches instead of scanning lanes: ``sorted_key``/``sorted_lane``
    index the pack-time lanes by (src·V + dst) key for binary search, and
    ``ovl_key``/``ovl_lane`` are an append-only overlay recording every
    lane claimed by an insertion since the last pack.  Locator hits are
    *candidates* — a lane may have been freed and reclaimed for another
    edge — so lookups verify the lane's current contents; every live edge
    is findable through one of the two (pack-time lanes via the base
    index, inserted lanes via the overlay).  A full overlay is a checked
    error that callers resolve by repacking (which rebuilds the base
    index and empties the overlay).
    """

    src: jax.Array        # int32[NE, BE]
    dst_rel: jax.Array    # int32[NE, BE]   dst - window*VB
    valid: jax.Array      # f32[NE, BE]     1.0 live / 0.0 pad
    window: jax.Array     # int32[NE]       window id per entry
    entry_start: jax.Array  # int32[NW+1]   window w owns entries
    #                       [entry_start[w], entry_start[w+1])
    sorted_key: jax.Array   # int64[NE*BE]  pack-time lane keys, ascending
    sorted_lane: jax.Array  # int32[NE*BE]  flat lane id per sorted key
    ovl_key: jax.Array      # int64[K]      keys inserted since the pack
    ovl_lane: jax.Array     # int32[K]      lane each insertion claimed
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    vb: int = dataclasses.field(metadata=dict(static=True))
    be: int = dataclasses.field(metadata=dict(static=True))
    # max entries any one window owns — bounds the per-window free-slot
    # scan of the incremental update (static so gather shapes stay fixed)
    max_entries_per_window: int = dataclasses.field(
        default=1, metadata=dict(static=True))

    @property
    def num_entries(self) -> int:
        return self.src.shape[0]

    @property
    def num_windows(self) -> int:
        return -(-self.num_vertices // self.vb)

    @property
    def overlay_capacity(self) -> int:
        return self.ovl_key.shape[0]


def pack_blocks(src: np.ndarray, dst: np.ndarray, valid: np.ndarray,
                num_vertices: int, be: int = DEFAULT_BE,
                vb: int = DEFAULT_VB, num_entries: int | None = None,
                spill_lanes_per_window: int = 0,
                extra_entries: int = 0,
                overlay_capacity: int = 1024,
                max_entries_per_window: int | None = None) -> PackedGraph:
    """Group live edges by dst window, split each group into BE-edge entries.

    Fully vectorised (one stable argsort + one scatter — no Python loop
    over windows, empty or not).  ``num_entries`` pins the entry capacity
    so a temporal stream keeps one compiled kernel across batches; excess
    capacity is appended as empty entries owned by the *last* window so
    the window array stays sorted (a window-0 tail would break the
    kernel's first-entry-of-run overwrite when window 0 is active).

    ``spill_lanes_per_window`` guarantees every window owns at least that
    many free (padded) lanes, adding whole empty entries where the last
    partial entry's slack is not enough — headroom for
    ``update.apply_batch_packed`` to claim insertion slots without a host
    repack.  Windows with no edges get entries too, so every window is
    insertable and every active window has a block the kernel writes.

    ``extra_entries`` (ignored when ``num_entries`` pins the capacity)
    appends that many additional empty tail entries, owned by the *last*
    window until a repack at the same total capacity redistributes them
    to whichever windows grew — size it to match the edge list's spare
    ``edge_capacity`` so repacks keep fitting as the graph grows (the
    spill guarantee itself may stop fitting under skewed growth; stream
    owners degrade it on repack, see ``serve.engine.ServeEngine``).

    ``overlay_capacity`` sizes the insertion overlay of the edge locator
    (see ``PackedGraph``): how many insertions ``apply_batch_packed`` can
    absorb before the stream owner must repack.

    ``max_entries_per_window`` pins the static per-window entry bound (a
    jit shape): a stream owner repacking mid-stream must pass the value
    pinned at bootstrap or the compiled update/kernel retrace.  It must
    cover the widest window of *this* pack (checked); ``num_entries``
    (every window can at most own all entries) is always a safe pin.
    """
    src = np.asarray(src, np.int32)[np.asarray(valid, bool)]
    dst = np.asarray(dst, np.int32)[np.asarray(valid, bool)]
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    win = dst // vb
    nw = -(-num_vertices // vb)

    counts = np.bincount(win, minlength=nw).astype(np.int64)
    n_base = -(-counts // be)                        # ceil, 0 for empty
    slack = n_base * be - counts
    need = np.maximum(0, spill_lanes_per_window - slack)
    n_w = n_base + -(-need // be)                    # entries per window
    offsets = np.concatenate([[0], np.cumsum(n_w)])
    ne = int(offsets[-1])
    cap = (num_entries if num_entries is not None
           else max(ne + max(0, extra_entries), 1))
    if ne > cap:
        raise ValueError(
            f"{ne} entries exceed capacity {cap}; raise num_entries or "
            "shrink spill_lanes_per_window (capacity sizing: DESIGN.md §8)")

    s = np.zeros((cap, be), np.int32)
    d = np.zeros((cap, be), np.int32)
    v = np.zeros((cap, be), np.float32)
    # rank of each (dst-sorted) edge within its window -> (entry, lane)
    edge_start = np.concatenate([[0], np.cumsum(counts)])[:-1]
    rank = np.arange(len(dst), dtype=np.int64) - edge_start[win]
    entry_idx = offsets[win] + rank // be
    lane_idx = rank % be
    s[entry_idx, lane_idx] = src
    d[entry_idx, lane_idx] = dst - win * vb
    v[entry_idx, lane_idx] = 1.0

    # entry -> window map; capacity tail belongs to the last window
    window = np.full(cap, nw - 1, np.int32)
    window[:ne] = np.repeat(np.arange(nw, dtype=np.int32), n_w)
    entry_start = offsets.astype(np.int32).copy()
    entry_start[nw] = cap
    owned = np.diff(entry_start.astype(np.int64))

    # edge locator: pack-time lanes sorted by key + an empty overlay
    lane_key = np.full(cap * be, LANE_SENTINEL, np.int64)
    flat = entry_idx * be + lane_idx
    lane_key[flat] = src.astype(np.int64) * num_vertices + dst
    order = np.argsort(lane_key)
    widest = max(1, int(owned.max()))
    if max_entries_per_window is None:
        max_entries_per_window = widest
    elif widest > max_entries_per_window:
        raise ValueError(
            f"{widest} entries in one window exceed the pinned "
            f"max_entries_per_window {max_entries_per_window}")
    return PackedGraph(
        src=jnp.asarray(s),
        dst_rel=jnp.asarray(d),
        valid=jnp.asarray(v),
        window=jnp.asarray(window),
        entry_start=jnp.asarray(entry_start),
        sorted_key=jnp.asarray(lane_key[order]),
        sorted_lane=jnp.asarray(order.astype(np.int32)),
        ovl_key=jnp.full((overlay_capacity,), LANE_SENTINEL, jnp.int64),
        ovl_lane=jnp.zeros((overlay_capacity,), jnp.int32),
        num_vertices=num_vertices, vb=vb, be=be,
        max_entries_per_window=max_entries_per_window)


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------

def _kernel(sel_ref, win_ref, first_ref, nact_ref,     # scalar prefetch
            src_ref, dstrel_ref, valid_ref, rsc_ref,   # tensor in
            out_ref):                                   # tensor out
    i = pl.program_id(0)
    active = (i < nact_ref[0]).astype(jnp.float32)
    be, vb = src_ref.shape[1], out_ref.shape[1]
    src = src_ref[0, :]
    w = jnp.take(rsc_ref[:], src, axis=0).astype(jnp.float32)
    w = w * valid_ref[0, :] * active                     # [BE]
    dst_rel = dstrel_ref[0, :]
    onehot = (dst_rel[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (be, vb), 1)
              ).astype(jnp.float32)
    part = jax.lax.dot_general(
        w[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [1, VB]

    @pl.when(first_ref[i] == 1)
    def _write():
        out_ref[0, :] = part[0]

    @pl.when(first_ref[i] == 0)
    def _accum():
        out_ref[0, :] += part[0]


@partial(jax.jit, static_argnames=("interpret",))
def frontier_spmv_padded(packed: PackedGraph, rsc: jax.Array,
                         active_window: jax.Array, *,
                         interpret: bool = False) -> jax.Array:
    """Gated blocked SpMV on pre-padded buffers.  Returns f32[V_pad]
    contributions (V_pad = NW*VB); inactive windows are zeroed.

    rsc: f32/bf16[V_pad] scaled ranks R/d — already padded, so an
    iteration loop that keeps its rank buffer padded pays no per-call
    pad/slice; active_window: bool[NW], precomputed by the caller.

    rsc may also be LONGER than NW*VB: a shard-local pack (shard.py)
    scatters into its own window range but gathers by *global* src from
    the full replicated vector — the whole rsc block is prefetched
    either way, only its length differs.
    """
    ne, be = packed.src.shape
    vb = packed.vb
    nw = packed.num_windows
    v_pad = nw * vb
    if rsc.shape[0] < v_pad:
        rsc = jnp.pad(rsc, (0, v_pad - rsc.shape[0]))
    v_rsc = rsc.shape[0]

    # --- device-side active-entry compaction (stable order) ---------------
    entry_active = active_window[packed.window]
    # stable argsort: active entries first, original order preserved
    order = jnp.argsort(~entry_active, stable=True)
    sel = order.astype(jnp.int32)
    nact = jnp.sum(entry_active.astype(jnp.int32)).astype(jnp.int32)
    win_sel = packed.window[sel]
    # windows of excess steps are pinned to the last active entry's window
    last = jnp.maximum(nact - 1, 0)
    pin = win_sel[last]
    idx = jnp.arange(ne, dtype=jnp.int32)
    win_eff = jnp.where(idx < nact, win_sel, pin)
    sel_eff = jnp.where(idx < nact, sel, sel[last])
    first = jnp.where(
        idx < nact,
        jnp.concatenate([jnp.ones((1,), jnp.int32),
                         (win_eff[1:] != win_eff[:-1]).astype(jnp.int32)]),
        0)
    # i==0 must write even when nact==0 (zeros) so block 0 is defined
    first = first.at[0].set(1)
    nact_arr = jnp.asarray([nact], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((v_rsc,), lambda i, sel, win, first, nact: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (1, vb), lambda i, sel, win, first, nact: (win[i], 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nw, vb), jnp.float32),
        interpret=interpret,
    )(sel_eff, win_eff, first, nact_arr,
      packed.src, packed.dst_rel, packed.valid, rsc)
    # inactive windows are never visited -> their blocks are undefined;
    # the contract (and the engine) wants zeros there.
    vmask = jnp.repeat(active_window, vb)
    return jnp.where(vmask, out.reshape(-1), 0.0)


@partial(jax.jit, static_argnames=("interpret",))
def frontier_spmv(packed: PackedGraph, rsc: jax.Array,
                  active_window: jax.Array, *, interpret: bool = False
                  ) -> jax.Array:
    """Gated blocked SpMV.  Returns f32[num_vertices] contributions."""
    return frontier_spmv_padded(packed, rsc, active_window,
                                interpret=interpret)[: packed.num_vertices]
