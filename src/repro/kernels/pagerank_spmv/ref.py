"""Pure-jnp oracle for the frontier-gated blocked SpMV (no Pallas).

Semantics: given edges packed into (window, entry) blocks (see
``pagerank_spmv.pack_blocks``), a scaled rank vector ``rsc[u] = R[u]/d_u``
and an ``active_window`` mask, compute

    out[v] = Σ_{valid e: dst(e)=v}  rsc[src(e)]      if window(v) active
    out[v] = 0                                        otherwise

which is exactly the masked pull-contribution the DF/DF-P engine consumes.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax


def frontier_spmv_ref_padded(src, dst_rel, valid, window, rsc,
                             active_window, vb: int):
    """src/dst_rel/valid: [NE, BE]; window: int32[NE]; rsc: f[V_pad];
    active_window: bool[NW].  Returns f[NW*VB] (inactive windows zero)."""
    ne, be = src.shape
    nw = active_window.shape[0]
    w = rsc[src.reshape(-1)].reshape(ne, be) * valid.astype(rsc.dtype)
    entry_active = active_window[window]
    w = w * entry_active[:, None].astype(rsc.dtype)
    flat_dst = window[:, None] * vb + dst_rel       # [NE, BE] global dst idx
    return jax.ops.segment_sum(
        w.reshape(-1), flat_dst.reshape(-1), num_segments=nw * vb)


def frontier_spmv_ref(src, dst_rel, valid, window, rsc, active_window,
                      num_vertices: int, vb: int):
    """As above, truncated to f[num_vertices]."""
    return frontier_spmv_ref_padded(src, dst_rel, valid, window, rsc,
                                    active_window, vb)[:num_vertices]
