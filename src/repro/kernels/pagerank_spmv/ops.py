"""jit'd public wrapper for the frontier-gated SpMV kernel.

On CPU (this container) the kernel runs in ``interpret=True`` mode — the
kernel body executes in Python/XLA for bit-level validation against
``ref.py``.  On TPU backends the compiled Mosaic kernel runs natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pagerank_spmv.pagerank_spmv import (
    DEFAULT_BE, DEFAULT_VB, PackedGraph, frontier_spmv, pack_blocks)
from repro.kernels.pagerank_spmv.ref import frontier_spmv_ref

__all__ = ["PackedGraph", "pack_blocks", "gated_contrib", "DEFAULT_BE",
           "DEFAULT_VB"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gated_contrib(packed: PackedGraph, ranks: jax.Array, inv_deg: jax.Array,
                  affected: jax.Array, *, use_kernel: bool = True
                  ) -> jax.Array:
    """contrib[v] = Σ_{u→v, u≠v} R[u]/d_u for v in active windows, else 0.

    ``affected``: bool[V] vertex mask — reduced to window granularity here.
    """
    nw = packed.num_windows
    vb = packed.vb
    v_pad = nw * vb
    aff_pad = jnp.pad(affected, (0, v_pad - affected.shape[0]))
    active_window = jnp.any(aff_pad.reshape(nw, vb), axis=1)
    rsc = (ranks * inv_deg).astype(jnp.float32)
    rsc = jnp.pad(rsc, (0, v_pad - rsc.shape[0]))
    if use_kernel:
        return frontier_spmv(packed, rsc, active_window,
                             interpret=not _on_tpu())
    return frontier_spmv_ref(packed.src, packed.dst_rel, packed.valid,
                             packed.window, rsc, active_window,
                             packed.num_vertices, vb)
