"""jit'd public wrapper for the frontier-gated SpMV kernel.

On CPU (this container) the kernel runs in ``interpret=True`` mode — the
kernel body executes in Python/XLA for bit-level validation against
``ref.py``.  On TPU backends the compiled Mosaic kernel runs natively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.pagerank_spmv.pagerank_spmv import (
    DEFAULT_BE, DEFAULT_VB, PackedGraph, frontier_spmv,
    frontier_spmv_padded, pack_blocks)
from repro.kernels.pagerank_spmv.ref import (frontier_spmv_ref,
                                             frontier_spmv_ref_padded)

__all__ = ["PackedGraph", "pack_blocks", "gated_contrib", "DEFAULT_BE",
           "DEFAULT_VB"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gated_contrib(packed: PackedGraph, ranks: jax.Array, inv_deg: jax.Array,
                  affected: Optional[jax.Array] = None, *,
                  active_window: Optional[jax.Array] = None,
                  use_kernel: bool = True, pad_out: bool = False
                  ) -> jax.Array:
    """contrib[v] = Σ_{u→v, u≠v} R[u]/d_u for v in active windows, else 0.

    Gating granularity: either ``affected`` (bool[V] vertex mask, reduced
    to windows here — the one-shot convenience form) or a precomputed
    ``active_window`` (bool[NW]).  An iteration loop should pass
    ``active_window`` plus *pre-padded* ``ranks``/``inv_deg`` (length
    NW*VB) and ``pad_out=True`` so no pad/reduce/slice is re-done inside
    the while_loop body on every call.
    """
    nw = packed.num_windows
    vb = packed.vb
    v_pad = nw * vb
    if active_window is None:
        if affected is None:
            raise ValueError("need affected or active_window")
        aff = affected
        if aff.shape[0] != v_pad:
            aff = jnp.pad(aff, (0, v_pad - aff.shape[0]))
        active_window = jnp.any(aff.reshape(nw, vb), axis=1)
    rsc = (ranks * inv_deg).astype(jnp.float32)
    if rsc.shape[0] != v_pad:
        rsc = jnp.pad(rsc, (0, v_pad - rsc.shape[0]))
    if use_kernel:
        out = frontier_spmv_padded(packed, rsc, active_window,
                                   interpret=not _on_tpu())
    else:
        out = frontier_spmv_ref_padded(packed.src, packed.dst_rel,
                                       packed.valid, packed.window, rsc,
                                       active_window, vb)
    return out if pad_out else out[: packed.num_vertices]
