"""Incremental, jit-able ``PackedGraph`` maintenance.

``apply_batch_packed`` mirrors ``graph.dynamic.apply_batch`` on the
*blocked* structure the frontier-gated kernel consumes, so a temporal
stream never pays the host-side ``pack_blocks`` rebuild per micro-batch
(the "full recompute per update" failure mode incremental maintenance
must avoid — Bahmani et al., Zhang et al.):

  * lookups (deletion targets, duplicate-insert checks) go through the
    packed structure's *edge locator*: binary search over the pack-time
    ``sorted_key`` index plus a linear probe of the small insertion
    overlay, each candidate verified against the lane's current
    ``(src, dst_rel, window)`` contents — O(|Δ|·log L), never a scan of
    all lanes;
  * deletion (u, v): flip the verified lane's ``valid`` to 0 — no-op if
    absent;
  * insertion (u, v): the k-th kept insertion into a dst window claims
    that window's k-th free lane — the slack of its last partial entry
    plus the spill entries ``pack_blocks(spill_lanes_per_window=...)``
    reserved — found by a per-window scan over entry free counts
    (bounded by ``max_entries_per_window``, a static shape), and is
    recorded in the overlay so later batches can find it;
  * ``window``/``entry_start``/``sorted_*`` and every array shape are
    untouched, so one compiled update *and* one compiled kernel loop
    serve the whole stream (asserted via ``TRACE_COUNTS`` in tests).

Running out of free lanes in a window ("spill exhaustion") or of overlay
slots is a checked error: the device function counts dropped insertions
and the host wrapper raises the same message shape as ``pack_blocks``
capacity overflow.  Callers that want to keep going repack with
``pack_graph`` (which defragments freed lanes, rebuilds the base index
and empties the overlay) — the serve engine does exactly that.
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.dynamic import BatchUpdate
from repro.graph.structure import EdgeListGraph
from repro.kernels.pagerank_spmv.pagerank_spmv import (
    DEFAULT_BE, DEFAULT_VB, LANE_SENTINEL, PackedGraph, pack_blocks)

__all__ = ["apply_batch_packed", "pack_graph", "packed_edge_set",
           "TRACE_COUNTS"]

# retracing telemetry: incremented at trace time (not per call), so a
# temporal stream can assert "one compiled update, no recompiles"
TRACE_COUNTS: collections.Counter = collections.Counter()


def pack_graph(graph: EdgeListGraph, *, be: int = DEFAULT_BE,
               vb: int = DEFAULT_VB, num_entries: int | None = None,
               spill_lanes_per_window: int = 0,
               extra_entries: int = 0,
               overlay_capacity: int = 1024,
               max_entries_per_window: int | None = None) -> PackedGraph:
    """Host-side bootstrap/repack: EdgeListGraph -> PackedGraph."""
    return pack_blocks(np.asarray(graph.src), np.asarray(graph.dst),
                       np.asarray(graph.valid), graph.num_vertices,
                       be=be, vb=vb, num_entries=num_entries,
                       spill_lanes_per_window=spill_lanes_per_window,
                       extra_entries=extra_entries,
                       overlay_capacity=overlay_capacity,
                       max_entries_per_window=max_entries_per_window)


def packed_edge_set(packed: PackedGraph) -> set:
    """Host-side set of live (src, dst) pairs — the parity oracle."""
    src = np.asarray(packed.src)
    dst = (np.asarray(packed.window)[:, None] * packed.vb
           + np.asarray(packed.dst_rel))
    live = np.asarray(packed.valid) > 0
    return set(zip(src[live].tolist(), dst[live].tolist()))


@jax.jit
def _apply_batch_packed(packed: PackedGraph, update: BatchUpdate):
    TRACE_COUNTS["apply_batch_packed"] += 1            # trace-time only
    V = packed.num_vertices
    vb, be = packed.vb, packed.be
    M = packed.max_entries_per_window
    ne = packed.num_entries
    L = ne * be                                        # lanes; L = drop
    K = packed.overlay_capacity
    src_flat = packed.src.reshape(-1)
    rel_flat = packed.dst_rel.reshape(-1)
    valid = packed.valid.reshape(-1)

    def locate(key, u, v, live):
        """Flat lane currently holding edge (u, v), else L.

        Locator candidates (base binary search + overlay probe) are
        verified against the lanes' current contents and liveness.
        """
        def verify(lane, ok):
            lane_c = jnp.clip(lane, 0, L - 1)
            d = (packed.window[lane_c // be] * vb + rel_flat[lane_c])
            return (ok & (src_flat[lane_c] == u) & (d == v)
                    & (live[lane_c] > 0))

        pos = jnp.clip(jnp.searchsorted(packed.sorted_key, key), 0, L - 1)
        base_lane = packed.sorted_lane[pos]
        base_ok = verify(base_lane, jnp.asarray(True))
        ovl_hit = verify(packed.ovl_lane, packed.ovl_key == key)  # [K]
        ovl_lane = packed.ovl_lane[jnp.argmax(ovl_hit)]
        # a live edge occupies exactly one lane, so at most one verifies
        return jnp.where(base_ok, base_lane,
                         jnp.where(jnp.any(ovl_hit), ovl_lane, L))

    # ---- deletions ------------------------------------------------------
    del_key = (update.del_src.astype(jnp.int64) * V + update.del_dst)
    del_t = jax.vmap(lambda k, u, v, m: jnp.where(
        m, locate(k, u, v, valid), L))(
            del_key, update.del_src, update.del_dst, update.del_mask)
    valid = valid.at[del_t].set(0.0, mode="drop")

    # ---- insertions -----------------------------------------------------
    ins_w = update.ins_dst // vb
    ins_rel = update.ins_dst - ins_w * vb
    ins_key = (update.ins_src.astype(jnp.int64) * V + update.ins_dst)
    # duplicate-of-live check against the post-deletion lanes, so a
    # delete+reinsert of one edge within a batch lands back in a window
    dup = jax.vmap(lambda k, u, v: locate(k, u, v, valid) < L)(
        ins_key, update.ins_src, update.ins_dst)
    keep = update.ins_mask & ~dup
    # de-dup within the batch itself (same scheme as apply_batch)
    key = jnp.where(keep, ins_key, -1)
    sorted_key = jnp.sort(key)
    first = jnp.concatenate(
        [jnp.array([True]), sorted_key[1:] != sorted_key[:-1]])
    order = jnp.argsort(key)
    keep = keep & jnp.zeros_like(keep).at[order].set(
        first & (sorted_key >= 0))
    # k-th kept insertion into a window -> that window's k-th free lane
    icap = keep.shape[0]
    i = jnp.arange(icap)
    rank = jnp.sum(keep[None, :] & (ins_w[None, :] == ins_w[:, None])
                   & (i[None, :] < i[:, None]), axis=1)

    # per-window free-slot scan: entry free counts -> (entry, lane). All
    # shapes are O(|Δ|·M) / O(|Δ|·BE) — hub windows with many entries
    # only widen the tiny M axis, nothing rescans the full lane array.
    free_cnt = jnp.sum((valid.reshape(ne, be) <= 0).astype(jnp.int32),
                       axis=1)
    eids = packed.entry_start[ins_w][:, None] + jnp.arange(M)   # [I, M]
    emask = eids < packed.entry_start[ins_w + 1][:, None]
    cnt = jnp.where(emask, free_cnt[jnp.clip(eids, 0, ne - 1)], 0)
    cumc = jnp.cumsum(cnt, axis=1)                              # [I, M]
    ok_window = keep & (rank < cumc[:, -1])
    m_idx = jnp.argmax(cumc > rank[:, None], axis=1)
    within = rank - jnp.where(m_idx > 0,
                              jnp.take_along_axis(
                                  cumc, jnp.maximum(m_idx - 1, 0)[:, None],
                                  axis=1)[:, 0], 0)
    entry = jnp.clip(eids[i, m_idx], 0, ne - 1)
    rowfree = valid.reshape(ne, be)[entry] <= 0                 # [I, BE]
    rowcum = jnp.cumsum(rowfree.astype(jnp.int32), axis=1)
    lane_in = jnp.argmax(rowcum == (within + 1)[:, None], axis=1)
    tgt = entry * be + lane_in

    # overlay append (so later batches can locate these edges); overlay
    # slots, like lanes, are a checked capacity
    used = jnp.sum((packed.ovl_key != LANE_SENTINEL).astype(jnp.int32))
    grank = jnp.cumsum((ok_window).astype(jnp.int32)) - 1
    slot = jnp.where(ok_window, used + grank, K)
    final_ok = ok_window & (slot < K)
    slot = jnp.where(final_ok, slot, K)
    ovl_key = packed.ovl_key.at[slot].set(ins_key, mode="drop")
    ovl_lane = packed.ovl_lane.at[slot].set(tgt.astype(jnp.int32),
                                            mode="drop")
    dropped = (keep & ~ok_window) | (ok_window & ~final_ok)

    tgt = jnp.where(final_ok, tgt, L)
    src = src_flat.at[tgt].set(update.ins_src, mode="drop")
    dst_rel = rel_flat.at[tgt].set(ins_rel, mode="drop")
    valid = valid.at[tgt].set(1.0, mode="drop")
    new = dataclasses.replace(packed, src=src.reshape(ne, be),
                              dst_rel=dst_rel.reshape(ne, be),
                              valid=valid.reshape(ne, be),
                              ovl_key=ovl_key, ovl_lane=ovl_lane)
    return new, jnp.sum(dropped.astype(jnp.int32))


def apply_batch_packed(packed: PackedGraph, update: BatchUpdate, *,
                       check: bool = True) -> PackedGraph:
    """Pure device function Packedᵗ⁻¹, Δᵗ → Packedᵗ (shapes unchanged).

    ``check=True`` syncs one scalar to raise on spill/overlay exhaustion
    — skip it only when the caller audits overflow out of band.
    """
    new, dropped = _apply_batch_packed(packed, update)
    if check:
        n = int(dropped)
        if n:
            raise ValueError(
                f"{n} insertions exceed spill capacity of their dst "
                f"windows or the locator overlay; repack with pack_graph "
                "/ raise spill_lanes_per_window or overlay_capacity "
                "(capacity sizing: DESIGN.md §8)")
    return new
