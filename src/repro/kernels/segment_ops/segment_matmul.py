"""Gated blocked segment-SpMM Pallas kernel — GNN message aggregation.

Same frontier-window gating as kernels/pagerank_spmv (see that module's
docstring for the scheme) but aggregates *feature rows* instead of scalars:

    out[v, :] = Σ_{u→v} X[u, :]        for v in active dst windows

i.e. ``A_maskᵀ @ X`` with dst-window granular skipping.  This is the kernel
behind ``core/incremental_gnn.py`` — the paper's frontier technique applied
to GNN embedding refresh (DESIGN.md §5) — and the generic aggregation for
GraphSAGE/PNA full-graph layers.

Scatter-as-matmul: onehotᵀ[VB,BE] @ X_gathered[BE,D] is an MXU contraction;
D and VB are kept multiples of 128 by the wrapper (pad).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pagerank_spmv.pagerank_spmv import PackedGraph


def _kernel(sel_ref, win_ref, first_ref, nact_ref,
            src_ref, dstrel_ref, valid_ref, x_ref,
            out_ref):
    i = pl.program_id(0)
    active = (i < nact_ref[0]).astype(jnp.float32)
    be, vb = src_ref.shape[1], out_ref.shape[1]
    src = src_ref[0, :]
    xg = jnp.take(x_ref[:], src, axis=0).astype(jnp.float32)    # [BE, D]
    xg = xg * (valid_ref[0, :] * active)[:, None]
    dst_rel = dstrel_ref[0, :]
    onehot = (dst_rel[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (be, vb), 1)
              ).astype(jnp.float32)                              # [BE, VB]
    part = jax.lax.dot_general(
        onehot, xg, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # [VB, D]

    @pl.when(first_ref[i] == 1)
    def _write():
        out_ref[0, :, :] = part

    @pl.when(first_ref[i] == 0)
    def _accum():
        out_ref[0, :, :] += part


@partial(jax.jit, static_argnames=("interpret",))
def gated_spmm(packed: PackedGraph, feats: jax.Array,
               active_window: jax.Array, *, interpret: bool = False
               ) -> jax.Array:
    """feats: f[V_pad, D] -> f32[num_vertices, D] gated aggregation."""
    ne, be = packed.src.shape
    vb = packed.vb
    nw = packed.num_windows
    v_pad = nw * vb
    d = feats.shape[1]
    d_pad = -(-d // 128) * 128
    if feats.shape != (v_pad, d_pad):
        feats = jnp.pad(feats.astype(jnp.float32),
                        ((0, v_pad - feats.shape[0]), (0, d_pad - d)))

    entry_active = active_window[packed.window]
    order = jnp.argsort(~entry_active, stable=True)
    sel = order.astype(jnp.int32)
    nact = jnp.sum(entry_active.astype(jnp.int32)).astype(jnp.int32)
    win_sel = packed.window[sel]
    last = jnp.maximum(nact - 1, 0)
    idx = jnp.arange(ne, dtype=jnp.int32)
    win_eff = jnp.where(idx < nact, win_sel, win_sel[last])
    sel_eff = jnp.where(idx < nact, sel, sel[last])
    first = jnp.where(
        idx < nact,
        jnp.concatenate([jnp.ones((1,), jnp.int32),
                         (win_eff[1:] != win_eff[:-1]).astype(jnp.int32)]),
        0)
    first = first.at[0].set(1)
    nact_arr = jnp.asarray([nact], jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((1, be), lambda i, sel, win, first, nact: (sel[i], 0)),
            pl.BlockSpec((v_pad, d_pad),
                         lambda i, sel, win, first, nact: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, vb, d_pad), lambda i, sel, win, first, nact: (win[i], 0, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nw, vb, d_pad), jnp.float32),
        interpret=interpret,
    )(sel_eff, win_eff, first, nact_arr,
      packed.src, packed.dst_rel, packed.valid, feats)
    out = out.reshape(nw * vb, d_pad)[: packed.num_vertices, :d]
    vmask = jnp.repeat(active_window, vb)[: packed.num_vertices]
    return jnp.where(vmask[:, None], out, 0.0)
