"""Pure-jnp oracle for the gated blocked segment-SpMM (GNN aggregation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_spmm_ref(src, dst_rel, valid, window, feats, active_window,
                   num_vertices: int, vb: int):
    """out[v, :] = Σ_{valid e: dst(e)=v} feats[src(e), :] on active windows.

    src/dst_rel/valid: [NE, BE]; feats: f32[V_pad, D]; active_window: bool[NW].
    """
    ne, be = src.shape
    nw = active_window.shape[0]
    d = feats.shape[1]
    x = feats[src.reshape(-1)].reshape(ne, be, d)
    x = x * valid[:, :, None].astype(feats.dtype)
    x = x * active_window[window][:, None, None].astype(feats.dtype)
    flat_dst = (window[:, None] * vb + dst_rel).reshape(-1)
    out = jax.ops.segment_sum(x.reshape(-1, d), flat_dst,
                              num_segments=nw * vb)
    return out[:num_vertices]
