"""Public wrapper for the gated segment-SpMM kernel (interpret on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pagerank_spmv.pagerank_spmv import PackedGraph, pack_blocks
from repro.kernels.segment_ops.ref import gated_spmm_ref
from repro.kernels.segment_ops.segment_matmul import gated_spmm

__all__ = ["PackedGraph", "pack_blocks", "aggregate_features"]


def aggregate_features(packed: PackedGraph, feats: jax.Array,
                       affected: jax.Array, *, use_kernel: bool = True
                       ) -> jax.Array:
    """Σ_{u→v} feats[u] for v in windows containing any affected vertex."""
    nw, vb = packed.num_windows, packed.vb
    v_pad = nw * vb
    aff_pad = jnp.pad(affected, (0, v_pad - affected.shape[0]))
    active_window = jnp.any(aff_pad.reshape(nw, vb), axis=1)
    if use_kernel:
        return gated_spmm(packed, feats, active_window,
                          interpret=jax.default_backend() != "tpu")
    f = feats.astype(jnp.float32)
    f = jnp.pad(f, ((0, v_pad - f.shape[0]), (0, 0)))
    return gated_spmm_ref(packed.src, packed.dst_rel, packed.valid,
                          packed.window, f, active_window,
                          packed.num_vertices, vb)
