"""jnp oracle for the walk-repair kernel — same hop recurrence, no
bucketing.  Output is bitwise identical to ``walk_repair.resample_rows``
with every bucket active; the differential tests and the off-TPU
shard_map path (DESIGN.md §9) lean on it the way the SpMV shard path
leans on ``frontier_spmv_ref_padded``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.structure import CSRView


@partial(jax.jit, static_argnames=("alpha",))
def resample_rows_ref(csr: CSRView, rows: jax.Array, t0: jax.Array,
                      u: jax.Array, *, alpha: float) -> jax.Array:
    C, L = rows.shape
    if L == 1:
        return rows
    E = csr.indices.shape[0]
    rows0 = rows[:, 0]
    cur = jnp.maximum(rows0, 0)
    alive = rows0 >= 0
    out = [rows0]
    for t in range(1, L):
        alive = alive & (u[:, t - 1, 0] < alpha)
        deg = csr.deg[cur]
        j = jnp.minimum(
            (u[:, t - 1, 1] * (deg + 1).astype(jnp.float32))
            .astype(jnp.int32), deg)
        idx = jnp.clip(csr.indptr[cur] + j, 0, E - 1)
        nxt = jnp.where(j >= deg, cur, csr.indices[idx])
        val = jnp.where(t <= t0, rows[:, t], jnp.where(alive, nxt, -1))
        cur = jnp.where(val >= 0, val, cur)
        out.append(val)
    return jnp.stack(out, axis=1)
