"""Bucketed suffix-resampling kernel for stale PPR walks.

Repair of a walk index (repro.ppr.repair) is embarrassingly parallel per
walk: every stale walk keeps its prefix [0..t0] and re-rolls the suffix
on the new CSR with its own per-hop uniforms.  This kernel packs the
compacted stale walks into lane buckets of ``WALK_BUCKET`` (= 128, one
vector lane per walk) and gives each grid program one bucket; the CSR
arrays are prefetched whole into VMEM and gathered per hop — the same
shape of device-side gather the frontier SpMV kernel uses for its rank
block.

Bucket gating follows the gated-DMA idiom of ``frontier_spmv_padded``:
the walk capacity is a pow2 that can far exceed the actual stale count,
so grid steps past the last active bucket re-map (scalar-prefetch
index_map) onto that bucket — its blocks stay VMEM-resident and the
revisit recomputes identical values, so excess steps cost no HBM
traffic.  Columns past the active count hold sentinel walks whose rows
the caller scatters with mode="drop".

Bitwise contract — the invariant everything downstream leans on: the
per-hop uniforms are threefry draws, and running threefry inside the
kernel would not be bit-identical to the jnp path, so the caller
precomputes them (walks._walk_draws) and passes ``u``.  What remains in
the kernel is the pure CSR hop recurrence — integer gathers plus one
f32 multiply — which is IEEE-identical to ``repair._resample_impl``, so
kernel repair == jnp repair == fresh rebuild, bit for bit.

Off-TPU note (DESIGN.md §9): interpret-mode Pallas is not SPMD-safe
under shard_map on jax 0.4.x; ppr/shard.py only enables this kernel
inside shard_map when the backend is real TPU.  Single-device interpret
use (tests, bench) is fine.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.graph.structure import CSRView

WALK_BUCKET = 128      # walks per grid program — one per vector lane


def _kernel(sel_ref,                                  # scalar prefetch
            indptr_ref, indices_ref, deg_ref,         # CSR, VMEM-resident
            rows_ref, t0_ref, ucont_ref, uchoice_ref,  # per-bucket walks
            out_ref, *, alpha, max_len, num_edges):
    rows0 = rows_ref[0, :]
    cur = jnp.maximum(rows0, 0)
    t0 = t0_ref[0, :]
    alive = rows0 >= 0
    out_ref[0, :] = rows0
    # static hop loop: L is small (≈16-20) and fixed per executable
    for t in range(1, max_len):
        alive = alive & (ucont_ref[t - 1, :] < alpha)
        deg = jnp.take(deg_ref[:], cur)
        j = jnp.minimum(
            (uchoice_ref[t - 1, :]
             * (deg + 1).astype(jnp.float32)).astype(jnp.int32), deg)
        idx = jnp.clip(jnp.take(indptr_ref[:], cur) + j, 0, num_edges - 1)
        nxt = jnp.where(j >= deg, cur, jnp.take(indices_ref[:], idx))
        val = jnp.where(t <= t0, rows_ref[t, :],
                        jnp.where(alive, nxt, -1))
        cur = jnp.where(val >= 0, val, cur)
        out_ref[t, :] = val


@partial(jax.jit, static_argnames=("alpha", "interpret"))
def resample_rows(csr: CSRView, rows: jax.Array, t0: jax.Array,
                  u: jax.Array, *, alpha: float,
                  num_active: jax.Array | None = None,
                  interpret: bool = False) -> jax.Array:
    """Re-walk ``rows`` (int32[C, L]) on ``csr``, keeping each row's
    prefix [0..t0]; ``u`` f32[C, L-1, 2] are the precomputed per-hop
    uniforms ([..., 0] continue, [..., 1] choice).  ``num_active`` gates
    trailing buckets off (rows past it must be sentinels the caller
    drops).  Returns int32[C, L].
    """
    C, L = rows.shape
    if L == 1:
        return rows
    wb = WALK_BUCKET
    nb = -(-C // wb)
    cp = nb * wb
    if cp > C:
        rows = jnp.concatenate(
            [rows, jnp.full((cp - C, L), -1, jnp.int32)])
        t0 = jnp.concatenate([t0, jnp.zeros((cp - C,), jnp.int32)])
        u = jnp.concatenate([u, jnp.zeros((cp - C, L - 1, 2), jnp.float32)])
    rows_t = rows.T                                       # [L, Cp]
    t0_r = t0[None, :]                                    # [1, Cp]
    ucont = u[:, :, 0].T                                  # [L-1, Cp]
    uchoice = u[:, :, 1].T
    E = csr.indices.shape[0]
    n_ptr, n_deg = csr.indptr.shape[0], csr.deg.shape[0]

    if num_active is None:
        num_active = jnp.int32(cp)
    nact_b = jnp.clip((num_active + wb - 1) // wb, 1, nb).astype(jnp.int32)
    bidx = jnp.arange(nb, dtype=jnp.int32)
    sel = jnp.where(bidx < nact_b, bidx, nact_b - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n_ptr,), lambda i, sel: (0,)),
            pl.BlockSpec((E,), lambda i, sel: (0,)),
            pl.BlockSpec((n_deg,), lambda i, sel: (0,)),
            pl.BlockSpec((L, wb), lambda i, sel: (0, sel[i])),
            pl.BlockSpec((1, wb), lambda i, sel: (0, sel[i])),
            pl.BlockSpec((L - 1, wb), lambda i, sel: (0, sel[i])),
            pl.BlockSpec((L - 1, wb), lambda i, sel: (0, sel[i])),
        ],
        out_specs=pl.BlockSpec((L, wb), lambda i, sel: (0, sel[i])),
    )
    out = pl.pallas_call(
        partial(_kernel, alpha=alpha, max_len=L, num_edges=E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, cp), jnp.int32),
        interpret=interpret,
    )(sel, csr.indptr, csr.indices, csr.deg, rows_t, t0_r, ucont, uchoice)
    # blocks of gated-off buckets are undefined — their columns hold
    # sentinel walks the caller scatters with mode="drop"
    return out.T[:C]
