"""DF/DF-P engine variant running on the Pallas frontier-gated kernel.

This is the single-pod *performance path*: contributions come from the
blocked, window-gated SpMV (f32, MXU scatter) instead of the XLA
segment_sum (f64).  Frontier marking still uses the edge-list ``push_or``
(boolean propagation is cheap).

Two precision regimes:

  * ``kernel_pagerank_loop`` — pure f32, tolerances default to
    f32-appropriate values; fixed points agree with the f64 engine to
    f32 precision.  The loop keeps its rank buffer *padded* to NW·VB and
    receives a precomputed ``active_window`` per iteration, so the
    while_loop body pays no pad/reduce/slice glue around the kernel.
  * ``hybrid_pagerank`` — the serving ladder: f32 kernel iterations to
    ``tol_f32``, then a short f64 XLA polish seeded with the kernel
    phase's ``affected_ever`` set, down to the paper's τ.  The result is
    a drop-in ``PageRankResult`` meeting the tier-1 L∞ ≤ 1e-6
    equivalence contracts of the f64 engine (DESIGN.md §8).

Work accounting matches the kernel's actual granularity: per iteration,
``edges_processed`` adds the live-edge counts of *active entries* and
``vertices_processed`` adds VB per active window — what the gated SpMV
really gathers/updates, comparable against ``PageRankResult``'s
per-vertex numbers from the XLA engine.
"""
from __future__ import annotations

import collections
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.core.pagerank import ALPHA, initial_affected
from repro.graph.structure import EdgeListGraph
from repro.kernels.pagerank_spmv.ops import PackedGraph, gated_contrib
from repro.obs import trace as obs_trace
from repro.obs.frontier import NUM_FIELDS as _TEL_K
from repro.obs.frontier import FrontierTelemetry
from repro.obs.frontier import telemetry_row as _tel_row

# trace-time counters (see kernels.pagerank_spmv.update.TRACE_COUNTS):
# a temporal stream must compile the loop once and never again
TRACE_COUNTS: collections.Counter = collections.Counter()


class KernelPRResult(NamedTuple):
    ranks: jax.Array             # f32[V]
    iterations: jax.Array
    delta: jax.Array
    affected_ever: jax.Array
    edges_processed: jax.Array   # i64[] Σ live edges of active entries
    vertices_processed: jax.Array  # i64[] Σ VB per active window
    telemetry: Optional[jax.Array] = None  # f32[max_iter, k] when requested


def _loop_setup(graph, packed, *, alpha, tol, frontier_tol, prune_tol,
                max_iter, closed_form, prune, expand, use_kernel,
                telemetry=False):
    """Shared (cond, body, state0) builder for the plain and fused loops.

    Both entry points run the IDENTICAL body/cond closures, so the fused
    path (which peels the first sweep out of the while_loop) is bitwise
    equal to the plain loop — ``cond(state0)`` is always true (delta
    starts at inf, it at 0), so peeling one ``body`` application off the
    front is a pure re-association.
    """
    V = graph.num_vertices
    nw, vb = packed.num_windows, packed.vb
    v_pad = nw * vb
    deg = graph.out_degree(include_self_loop=True)
    inv_deg_pad = jnp.pad((1.0 / deg).astype(jnp.float32), (0, v_pad - V))
    # per-entry live-edge counts: constant across the loop (the packed
    # structure only changes between solves), so the per-iteration work
    # counter is an O(NE) masked sum, not an O(NE·BE) rescan
    entry_edges = jnp.sum((packed.valid > 0), axis=1).astype(jnp.int64)
    c0 = jnp.float32((1.0 - alpha) / V)
    a32 = jnp.float32(alpha)

    def body(state):
        ranks_pad, affected, ever, _, it, edges, verts = state[:7]
        aff_pad = jnp.pad(affected, (0, v_pad - V))
        active_window = jnp.any(aff_pad.reshape(nw, vb), axis=1)
        contrib = gated_contrib(packed, ranks_pad, inv_deg_pad,
                                active_window=active_window,
                                use_kernel=use_kernel, pad_out=True)
        if closed_form:
            r_new_all = (c0 + a32 * contrib) / (1.0 - a32 * inv_deg_pad)
        else:
            r_new_all = c0 + a32 * (contrib + ranks_pad * inv_deg_pad)
        r_new = jnp.where(aff_pad, r_new_all, ranks_pad)
        dr = jnp.abs(r_new - ranks_pad)[:V]
        rel = dr / jnp.maximum(jnp.maximum(r_new[:V], ranks_pad[:V]), 1e-30)
        delta = jnp.max(jnp.where(affected, dr, 0.0))
        new_affected = affected
        if prune:
            new_affected = new_affected & ~(affected & (rel <= prune_tol))
        if expand:
            big = affected & (rel > frontier_tol)
            new_affected = new_affected | graph.push_or(big) | big
        edges = edges + jnp.sum(
            jnp.where(active_window[packed.window], entry_edges, 0))
        verts = verts + jnp.sum(active_window.astype(jnp.int64)) * vb
        out = (r_new, new_affected, ever | new_affected, delta, it + 1,
               edges, verts)
        if not telemetry:
            return out
        row = _tel_row(jnp.sum(affected), delta,
                       jnp.sum(new_affected & ~affected),
                       jnp.sum(affected & ~new_affected),
                       jnp.sum(active_window), jnp.float32)
        tel = jax.lax.dynamic_update_slice(
            state[7], row[None, :], (it, jnp.asarray(0, jnp.int32)))
        return out + (tel,)

    def cond(state):
        return (state[3] > tol) & (state[4] < max_iter)

    def state0(init_ranks, init_affected):
        st = (jnp.pad(init_ranks.astype(jnp.float32), (0, v_pad - V)),
              init_affected, init_affected,
              jnp.asarray(jnp.inf, jnp.float32),
              jnp.asarray(0, jnp.int32),
              jnp.asarray(0, jnp.int64), jnp.asarray(0, jnp.int64))
        if telemetry:
            st += (jnp.zeros((max_iter, _TEL_K), jnp.float32),)
        return st

    return cond, body, state0


@partial(jax.jit, static_argnames=("closed_form", "prune", "expand",
                                   "max_iter", "use_kernel", "telemetry"))
def kernel_pagerank_loop(graph: EdgeListGraph, packed: PackedGraph,
                         init_ranks: jax.Array, init_affected: jax.Array, *,
                         alpha: float = ALPHA, tol: float = 1e-7,
                         frontier_tol: float = 1e-5, prune_tol: float = 1e-5,
                         max_iter: int = 500, closed_form: bool = False,
                         prune: bool = False, expand: bool = True,
                         use_kernel: bool = True,
                         telemetry: bool = False) -> KernelPRResult:
    TRACE_COUNTS["kernel_pagerank_loop"] += 1          # trace-time only
    V = graph.num_vertices
    cond, body, state0 = _loop_setup(
        graph, packed, alpha=alpha, tol=tol, frontier_tol=frontier_tol,
        prune_tol=prune_tol, max_iter=max_iter, closed_form=closed_form,
        prune=prune, expand=expand, use_kernel=use_kernel,
        telemetry=telemetry)
    out = jax.lax.while_loop(cond, body, state0(init_ranks, init_affected))
    ranks_pad, _, ever, delta, it, edges, verts = out[:7]
    return KernelPRResult(ranks_pad[:V], it, delta, ever, edges, verts,
                          telemetry=out[7] if telemetry else None)


@partial(jax.jit, static_argnames=("closed_form", "prune", "expand",
                                   "max_iter", "use_kernel", "telemetry"))
def _fused_update_loop(graph_new: EdgeListGraph, packed: PackedGraph,
                       update, init_ranks: jax.Array,
                       init_affected: jax.Array, *,
                       alpha: float = ALPHA, tol: float = 1e-7,
                       frontier_tol: float = 1e-5, prune_tol: float = 1e-5,
                       max_iter: int = 500, closed_form: bool = False,
                       prune: bool = False, expand: bool = True,
                       use_kernel: bool = True, telemetry: bool = False):
    """ONE device program: packed micro-batch maintenance + the whole
    f32 loop, first sweep peeled so it fuses with the update pass.

    Applies ``update`` to ``packed`` (inlining ``_apply_batch_packed``),
    runs the first gated sweep on the freshly updated structure in the
    same program, then enters the while_loop at iteration 1.  Returns
    ``(new_packed, dropped, KernelPRResult)``; ``dropped`` is the spill
    overflow count the host wrapper turns into the usual checked error.
    Re-running after a repack is safe: the update's deletions are
    already absent and its insertions already live, so maintenance
    degenerates to a no-op and only the solve repeats.
    """
    TRACE_COUNTS["fused_update_loop"] += 1             # trace-time only
    from repro.kernels.pagerank_spmv.update import _apply_batch_packed
    new_packed, dropped = _apply_batch_packed(packed, update)
    V = graph_new.num_vertices
    cond, body, state0 = _loop_setup(
        graph_new, new_packed, alpha=alpha, tol=tol,
        frontier_tol=frontier_tol, prune_tol=prune_tol, max_iter=max_iter,
        closed_form=closed_form, prune=prune, expand=expand,
        use_kernel=use_kernel, telemetry=telemetry)
    # cond(state0) is unconditionally true (delta=inf, it=0 < max_iter),
    # so the peel preserves the plain loop's exact iteration sequence
    state1 = body(state0(init_ranks, init_affected))
    out = jax.lax.while_loop(cond, body, state1)
    ranks_pad, _, ever, delta, it, edges, verts = out[:7]
    return new_packed, dropped, KernelPRResult(
        ranks_pad[:V], it, delta, ever, edges, verts,
        telemetry=out[7] if telemetry else None)


def _merged_telemetry(k: KernelPRResult, p: Optional[pr.PageRankResult]):
    """Trimmed f64 [iters, k] rows of the hybrid ladder, kernel phase
    first then polish — the one host transfer the telemetry path does."""
    parts = [FrontierTelemetry.from_padded(k.telemetry, k.iterations)]
    if p is not None and p.telemetry is not None:
        parts.append(FrontierTelemetry.from_padded(p.telemetry,
                                                   p.iterations))
    return FrontierTelemetry.concat(*parts).data


def fused_hybrid_pagerank(graph_new: EdgeListGraph, packed: PackedGraph,
                          update, init_ranks: jax.Array,
                          init_affected: jax.Array, *,
                          alpha: float = ALPHA, tol: float = pr.TOL,
                          tol_f32: float = 1e-7,
                          frontier_tol: float = pr.FRONTIER_TOL,
                          prune_tol: float = pr.PRUNE_TOL,
                          kernel_frontier_tol: float = 1e-5,
                          kernel_prune_tol: float = 1e-5,
                          max_iter: int = pr.MAX_ITER,
                          closed_form: bool = False, prune: bool = False,
                          expand: bool = True, polish: bool = True,
                          use_kernel: bool = True,
                          telemetry: bool = False):
    """Fused serving step: ``(new_packed, PageRankResult)`` from one
    device program for maintenance + the entire f32 phase (plus the
    usual f64 polish program when ``polish=True``).

    Spill/overlay exhaustion raises the same checked ``ValueError`` as
    ``apply_batch_packed`` — the caller repacks at the pinned shapes and
    re-invokes with the SAME update (idempotent, see _fused_update_loop).

    ``telemetry=True`` records per-iteration obs.frontier rows in both
    phases (result.telemetry: trimmed f64 [iters, k], kernel rows then
    polish rows); the tracer, when enabled, gets one span per device
    program with honest durations (``Tracer.sync``).
    """
    tr = obs_trace.get_tracer()
    with tr.span("fused_update_loop", program="update+f32_loop"):
        new_packed, dropped, k = _fused_update_loop(
            graph_new, packed, update, init_ranks, init_affected,
            alpha=alpha, tol=tol_f32, frontier_tol=kernel_frontier_tol,
            prune_tol=kernel_prune_tol, max_iter=max_iter,
            closed_form=closed_form, prune=prune, expand=expand,
            use_kernel=use_kernel, telemetry=telemetry)
        tr.sync(k.ranks)
    n = int(dropped)
    if n:
        raise ValueError(
            f"{n} insertions exceed spill capacity of their dst "
            f"windows or the locator overlay; repack with pack_graph "
            "/ raise spill_lanes_per_window or overlay_capacity "
            "(capacity sizing: DESIGN.md §8)")
    if not polish:
        return new_packed, pr.PageRankResult(
            k.ranks.astype(jnp.float64), k.iterations,
            k.delta.astype(jnp.float64), k.affected_ever,
            k.edges_processed, k.vertices_processed,
            telemetry=_merged_telemetry(k, None) if telemetry else None)
    with tr.span("polish.f64", program="xla_polish"):
        p = pr._pagerank_loop(graph_new, k.ranks.astype(jnp.float64),
                              k.affected_ever, alpha=alpha, tol=tol,
                              frontier_tol=frontier_tol,
                              prune_tol=prune_tol,
                              max_iter=max_iter, closed_form=closed_form,
                              prune=prune, expand=expand,
                              telemetry=telemetry)
        tr.sync(p.ranks)
    return new_packed, pr.PageRankResult(
        p.ranks, k.iterations + p.iterations, p.delta,
        k.affected_ever | p.affected_ever,
        k.edges_processed + p.edges_processed,
        k.vertices_processed + p.vertices_processed,
        telemetry=_merged_telemetry(k, p) if telemetry else None)


def hybrid_pagerank(graph: EdgeListGraph, packed: PackedGraph,
                    init_ranks: jax.Array, init_affected: jax.Array, *,
                    alpha: float = ALPHA, tol: float = pr.TOL,
                    tol_f32: float = 1e-7,
                    frontier_tol: float = pr.FRONTIER_TOL,
                    prune_tol: float = pr.PRUNE_TOL,
                    kernel_frontier_tol: float = 1e-5,
                    kernel_prune_tol: float = 1e-5,
                    max_iter: int = pr.MAX_ITER, closed_form: bool = False,
                    prune: bool = False, expand: bool = True,
                    polish: bool = True, use_kernel: bool = True,
                    telemetry: bool = False) -> pr.PageRankResult:
    """Precision ladder: f32 kernel iterations to ``tol_f32``, then an
    optional f64 XLA polish seeded from the kernel phase's affected_ever
    set down to ``tol`` — same fixed point and result type as the f64
    engine, with the bulk of the iterations on the gated f32 path."""
    tr = obs_trace.get_tracer()
    with tr.span("kernel_loop.f32", program="f32_loop"):
        k = kernel_pagerank_loop(graph, packed, init_ranks, init_affected,
                                 alpha=alpha, tol=tol_f32,
                                 frontier_tol=kernel_frontier_tol,
                                 prune_tol=kernel_prune_tol,
                                 max_iter=max_iter,
                                 closed_form=closed_form, prune=prune,
                                 expand=expand, use_kernel=use_kernel,
                                 telemetry=telemetry)
        tr.sync(k.ranks)
    if not polish:
        return pr.PageRankResult(
            k.ranks.astype(jnp.float64), k.iterations,
            k.delta.astype(jnp.float64), k.affected_ever,
            k.edges_processed, k.vertices_processed,
            telemetry=_merged_telemetry(k, None) if telemetry else None)
    with tr.span("polish.f64", program="xla_polish"):
        p = pr._pagerank_loop(graph, k.ranks.astype(jnp.float64),
                              k.affected_ever, alpha=alpha, tol=tol,
                              frontier_tol=frontier_tol,
                              prune_tol=prune_tol,
                              max_iter=max_iter, closed_form=closed_form,
                              prune=prune, expand=expand,
                              telemetry=telemetry)
        tr.sync(p.ranks)
    return pr.PageRankResult(
        p.ranks, k.iterations + p.iterations, p.delta,
        k.affected_ever | p.affected_ever,
        k.edges_processed + p.edges_processed,
        k.vertices_processed + p.vertices_processed,
        telemetry=_merged_telemetry(k, p) if telemetry else None)


def df_pagerank_kernel(graph_prev: EdgeListGraph, graph_new: EdgeListGraph,
                       packed_new: PackedGraph, touched: jax.Array,
                       prev_ranks: jax.Array, *, prune: bool = False,
                       **kw) -> KernelPRResult:
    aff = initial_affected(graph_prev, graph_new, touched)
    return kernel_pagerank_loop(graph_new, packed_new, prev_ranks, aff,
                                prune=prune, closed_form=prune, **kw)
