"""DF/DF-P engine variant running on the Pallas frontier-gated kernel.

This is the single-pod *performance path*: contributions come from the
blocked, window-gated SpMV (f32, MXU scatter) instead of the XLA
segment_sum (f64).  Frontier marking still uses the edge-list ``push_or``
(boolean propagation is cheap).  Tolerances default to f32-appropriate
values; fixed points agree with the f64 engine to f32 precision (tested).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pagerank import ALPHA, initial_affected
from repro.graph.structure import EdgeListGraph
from repro.kernels.pagerank_spmv.ops import PackedGraph, gated_contrib


class KernelPRResult(NamedTuple):
    ranks: jax.Array
    iterations: jax.Array
    delta: jax.Array
    affected_ever: jax.Array


@partial(jax.jit, static_argnames=("closed_form", "prune", "expand",
                                   "max_iter", "use_kernel"))
def kernel_pagerank_loop(graph: EdgeListGraph, packed: PackedGraph,
                         init_ranks: jax.Array, init_affected: jax.Array, *,
                         alpha: float = ALPHA, tol: float = 1e-7,
                         frontier_tol: float = 1e-5, prune_tol: float = 1e-5,
                         max_iter: int = 500, closed_form: bool = False,
                         prune: bool = False, expand: bool = True,
                         use_kernel: bool = True) -> KernelPRResult:
    V = graph.num_vertices
    deg = graph.out_degree(include_self_loop=True)
    inv_deg = (1.0 / deg).astype(jnp.float32)
    c0 = jnp.float32((1.0 - alpha) / V)
    alpha = jnp.float32(alpha)

    def body(state):
        ranks, affected, ever, _, it = state
        contrib = gated_contrib(packed, ranks, inv_deg, affected,
                                use_kernel=use_kernel)
        if closed_form:
            r_new_all = (c0 + alpha * contrib) / (1.0 - alpha * inv_deg)
        else:
            r_new_all = c0 + alpha * (contrib + ranks * inv_deg)
        r_new = jnp.where(affected, r_new_all, ranks)
        dr = jnp.abs(r_new - ranks)
        rel = dr / jnp.maximum(jnp.maximum(r_new, ranks), 1e-30)
        delta = jnp.max(jnp.where(affected, dr, 0.0))
        new_affected = affected
        if prune:
            new_affected = new_affected & ~(affected & (rel <= prune_tol))
        if expand:
            big = affected & (rel > frontier_tol)
            new_affected = new_affected | graph.push_or(big) | big
        return (r_new, new_affected, ever | new_affected, delta, it + 1)

    def cond(state):
        return (state[3] > tol) & (state[4] < max_iter)

    state0 = (init_ranks.astype(jnp.float32), init_affected, init_affected,
              jnp.asarray(jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32))
    ranks, _, ever, delta, it = jax.lax.while_loop(cond, body, state0)
    return KernelPRResult(ranks, it, delta, ever)


def df_pagerank_kernel(graph_prev: EdgeListGraph, graph_new: EdgeListGraph,
                       packed_new: PackedGraph, touched: jax.Array,
                       prev_ranks: jax.Array, *, prune: bool = False,
                       **kw) -> KernelPRResult:
    aff = initial_affected(graph_prev, graph_new, touched)
    return kernel_pagerank_loop(graph_new, packed_new, prev_ranks, aff,
                                prune=prune, closed_form=prune, **kw)
