"""Beyond-paper: the DF frontier driving incremental GNN embedding refresh.

The paper's insight — *changes propagate along out-edges; re-process a
vertex only while its value still moves more than a tolerance* — applies
verbatim to GNN inference on dynamic graphs (DESIGN.md §5):

  * a batch update Δ touches endpoints → their out-neighbours' embeddings
    are stale (initial frontier, Alg.1 lines 4-6);
  * recompute embeddings for affected nodes only; if a node's embedding
    moves more than τ_f in relative L2 norm, its out-neighbours join the
    frontier (expansion);  DF-P-style pruning drops nodes whose embeddings
    stopped moving;
  * after ≤ n_layers rounds (GNN receptive field) the refresh is exact —
    unlike PageRank there is a finite propagation depth, so the loop runs
    at most ``n_layers`` rounds, marking then recomputing.

The aggregation can route through the frontier-gated Pallas SpMM
(kernels/segment_ops) — only active dst windows are touched, the same
work-skipping the SpMV kernel gives PageRank.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.graph.structure import EdgeListGraph


class RefreshResult(NamedTuple):
    embeddings: jax.Array
    affected_ever: jax.Array
    rounds: jax.Array
    nodes_recomputed: jax.Array


@partial(jax.jit, static_argnames=("layer_fn", "n_layers"))
def incremental_refresh(graph: EdgeListGraph,
                        feats: jax.Array,
                        old_embeddings: jax.Array,
                        touched: jax.Array,
                        layer_fn: Callable,
                        n_layers: int,
                        frontier_tol: float = 1e-3) -> RefreshResult:
    """Refresh node embeddings after a batch update.

    layer_fn(graph, feats) -> new embeddings (full-graph one-shot GNN
    forward, e.g. partial(sage_forward, cfg, params) adapted); we compute
    it once and BLEND per the frontier — affected nodes take new values,
    unaffected keep old.  Expansion iterates at most ``n_layers`` rounds
    (receptive field bound).

    Returns embeddings equal to the full recompute on the affected
    receptive field, old values elsewhere; `affected_ever` reports the
    work-skipping ratio.
    """
    affected = touched | graph.push_or(touched)
    new_full = layer_fn(graph, feats)        # [N, D]

    # relative movement of each candidate node (Δr/r analogue on vectors)
    dn = jnp.linalg.norm(new_full - old_embeddings, axis=-1)
    base = jnp.maximum(jnp.linalg.norm(old_embeddings, axis=-1), 1e-12)
    rel = dn / base

    def round_body(i, carry):
        affected, ever = carry
        moved = affected & (rel > frontier_tol)   # expansion test (τ_f)
        nxt = graph.push_or(moved)
        return (affected | nxt, ever | nxt)

    affected, ever = jax.lax.fori_loop(
        0, n_layers, round_body, (affected, affected))
    emb = jnp.where(affected[:, None], new_full, old_embeddings)
    return RefreshResult(emb, ever,
                         jnp.asarray(n_layers, jnp.int32),
                         jnp.sum(affected.astype(jnp.int64)))
