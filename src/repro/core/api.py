"""Unified entry point: ``update_pagerank`` — one call, five approaches.

This is the public API the launcher, benchmarks and examples use.  It owns
the snapshot bookkeeping (Gᵗ⁻¹ vs Gᵗ) so callers only hold a DynamicGraph
and a stream of BatchUpdates.
"""
from __future__ import annotations

from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.graph.dynamic import BatchUpdate, apply_batch, touched_vertices_mask
from repro.graph.structure import EdgeListGraph

Method = Literal["static", "naive", "traversal", "frontier", "frontier_prune"]
Engine = Literal["xla", "kernel"]

METHODS = ("static", "naive", "traversal", "frontier", "frontier_prune")
ENGINES = ("xla", "kernel")

# per-method flags for the one `_pagerank_loop` behind all five approaches
# (core/pagerank.py docstring table); shared by the single-device path and
# the serve engine (repro.serve.engine).
LOOP_FLAGS = {
    "static": dict(track_affected=False),
    "naive": dict(track_affected=False),
    "traversal": dict(),
    "frontier": dict(expand=True),
    "frontier_prune": dict(expand=True, prune=True, closed_form=True),
}

# the same table for the kernel engine's loops, which have no
# track_affected knob (they always need affected_ever for the f64 polish)
KERNEL_FLAGS = {m: {k: v for k, v in f.items() if k != "track_affected"}
                for m, f in LOOP_FLAGS.items()}
for _f in KERNEL_FLAGS.values():          # kernel loop defaults expand=True
    _f.setdefault("expand", False)

# one compiled distributed engine per (mesh, graph shape, method options);
# FIFO-bounded so shape sweeps don't pin compiled executables forever
_DIST_ENGINES: dict = {}
_DIST_ENGINES_MAX = 8


def build_initial_state(graph_prev: EdgeListGraph,
                        graph_new: EdgeListGraph,
                        update: Optional[BatchUpdate],
                        prev_ranks: Optional[jax.Array],
                        method: Method) -> tuple:
    """Method → (init_ranks, init_affected): the paper's per-approach
    preprocessing (Alg.1 lines 1-6), shared by every engine.

    * ``static``          — cold start 1/|V|, everything affected;
    * ``naive``           — warm start, everything affected;
    * ``traversal``       — warm start, BFS-reachable from Δ endpoints;
    * ``frontier*``       — warm start, Δ endpoints + their out-neighbours
                            in Gᵗ⁻¹ ∪ Gᵗ.

    Callers: ``update_pagerank`` (single device), ``distributed_pagerank``
    (mesh) and the online serve loop (repro.serve.engine), which also uses
    |init_affected|/|V| as its static-fallback signal.
    """
    V = graph_new.num_vertices
    if method == "static":
        return jnp.full((V,), 1.0 / V, jnp.float64), jnp.ones((V,), bool)
    if prev_ranks is None:
        raise ValueError(f"method {method!r} needs prev_ranks")
    if method == "naive":
        return prev_ranks, jnp.ones((V,), bool)
    if update is None:
        raise ValueError(f"method {method!r} needs the batch update")
    touched = touched_vertices_mask(update, V)
    if method == "traversal":
        return prev_ranks, pr.reachability_mask(graph_prev, graph_new,
                                                touched)
    if method in ("frontier", "frontier_prune"):
        return prev_ranks, pr.initial_affected(graph_prev, graph_new,
                                               touched)
    raise ValueError(f"unknown method {method!r}")


def distributed_pagerank(graph_prev: EdgeListGraph,
                         graph_new: EdgeListGraph,
                         update: Optional[BatchUpdate],
                         prev_ranks: Optional[jax.Array],
                         method: Method,
                         mesh,
                         init_state: Optional[tuple] = None,
                         **kw) -> pr.PageRankResult:
    """``update_pagerank`` on a multi-device mesh via the shard_map engine.

    Same method semantics as the single-device path: the initial affected
    set is built per approach (or taken from ``init_state`` when the
    caller already ran ``build_initial_state``, e.g. the serve engine's
    fallback check), then the DF (or DF-P, for ``frontier_prune``)
    distributed iteration runs to the shared fixed point.  Engines are
    cached per (mesh, shape, options) so a temporal stream compiles once.
    """
    from repro.dist.pagerank_dist import DistributedEngine

    V = graph_new.num_vertices
    ranks, affected = (init_state if init_state is not None else
                       build_initial_state(graph_prev, graph_new, update,
                                           prev_ranks, method))
    prune = method == "frontier_prune"
    key = (mesh, V, graph_new.edge_capacity, prune,
           tuple(sorted(kw.items())))
    eng = _DIST_ENGINES.get(key)
    if eng is None:
        while len(_DIST_ENGINES) >= _DIST_ENGINES_MAX:
            _DIST_ENGINES.pop(next(iter(_DIST_ENGINES)))
        eng = _DIST_ENGINES.setdefault(key, DistributedEngine(
            mesh, V, graph_new.edge_capacity, prune=prune, **kw))
    r, it, delta, ever, edges, verts = eng.run(graph_new, ranks, affected)
    return pr.PageRankResult(r, it, delta, ever, edges, verts)


def update_pagerank(graph_prev: EdgeListGraph,
                    graph_new: EdgeListGraph,
                    update: Optional[BatchUpdate],
                    prev_ranks: Optional[jax.Array],
                    method: Method = "frontier_prune",
                    mesh=None,
                    engine: Engine = "xla",
                    packed=None,
                    **kw) -> pr.PageRankResult:
    """Recompute ranks for Gᵗ given Gᵗ⁻¹, Δᵗ and Rᵗ⁻¹ with the chosen method.

    ``mesh``: optional jax Mesh (with a ``model`` axis) — dispatches to the
    shard_map distributed engine (repro.dist.pagerank_dist) instead of the
    single-device loop.

    ``engine="kernel"``: Pallas hot path — hybrid-precision f32
    frontier-gated SpMV iterations + f64 polish (core.kernel_engine),
    same ``PageRankResult`` contract.  ``packed`` supplies the blocked
    structure for streaming callers that maintain it incrementally
    (``kernels.pagerank_spmv.update.apply_batch_packed``); when omitted a
    one-shot ``pack_graph`` bootstrap is done here.

    ``engine="kernel"`` + ``mesh``: the sharded kernel path — the
    PackedGraph is partitioned by dst-window ranges over the mesh's
    ``model`` axis and the hybrid ladder runs under shard_map
    (dist.pagerank_dist.sharded_kernel_pagerank).  One-shot calls pack
    per call; streaming callers hold a ``ShardedKernelEngine`` (the
    serve engine does) so pack + compile happen once per stream.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; options {ENGINES}")
    if mesh is not None:
        if engine == "kernel":
            from repro.dist.pagerank_dist import sharded_kernel_pagerank
            if packed is not None:
                # a single-pod PackedGraph cannot seed the sharded path;
                # silently repacking would hide that the caller's
                # incrementally-maintained structure is being discarded
                raise ValueError(
                    "packed= is the single-pod structure; the sharded "
                    "path takes sharded=/spec= (streaming callers hold "
                    "a dist.ShardedKernelEngine, as the serve engine "
                    "does)")
            init_ranks, init_affected = build_initial_state(
                graph_prev, graph_new, update, prev_ranks, method)
            return sharded_kernel_pagerank(graph_new, init_ranks,
                                           init_affected, mesh,
                                           **KERNEL_FLAGS[method], **kw)
        return distributed_pagerank(graph_prev, graph_new, update,
                                    prev_ranks, method, mesh, **kw)
    init_ranks, init_affected = build_initial_state(
        graph_prev, graph_new, update, prev_ranks, method)
    if engine == "kernel":
        from repro.core.kernel_engine import hybrid_pagerank
        from repro.kernels.pagerank_spmv.update import pack_graph
        if packed is None:
            # spill >= 1 guarantees every window owns an entry, so every
            # active window has a block the kernel writes (zeros included)
            packed = pack_graph(graph_new, spill_lanes_per_window=1)
        return hybrid_pagerank(graph_new, packed, init_ranks, init_affected,
                               **KERNEL_FLAGS[method], **kw)
    return pr._pagerank_loop(graph_new, init_ranks, init_affected,
                             **LOOP_FLAGS[method], **kw)


def step_stream(graph: EdgeListGraph, update: BatchUpdate,
                prev_ranks: jax.Array, method: Method = "frontier_prune",
                mesh=None, **kw):
    """One temporal-stream step: apply Δ, update ranks.  Returns (Gᵗ, result)."""
    graph_new = apply_batch(graph, update)
    res = update_pagerank(graph, graph_new, update, prev_ranks, method,
                          mesh=mesh, **kw)
    return graph_new, res
