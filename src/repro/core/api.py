"""Unified entry point: ``update_pagerank`` — one call, five approaches.

This is the public API the launcher, benchmarks and examples use.  It owns
the snapshot bookkeeping (Gᵗ⁻¹ vs Gᵗ) so callers only hold a DynamicGraph
and a stream of BatchUpdates.
"""
from __future__ import annotations

from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.graph.dynamic import BatchUpdate, apply_batch, touched_vertices_mask
from repro.graph.structure import EdgeListGraph

Method = Literal["static", "naive", "traversal", "frontier", "frontier_prune"]

METHODS = ("static", "naive", "traversal", "frontier", "frontier_prune")


def update_pagerank(graph_prev: EdgeListGraph,
                    graph_new: EdgeListGraph,
                    update: Optional[BatchUpdate],
                    prev_ranks: Optional[jax.Array],
                    method: Method = "frontier_prune",
                    **kw) -> pr.PageRankResult:
    """Recompute ranks for Gᵗ given Gᵗ⁻¹, Δᵗ and Rᵗ⁻¹ with the chosen method."""
    if method == "static":
        return pr.static_pagerank(graph_new, **kw)
    if prev_ranks is None:
        raise ValueError(f"method {method!r} needs prev_ranks")
    if method == "naive":
        return pr.naive_dynamic_pagerank(graph_new, prev_ranks, **kw)
    if update is None:
        raise ValueError(f"method {method!r} needs the batch update")
    touched = touched_vertices_mask(update, graph_new.num_vertices)
    if method == "traversal":
        return pr.dynamic_traversal_pagerank(
            graph_prev, graph_new, touched, prev_ranks, **kw)
    if method == "frontier":
        return pr.dynamic_frontier_pagerank(
            graph_prev, graph_new, touched, prev_ranks, **kw)
    if method == "frontier_prune":
        return pr.dynamic_frontier_prune_pagerank(
            graph_prev, graph_new, touched, prev_ranks, **kw)
    raise ValueError(f"unknown method {method!r}")


def step_stream(graph: EdgeListGraph, update: BatchUpdate,
                prev_ranks: jax.Array, method: Method = "frontier_prune",
                **kw):
    """One temporal-stream step: apply Δ, update ranks.  Returns (Gᵗ, result)."""
    graph_new = apply_batch(graph, update)
    res = update_pagerank(graph, graph_new, update, prev_ranks, method, **kw)
    return graph_new, res
