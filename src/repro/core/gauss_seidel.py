"""Window-sequential (block-Gauss-Seidel) PageRank — the paper's async
advantage, deterministically.

The paper's OpenMP engine is asynchronous: a vertex processed later in a
sweep reads ranks already updated earlier in the same sweep, which
converges markedly faster than synchronous Jacobi.  That ordering is
non-deterministic on CPU threads and inexpressible per-element on TPU —
but the PackedGraph (kernels/pagerank_spmv) already orders edges by dst
window, and a TPU grid executes blocks **sequentially**, so the exact
same benefit is available deterministically at *window* granularity:

  sweep = scan over packed entries in window order; each window's rank
  update uses the freshest rank vector, committed before later windows
  read it.

Implemented as a jit-able lax.scan with the finalize-on-window-change
pattern (entries of one window accumulate; the first entry of the next
window triggers the previous window's rank commit).  The Pallas-native
version maps the same schedule onto the kernel grid with
input_output_aliasing — documented as the hardware path; this XLA
version is the portable reference and is what the tests/benches run.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pagerank import ALPHA
from repro.graph.structure import EdgeListGraph
from repro.kernels.pagerank_spmv.pagerank_spmv import PackedGraph


class GSResult(NamedTuple):
    ranks: jax.Array
    sweeps: jax.Array
    delta: jax.Array


@partial(jax.jit, static_argnames=("max_sweeps",))
def gauss_seidel_pagerank(graph: EdgeListGraph, packed: PackedGraph,
                          init_ranks: jax.Array, *,
                          alpha: float = ALPHA, tol: float = 1e-7,
                          max_sweeps: int = 500) -> GSResult:
    """Window-sequential sweeps to the DF-P closed-form fixed point (f32).

    graph supplies degrees; packed supplies the window-ordered edges.
    """
    V = graph.num_vertices
    vb = packed.vb
    nw = packed.num_windows
    v_pad = nw * vb
    deg = graph.out_degree(include_self_loop=True)
    inv_deg = jnp.pad((1.0 / deg).astype(jnp.float32),
                      (0, v_pad - V), constant_values=1.0)
    c0 = jnp.float32((1.0 - alpha) / V)
    a = jnp.float32(alpha)
    ne = packed.num_entries
    first = jnp.concatenate([
        jnp.ones((1,), jnp.int32),
        (packed.window[1:] != packed.window[:-1]).astype(jnp.int32)])

    def commit(ranks, contrib, win):
        """Closed-form rank update for one window, using fresh contrib."""
        old = jax.lax.dynamic_slice(ranks, (win * vb,), (vb,))
        iw = jax.lax.dynamic_slice(inv_deg, (win * vb,), (vb,))
        new = (c0 + a * contrib) / (1.0 - a * iw)
        d = jnp.max(jnp.abs(new - old))
        return jax.lax.dynamic_update_slice(ranks, new, (win * vb,)), d

    def sweep(ranks0):
        def entry_step(carry, inp):
            ranks, pending, pwin, dmax = carry
            src, dst_rel, valid, win, fst = inp
            # first entry of a NEW window -> commit the pending window
            def do_commit(args):
                ranks, pending, pwin, dmax = args
                ranks, d = commit(ranks, pending, pwin)
                return ranks, jnp.maximum(dmax, d)

            ranks, dmax = jax.lax.cond(
                (fst == 1) & (pwin >= 0), do_commit,
                lambda args: (args[0], args[3]),
                (ranks, pending, pwin, dmax))
            pending = jnp.where(fst == 1, jnp.zeros((vb,), jnp.float32),
                                pending)
            # accumulate this entry's contribution with FRESH ranks (GS)
            w = jnp.take(ranks * inv_deg[: ranks.shape[0]], src) * valid
            onehot = (dst_rel[:, None] ==
                      jnp.arange(vb, dtype=jnp.int32)[None, :]
                      ).astype(jnp.float32)
            part = w @ onehot
            return (ranks, pending + part, win, dmax), None

        init = (ranks0, jnp.zeros((vb,), jnp.float32),
                jnp.asarray(-1, jnp.int32), jnp.zeros((), jnp.float32))
        (ranks, pending, pwin, dmax), _ = jax.lax.scan(
            entry_step, init,
            (packed.src, packed.dst_rel, packed.valid, packed.window,
             first))
        ranks, d = commit(ranks, pending, pwin)      # last window
        return ranks, jnp.maximum(dmax, d)

    def body(state):
        ranks, _, it = state
        ranks, delta = sweep(ranks)
        return (ranks, delta, it + 1)

    ranks0 = jnp.pad(init_ranks.astype(jnp.float32), (0, v_pad - V))
    ranks, delta, sweeps = jax.lax.while_loop(
        lambda s: (s[1] > tol) & (s[2] < max_sweeps), body,
        (ranks0, jnp.asarray(jnp.inf, jnp.float32),
         jnp.asarray(0, jnp.int32)))
    return GSResult(ranks[:V], sweeps, delta)
