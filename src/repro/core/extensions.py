"""Beyond-paper engine extensions on the same DF/DF-P machinery.

* **Personalised PageRank** — the teleport mass lands on a seed
  distribution p instead of uniformly: R = α·A^T R + (1-α)·p.  The DF/DF-P
  frontier logic is unchanged (rank-change propagation is topology-driven,
  not teleport-driven), so incremental updates work verbatim: pass
  ``personalization`` to get incremental PPR on dynamic graphs — a feature
  the paper's own applications (recommendation, local community detection)
  want but the paper does not implement.

* **Weighted PageRank** — per-edge weights w(u,v); contributions become
  R[u]·w(u,v)/W_out(u).  Weights live in a parallel f64[E_cap] array;
  deletions/insertions reuse the BatchUpdate machinery (weight slot
  updated alongside the edge slot).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pagerank import (ALPHA, FRONTIER_TOL, MAX_ITER, PRUNE_TOL,
                                 TOL, PageRankResult, PRState,
                                 initial_affected)
from repro.graph.structure import EdgeListGraph


@partial(jax.jit, static_argnames=("closed_form", "prune", "expand",
                                   "max_iter"))
def _generalized_loop(graph: EdgeListGraph,
                      init_ranks: jax.Array,
                      init_affected: jax.Array,
                      teleport: jax.Array,          # f64[V], sums to 1
                      edge_weight: Optional[jax.Array] = None,  # f64[E_cap]
                      *, alpha: float = ALPHA, tol: float = TOL,
                      frontier_tol: float = FRONTIER_TOL,
                      prune_tol: float = PRUNE_TOL, max_iter: int = MAX_ITER,
                      closed_form: bool = False, prune: bool = False,
                      expand: bool = False) -> PageRankResult:
    V = graph.num_vertices
    if edge_weight is None:
        w_out = graph.out_degree(include_self_loop=False) \
            .astype(jnp.float64)
        contrib_w = jnp.ones((graph.edge_capacity,), jnp.float64)
        self_w = jnp.ones((V,), jnp.float64)
    else:
        w_out = jax.ops.segment_sum(
            jnp.where(graph.valid, edge_weight, 0.0), graph.src,
            num_segments=V)
        contrib_w = edge_weight
        self_w = jnp.ones((V,), jnp.float64)     # self-loop weight 1
    w_tot = w_out + self_w                        # incl. implicit self-loop
    inv_w = 1.0 / w_tot
    base = (1.0 - alpha) * teleport
    in_deg = graph.in_degree(include_self_loop=False).astype(jnp.int64)

    def body(state: PRState) -> PRState:
        ranks, affected = state.ranks, state.affected
        vals = jnp.where(graph.valid,
                         ranks[graph.src] * contrib_w * inv_w[graph.src],
                         0.0)
        contrib = jax.ops.segment_sum(vals, graph.dst, num_segments=V)
        if closed_form:
            r_new_all = (base + alpha * contrib) / \
                (1.0 - alpha * self_w * inv_w)
        else:
            r_new_all = base + alpha * (contrib + ranks * self_w * inv_w)
        r_new = jnp.where(affected, r_new_all, ranks)
        dr = jnp.abs(r_new - ranks)
        rel = dr / jnp.maximum(jnp.maximum(r_new, ranks), 1e-300)
        delta = jnp.max(jnp.where(affected, dr, 0.0))
        new_affected = affected
        if prune:
            new_affected = new_affected & ~(affected & (rel <= prune_tol))
        if expand:
            big = affected & (rel > frontier_tol)
            new_affected = new_affected | graph.push_or(big) | big
        edges = state.edges_processed + jnp.sum(
            jnp.where(affected, in_deg, 0))
        verts = state.vertices_processed + jnp.sum(
            affected.astype(jnp.int64))
        return PRState(r_new, new_affected,
                       state.affected_ever | new_affected, delta,
                       state.it + 1, edges, verts)

    out = jax.lax.while_loop(
        lambda s: (s.delta > tol) & (s.it < max_iter), body,
        PRState(init_ranks.astype(jnp.float64), init_affected,
                init_affected, jnp.asarray(jnp.inf, jnp.float64),
                jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int64),
                jnp.asarray(0, jnp.int64)))
    return PageRankResult(out.ranks, out.it, out.delta, out.affected_ever,
                          out.edges_processed, out.vertices_processed)


def personalized_pagerank(graph: EdgeListGraph, seeds: jax.Array,
                          prev_ranks: Optional[jax.Array] = None,
                          graph_prev: Optional[EdgeListGraph] = None,
                          touched: Optional[jax.Array] = None,
                          **kw) -> PageRankResult:
    """PPR from a seed mask.  Static when prev_ranks is None; incremental
    DF-P update when (prev_ranks, graph_prev, touched) are given."""
    V = graph.num_vertices
    p = seeds.astype(jnp.float64)
    p = p / jnp.maximum(jnp.sum(p), 1e-300)
    if prev_ranks is None:
        return _generalized_loop(
            graph, p, jnp.ones((V,), bool), p, None, **kw)
    aff = initial_affected(graph_prev, graph, touched)
    return _generalized_loop(graph, prev_ranks, aff, p, None,
                             expand=True, prune=True, closed_form=True,
                             **kw)


def weighted_pagerank(graph: EdgeListGraph, edge_weight: jax.Array,
                      prev_ranks: Optional[jax.Array] = None,
                      graph_prev: Optional[EdgeListGraph] = None,
                      touched: Optional[jax.Array] = None,
                      **kw) -> PageRankResult:
    """Edge-weighted (DF-P-incremental when warm inputs are given)."""
    V = graph.num_vertices
    uniform = jnp.full((V,), 1.0 / V, jnp.float64)
    if prev_ranks is None:
        return _generalized_loop(graph, uniform, jnp.ones((V,), bool),
                                 uniform, edge_weight, **kw)
    aff = initial_affected(graph_prev, graph, touched)
    return _generalized_loop(graph, prev_ranks, aff, uniform, edge_weight,
                             expand=True, prune=True, closed_form=True,
                             **kw)
