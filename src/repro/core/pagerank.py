"""DF* PageRank — the paper's contribution, as a single jit-able JAX engine.

Implements all five approaches from the paper on one substrate:

  * ``static``     — power iteration from 1/|V| (paper §3.1)
  * ``naive``      — ND: warm start, update every vertex (paper §3.3.1)
  * ``traversal``  — DT: BFS-reachable marking, update marked (paper §3.3.2)
  * ``frontier``   — DF: incremental frontier expansion (paper §4.1.1)
  * ``frontier_prune`` — DF-P: expansion + contraction, closed-form rank
                      update for the implicit self-loop (paper §4.1.2, Eq. 2)

Faithfulness notes (see DESIGN.md §3 for the full adaptation table):
  * pull-based updates, L∞ convergence at τ=1e-10 (fp64 ranks), α=0.85,
    MAX_ITERATIONS=500 — all paper defaults;
  * frontier metric is the paper's optimum Δr / max(r_old, r_new) with
    τ_f = τ_p = 1e-6 (paper §4.2/§4.3);
  * self-loops on every vertex are *implicit*: out-degree is valid_deg+1 and
    the self contribution R[v]/d_v is added analytically (DF) or folded into
    the closed form (DF-P) — identical fixed point to the paper's explicit
    self-loop edges;
  * iterations are synchronous (Jacobi) rather than the paper's asynchronous
    single-vector scheme — a TPU-mandated change that alters the iterate
    sequence, not the fixed point.  The paper's pruning/expansion semantics
    are applied per iteration exactly as Algorithm 1 lines 9-26.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.graph.structure import EdgeListGraph
from repro.obs.frontier import NUM_FIELDS as _TEL_K
from repro.obs.frontier import telemetry_row as _tel_row

ALPHA = 0.85
TOL = 1e-10
FRONTIER_TOL = 1e-6
PRUNE_TOL = 1e-6
MAX_ITER = 500


class PageRankResult(NamedTuple):
    ranks: jax.Array          # f64[V]
    iterations: jax.Array     # i32[]   iterations executed
    delta: jax.Array          # f64[]   final L∞ change
    affected_ever: jax.Array  # bool[V] vertices ever marked affected
    edges_processed: jax.Array  # i64[]  Σ over iterations of active in-edges
    vertices_processed: jax.Array  # i64[] Σ over iterations of active vertices
    # per-iteration frontier telemetry (obs.frontier schema): None unless
    # the loop ran with telemetry=True; padded [max_iter, k] device rows
    # straight out of a loop, trimmed host-side f64 [iters, k] from the
    # engine wrappers (hybrid ladder, serve engine)
    telemetry: Optional[jax.Array] = None


class PRState(NamedTuple):
    ranks: jax.Array
    affected: jax.Array
    affected_ever: jax.Array
    delta: jax.Array
    it: jax.Array
    edges_processed: jax.Array
    vertices_processed: jax.Array


def _contrib(graph: EdgeListGraph, ranks: jax.Array,
             inv_out_deg: jax.Array) -> jax.Array:
    """c[v] = Σ_{u∈in(v), u≠v} R[u]/d_u  (pull step; self-loop excluded)."""
    w = jnp.where(graph.valid, ranks[graph.src] * inv_out_deg[graph.src], 0.0)
    return jax.ops.segment_sum(w, graph.dst, num_segments=graph.num_vertices)


def _rank_update(ranks, contrib, inv_deg, c0, alpha, closed_form: bool):
    """DF vs DF-P rank formulas (Algorithm 1 lines 13-16).

    closed_form=False:  r = C0 + α (c + R[v]/d_v)     [self-loop as one term]
    closed_form=True:   r = (C0 + α c) / (1 - α/d_v)  [paper Eq. 2]
    """
    if closed_form:
        return (c0 + alpha * contrib) / (1.0 - alpha * inv_deg)
    return c0 + alpha * (contrib + ranks * inv_deg)


@partial(jax.jit, static_argnames=(
    "closed_form", "prune", "expand", "track_affected", "max_iter",
    "telemetry"))
def _pagerank_loop(graph: EdgeListGraph,
                   init_ranks: jax.Array,
                   init_affected: jax.Array,
                   *,
                   alpha: float = ALPHA,
                   tol: float = TOL,
                   frontier_tol: float = FRONTIER_TOL,
                   prune_tol: float = PRUNE_TOL,
                   max_iter: int = MAX_ITER,
                   closed_form: bool = False,
                   prune: bool = False,
                   expand: bool = False,
                   track_affected: bool = True,
                   telemetry: bool = False) -> PageRankResult:
    """The one loop behind all five approaches.

    static/naive: affected = all True, expand = prune = False.
    traversal:    affected = BFS mask,  expand = prune = False.
    DF:           expand = True.
    DF-P:         expand = prune = closed_form = True.

    ``telemetry=True`` (static) additionally carries a padded
    ``[max_iter, k]`` f64 row buffer through the loop and fills one
    obs.frontier row per iteration — same program count, one extra
    carried array; with the default False the trace is unchanged.
    """
    V = graph.num_vertices
    deg = graph.out_degree(include_self_loop=True)
    inv_deg = 1.0 / deg.astype(jnp.float64)
    c0 = (1.0 - alpha) / V
    in_deg = graph.in_degree(include_self_loop=False).astype(jnp.int64)

    def body(state: PRState) -> PRState:
        ranks, affected = state.ranks, state.affected
        contrib = _contrib(graph, ranks, inv_deg)
        r_new_all = _rank_update(ranks, contrib, inv_deg, c0, alpha,
                                 closed_form)
        r_new = jnp.where(affected, r_new_all, ranks)
        dr = jnp.abs(r_new - ranks)
        rel = dr / jnp.maximum(jnp.maximum(r_new, ranks), 1e-300)
        delta = jnp.max(jnp.where(affected, dr, 0.0))

        new_affected = affected
        if prune:
            # Alg.1 line 19: prune v if relative change within τ_p
            new_affected = new_affected & ~(affected & (rel <= prune_tol))
        if expand:
            # Alg.1 line 22: expand to out-neighbours if rel change > τ_f.
            # out(v) includes v itself (universal self-loop, §5.1.3) — the
            # implicit self-loop must be replicated here or vertices whose
            # rank still moves would drop out of the frontier.
            big = affected & (rel > frontier_tol)
            marks = graph.push_or(big)
            new_affected = new_affected | marks | big

        edges = state.edges_processed + jnp.sum(
            jnp.where(affected, in_deg, 0))
        verts = state.vertices_processed + jnp.sum(
            affected.astype(jnp.int64))
        ever = state.affected_ever | new_affected if track_affected \
            else state.affected_ever
        new_state = PRState(r_new, new_affected, ever, delta, state.it + 1,
                            edges, verts)
        if not telemetry:
            return new_state
        n_aff = jnp.sum(affected)
        row = _tel_row(n_aff, delta,
                       jnp.sum(new_affected & ~affected),
                       jnp.sum(affected & ~new_affected),
                       n_aff, jnp.float64)
        return new_state, row

    def cond(state: PRState) -> jax.Array:
        return (state.delta > tol) & (state.it < max_iter)

    state0 = PRState(
        ranks=init_ranks.astype(jnp.float64),
        affected=init_affected,
        affected_ever=init_affected,
        delta=jnp.asarray(jnp.inf, jnp.float64),
        it=jnp.asarray(0, jnp.int32),
        edges_processed=jnp.asarray(0, jnp.int64),
        vertices_processed=jnp.asarray(0, jnp.int64),
    )
    if not telemetry:
        out = jax.lax.while_loop(cond, body, state0)
        return PageRankResult(out.ranks, out.it, out.delta,
                              out.affected_ever, out.edges_processed,
                              out.vertices_processed)

    def body_tel(carry):
        state, tel = carry
        new_state, row = body(state)
        tel = jax.lax.dynamic_update_slice(
            tel, row[None, :], (state.it, jnp.asarray(0, jnp.int32)))
        return new_state, tel

    out, tel = jax.lax.while_loop(
        lambda c: cond(c[0]), body_tel,
        (state0, jnp.zeros((max_iter, _TEL_K), jnp.float64)))
    return PageRankResult(out.ranks, out.it, out.delta, out.affected_ever,
                          out.edges_processed, out.vertices_processed,
                          telemetry=tel)


# --------------------------------------------------------------------------
# Public approaches
# --------------------------------------------------------------------------

def static_pagerank(graph: EdgeListGraph, *, alpha: float = ALPHA,
                    tol: float = TOL, max_iter: int = MAX_ITER
                    ) -> PageRankResult:
    V = graph.num_vertices
    init = jnp.full((V,), 1.0 / V, jnp.float64)
    aff = jnp.ones((V,), bool)
    return _pagerank_loop(graph, init, aff, alpha=alpha, tol=tol,
                          max_iter=max_iter, track_affected=False)


def naive_dynamic_pagerank(graph: EdgeListGraph, prev_ranks: jax.Array, *,
                           alpha: float = ALPHA, tol: float = TOL,
                           max_iter: int = MAX_ITER) -> PageRankResult:
    aff = jnp.ones((graph.num_vertices,), bool)
    return _pagerank_loop(graph, prev_ranks, aff, alpha=alpha, tol=tol,
                          max_iter=max_iter, track_affected=False)


@partial(jax.jit, static_argnames=("max_pulses",))
def reachability_mask(graph_prev: EdgeListGraph, graph_new: EdgeListGraph,
                      seeds: jax.Array, max_pulses: int = 0) -> jax.Array:
    """DT preprocessing: vertices reachable from seeds in Gᵗ⁻¹ ∪ Gᵗ.

    BFS queues don't vectorise on TPU; we use frontier pulses of
    ``push_or`` until fixpoint (≤ diameter iterations) in a while_loop.
    """
    def body(carry):
        reach, frontier, _ = carry
        nxt = graph_prev.push_or(frontier) | graph_new.push_or(frontier)
        new = nxt & ~reach
        return reach | new, new, jnp.any(new)

    def cond(carry):
        return carry[2]

    reach, _, _ = jax.lax.while_loop(
        cond, body, (seeds, seeds, jnp.asarray(True)))
    return reach


def dynamic_traversal_pagerank(graph_prev: EdgeListGraph,
                               graph_new: EdgeListGraph,
                               seeds: jax.Array, prev_ranks: jax.Array, *,
                               alpha: float = ALPHA, tol: float = TOL,
                               max_iter: int = MAX_ITER) -> PageRankResult:
    """DT: mark everything reachable from update endpoints, then iterate."""
    aff = reachability_mask(graph_prev, graph_new, seeds)
    return _pagerank_loop(graph_new, prev_ranks, aff, alpha=alpha, tol=tol,
                          max_iter=max_iter)


def initial_affected(graph_prev: EdgeListGraph, graph_new: EdgeListGraph,
                     touched: jax.Array) -> jax.Array:
    """DF/DF-P initial marking (Alg.1 lines 4-6): out-neighbours of update
    endpoints in *both* snapshots.  ``touched``: bool[V] of u endpoints.

    Because every vertex carries a self-loop (paper §5.1.3/5.1.4), u is a
    member of out(u) in the paper's edge list, so u itself is marked: its
    own rank depends on its changed out-degree through the self-loop term.
    """
    return touched | graph_prev.push_or(touched) | graph_new.push_or(touched)


def dynamic_frontier_pagerank(graph_prev: EdgeListGraph,
                              graph_new: EdgeListGraph,
                              touched: jax.Array, prev_ranks: jax.Array, *,
                              alpha: float = ALPHA, tol: float = TOL,
                              frontier_tol: float = FRONTIER_TOL,
                              max_iter: int = MAX_ITER) -> PageRankResult:
    """DF (the paper §4.1.1)."""
    aff = initial_affected(graph_prev, graph_new, touched)
    return _pagerank_loop(graph_new, prev_ranks, aff, alpha=alpha, tol=tol,
                          frontier_tol=frontier_tol, max_iter=max_iter,
                          expand=True)


def dynamic_frontier_prune_pagerank(graph_prev: EdgeListGraph,
                                    graph_new: EdgeListGraph,
                                    touched: jax.Array,
                                    prev_ranks: jax.Array, *,
                                    alpha: float = ALPHA, tol: float = TOL,
                                    frontier_tol: float = FRONTIER_TOL,
                                    prune_tol: float = PRUNE_TOL,
                                    max_iter: int = MAX_ITER
                                    ) -> PageRankResult:
    """DF-P (the paper §4.1.2): expansion + pruning + closed-form update."""
    aff = initial_affected(graph_prev, graph_new, touched)
    return _pagerank_loop(graph_new, prev_ranks, aff, alpha=alpha, tol=tol,
                          frontier_tol=frontier_tol, prune_tol=prune_tol,
                          max_iter=max_iter, expand=True, prune=True,
                          closed_form=True)
