"""Pure-NumPy oracle of the paper's Algorithm 1 (asynchronous schedule).

This is the ground truth the JAX engine and Pallas kernels are tested
against.  It mirrors the paper's OpenMP implementation semantics exactly:
single rank vector, per-vertex in-place (asynchronous) updates in vertex
order, explicit self-loop semantics via d_v = out_deg+1, pull-based.

Because the JAX engine is synchronous (Jacobi), iterate sequences differ;
tests therefore compare *fixed points* (converged ranks) which are schedule
independent, plus exact L1 error targets vs a 1e-100-style reference.
"""
from __future__ import annotations

import numpy as np

ALPHA = 0.85
TOL = 1e-10
MAX_ITER = 500


def build_csr(src, dst, num_vertices):
    """in-CSR (by dst) and out-degree for pull-based updates."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    order = np.argsort(dst, kind="stable")
    s, d = src[order], dst[order]
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.add.at(indptr, d + 1, 1)
    np.cumsum(indptr, out=indptr)
    out_deg = np.zeros(num_vertices, np.int64)
    np.add.at(out_deg, src, 1)
    return indptr, s, out_deg + 1      # implicit self-loop


def static_pagerank_ref(src, dst, num_vertices, alpha=ALPHA, tol=TOL,
                        max_iter=MAX_ITER):
    src = np.asarray(src)
    dst = np.asarray(dst)
    out_deg = np.zeros(num_vertices, np.int64)
    np.add.at(out_deg, src, 1)
    deg = out_deg + 1                       # implicit self-loop
    r = np.full(num_vertices, 1.0 / num_vertices)
    c0 = (1 - alpha) / num_vertices
    for it in range(max_iter):
        contrib = np.zeros(num_vertices)
        np.add.at(contrib, dst, r[src] / deg[src])
        r_new = c0 + alpha * (contrib + r / deg)
        delta = np.max(np.abs(r_new - r))
        r = r_new
        if delta <= tol:
            return r, it + 1
    return r, max_iter


def df_pagerank_ref(src_prev, dst_prev, src_new, dst_new, num_vertices,
                    prev_ranks, touched, alpha=ALPHA, tol=TOL,
                    frontier_tol=1e-6, prune_tol=1e-6, max_iter=MAX_ITER,
                    prune=False, closed_form=False):
    """Asynchronous DF / DF-P exactly per Algorithm 1."""
    def out_adj(src, dst):
        order = np.argsort(src, kind="stable")
        s, d = src[order], dst[order]
        indptr = np.zeros(num_vertices + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, d

    op_ptr, op_idx = out_adj(src_prev, dst_prev)
    on_ptr, on_idx = out_adj(src_new, dst_new)
    in_ptr, in_src, deg = build_csr(src_new, dst_new, num_vertices)

    r = np.array(prev_ranks, dtype=np.float64)
    affected = np.zeros(num_vertices, bool)
    for u in np.nonzero(touched)[0]:
        affected[u] = True                     # self-loop: u ∈ out(u)
        affected[op_idx[op_ptr[u]:op_ptr[u + 1]]] = True
        affected[on_idx[on_ptr[u]:on_ptr[u + 1]]] = True
    ever = affected.copy()
    c0 = (1 - alpha) / num_vertices

    for it in range(max_iter):
        delta = 0.0
        for v in np.nonzero(affected)[0]:
            ins = in_src[in_ptr[v]:in_ptr[v + 1]]
            c = np.sum(r[ins] / deg[ins])
            if closed_form:
                r_new = (c0 + alpha * c) / (1 - alpha / deg[v])
            else:
                r_new = c0 + alpha * (c + r[v] / deg[v])
            dr = abs(r_new - r[v])
            delta = max(delta, dr)
            rel = dr / max(r_new, r[v])
            if prune and rel <= prune_tol:
                affected[v] = False
            if rel > frontier_tol:
                affected[v] = True             # self-loop: v ∈ out(v)
                nbrs = on_idx[on_ptr[v]:on_ptr[v + 1]]
                affected[nbrs] = True
                ever[nbrs] = True
            r[v] = r_new          # asynchronous: visible immediately
        if delta <= tol:
            return r, it + 1, ever
    return r, max_iter, ever


def l1_error(ranks, reference):
    return float(np.sum(np.abs(np.asarray(ranks) - np.asarray(reference))))
