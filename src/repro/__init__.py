"""repro — DF* PageRank dynamic-graph framework on JAX (TPU-targeted).

x64 is enabled globally: the paper (§5.1.2) uses 64-bit floats for vertex
ranks with iteration tolerance 1e-10, which is unrepresentable in f32; the
graph substrate also packs (src,dst) into int64 keys.  All model code passes
explicit dtypes (bf16/f32/int32) so LM/GNN/recsys paths are unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro import compat  # noqa: E402,F401  (installs jax.* API shims)

__version__ = "1.0.0"
