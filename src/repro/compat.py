"""Forward-compat shims: expose the modern ``jax.*`` distributed API names
on the pinned jax 0.4.x toolchain.

The distributed layer (repro.dist) and its tests are written against the
current jax API surface — ``jax.shard_map(..., check_vma=...)`` and
``with jax.set_mesh(mesh):`` — which 0.4.x spells
``jax.experimental.shard_map.shard_map(..., check_rep=...)`` and
``with mesh:`` (Mesh is itself a context manager that installs the active
resource env).  Importing :mod:`repro` installs these aliases once, so the
same source runs unchanged on either jax generation.  Nothing is patched
when the names already exist.
"""
from __future__ import annotations

import jax

try:  # modern jax: the real thing
    shard_map = jax.shard_map          # type: ignore[attr-defined]
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_04x

    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, **kwargs):
        """0.4.x adapter: ``check_vma`` (new name) -> ``check_rep``."""
        if check_rep is None:
            check_rep = True if check_vma is None else bool(check_vma)
        return _shard_map_04x(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              **kwargs)

    jax.shard_map = shard_map          # type: ignore[attr-defined]


if not hasattr(jax, "set_mesh"):
    def _set_mesh(mesh):
        """On 0.4.x a Mesh is already a context manager that sets the
        thread-local resource env ``with mesh:`` — return it unchanged so
        ``with jax.set_mesh(mesh):`` works on both generations."""
        return mesh

    jax.set_mesh = _set_mesh           # type: ignore[attr-defined]


def active_mesh():
    """The mesh installed by ``jax.set_mesh``/``with mesh:``, else None.

    Used by repro.dist.constraints to resolve logical axis names without
    threading the mesh through every model call.
    """
    try:                               # modern jax
        m = jax.sharding.get_abstract_mesh()   # type: ignore[attr-defined]
        if m is not None and m.axis_names:
            return m
    except AttributeError:
        pass
    try:                               # 0.4.x thread-local resource env
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:                  # pragma: no cover - defensive
        pass
    return None
