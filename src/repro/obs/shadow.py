"""Sampled shadow verification: live measurement of DF-P pruning drift.

DF-P trades exactness for work: pruned vertices keep slightly stale
ranks, and over thousands of micro-batches that error can compound in
ways the paper only measures offline.  The shadow verifier closes the
loop in production: every Kth published snapshot is re-solved *from
scratch* by the reference engine (``core.pagerank.static_pagerank``,
f64 XLA, tol=1e-10 — the oracle every parity test trusts) and the
serving ranks are diffed against it:

  * ``l1``   — total variation-style drift, the paper's offline metric;
  * ``linf`` — worst single vertex, what a query actually returns.

The reference solve is orders of magnitude more expensive than a
micro-batch step, so it runs on a **background daemon thread** with a
depth-1 "latest wins" mailbox: if a new sample arrives while the
previous one is still solving, the stale pending sample is replaced
(``skipped`` counts them) — the hot path never blocks, and backlog can
never grow.  ``background=False`` solves synchronously (tests,
benchmarks that want determinism).

Divergence beyond the configured budgets produces ``Incident`` records
(drained by the ``CorrectnessMonitor``); every completed sample lands
in ``reports`` and the gauge dict regardless, so the exporter shows
the drift trajectory even while it is healthy.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import List, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.obs.sentinel import ERROR, Incident

__all__ = ["ShadowReport", "ShadowVerifier"]


class ShadowReport(NamedTuple):
    generation: int     # snapshot generation that was verified
    l1: float           # sum |serving - reference|
    linf: float         # max |serving - reference|
    mass_err: float     # |sum(reference) - 1| (reference sanity)
    iterations: int     # reference solve iterations
    solve_s: float      # reference solve wall time
    lag_batches: int    # batches published between submit and finish


class _Job(NamedTuple):
    generation: int
    last_seq: int
    graph: object       # EdgeListGraph snapshot (immutable pytree)
    ranks: object       # served f64 ranks for the same generation
    submitted_at_count: int


class ShadowVerifier:
    """Every-Kth-batch reference verification off the hot path."""

    def __init__(self, every: int = 64, l1_budget: float = 1e-4,
                 linf_budget: float = 1e-5, tol: float = 1e-10,
                 max_iter: int = 500, background: bool = True,
                 max_reports: int = 1024, clock=time.time):
        if every < 1:
            raise ValueError("every must be >= 1")
        self.every = every
        self.l1_budget = l1_budget
        self.linf_budget = linf_budget
        self.tol = tol
        self.max_iter = max_iter
        self.background = background
        self._clock = clock
        self.reports: deque = deque(maxlen=max_reports)
        self.samples = 0           # completed reference solves
        self.skipped = 0           # samples displaced by a newer one
        self._count = 0            # batches offered via maybe_submit
        self._incidents: List[Incident] = []
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: Optional[_Job] = None
        self._busy = False
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        if background:
            self._thread = threading.Thread(target=self._loop,
                                            name="shadow-verifier",
                                            daemon=True)
            self._thread.start()

    # ---- hot-path side ---------------------------------------------------
    def maybe_submit(self, generation: int, last_seq: int, graph,
                     ranks) -> bool:
        """Offer one published snapshot; True if it was sampled.

        Fires on the first batch and every ``every`` batches after, so
        short streams still get at least one reference point.
        """
        take = (self._count % self.every) == 0
        self._count += 1
        if not take:
            return False
        job = _Job(int(generation), int(last_seq), graph, ranks,
                   self._count)
        if not self.background:
            self._verify(job)
            return True
        with self._cond:
            if self._pending is not None:
                self.skipped += 1          # latest wins, backlog stays 0
            self._pending = job
            self._cond.notify()
        return True

    def take_incidents(self) -> List[Incident]:
        """Drain incidents produced since the last call (thread-safe)."""
        with self._lock:
            out, self._incidents = self._incidents, []
        return out

    def gauges(self) -> dict:
        with self._lock:
            last = self.reports[-1] if self.reports else None
        g = {"shadow_samples": float(self.samples),
             "shadow_skipped": float(self.skipped)}
        if last is not None:
            g.update(shadow_l1=last.l1, shadow_linf=last.linf,
                     shadow_lag_batches=float(last.lag_batches))
        return g

    # ---- verification ----------------------------------------------------
    def _verify(self, job: _Job) -> ShadowReport:
        t0 = self._clock()
        ref = pr.static_pagerank(job.graph, tol=self.tol,
                                 max_iter=self.max_iter)
        diff = jnp.abs(jnp.asarray(job.ranks, jnp.float64)
                       - ref.ranks)
        l1 = float(jnp.sum(diff))
        linf = float(jnp.max(diff))
        mass_err = float(jnp.abs(jnp.sum(ref.ranks) - 1.0))
        rep = ShadowReport(job.generation, l1, linf, mass_err,
                           int(ref.iterations), self._clock() - t0,
                           self._count - job.submitted_at_count)
        now = self._clock()
        incs = []
        if l1 > self.l1_budget:
            incs.append(Incident(
                "shadow_l1", ERROR, job.generation, job.last_seq, l1,
                self.l1_budget,
                f"serving snapshot diverged from the f64 reference by "
                f"L1={l1:.3e} (budget {self.l1_budget:.1e})", now))
        if linf > self.linf_budget:
            incs.append(Incident(
                "shadow_linf", ERROR, job.generation, job.last_seq, linf,
                self.linf_budget,
                f"worst-vertex divergence {linf:.3e} exceeds "
                f"{self.linf_budget:.1e}", now))
        with self._lock:
            self.reports.append(rep)
            self.samples += 1
            self._incidents.extend(incs)
        return rep

    # ---- background thread -----------------------------------------------
    def _loop(self):
        while True:
            with self._cond:
                while self._pending is None and not self._stopping:
                    self._cond.wait()
                if self._stopping and self._pending is None:
                    return
                job, self._pending = self._pending, None
                self._busy = True
            try:
                self._verify(job)
            finally:
                with self._cond:
                    self._busy = False
                    self._cond.notify_all()

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until no sample is pending or running; True if idle."""
        if not self.background:
            return True
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self._cond:
            while self._pending is not None or self._busy:
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    return False
                self._cond.wait(timeout=left)
        return True

    def stop(self, timeout: Optional[float] = None):
        """Drain the mailbox, finish in-flight work, join the thread.

        The worker loop only exits once ``_stopping`` is set AND the
        mailbox is empty, so a sample submitted just before shutdown is
        still verified (and its incidents recorded) before the join
        returns — a pending divergence is reported, never dropped.  The
        default join is unbounded: an abandoned daemon thread would die
        mid-solve at interpreter exit, which is exactly the silent-drop
        this guards against; pass ``timeout`` only if the caller can
        tolerate that.  Idempotent.
        """
        if self._thread is None:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError(
                "shadow-verifier thread did not drain within "
                f"{timeout}s; a pending sample may be unreported")
        self._thread = None
