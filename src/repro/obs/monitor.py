"""CorrectnessMonitor: one object wiring sentinels, shadow verification,
the flight recorder and SLO burn-rate alerting into ``ServeEngine``.

The engine calls exactly two hooks:

  * ``on_bootstrap(engine)`` after the generation-0 publish — binds the
    recorder to the engine's configuration and captures the bootstrap
    anchor (edge list + ranks + packed leaves);
  * ``on_batch(...)`` after every publish — runs the invariant
    sentinel (which also yields the rank digest), appends the batch to
    the flight-recorder ring, offers the snapshot to the shadow
    verifier, feeds the SLO ledgers, and forwards every gauge into
    ``ServeMetrics`` so the existing ``MetricsExporter`` renders the
    whole correctness surface with zero extra plumbing.

Incident flow: sentinel/shadow/SLO violations become ``Incident``
records on ``self.incidents``, each mirrored as a trace instant and
(optionally) a JSONL line.  The first *error*-severity incident
triggers an automatic flight-recorder ``dump()`` into
``config.incident_dir`` — the bundle that ``launch/replay.py`` then
re-executes bit-for-bit.  Dumps are rate-limited by
``max_incident_dumps`` so a persistent violation cannot fill a disk.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import Counter
from typing import List, Optional, Sequence, Tuple

from repro.obs import trace as obs_trace
from repro.obs.recorder import FlightRecorder
from repro.obs.sentinel import (ERROR, Incident, InvariantSentinel,
                                SentinelConfig)
from repro.obs.shadow import ShadowVerifier
from repro.obs.slo import DEFAULT_WINDOWS, SloSet

__all__ = ["MonitorConfig", "CorrectnessMonitor"]


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    sentinel: SentinelConfig = dataclasses.field(
        default_factory=SentinelConfig)
    # shadow verification
    shadow_every: int = 64            # sample every Kth batch (0 = off)
    shadow_l1_budget: float = 1e-4
    shadow_linf_budget: float = 1e-5
    shadow_background: bool = True
    # flight recorder
    recorder_capacity: int = 256
    anchor_every: int = 64
    incident_dir: Optional[str] = None
    max_incident_dumps: int = 4
    # SLO objectives (DESIGN.md §12)
    latency_slo_ms: float = 500.0     # per-batch publish latency ceiling
    staleness_slo_events: int = 512   # query-visible staleness ceiling
    latency_objective: float = 0.99
    staleness_objective: float = 0.99
    shadow_objective: float = 0.99
    slo_windows: Sequence[Tuple[float, float]] = DEFAULT_WINDOWS
    slo_min_events: int = 12          # significance gate per window


class CorrectnessMonitor:
    """Correctness half of ``repro.obs``, attached to one ServeEngine."""

    def __init__(self, config: Optional[MonitorConfig] = None,
                 sink=None, clock=time.time):
        self.config = config or MonitorConfig()
        cfg = self.config
        self._clock = clock
        self.sink = sink                     # optional obs.JsonlSink
        self.sentinel = InvariantSentinel(cfg.sentinel, clock=clock)
        self.shadow = (ShadowVerifier(
            every=cfg.shadow_every, l1_budget=cfg.shadow_l1_budget,
            linf_budget=cfg.shadow_linf_budget,
            background=cfg.shadow_background, clock=clock)
            if cfg.shadow_every > 0 else None)
        self.recorder = FlightRecorder(capacity=cfg.recorder_capacity,
                                       anchor_every=cfg.anchor_every)
        self.slos = SloSet.serving(
            latency_objective=cfg.latency_objective,
            staleness_objective=cfg.staleness_objective,
            shadow_objective=cfg.shadow_objective,
            windows=cfg.slo_windows, min_events=cfg.slo_min_events)
        self.incidents: List[Incident] = []
        self.last_bundle: Optional[str] = None
        self._dumps = 0
        self._shadow_seen = 0

    # ---- engine hooks ----------------------------------------------------
    def on_bootstrap(self, engine) -> None:
        snap = engine.store.snapshot()
        self.recorder.bind_engine(engine)
        self.recorder.record_anchor(snap.generation, snap.graph,
                                    snap.ranks, packed=engine._packed,
                                    last_seq=snap.last_seq)

    def on_batch(self, *, engine, batch, graph, result, method: str,
                 fallback: bool, latency_s: float, affected: int,
                 fault: Optional[dict] = None) -> None:
        cfg = self.config
        gen = engine.store.generation
        last_seq = int(batch.last_seq)
        digest, incidents = self.sentinel.observe(
            generation=gen, last_seq=last_seq, ranks=result.ranks,
            delta=float(result.delta), iterations=int(result.iterations),
            affected=affected, fallback=fallback)
        self.recorder.record_batch(
            generation=gen, batch=batch, graph=graph, ranks=result.ranks,
            method=method, fallback=fallback,
            iterations=int(result.iterations), digest=digest,
            packed=engine._packed, fault=fault)
        if self.shadow is not None:
            self.shadow.maybe_submit(gen, last_seq, graph, result.ranks)
            incidents += self.shadow.take_incidents()
            # fold completed samples into the shadow error budget
            n_new = self.shadow.samples - self._shadow_seen
            if n_new > 0:
                for rep in list(self.shadow.reports)[-n_new:]:
                    self.slos.record("shadow",
                                     rep.l1 <= cfg.shadow_l1_budget)
                self._shadow_seen = self.shadow.samples
        self.slos.record("latency",
                         latency_s * 1e3 <= cfg.latency_slo_ms)
        staleness = max(0, engine.ingest.latest_seq - last_seq)
        self.slos.record("staleness",
                         staleness <= cfg.staleness_slo_events)
        now = self._clock()
        for alert in self.slos.evaluate():
            incidents.append(Incident(
                "slo_burn", "warn", gen, last_seq, alert.burn_long,
                alert.threshold,
                f"SLO '{alert.slo}' burning its error budget at "
                f"{alert.burn_long:.1f}x over {alert.long_window_s:g}s "
                f"(short window {alert.burn_short:.1f}x)", now))
        self._handle(incidents, gen)
        m = engine.metrics
        for name, value in self.gauges().items():
            m.set_gauge(name, value)

    # ---- incident handling -----------------------------------------------
    def _handle(self, incidents: List[Incident], gen: int) -> None:
        if not incidents:
            return
        tr = obs_trace.get_tracer()
        for inc in incidents:
            self.incidents.append(inc)
            tr.instant("obs.incident", kind=inc.kind,
                       severity=inc.severity, generation=inc.generation,
                       value=inc.value, threshold=inc.threshold)
            if self.sink is not None:
                self.sink.write(inc.as_dict(), kind="incident")
        cfg = self.config
        first_error = next((i for i in incidents if i.severity == ERROR),
                           None)
        if (first_error is not None and cfg.incident_dir
                and self._dumps < cfg.max_incident_dumps):
            path = os.path.join(cfg.incident_dir,
                                f"incident_gen{gen:08d}")
            try:
                self.recorder.dump(path, end_gen=gen,
                                   incident=first_error.as_dict())
                self._dumps += 1
                self.last_bundle = path
                tr.instant("obs.incident_bundle", path=path)
            except Exception as e:   # recording must never kill serving
                tr.instant("obs.incident_bundle_failed", error=str(e))

    # ---- reporting -------------------------------------------------------
    def gauges(self) -> dict:
        g = dict(self.sentinel.gauges)
        if self.shadow is not None:
            g.update(self.shadow.gauges())
        g.update(self.slos.gauges())
        g["incidents_total"] = float(len(self.incidents))
        return g

    def summary(self) -> dict:
        by_kind = Counter(i.kind for i in self.incidents)
        out = dict(batches=self.sentinel.batches,
                   incidents_total=len(self.incidents),
                   incidents_by_kind=dict(by_kind),
                   incident_bundle=self.last_bundle)
        if self.shadow is not None and self.shadow.reports:
            last = self.shadow.reports[-1]
            out.update(shadow_samples=self.shadow.samples,
                       shadow_skipped=self.shadow.skipped,
                       shadow_l1=last.l1, shadow_linf=last.linf)
        return out

    def close(self) -> None:
        """Drain the shadow thread and collect its final incidents."""
        if self.shadow is not None:
            self.shadow.stop()
            tail = self.shadow.take_incidents()
            if tail:
                self._handle(tail, tail[-1].generation)
