"""Flight recorder: bounded batch ring + checkpoint anchors + replay.

When a sentinel or shadow check flags a generation, "what exactly did
the engine do?" must be answerable *after the fact*.  The recorder
keeps, in memory, everything needed to deterministically re-execute
the recent past:

  * a bounded ring of ``BatchRecord``s — per micro-batch: the full
    coalesced ``BatchUpdate`` (host copies of the padded arrays), the
    generation/seq window, the engine's decisions (method, static
    fallback, iteration count), any injected fault, and the published
    snapshot's **rank digest** (obs.sentinel);
  * periodic **anchors** — host copies of the complete engine state
    after a recorded generation: the edge list, the f64 ranks, and (on
    the kernel engine) every ``PackedGraph`` leaf.  Anchors reuse the
    ``ft.checkpoint`` on-disk format when dumped, so a bundle is just
    a checkpoint plus a manifest plus the batch ring.

**Replay determinism contract** (DESIGN.md §12): JAX programs are
functional — the same jitted program applied to the same inputs yields
bit-identical outputs on a deterministic backend (CPU, TPU).  The
engine's per-batch inputs are exactly (graph, ranks, packed, update,
method decision), all of which the anchor + ring capture, so replaying
a window from its anchor reproduces every published rank vector
**bit-for-bit** — verified digest-by-digest.  The one stateful input,
an injected *rank* fault, is recorded and re-applied; *event* faults
corrupt the update before it is recorded, so the recorded stream
already contains them.  A single-device PPR walk index replays too:
the manifest anchors its *identity* (statics + base PRNG key), and
walk sampling is a pure function of (graph, identity), so the replayed
engine rebuilds the index bit-identically from the anchor graph and
repairs it through the window exactly as the live engine did.  Out of
scope: the sharded mesh path (per-shard packed/walk device state is
not anchored; ``replay`` refuses rather than diverging silently).

``dump()`` writes an **incident bundle** directory::

    <dir>/manifest.json       engine config, record metadata, incident
    <dir>/anchor/step_*/      ft.checkpoint of the anchor state
    <dir>/records.npz         the coalesced update arrays per record

``replay(source)`` accepts a live ``FlightRecorder`` or a bundle path
and returns a ``ReplayReport`` whose per-step rows compare recomputed
digests and decisions against the recorded ones.  The
``repro.launch.replay`` CLI wraps it.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Dict, List, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.ft import checkpoint as ft_checkpoint
from repro.graph.dynamic import BatchUpdate
from repro.graph.structure import EdgeListGraph
from repro.obs.sentinel import rank_digest

__all__ = ["BatchRecord", "FlightRecorder", "ReplayReport", "ReplayStep",
           "load_bundle", "replay"]

BUNDLE_VERSION = 1
_MANIFEST = "manifest.json"
_RECORDS = "records.npz"
_ANCHOR_DIR = "anchor"

# PackedGraph array leaves, in dataclass field order
_PACKED_LEAVES = ("src", "dst_rel", "valid", "window", "entry_start",
                  "sorted_key", "sorted_lane", "ovl_key", "ovl_lane")


def _ppr_config(engine) -> Optional[dict]:
    """JSON-serializable identity of the engine's walk index (either
    index type), or None.  Everything that determines the sampled walks
    besides the graph — enough for replay to rebuild bit-identically."""
    idx = getattr(engine, "_ppr", None)
    if idx is None:
        return None
    return dict(num_walks=int(idx.num_walks), max_len=int(idx.max_len),
                alpha=float(idx.alpha),
                key=[int(x) for x in np.asarray(idx.key)])


class BatchRecord(NamedTuple):
    generation: int
    first_seq: int
    last_seq: int
    num_events: int
    num_coalesced: int
    oldest_t: float
    method: str          # method actually solved with ("static" = fallback)
    fallback: bool
    iterations: int
    digest: int          # rank digest of the published snapshot
    fault: Optional[dict]
    update: Dict[str, np.ndarray]   # BatchUpdate leaves, host copies

    def meta(self) -> dict:
        d = self._asdict()
        d.pop("update")
        return d


class FlightRecorder:
    """In-memory ring of recent batches + state anchors."""

    def __init__(self, capacity: int = 256, anchor_every: int = 64):
        if capacity < 1 or anchor_every < 1:
            raise ValueError("capacity and anchor_every must be >= 1")
        self.capacity = capacity
        self.anchor_every = anchor_every
        self._records: deque = deque(maxlen=capacity)
        # generation -> (state arrays dict, last_seq); state after that
        # generation's publish
        self._anchors: Dict[int, tuple] = {}
        self.config: dict = {}

    # ---- capture ---------------------------------------------------------
    def bind_engine(self, engine) -> None:
        """Snapshot the engine's replay-relevant configuration (static
        for the engine's lifetime, so bound once at bootstrap)."""
        scal = (int, float, bool, str)
        cfg = dict(
            method=engine.method,
            engine=engine.engine,
            static_fallback_frac=float(engine.static_fallback_frac),
            num_vertices=int(engine._graph.num_vertices),
            edge_capacity=int(engine._graph.edge_capacity),
            ingest_capacity=int(getattr(engine.ingest, "capacity", 8)),
            mesh=engine.mesh is not None,
            ppr=_ppr_config(engine),
            pr_kw={k: v for k, v in engine.pr_kw.items()
                   if isinstance(v, scal)},
            kernel_kw={k: v for k, v in engine._kernel_kw.items()
                       if isinstance(v, scal)},
        )
        if engine._packed is not None:
            p = engine._packed
            cfg["pack_kw"] = {k: v for k, v in engine._pack_kw.items()
                              if isinstance(v, (int, float))}
            cfg["packed_statics"] = dict(
                num_vertices=p.num_vertices, vb=p.vb, be=p.be,
                max_entries_per_window=p.max_entries_per_window)
        self.config = cfg

    def record_anchor(self, generation: int, graph, ranks, packed=None,
                      last_seq: int = -1) -> None:
        state = dict(
            ranks=np.asarray(ranks),
            graph_src=np.asarray(graph.src),
            graph_dst=np.asarray(graph.dst),
            graph_valid=np.asarray(graph.valid),
            graph_num_edges=np.asarray(graph.num_edges),
        )
        if packed is not None:
            for name in _PACKED_LEAVES:
                state[f"packed_{name}"] = np.asarray(getattr(packed, name))
        self._anchors[int(generation)] = (state, int(last_seq))

    def record_batch(self, *, generation: int, batch, graph, ranks,
                     method: str, fallback: bool, iterations: int,
                     digest: int, packed=None,
                     fault: Optional[dict] = None) -> None:
        upd = {f: np.asarray(getattr(batch.update, f))
               for f in BatchUpdate._fields}
        self._records.append(BatchRecord(
            int(generation), int(batch.first_seq), int(batch.last_seq),
            int(batch.num_events), int(batch.num_coalesced),
            float(batch.oldest_t), str(method), bool(fallback),
            int(iterations), int(digest),
            dict(fault) if fault else None, upd))
        if generation % self.anchor_every == 0:
            self.record_anchor(generation, graph, ranks, packed=packed,
                               last_seq=int(batch.last_seq))
        self._gc_anchors()

    def _gc_anchors(self) -> None:
        """Drop anchors that can no longer seed a replay: keep the newest
        anchor at-or-before the oldest record's predecessor, and all
        newer ones."""
        if not self._records:
            return
        need = self._records[0].generation - 1
        covering = [g for g in self._anchors if g <= need]
        if covering:
            keep_min = max(covering)
            for g in [g for g in self._anchors if g < keep_min]:
                del self._anchors[g]

    # ---- reading ---------------------------------------------------------
    @property
    def records(self) -> List[BatchRecord]:
        return list(self._records)

    @property
    def anchor_generations(self) -> List[int]:
        return sorted(self._anchors)

    def _covering_anchor(self, first_gen: int) -> int:
        """Newest anchor generation <= first_gen - 1."""
        covering = [g for g in self._anchors if g <= first_gen - 1]
        if not covering:
            raise ValueError(
                f"no anchor covers generation {first_gen}; anchors at "
                f"{sorted(self._anchors)}")
        return max(covering)

    def window(self, end_gen: Optional[int] = None):
        """(anchor_gen, anchor_state, anchor_last_seq, records) for the
        replayable window ending at ``end_gen`` (default: newest)."""
        recs = [r for r in self._records
                if end_gen is None or r.generation <= end_gen]
        if not recs:
            raise ValueError("flight recorder has no records in range")
        a = self._covering_anchor(recs[0].generation)
        recs = [r for r in recs if r.generation > a]
        state, last_seq = self._anchors[a]
        return a, state, last_seq, recs

    # ---- bundle I/O ------------------------------------------------------
    def dump(self, directory: str, end_gen: Optional[int] = None,
             incident: Optional[dict] = None) -> str:
        """Write an incident bundle; returns the bundle directory."""
        a, state, a_seq, recs = self.window(end_gen)
        os.makedirs(directory, exist_ok=True)
        ft_checkpoint.save(os.path.join(directory, _ANCHOR_DIR),
                           step=a, state=state, keep_last=1)
        arrays = {f"u{i:05d}_{k}": v
                  for i, r in enumerate(recs) for k, v in r.update.items()}
        np.savez_compressed(os.path.join(directory, _RECORDS), **arrays)
        manifest = dict(
            version=BUNDLE_VERSION,
            config=self.config,
            incident=incident,
            anchor=dict(generation=a, last_seq=a_seq),
            records=[r.meta() for r in recs],
        )
        with open(os.path.join(directory, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1, default=_jsonable)
        return directory

    def __len__(self) -> int:
        return len(self._records)


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return str(v)


def _load_ckpt_arrays(step_dir: str) -> Dict[str, np.ndarray]:
    """Read an ft.checkpoint step directory back into a flat dict (the
    keystr of a flat dict leaf is ``['name']``)."""
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        man = json.load(f)
    out = {}
    for rec in man["leaves"]:
        key = rec["key"].strip("[]'\"")
        out[key] = np.load(os.path.join(step_dir, rec["file"]))
    return out


def load_bundle(directory: str):
    """(config, anchor_gen, anchor_state, anchor_last_seq, records)."""
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    if manifest.get("version") != BUNDLE_VERSION:
        raise ValueError(
            f"bundle version {manifest.get('version')} != "
            f"{BUNDLE_VERSION}")
    a = int(manifest["anchor"]["generation"])
    step_dir = os.path.join(directory, _ANCHOR_DIR, f"step_{a:010d}")
    state = _load_ckpt_arrays(step_dir)
    npz = np.load(os.path.join(directory, _RECORDS))
    records = []
    for i, meta in enumerate(manifest["records"]):
        upd = {f: npz[f"u{i:05d}_{f}"] for f in BatchUpdate._fields}
        records.append(BatchRecord(
            int(meta["generation"]), int(meta["first_seq"]),
            int(meta["last_seq"]), int(meta["num_events"]),
            int(meta["num_coalesced"]), float(meta["oldest_t"]),
            str(meta["method"]), bool(meta["fallback"]),
            int(meta["iterations"]), int(meta["digest"]),
            meta.get("fault"), upd))
    return (manifest["config"], a, state,
            int(manifest["anchor"]["last_seq"]), records,
            manifest.get("incident"))


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

class ReplayStep(NamedTuple):
    generation: int
    method: str
    fallback: bool
    digest: int
    want_digest: int
    bitwise: bool        # digest == want_digest
    decisions_match: bool  # method + fallback agree with the record

    @property
    def ok(self) -> bool:
        return self.bitwise and self.decisions_match


class ReplayReport(NamedTuple):
    anchor_generation: int
    steps: List[ReplayStep]

    @property
    def ok(self) -> bool:
        return bool(self.steps) and all(s.ok for s in self.steps)

    @property
    def num_bitwise(self) -> int:
        return sum(s.bitwise for s in self.steps)

    def describe(self) -> str:
        lines = [f"replay from anchor generation "
                 f"{self.anchor_generation}: {len(self.steps)} batches"]
        for s in self.steps:
            verdict = "BITWISE" if s.bitwise else "MISMATCH"
            note = "" if s.decisions_match else " (decision diverged)"
            lines.append(
                f"  gen {s.generation:6d} {s.method:>14s}"
                f"{' [fallback]' if s.fallback else ''} "
                f"digest {s.digest & 0xFFFFFFFFFFFFFFFF:016x} vs "
                f"{s.want_digest & 0xFFFFFFFFFFFFFFFF:016x} "
                f"{verdict}{note}")
        lines.append(f"  => {self.num_bitwise}/{len(self.steps)} "
                     f"bit-for-bit"
                     + ("" if self.ok else "  ** REPLAY DIVERGED **"))
        return "\n".join(lines)


class _ReplayFeed:
    """IngestQueue stand-in serving the recorded batches verbatim."""

    def __init__(self, batches, capacity: int, start_seq: int):
        self._batches = list(batches)
        self.capacity = capacity
        self.start_seq = start_seq
        self.flush_size = max(1, capacity)
        self.latest_seq = (self._batches[-1].last_seq if self._batches
                           else start_seq - 1)

    def poll(self, force: bool = False):
        return self._batches.pop(0) if self._batches else None

    def pending(self) -> int:
        return len(self._batches)


def replay(source, end_gen: Optional[int] = None) -> ReplayReport:
    """Re-execute a recorded window and diff it against the record.

    ``source`` is a live ``FlightRecorder`` or an incident-bundle
    directory written by ``dump()``.  Raises ``NotImplementedError``
    for configurations whose device state is not anchored (the sharded
    mesh path, and legacy bundles that recorded only that a PPR index
    existed without its identity) — see the module docstring.
    """
    if isinstance(source, (str, os.PathLike)):
        cfg, a, state, a_seq, recs, _ = load_bundle(os.fspath(source))
        if end_gen is not None:
            recs = [r for r in recs if r.generation <= end_gen]
    else:
        cfg = source.config
        a, state, a_seq, recs = source.window(end_gen)
    if not cfg:
        raise ValueError("recorder was never bound to an engine "
                         "(no config); cannot replay")
    if cfg.get("mesh"):
        raise NotImplementedError(
            "replay of the sharded mesh path is not supported: per-shard "
            "packed state is not anchored (DESIGN.md §12)")
    pcfg = cfg.get("ppr")
    if pcfg is True:
        # pre-identity bundle: we know an index existed but not its key,
        # so it cannot be reconstructed — the old blanket refusal stands
        raise NotImplementedError(
            "replay with a live PPR walk index needs the index identity "
            "in the bundle config; this legacy bundle predates it "
            "(DESIGN.md §12)")
    if not recs:
        raise ValueError("no records to replay in the requested window")

    # deferred: repro.serve imports repro.obs at package init
    from repro.serve.engine import ServeEngine
    from repro.serve.ingest import CoalescedBatch
    from repro.serve.state import RankStore

    graph = EdgeListGraph(
        src=jnp.asarray(state["graph_src"]),
        dst=jnp.asarray(state["graph_dst"]),
        valid=jnp.asarray(state["graph_valid"]),
        num_vertices=int(cfg["num_vertices"]),
        num_edges=jnp.asarray(state["graph_num_edges"]))
    batches = [CoalescedBatch(
        update=BatchUpdate(**{f: jnp.asarray(r.update[f])
                              for f in BatchUpdate._fields}),
        num_events=r.num_events, num_coalesced=r.num_coalesced,
        first_seq=r.first_seq, last_seq=r.last_seq,
        oldest_t=r.oldest_t) for r in recs]
    feed = _ReplayFeed(batches, int(cfg.get("ingest_capacity", 8)), a_seq)
    store = RankStore()
    store.seed_generation(a)

    kernel_opts = None
    if cfg["engine"] == "kernel":
        ps = cfg["packed_statics"]
        kernel_opts = dict(cfg.get("kernel_kw", {}))
        pk = dict(cfg.get("pack_kw", {}))
        pk.pop("max_entries_per_window", None)
        kernel_opts.update(pk)   # be/vb pinned => autotune stays off
    engine = ServeEngine(graph, feed, store, method=cfg["method"],
                         engine=cfg["engine"], kernel_opts=kernel_opts,
                         static_fallback_frac=cfg["static_fallback_frac"],
                         telemetry=False, **cfg.get("pr_kw", {}))
    if cfg["engine"] == "kernel":
        from repro.kernels.pagerank_spmv.pagerank_spmv import PackedGraph
        ps = cfg["packed_statics"]
        engine._packed = PackedGraph(
            **{n: jnp.asarray(state[f"packed_{n}"])
               for n in _PACKED_LEAVES},
            num_vertices=int(ps["num_vertices"]), vb=int(ps["vb"]),
            be=int(ps["be"]),
            max_entries_per_window=int(ps["max_entries_per_window"]))
        engine._pack_kw["max_entries_per_window"] = \
            int(ps["max_entries_per_window"])
    if pcfg:
        # rebuild the walk index on the anchor graph from its recorded
        # identity — bitwise what the live engine held at the anchor
        # (sampling is a pure function of (graph, identity)), so the
        # per-batch repairs re-run inside engine.step just as they did
        from repro.ppr.walks import WalkIndex, _build_steps
        key = jnp.asarray(pcfg["key"], jnp.uint32)
        csr = graph.to_device_csr()
        engine._ppr = WalkIndex(
            steps=_build_steps(csr, key, int(cfg["num_vertices"]),
                               int(pcfg["num_walks"]),
                               int(pcfg["max_len"]),
                               float(pcfg["alpha"])),
            csr=csr, key=key, num_walks=int(pcfg["num_walks"]),
            max_len=int(pcfg["max_len"]), alpha=float(pcfg["alpha"]))
    engine.bootstrap(ranks=jnp.asarray(state["ranks"]), last_seq=a_seq)

    steps: List[ReplayStep] = []
    for rec in recs:
        if rec.fault and rec.fault.get("kind") == "rank":
            engine.inject_fault(**rec.fault)
        fb_before = engine.metrics.static_fallbacks
        if not engine.step(force=True):
            raise RuntimeError(
                f"replay feed exhausted before generation "
                f"{rec.generation}")
        snap = store.snapshot()
        fallback = engine.metrics.static_fallbacks > fb_before
        method = "static" if fallback else cfg["method"]
        digest = rank_digest(snap.ranks)
        steps.append(ReplayStep(
            generation=snap.generation, method=method, fallback=fallback,
            digest=digest, want_digest=rec.digest,
            bitwise=digest == rec.digest,
            decisions_match=(snap.generation == rec.generation
                             and fallback == rec.fallback
                             and method == rec.method)))
    return ReplayReport(anchor_generation=a, steps=steps)
