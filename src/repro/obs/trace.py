"""Low-overhead span tracing for the serving stack (`repro.obs`).

One ``Tracer`` holds a bounded, thread-safe ring buffer of *complete*
spans (name, start, duration, thread, args) recorded against a
monotonic clock.  The API is deliberately tiny:

  * ``with tracer.span("fused_update_loop", seq=s): ...`` — a
    context-manager span; nesting is per-thread (each thread's spans
    land on its own Chrome-trace track and nest by interval
    containment, the format's native rule);
  * ``@traced("name")`` — decorator form of the same;
  * ``tracer.record(name, t0, dur, **args)`` — an already-measured
    interval (used when a span's start must precede work whose outcome
    decides whether to record at all, e.g. an ingest poll that may
    yield no batch);
  * ``tracer.instant(name, **args)`` / ``tracer.counter(name, **vals)``
    — point annotations and counter tracks;
  * ``tracer.sync(x)`` — ``jax.block_until_ready`` *only when tracing
    is enabled*, so device-program boundaries get honest durations
    without perturbing the untraced hot path.

Disabled tracers are free: ``span`` returns a shared no-op context
manager, nothing is allocated, nothing is locked, and — critically —
nothing forces a device sync, so with tracing off the serving hot path
runs byte-for-byte the PR-6 program schedule (tests assert the trace
counters and ``device_programs_per_batch`` are unchanged).

Export is Chrome trace format (the JSON array-of-events flavour):
``to_chrome()`` returns ``{"traceEvents": [...]}`` with complete-event
(``"ph": "X"``) records carrying ``name``/``ts``/``dur``/``pid``/
``tid``/``args`` in microseconds — loadable in ``chrome://tracing`` and
Perfetto as-is.  ``write(path)`` dumps it; round-tripping through
``json.loads`` is part of the tier-1 contract.

``timeit`` is the one timing idiom for host-side measurement (the
benchmarks use it instead of ad-hoc ``time.monotonic()`` pairs)::

    with timeit() as t:
        work()
    print(t.seconds)
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Iterator, List, Optional

__all__ = [
    "Span", "Tracer", "timeit", "get_tracer", "set_tracer",
    "start_tracing", "stop_tracing", "tracing", "span", "traced",
]


class timeit:
    """Minimal elapsed-time context manager: ``with timeit() as t: ...``
    then read ``t.seconds``.  ``clock`` defaults to ``time.perf_counter``
    (monotonic, highest host resolution)."""

    __slots__ = ("_clock", "_t0", "seconds")

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.seconds = 0.0

    def __enter__(self) -> "timeit":
        self._t0 = self._clock()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = self._clock() - self._t0


class Span:
    """One recorded interval (times in seconds on the tracer's clock)."""

    __slots__ = ("name", "t0", "dur", "tid", "args")

    def __init__(self, name: str, t0: float, dur: float, tid: int,
                 args: Optional[dict]):
        self.name = name
        self.t0 = t0
        self.dur = dur
        self.tid = tid
        self.args = args

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, t0={self.t0:.6f}, "
                f"dur={self.dur * 1e3:.3f}ms)")


class _NopSpan:
    """Shared do-nothing context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOP = _NopSpan()


class _LiveSpan:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer.now()
        return self

    def __exit__(self, *exc):
        t = self._tracer
        t.record(self._name, self._t0, t.now() - self._t0,
                 **(self._args or {}))
        return False


class Tracer:
    """Thread-safe ring buffer of spans with Chrome-trace export.

    ``capacity`` bounds memory: the buffer keeps the newest spans and
    silently drops the oldest (``dropped`` counts them), so a tracer
    left on for a long serve run cannot grow without bound.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 clock=time.perf_counter, pid: Optional[int] = None):
        self.enabled = enabled
        self.capacity = capacity
        self._clock = clock
        self._epoch = clock()
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0
        self.pid = os.getpid() if pid is None else pid

    # ---- clock -----------------------------------------------------------
    def now(self) -> float:
        """Seconds since the tracer's epoch (cheap even when disabled)."""
        return self._clock() - self._epoch

    # ---- recording -------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager recording one complete span on this thread."""
        if not self.enabled:
            return _NOP
        return _LiveSpan(self, name, args or None)

    def record(self, name: str, t0: float, dur: float, **args) -> None:
        """Record an interval measured by the caller (tracer-clock t0)."""
        if not self.enabled:
            return
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(("X", name, t0, dur,
                              threading.get_ident(), args or None))

    def instant(self, name: str, **args) -> None:
        """Point annotation ("ph": "i") at the current time."""
        if not self.enabled:
            return
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(("i", name, self.now(), 0.0,
                              threading.get_ident(), args or None))

    def counter(self, name: str, **values) -> None:
        """Counter-track sample ("ph": "C"): numeric series over time."""
        if not self.enabled:
            return
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(("C", name, self.now(), 0.0,
                              threading.get_ident(), values))

    def sync(self, x) -> None:
        """``jax.block_until_ready(x)`` only when tracing is enabled, so
        spans around device programs measure the program, not the
        dispatch — and the untraced hot path never syncs."""
        if self.enabled and x is not None:
            import jax
            jax.block_until_ready(x)

    # ---- reading ---------------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Snapshot of recorded "X" spans (optionally filtered by name)."""
        with self._lock:
            rows = list(self._buf)
        return [Span(n, t0, dur, tid, args)
                for ph, n, t0, dur, tid, args in rows
                if ph == "X" and (name is None or n == name)]

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    # ---- export ----------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome trace format: {"traceEvents": [...]} in microseconds."""
        with self._lock:
            rows = list(self._buf)
        events = []
        tids = {}
        for ph, name, t0, dur, tid, args in rows:
            tids.setdefault(tid, len(tids))
            ev = dict(name=name, ph=ph, ts=round(t0 * 1e6, 3),
                      pid=self.pid, tid=tids[tid], cat="repro")
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            if args:
                ev["args"] = {k: _jsonable(v) for k, v in args.items()}
            events.append(ev)
        # thread-name metadata so Perfetto labels the tracks
        meta = [dict(name="thread_name", ph="M", pid=self.pid, tid=i,
                     args={"name": f"thread-{i}"})
                for i in sorted(tids.values())]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def _jsonable(v):
    """Coerce numpy/jax scalars so the trace always json-serializes."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    try:
        return v.item()          # 0-d numpy / jax scalar
    except Exception:
        return str(v)


# ---------------------------------------------------------------------------
# process-global tracer: disabled by default (zero overhead); the launch
# drivers enable it behind --trace
# ---------------------------------------------------------------------------

_TRACER = Tracer(enabled=False)
_TRACE_PATH: Optional[str] = None


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-global tracer; returns the previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def start_tracing(path: Optional[str] = None,
                  capacity: int = 65536) -> Tracer:
    """Enable the global tracer (fresh buffer); remember ``path`` for
    ``stop_tracing`` to write the Chrome-trace JSON to."""
    global _TRACE_PATH
    _TRACE_PATH = path
    set_tracer(Tracer(capacity=capacity, enabled=True))
    return _TRACER


def stop_tracing(write: bool = True) -> Optional[str]:
    """Disable the global tracer; write the trace if a path was given."""
    global _TRACE_PATH
    tracer, path = _TRACER, _TRACE_PATH
    out = None
    if write and path is not None and tracer.enabled:
        out = tracer.write(path)
    tracer.enabled = False
    _TRACE_PATH = None
    return out


@contextmanager
def tracing(path: Optional[str] = None,
            capacity: int = 65536) -> Iterator[Tracer]:
    """``with tracing("t.json") as tr: ...`` — scoped global tracing."""
    prev = set_tracer(Tracer(capacity=capacity, enabled=True))
    try:
        yield _TRACER
    finally:
        if path is not None:
            _TRACER.write(path)
        set_tracer(prev)


def span(name: str, **args):
    """Span on the process-global tracer (no-op unless tracing is on)."""
    return _TRACER.span(name, **args)


def traced(name: Optional[str] = None):
    """Decorator: trace every call of ``fn`` as one span."""

    def wrap(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def inner(*a, **kw):
            tr = _TRACER
            if not tr.enabled:
                return fn(*a, **kw)
            with tr.span(label):
                return fn(*a, **kw)

        return inner

    return wrap
