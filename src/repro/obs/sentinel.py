"""On-device invariant sentinels: cheap per-batch correctness checks.

The serving engine publishes a new f64 rank vector every micro-batch;
this module checks, in ONE fused device program per batch, the
invariants any correct DF/DF-P fixed point must satisfy:

  * **mass**   — with the paper's implicit self-loop on every vertex
    there are no dangling vertices, so the true fixed point has
    ``sum(ranks) == 1``.  A converged solve with L∞ residual δ can be
    off by at most ``δ·V/(1-α)``, which bounds the honest tolerance;
    a rank corruption at a vertex the next frontier never touches (the
    DF blind spot) shows up here immediately and forever.
  * **nonnegativity / finiteness** — ranks are probabilities; a NaN or
    negative entry means the update rule itself was violated
    (f32-ladder underflow, bad maintenance, memory corruption).
  * **residual** — the solve claims convergence; its final L∞ delta
    must actually be ≤ the configured ceiling (``max_iter`` exits are
    the one legitimate way to land above the loop tolerance, and they
    deserve an incident).
  * **anomaly scores** — iteration count and affected-set size per
    batch are scored against an exponentially-weighted running
    baseline (EWMA mean/variance).  These are *warnings*: they catch
    "the stream changed shape" (event corruption, feed bugs, capacity
    cliffs) that no algebraic invariant sees.

The same program also produces the **rank digest**: the int64 bit
pattern of every f64 rank folded into one position-weighted wrapping
sum.  Equal digests ⇒ bit-identical rank vectors (up to the vanishing
probability of a weighted-sum collision); the digest is what the
flight recorder stores and what replay diffs against, so "reproduced
bit-for-bit" is a single integer comparison per batch.

Violations become structured ``Incident`` records (JSON-able via
``as_dict``), plus a trace instant on the global tracer; per-batch
gauges (mass error, min rank, anomaly z-scores) flow through
``ServeMetrics.set_gauge`` so the Prometheus exporter sees them.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Incident", "SentinelConfig", "InvariantSentinel",
           "rank_digest"]

# incident severities: "error" trips the flight-recorder dump,
# "warn" is recorded and exported but does not dump a bundle
ERROR = "error"
WARN = "warn"


@jax.jit
def _digest_and_stats(ranks: jax.Array):
    """One device program: digest + (mass, min, all-finite) scalars."""
    r = ranks.astype(jnp.float64)
    bits = jax.lax.bitcast_convert_type(r, jnp.int64)
    # position-weighted wrapping sum: permutation- and bit-sensitive,
    # while staying a single O(V) reduction (odd weights keep every
    # position's contribution invertible mod 2^64)
    idx = jnp.arange(bits.shape[0], dtype=jnp.int64)
    digest = jnp.sum(bits * (2 * idx + 1))
    return digest, jnp.sum(r), jnp.min(r), jnp.all(jnp.isfinite(r))


def rank_digest(ranks: jax.Array) -> int:
    """int64 digest of the exact bit pattern of an f64 rank vector."""
    return int(_digest_and_stats(jnp.asarray(ranks))[0])


@dataclasses.dataclass(frozen=True)
class Incident:
    """One structured invariant violation (DESIGN.md §12 schema)."""

    kind: str          # e.g. "rank_mass", "shadow_l1", "slo_burn"
    severity: str      # "error" | "warn"
    generation: int    # snapshot generation the violation was seen at
    last_seq: int      # newest ingest seq folded into that snapshot
    value: float       # the measured quantity
    threshold: float   # the bound it violated
    message: str       # human-readable one-liner
    t: float           # wall-clock time of detection

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SentinelConfig:
    """Tolerances for the per-batch invariant checks.

    ``mass_tol`` defaults to the loose end of the honest bound
    ``tol·V/(1-α)`` for the serving defaults (tol=1e-10, α=0.85): at
    V=1e6 that is ≈6.7e-4, so 1e-3 never false-positives on a converged
    solve while catching any single-vertex corruption ≳1e-3.
    """

    mass_tol: float = 1e-3
    residual_tol: float = 1e-6      # ceiling on the solve's final delta
    negative_tol: float = 0.0       # min rank must be >= -negative_tol
    anomaly_z: float = 8.0          # z-score that trips a warn incident
    anomaly_warmup: int = 16        # batches before anomaly scoring arms
    ewma_alpha: float = 0.1         # baseline update rate


class _Ewma:
    """EWMA mean/variance with a warmup gate; yields z-scores."""

    __slots__ = ("alpha", "mean", "var", "count")

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.mean = 0.0
        self.var = 0.0
        self.count = 0

    def score(self, x: float) -> float:
        """z-score of ``x`` against the current baseline (0.0 during
        warmup), then folds ``x`` into the baseline."""
        z = 0.0
        if self.count > 0:
            sd = math.sqrt(max(self.var, 1e-12))
            z = abs(x - self.mean) / sd if self.count > 1 else 0.0
        diff = x - self.mean
        incr = self.alpha * diff
        self.mean += incr
        self.var = (1.0 - self.alpha) * (self.var + diff * incr)
        self.count += 1
        return z


class InvariantSentinel:
    """Per-batch invariant checks over the published snapshot.

    ``observe`` returns ``(digest, incidents)``; gauges land in
    ``self.gauges`` (a plain dict the monitor forwards to
    ``ServeMetrics``) and a trace instant is emitted per incident.
    """

    def __init__(self, config: Optional[SentinelConfig] = None,
                 clock=time.time):
        self.config = config or SentinelConfig()
        self._clock = clock
        self._iters = _Ewma(self.config.ewma_alpha)
        self._affected = _Ewma(self.config.ewma_alpha)
        self.batches = 0
        self.trips = 0
        self.gauges: dict = {}

    def observe(self, *, generation: int, last_seq: int, ranks: jax.Array,
                delta: float, iterations: int, affected: int,
                fallback: bool) -> Tuple[int, List[Incident]]:
        cfg = self.config
        digest, mass, rmin, finite = _digest_and_stats(ranks)
        digest = int(digest)
        mass = float(mass)
        rmin = float(rmin)
        finite = bool(finite)
        now = self._clock()
        incidents: List[Incident] = []

        def trip(kind, severity, value, threshold, message):
            incidents.append(Incident(kind, severity, int(generation),
                                      int(last_seq), float(value),
                                      float(threshold), message, now))

        if not finite:
            trip("rank_nonfinite", ERROR, float("nan"), 0.0,
                 "published ranks contain NaN/Inf")
        else:
            mass_err = abs(mass - 1.0)
            if mass_err > cfg.mass_tol:
                trip("rank_mass", ERROR, mass_err, cfg.mass_tol,
                     f"rank mass {mass:.12f} drifted from 1 by "
                     f"{mass_err:.3e}")
            if rmin < -cfg.negative_tol:
                trip("rank_negative", ERROR, rmin, -cfg.negative_tol,
                     f"negative rank {rmin:.3e} in published snapshot")
        if delta > cfg.residual_tol:
            trip("residual", ERROR, delta, cfg.residual_tol,
                 f"solve left L-inf residual {delta:.3e} above "
                 f"{cfg.residual_tol:.1e} (max_iter exit?)")
        # anomaly scoring: static-fallback batches are legitimately
        # shaped nothing like the dynamic baseline, so they neither
        # score nor pollute the EWMA
        z_it = z_af = 0.0
        if not fallback:
            armed = self._iters.count >= cfg.anomaly_warmup
            z_it = self._iters.score(float(iterations))
            z_af = self._affected.score(float(affected))
            if armed:
                if z_it > cfg.anomaly_z:
                    trip("anomaly_iterations", WARN, z_it, cfg.anomaly_z,
                         f"iteration count {iterations} is {z_it:.1f} "
                         f"sigma from the EWMA baseline "
                         f"{self._iters.mean:.1f}")
                if z_af > cfg.anomaly_z:
                    trip("anomaly_affected", WARN, z_af, cfg.anomaly_z,
                         f"affected-set size {affected} is {z_af:.1f} "
                         f"sigma from the EWMA baseline "
                         f"{self._affected.mean:.1f}")

        self.batches += 1
        self.trips += len(incidents)
        self.gauges = {
            "sentinel_rank_mass_err": abs(mass - 1.0) if finite
            else float("inf"),
            "sentinel_rank_min": rmin,
            "sentinel_residual": float(delta),
            "sentinel_anomaly_iterations_z": z_it,
            "sentinel_anomaly_affected_z": z_af,
            "sentinel_trips": float(self.trips),
        }
        return digest, incidents
