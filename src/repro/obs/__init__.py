"""repro.obs — end-to-end observability for the serving stack.

Two halves (DESIGN.md §11 performance, §12 correctness):

**Performance** —
  * ``trace``    — a low-overhead, thread-safe span tracer with
    Chrome-trace-format export (``chrome://tracing`` / Perfetto) and the
    ``timeit`` micro-helper, the one host-timing idiom;
  * ``frontier`` — the per-iteration convergence-telemetry schema the
    XLA and kernel engine loops record when asked (``telemetry=True``):
    affected count, L∞ residual, frontier growth/prune, active work
    units per iteration as a compact ``[iters, k]`` array;
  * ``export``   — Prometheus-text and JSON-lines exporters plus a tiny
    scrape server over ``ServeMetrics``.

**Correctness** —
  * ``sentinel`` — per-batch on-device invariant checks (rank mass,
    nonnegativity, residual, EWMA anomaly scores) and the bitwise rank
    digest; violations become structured ``Incident`` records;
  * ``shadow``   — sampled background verification of every Kth
    snapshot against the f64 XLA reference solve (live DF-P drift);
  * ``recorder`` — a flight recorder (batch ring + checkpoint anchors)
    with deterministic bit-for-bit ``replay``;
  * ``slo``      — SLO objectives with multi-window burn-rate alerts;
  * ``monitor``  — ``CorrectnessMonitor``, the facade ``ServeEngine``
    drives (``ServeEngine(..., monitor=...)``).

Tracing and telemetry are **off by default and free when off**: the
global tracer is disabled (spans are shared no-op context managers, no
device syncs), and the loops' ``telemetry`` flag is static, so the
untraced hot path compiles to the identical device-program schedule.
The correctness monitor is opt-in per engine and adds one fused
invariant program per batch; the shadow solve runs off the hot path.
"""
from repro.obs.export import JsonlSink, MetricsExporter, prometheus_text
from repro.obs.frontier import FIELDS as TELEMETRY_FIELDS
from repro.obs.frontier import NUM_FIELDS as TELEMETRY_NUM_FIELDS
from repro.obs.frontier import FrontierTelemetry
from repro.obs.trace import (Tracer, get_tracer, set_tracer, span,
                             start_tracing, stop_tracing, traced, tracing,
                             timeit)
from repro.obs.sentinel import (Incident, InvariantSentinel,
                                SentinelConfig, rank_digest)
from repro.obs.shadow import ShadowReport, ShadowVerifier
from repro.obs.slo import BurnRateAlert, SloSet, SloTracker
from repro.obs.recorder import (BatchRecord, FlightRecorder, ReplayReport,
                                load_bundle, replay)
from repro.obs.monitor import CorrectnessMonitor, MonitorConfig

__all__ = [
    "BatchRecord", "BurnRateAlert", "CorrectnessMonitor",
    "FlightRecorder", "FrontierTelemetry", "Incident",
    "InvariantSentinel", "JsonlSink", "MetricsExporter", "MonitorConfig",
    "ReplayReport", "SentinelConfig", "ShadowReport", "ShadowVerifier",
    "SloSet", "SloTracker", "Tracer", "TELEMETRY_FIELDS",
    "TELEMETRY_NUM_FIELDS", "get_tracer", "load_bundle",
    "prometheus_text", "rank_digest", "replay", "set_tracer", "span",
    "start_tracing", "stop_tracing", "traced", "tracing", "timeit",
]
