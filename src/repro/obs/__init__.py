"""repro.obs — end-to-end observability for the serving stack.

Three pieces (DESIGN.md §11):

  * ``trace``    — a low-overhead, thread-safe span tracer with
    Chrome-trace-format export (``chrome://tracing`` / Perfetto) and the
    ``timeit`` micro-helper, the one host-timing idiom;
  * ``frontier`` — the per-iteration convergence-telemetry schema the
    XLA and kernel engine loops record when asked (``telemetry=True``):
    affected count, L∞ residual, frontier growth/prune, active work
    units per iteration as a compact ``[iters, k]`` array;
  * ``export``   — Prometheus-text and JSON-lines exporters plus a tiny
    scrape server over ``ServeMetrics``.

Tracing and telemetry are **off by default and free when off**: the
global tracer is disabled (spans are shared no-op context managers, no
device syncs), and the loops' ``telemetry`` flag is static, so the
untraced hot path compiles to the identical device-program schedule.
"""
from repro.obs.export import JsonlSink, MetricsExporter, prometheus_text
from repro.obs.frontier import FIELDS as TELEMETRY_FIELDS
from repro.obs.frontier import NUM_FIELDS as TELEMETRY_NUM_FIELDS
from repro.obs.frontier import FrontierTelemetry
from repro.obs.trace import (Tracer, get_tracer, set_tracer, span,
                             start_tracing, stop_tracing, traced, tracing,
                             timeit)

__all__ = [
    "FrontierTelemetry", "JsonlSink", "MetricsExporter", "Tracer",
    "TELEMETRY_FIELDS", "TELEMETRY_NUM_FIELDS", "get_tracer",
    "prometheus_text", "set_tracer", "span", "start_tracing",
    "stop_tracing", "traced", "tracing", "timeit",
]
