"""SLO objectives with multi-window burn-rate alerting.

An SLO here is "fraction of good events ≥ objective" over a rolling
window — e.g. 99% of batches publish under the latency ceiling, 99% of
shadow samples stay inside the error budget.  The *error budget* is
``1 - objective``; the **burn rate** over a window is::

    burn = (bad events / total events in window) / error_budget

so burn 1.0 exactly exhausts the budget if sustained, and burn 14.4
over an hour eats a 30-day budget in ~2 days — the classic SRE
multi-window multi-burn-rate alerting rule.  An alert fires only when
BOTH a long window and its short companion (long/12 by convention)
exceed the threshold: the long window gives significance, the short
window makes the alert reset quickly once the system recovers.

``SloTracker`` is deliberately tiny: a deque of (t, bad) samples
pruned to the longest window, exact counts per window (no buckets —
serving pushes a few dozen events/s at most), burn rates, and
edge-triggered ``BurnRateAlert``s.  ``SloSet`` groups the serving
objectives and renders everything as gauges for the existing
``MetricsExporter`` (``repro_slo_<name>_burn_<window>s`` etc.).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

__all__ = ["BurnRateAlert", "SloTracker", "SloSet"]

# (long_window_seconds, burn_rate_threshold) pairs; the short window is
# long/12.  Defaults are scaled for minutes-long serve runs rather than
# the 30-day SRE horizon — the *arithmetic* is identical.
DEFAULT_WINDOWS: Tuple[Tuple[float, float], ...] = (
    (60.0, 14.4), (300.0, 6.0))
SHORT_DIVISOR = 12.0


class BurnRateAlert(NamedTuple):
    slo: str                # tracker name
    long_window_s: float
    short_window_s: float
    burn_long: float
    burn_short: float
    threshold: float
    t: float


class SloTracker:
    """Rolling good/bad ledger for one objective."""

    def __init__(self, name: str, objective: float = 0.99,
                 windows: Sequence[Tuple[float, float]] = DEFAULT_WINDOWS,
                 min_events: int = 12, clock=time.monotonic):
        if not 0.0 < objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        self.name = name
        self.objective = objective
        self.budget = 1.0 - objective
        self.windows = tuple(windows)
        # significance gate: a window alerts only once it holds this
        # many samples, so the first (compile-heavy) batches of a run
        # cannot trip a burn alert on one bad event out of one
        self.min_events = min_events
        self._clock = clock
        self._horizon = max(w for w, _ in self.windows)
        self._events: deque = deque()     # (t, bad) with t monotone
        self.total = 0
        self.bad = 0

    def record(self, good: bool) -> None:
        now = self._clock()
        self._events.append((now, not good))
        self.total += 1
        self.bad += int(not good)
        cutoff = now - self._horizon
        while self._events and self._events[0][0] < cutoff:
            self._events.popleft()

    def counts(self, window_s: float) -> Tuple[int, int]:
        """(total, bad) events inside the trailing window."""
        cutoff = self._clock() - window_s
        total = bad = 0
        for t, is_bad in reversed(self._events):
            if t < cutoff:
                break
            total += 1
            bad += int(is_bad)
        return total, bad

    def burn_rate(self, window_s: float) -> float:
        total, bad = self.counts(window_s)
        if total == 0:
            return 0.0
        return (bad / total) / self.budget

    def evaluate(self) -> List[BurnRateAlert]:
        """Alerts currently firing (long AND short window over threshold)."""
        now = self._clock()
        alerts = []
        for long_w, thr in self.windows:
            short_w = long_w / SHORT_DIVISOR
            total, bad = self.counts(long_w)
            if total < self.min_events or total == 0:
                continue
            bl = (bad / total) / self.budget
            if bl < thr:
                continue
            bs = self.burn_rate(short_w)
            if bs >= thr:
                alerts.append(BurnRateAlert(self.name, long_w, short_w,
                                            bl, bs, thr, now))
        return alerts

    def gauges(self) -> dict:
        g = {f"slo_{self.name}_bad_total": float(self.bad)}
        for long_w, _ in self.windows:
            g[f"slo_{self.name}_burn_{int(long_w)}s"] = \
                self.burn_rate(long_w)
        return g


class SloSet:
    """The serving stack's SLOs as one evaluable group."""

    def __init__(self, trackers: Dict[str, SloTracker]):
        self.trackers = trackers
        # alert keys (slo, long_window) currently active, for
        # edge-triggered incident emission by the monitor
        self._active: set = set()

    @classmethod
    def serving(cls, *, latency_objective: float = 0.99,
                staleness_objective: float = 0.99,
                shadow_objective: float = 0.99,
                windows: Sequence[Tuple[float, float]] = DEFAULT_WINDOWS,
                min_events: int = 12, clock=time.monotonic) -> "SloSet":
        """The three objectives of DESIGN.md §12: publish latency,
        query-visible staleness (in events), shadow error budget."""
        mk = lambda name, obj: SloTracker(                            # noqa
            name, obj, windows, min_events=min_events, clock=clock)
        return cls({
            "latency": mk("latency", latency_objective),
            "staleness": mk("staleness", staleness_objective),
            "shadow": mk("shadow", shadow_objective),
        })

    def record(self, name: str, good: bool) -> None:
        self.trackers[name].record(good)

    def evaluate(self) -> List[BurnRateAlert]:
        """Newly-firing alerts since the previous evaluation (edges)."""
        firing = [a for t in self.trackers.values() for a in t.evaluate()]
        keys = {(a.slo, a.long_window_s) for a in firing}
        new = [a for a in firing
               if (a.slo, a.long_window_s) not in self._active]
        self._active = keys
        return new

    def gauges(self) -> dict:
        g: dict = {"slo_alerts_active": float(len(self._active))}
        for t in self.trackers.values():
            g.update(t.gauges())
        return g


def percentile(values: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]); None on empty input."""
    if not values:
        return None
    xs = sorted(values)
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * len(xs) + 0.5)) - 1))
    return xs[k]
