"""Exportable metrics: Prometheus text format, JSON lines, and a tiny
scrape server for the serving stack.

``prometheus_text`` renders any flat metrics dict (the shape
``ServeMetrics.as_dict`` produces) as Prometheus exposition format:
numeric values become gauges, nested dicts become labeled series
(``packed_rebuilds_by_shard`` → ``repro_packed_rebuilds_by_shard
{shard="3"} 2``), and non-numeric values are skipped.  Keys are assumed
snake_case (the ``as_dict`` contract) and are prefixed with ``repro_``.

``JsonlSink`` appends one JSON object per line — the machine-readable
feed for per-batch records (metrics snapshots, frontier-telemetry
trajectories) that a log shipper or notebook can tail.

``MetricsExporter`` ties both to a live ``ServeMetrics`` (+ optionally
the serve engine, for gauges that live on engine attributes: halo
occupancy, tuned geometry, comm accounting): ``scrape()`` returns the
Prometheus text, ``write(path)`` dumps it, and ``serve(port)`` runs a
daemon HTTP server answering ``GET /metrics`` (Prometheus) and
``GET /metrics.json`` (the raw dict) — ``port=0`` picks an ephemeral
port, exposed as ``.port`` for tests.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["prometheus_text", "JsonlSink", "MetricsExporter"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _metric_name(key: str, prefix: str) -> str:
    return prefix + _NAME_RE.sub("_", str(key))


def _num(v) -> Optional[float]:
    """Coerce to float if numeric (incl. numpy/bool), else None."""
    if isinstance(v, bool):
        return float(v)
    if isinstance(v, (int, float)):
        return float(v)
    if isinstance(v, (np.integer, np.floating)):
        return float(v)
    return None


def prometheus_text(metrics: dict, prefix: str = "repro_",
                    help_text: Optional[dict] = None) -> str:
    """Render a metrics dict as Prometheus exposition text (gauges).

    * numeric value → ``<prefix><key> <value>``
    * dict value    → one labeled sample per entry:
      ``<prefix><key>{key="<k>"} <value>`` (shard maps, per-phase times)
    * anything else → skipped (strings are descriptions, not samples)
    """
    lines = []
    for key in sorted(metrics):
        value = metrics[key]
        name = _metric_name(key, prefix)
        if isinstance(value, dict):
            samples = [(str(k), _num(v)) for k, v in sorted(value.items())]
            samples = [(k, v) for k, v in samples if v is not None]
            if not samples:
                continue
            if help_text and key in help_text:
                lines.append(f"# HELP {name} {help_text[key]}")
            lines.append(f"# TYPE {name} gauge")
            for k, v in samples:
                lines.append(f'{name}{{key="{k}"}} {v:g}')
            continue
        v = _num(value)
        if v is None:
            continue
        if help_text and key in help_text:
            lines.append(f"# HELP {name} {help_text[key]}")
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {v:g}")
    return "\n".join(lines) + "\n"


class JsonlSink:
    """Append-only JSON-lines writer (one flush per record, so a killed
    serve process loses at most the in-flight line).

    ``max_bytes`` caps on-disk growth with logrotate-style rotation:
    when appending a line would push the file past the cap, the sink
    shifts ``path.1 -> path.2 -> ...`` (dropping ``path.<backups>``),
    renames ``path`` to ``path.1`` and starts fresh — a serve process
    left running for days keeps at most ``(backups + 1) * max_bytes``
    of telemetry.  ``backups=0`` truncates instead of keeping history.
    ``max_bytes=None`` (the default) preserves the unbounded append
    behaviour for short runs.
    """

    def __init__(self, path: str, clock=time.time,
                 max_bytes: Optional[int] = None, backups: int = 3):
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be positive (or None)")
        self.path = path
        self.max_bytes = max_bytes
        self.backups = backups
        self.rotations = 0
        self._clock = clock
        self._lock = threading.Lock()
        self._f = open(path, "a")
        self._size = self._f.tell()

    def write(self, record: dict, kind: Optional[str] = None) -> None:
        row = dict(record)
        if kind is not None:
            row["kind"] = kind
        row.setdefault("t", self._clock())
        line = json.dumps(row, default=_default) + "\n"
        with self._lock:
            if self._f.closed:
                return
            if (self.max_bytes is not None and self._size > 0
                    and self._size + len(line) > self.max_bytes):
                self._rotate_locked()
            self._f.write(line)
            self._f.flush()
            self._size += len(line)

    def _rotate_locked(self) -> None:
        self._f.close()
        if self.backups > 0:
            for i in range(self.backups - 1, 0, -1):
                src = f"{self.path}.{i}"
                if os.path.exists(src):
                    os.replace(src, f"{self.path}.{i + 1}")
            os.replace(self.path, f"{self.path}.1")
        self._f = open(self.path, "w")
        self._size = 0
        self.rotations += 1

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


def _default(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    return str(v)


class MetricsExporter:
    """Live exporter over a ``ServeMetrics`` (+ optional engine gauges).

    ``extra`` is a zero-arg callable returning a dict merged into every
    collection — the serve engine passes one exposing its
    engine-attribute gauges (halo occupancy, tuned geometry, comm info)
    so nothing reportable lives only on a Python object.
    """

    def __init__(self, metrics, extra: Optional[Callable[[], dict]] = None,
                 prefix: str = "repro_"):
        self.metrics = metrics
        self.extra = extra
        self.prefix = prefix
        self._httpd = None
        self._thread = None
        self.port: Optional[int] = None

    def collect(self) -> dict:
        d = dict(self.metrics.as_dict())
        if self.extra is not None:
            d.update(self.extra())
        return d

    def scrape(self) -> str:
        return prometheus_text(self.collect(), prefix=self.prefix)

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.scrape())
        return path

    # ---- scrape server ---------------------------------------------------
    def serve(self, port: int = 0, host: str = "127.0.0.1") -> int:
        """Start a daemon HTTP scrape server; returns the bound port.

        ``port=0`` binds an ephemeral port (read it from the return
        value or ``.port``), so parallel tests and co-located serve
        processes never collide.  Calling ``serve`` twice without a
        ``close`` in between is an error, and ``close`` is idempotent —
        the exporter also works as a context manager.
        """
        if self._httpd is not None:
            raise RuntimeError(
                f"exporter already serving on port {self.port}; "
                f"close() it first")
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        exporter = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                if self.path.startswith("/metrics.json"):
                    body = json.dumps(exporter.collect(),
                                      default=_default).encode()
                    ctype = "application/json"
                elif self.path.startswith("/metrics"):
                    body = exporter.scrape().encode()
                    ctype = "text/plain; version=0.0.4"
                else:
                    self.send_response(404)
                    self.end_headers()
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                 # quiet scrapes
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-exporter",
                                        daemon=True)
        self._thread.start()
        return self.port

    def close(self) -> None:
        """Stop the scrape server and release the port (idempotent)."""
        httpd, self._httpd = self._httpd, None
        if httpd is not None:
            httpd.shutdown()
            httpd.server_close()
            if self._thread is not None:
                self._thread.join(timeout=5.0)
            self._thread = None
            self.port = None

    def __enter__(self) -> "MetricsExporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
