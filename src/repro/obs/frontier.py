"""Per-iteration convergence telemetry for the DF/DF-P loops.

The paper's whole claim is about the *trajectory* of the affected set —
how the frontier seeds, grows, prunes and dies per iteration — yet the
engines historically returned only endpoint scalars (iterations, final
delta).  This module fixes the schema: every engine loop, when asked
(``telemetry=True``, a static jit flag), carries a compact
``[max_iter, NUM_FIELDS]`` float row buffer through its ``while_loop``
and writes one row per iteration:

  ========== ============================================================
  column      meaning (per iteration, before the frontier update)
  ========== ============================================================
  affected    |affected| entering the iteration — the vertices whose
              rank the sweep recomputes (the paper's work proxy and the
              touched-mass signal of Rossi & Gleich / Jayaram et al.)
  residual    L∞ rank change over the affected set this iteration
  grew        vertices newly marked by frontier expansion (net:
              ``|new \\ old|``)
  pruned      vertices dropped by DF-P contraction (net: ``|old \\ new|``)
  active      engine-granularity work units gated on this iteration:
              active *windows* for the Pallas kernel loops, affected
              *vertices* for the XLA loop (its gating granularity)
  ========== ============================================================

The buffer rides loop state, so telemetry costs **zero extra device
programs** — it changes the compiled program (one more carried array and
a ``dynamic_update_slice`` per iteration) but not the program *count*,
and with ``telemetry=False`` the loops trace exactly the PR-6 program.
Host transfer happens only when a caller trims the padded buffer
(``FrontierTelemetry.from_padded``), i.e. only when tracing is on.

The XLA loop records rows in f64, the kernel loops in f32; counts are
exact in both up to 2^24 vertices and ``FrontierTelemetry`` normalizes
to f64 numpy.  ``affected`` and ``residual`` are engine-comparable: the
parity tests assert they match between the XLA and kernel engines on
the harness graphs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

FIELDS = ("affected", "residual", "grew", "pruned", "active")
NUM_FIELDS = len(FIELDS)
_IDX = {name: i for i, name in enumerate(FIELDS)}

__all__ = ["FIELDS", "NUM_FIELDS", "FrontierTelemetry", "telemetry_row"]


def telemetry_row(affected, residual, grew, pruned, active, dtype):
    """Build one ``[NUM_FIELDS]`` row inside a loop body (jax code).

    Kept here so the engine loops and this schema can never drift: the
    column order is defined once.
    """
    import jax.numpy as jnp
    return jnp.stack([affected.astype(dtype), residual.astype(dtype),
                      grew.astype(dtype), pruned.astype(dtype),
                      active.astype(dtype)])


class FrontierTelemetry(NamedTuple):
    """Trimmed, host-side telemetry: ``data`` is f64 ``[iters, k]``."""

    data: np.ndarray

    @classmethod
    def from_padded(cls, padded, iterations) -> "FrontierTelemetry":
        """Trim a loop's padded ``[max_iter, k]`` buffer to the rows the
        solve actually executed (this is the only device transfer the
        telemetry path performs)."""
        n = int(iterations)
        arr = np.asarray(padded, np.float64)[:n]
        return cls(np.ascontiguousarray(arr))

    @classmethod
    def concat(cls, *parts: "FrontierTelemetry") -> "FrontierTelemetry":
        """Stack phase trajectories (e.g. f32 kernel sweep + f64 polish)
        into one per-batch trajectory, in execution order."""
        rows = [p.data for p in parts if p is not None and len(p.data)]
        if not rows:
            return cls(np.zeros((0, NUM_FIELDS), np.float64))
        return cls(np.concatenate(rows, axis=0))

    @property
    def iterations(self) -> int:
        return int(self.data.shape[0])

    def column(self, name: str) -> np.ndarray:
        return self.data[:, _IDX[name]]

    def summary(self) -> dict:
        """Scalar digest for metrics/trace args (JSON-safe floats)."""
        if not len(self.data):
            return dict(iterations=0)
        aff = self.column("affected")
        res = self.column("residual")
        return dict(
            iterations=self.iterations,
            affected_initial=float(aff[0]),
            affected_peak=float(aff.max()),
            affected_final=float(aff[-1]),
            residual_initial=float(res[0]),
            residual_final=float(res[-1]),
            grew_total=float(self.column("grew").sum()),
            pruned_total=float(self.column("pruned").sum()),
            active_mean=float(self.column("active").mean()),
        )

    def rows(self) -> list:
        """Per-iteration dicts (the JSONL exporter's record shape)."""
        return [dict(zip(FIELDS, map(float, r))) for r in self.data]


def combine(kernel_tel: Optional[FrontierTelemetry],
            polish_tel: Optional[FrontierTelemetry]
            ) -> Optional[FrontierTelemetry]:
    """Hybrid-ladder helper: kernel phase then polish phase, or None if
    neither phase recorded anything."""
    if kernel_tel is None and polish_tel is None:
        return None
    return FrontierTelemetry.concat(
        *(p for p in (kernel_tel, polish_tel) if p is not None))
