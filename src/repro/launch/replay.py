"""Replay an incident bundle recorded by the serving flight recorder.

Re-executes every batch in the bundle from its checkpoint anchor
through a freshly-constructed ``ServeEngine`` (same method, engine and
pack geometry as the recording) and diffs each published snapshot's
rank digest — and the engine's method/fallback decisions — against
what the live engine recorded.  On a deterministic backend the replay
is **bit-for-bit** (DESIGN.md §12); any mismatch localises the first
divergent generation.

    PYTHONPATH=src python -m repro.launch.replay /path/to/bundle

Exit status: 0 when every batch reproduced bit-for-bit, 1 otherwise
(also under ``--strict`` when the bundle carries no batches).  Bundles
are written by ``CorrectnessMonitor`` on the first error-severity
incident (``MonitorConfig.incident_dir``) or manually via
``FlightRecorder.dump()``.
"""
from __future__ import annotations

import argparse
import json
import os

import repro  # noqa: F401  (enables x64 — digests are f64 bit patterns)
from repro.obs.recorder import load_bundle, replay


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="deterministically re-execute a recorded serving "
                    "window and verify it bit-for-bit")
    ap.add_argument("bundle", help="incident bundle directory "
                                   "(manifest.json + anchor/ + records.npz)")
    ap.add_argument("--end-gen", type=int, default=None,
                    help="replay only generations <= this")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on an empty replay window too")
    ap.add_argument("--json", default="",
                    help="write the per-step report as JSON here")
    args = ap.parse_args(argv)

    cfg, anchor_gen, _, _, records, incident = load_bundle(args.bundle)
    print(f"bundle {os.path.abspath(args.bundle)}: "
          f"method={cfg.get('method')} engine={cfg.get('engine')} "
          f"anchor=gen{anchor_gen} records={len(records)}")
    if incident:
        print(f"recorded incident: [{incident.get('severity')}] "
              f"{incident.get('kind')} at gen "
              f"{incident.get('generation')} — {incident.get('message')}")

    report = replay(args.bundle, end_gen=args.end_gen)
    print(report.describe())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dict(anchor_generation=report.anchor_generation,
                           ok=report.ok,
                           steps=[s._asdict() for s in report.steps]),
                      f, indent=1)
        print(f"report written to {args.json}")
    if not report.steps:
        print("replay window is empty")
        return 1 if args.strict else 0
    if report.ok:
        print(f"replay ok: {report.num_bitwise}/{len(report.steps)} "
              f"batches bit-for-bit")
        return 0
    print("REPLAY DIVERGED from the recorded digests")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
