"""Chaos-drive the read-replica tier and prove recovery to parity.

Runs the seeded ``ChaosHarness`` (serve/chaos.py): one ``ServeEngine``
writer, N ``ReadReplica``s on a fault-injectable transport, a
declarative kill/partition/delay schedule keyed to event offsets, and a
writer-parity assertion (L∞ ≤ 1e-6 at equal generation) after every
recovery point.  Exit status 0 only when every parity check passed.

    PYTHONPATH=src python -m repro.launch.replicate \\
        --replicas 2 --events 1200 --drop 0.05 --seed 7 \\
        --schedule "partition:r1@300+200;kill:r0@600+200;kill_writer@900"

Schedule grammar: ``kind[:target]@at[+duration]`` semicolon-separated,
kinds ``kill`` / ``partition`` / ``delay`` (with a target replica) and
``kill_writer`` (heartbeat failover).  The printed incident lines
(``replica_resync``, ``slo_burn``, ``writer_failover``) are what the CI
chaos lane greps for.
"""
from __future__ import annotations

import argparse
import json
import sys

import repro  # noqa: F401  (enables x64 — replicated ranks are f64)
from repro.serve.chaos import ChaosHarness


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="chaos-test the replicated serving tier")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--events", type=int, default=1200,
                    help="length of the seeded edge-event feed")
    ap.add_argument("--schedule", default="",
                    help="chaos schedule, e.g. "
                         "'partition:r1@300+200;kill_writer@900'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=int, default=9,
                    help="RMAT scale of the bootstrap graph (V = 2^scale)")
    ap.add_argument("--drop", type=float, default=0.0,
                    help="per-message drop probability")
    ap.add_argument("--dup", type=float, default=0.0,
                    help="per-message duplicate probability")
    ap.add_argument("--reorder", type=float, default=0.0,
                    help="per-message reorder (extra delay) probability")
    ap.add_argument("--staleness-slo", type=int, default=256,
                    help="replica staleness SLO in events (degradation "
                         "threshold)")
    ap.add_argument("--ckpt-dir", default="",
                    help="writer RankStore checkpoint directory (failover "
                         "consults the last committed step)")
    ap.add_argument("--method", default="frontier_prune")
    ap.add_argument("--json", default="",
                    help="write the chaos report as JSON here")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the per-recovery narration")
    args = ap.parse_args(argv)

    harness = ChaosHarness(
        num_replicas=args.replicas, events=args.events,
        schedule=args.schedule, seed=args.seed, scale=args.scale,
        drop_p=args.drop, dup_p=args.dup, reorder_p=args.reorder,
        staleness_slo_events=args.staleness_slo,
        ckpt_dir=args.ckpt_dir or None, method=args.method,
        verbose=not args.quiet)
    try:
        report = harness.run()
    except AssertionError as e:
        print(f"PARITY FAILURE: {e}", flush=True)
        return 1
    for line in report.lines():
        print(line, flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dataclasses_dict(report), f, indent=1)
        print(f"report written to {args.json}")
    print(f"chaos run complete: {report.parity_checks} parity checks OK, "
          f"{report.failovers} failovers, {report.resyncs} resyncs")
    return 0


def dataclasses_dict(report) -> dict:
    d = dict(report.__dict__)
    d["incidents"] = dict(d["incidents"])
    return d


if __name__ == "__main__":
    sys.exit(main())
