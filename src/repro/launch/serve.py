"""Online rank-serving driver: replay a SNAP temporal stream as a timed
event feed against the repro.serve service, interleaving rank queries.

The first 90% of the temporal edges preload G⁰ (paper §5.1.4); the rest
arrive one event at a time through the ingest queue (optionally paced at
``--rate`` events/s), the engine micro-batches them, and every
``--query-every`` events a query burst (point ranks + top-k) is served
from the current snapshot.  Prints the metrics summary and ``serve
complete``; exits non-zero if fewer than ``--min-queries`` queries were
served (CI smoke contract).

    PYTHONPATH=src python -m repro.launch.serve \
        --dataset sx-mathoverflow --events 5000

With ``--ckpt-dir``, (ranks, generation, last_seq) checkpoints are
written every ``--ckpt-every`` generations; on restart the driver
replays events [0, last_seq] into the graph and resumes the feed from
there — same replay-from-stream contract as launch/pagerank.py.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import repro  # noqa: F401
from repro import obs
from repro.core.api import ENGINES, METHODS
from repro.data.snap import PAPER_TABLE1, load_temporal
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.launch.pagerank import _resolve_mesh
from repro.ppr import IndexConfig
from repro.serve import IngestQueue, QueryClient, RankStore, ServeEngine, \
    ServeMetrics, preload_graph_and_feed


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sx-mathoverflow",
                    choices=list(PAPER_TABLE1))
    ap.add_argument("--method", default="frontier_prune", choices=METHODS)
    ap.add_argument("--engine", default="xla", choices=list(ENGINES),
                    help="rank-update engine: 'xla' (f64 segment_sum) or "
                         "'kernel' (Pallas frontier-gated SpMV with "
                         "device-side incremental PackedGraph maintenance "
                         "and the f32→f64 hybrid-precision ladder); "
                         "combined with --mesh the kernel path shards the "
                         "packed structure by dst-window ranges over the "
                         "mesh's model axis (on CPU force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N, DESIGN.md §9)")
    ap.add_argument("--events", type=int, default=5000,
                    help="number of post-preload edge events to feed")
    ap.add_argument("--flush-size", type=int, default=64)
    ap.add_argument("--flush-interval-ms", type=float, default=50.0)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="event feed pacing in events/s (0 = unpaced)")
    ap.add_argument("--query-every", type=int, default=100,
                    help="issue a query burst every K submitted events")
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--static-fallback-frac", type=float, default=0.25)
    ap.add_argument("--ppr-walks", type=int, default=0,
                    help="maintain a PPR walk index with R walks/vertex "
                         "(0 = off); query bursts then include an "
                         "index-backed personalized top-k; combined with "
                         "--mesh the index is range-sharded over the "
                         "mesh's model axis and repaired per shard "
                         "(DESIGN.md §14)")
    ap.add_argument("--ppr-len", type=int, default=16,
                    help="walk-index max length L (with --ppr-walks)")
    ap.add_argument("--mesh", choices=["none", "test", "production"],
                    default="none")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="checkpoint every K generations (with --ckpt-dir)")
    ap.add_argument("--min-queries", type=int, default=0,
                    help="exit non-zero unless this many queries were served")
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace JSON of the serve run here "
                         "(enables span tracing + per-iteration frontier "
                         "telemetry; rows land in <PATH>.frontier.jsonl)")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="run a Prometheus scrape server on this port "
                         "(0 = ephemeral, printed; -1 = off)")
    ap.add_argument("--metrics-path", default="",
                    help="write the final Prometheus exposition text here")
    ap.add_argument("--monitor", action="store_true",
                    help="enable correctness monitoring: invariant "
                         "sentinels, sampled shadow verification, flight "
                         "recorder, SLO burn-rate alerts (DESIGN.md §12)")
    ap.add_argument("--shadow-every", type=int, default=64,
                    help="shadow-verify every Kth micro-batch against "
                         "the f64 reference solve (0 = off)")
    ap.add_argument("--incident-dir", default="",
                    help="dump a replayable flight-recorder bundle here "
                         "on the first error-severity incident "
                         "(implies --monitor)")
    ap.add_argument("--inject-fault", default="",
                    help="DEBUG: corrupt the engine at a generation, as "
                         "GEN[:KIND[:VERTEX[:SCALE]]] with KIND rank|"
                         "event (e.g. 5:rank:0:4.0); implies --monitor")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = _resolve_mesh(args.mesh)
    ds = load_temporal(args.dataset)
    graph, events = preload_graph_and_feed(ds, args.events)
    shards = (f" shards={int(mesh.shape['model'])}"
              if mesh is not None and args.engine == "kernel" else "")
    print(f"dataset {ds.name}: |V|={ds.num_vertices:,} preload="
          f"{int(graph.num_valid_edges()):,} events={len(events):,} "
          f"method={args.method} engine={args.engine}{shards} "
          f"flush={args.flush_size}/{args.flush_interval_ms:g}ms")

    metrics = ServeMetrics()
    store = RankStore(ckpt_dir=args.ckpt_dir or None,
                      ckpt_every=args.ckpt_every)
    restored = store.restore_latest(ds.num_vertices) if args.ckpt_dir \
        else None
    start_event = 0
    if restored is not None:
        ranks, gen, last_seq = restored
        start_event = last_seq + 1
        if start_event > len(events):
            # the checkpointed ranks reflect events this run's feed does
            # not contain — replaying a truncated prefix would publish a
            # graph inconsistent with the restored ranks/last_seq
            print(f"FAIL: checkpoint last_seq={last_seq} exceeds the "
                  f"--events {args.events} feed; rerun with --events > "
                  f"{last_seq} (or a fresh --ckpt-dir)")
            return 1
        store.seed_generation(gen)             # gen clock survives restart
        if start_event > 0:         # replay the already-served prefix
            replay = events[:start_event]
            graph = apply_batch(graph, make_batch_update(
                np.zeros((0, 2)), replay, 8, max(8, len(replay))))
        print(f"restored generation {gen}; replayed {start_event} events")
    ingest = IngestQueue(flush_size=args.flush_size,
                         flush_interval=args.flush_interval_ms * 1e-3,
                         start_seq=start_event)
    ppr_cfg = (IndexConfig(num_walks=args.ppr_walks, max_len=args.ppr_len,
                           seed=args.seed)
               if args.ppr_walks > 0 else None)
    monitor = incident_sink = None
    if args.monitor or args.incident_dir or args.inject_fault:
        if args.incident_dir and args.trace:
            incident_sink = obs.JsonlSink(args.trace + ".incidents.jsonl")
        monitor = obs.CorrectnessMonitor(
            obs.MonitorConfig(shadow_every=args.shadow_every,
                              incident_dir=args.incident_dir or None),
            sink=incident_sink)
        print(f"correctness monitor on: shadow 1/{args.shadow_every}"
              + (f" incidents -> {args.incident_dir}"
                 if args.incident_dir else ""))
    engine = ServeEngine(graph, ingest, store, metrics=metrics,
                         method=args.method, mesh=mesh,
                         engine=args.engine,
                         static_fallback_frac=args.static_fallback_frac,
                         ppr_index=ppr_cfg, monitor=monitor)
    if args.inject_fault:
        parts = args.inject_fault.split(":")
        engine.inject_fault(
            int(parts[0]),
            kind=parts[1] if len(parts) > 1 else "rank",
            vertex=int(parts[2]) if len(parts) > 2 else 0,
            scale=float(parts[3]) if len(parts) > 3 else 2.0)
        print(f"fault armed: {args.inject_fault}")
    sink = None
    if args.trace:
        obs.start_tracing(args.trace)
        sink = obs.JsonlSink(args.trace + ".frontier.jsonl")
        engine.telemetry_sink = sink
        print(f"tracing to {args.trace} "
              f"(frontier rows: {args.trace}.frontier.jsonl)")
    exporter = None
    if args.metrics_port >= 0 or args.metrics_path:
        exporter = obs.MetricsExporter(metrics)
        if args.metrics_port >= 0:
            port = exporter.serve(port=args.metrics_port)
            print(f"metrics: http://127.0.0.1:{port}/metrics")
    if restored is not None:
        engine.bootstrap(ranks=restored[0], last_seq=start_event - 1)
    else:
        engine.bootstrap()
    if engine.kernel_geometry is not None:
        info = engine.tune_info
        how = (f"{info.source}"
               f"{' (cache hit)' if info.cache_hit else ''} "
               f"key={info.key} in {info.tune_time_s * 1e3:.1f}ms"
               if info is not None else "explicit (tuning off)")
        print(f"kernel geometry: {engine.kernel_geometry.describe()} "
              f"via {how}")
    client = QueryClient(store, ingest, metrics)
    rng = np.random.default_rng(args.seed)

    t0 = time.perf_counter()
    next_due = t0
    for i in range(start_event, len(events)):
        if args.rate > 0:                     # timed feed
            next_due += 1.0 / args.rate
            lag = next_due - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
        u, v = int(events[i, 0]), int(events[i, 1])
        metrics.record_admission(ingest.submit_insert(u, v) is not None)
        engine.step()                          # flush when size/deadline hit
        if args.query_every and (i + 1) % args.query_every == 0:
            verts = rng.integers(0, ds.num_vertices, size=4)
            client.get_ranks(verts)
            r = client.top_k(args.topk)
            ppr_note = ""
            if args.ppr_walks > 0:
                p = client.personalized_top_k(
                    [int(verts[0])], args.topk, mode="auto")
                ppr_note = f" ppr_top1={p.vertices[0]}"
            print(f"event {i + 1:6d}: gen={r.generation:5d} "
                  f"stale={r.staleness_events:4d}ev "
                  f"top1={r.vertices[0]} ({r.ranks[0]:.3e})"
                  f"{ppr_note}", flush=True)
    engine.drain()
    wall = time.perf_counter() - t0
    engine.close()   # joins the shadow thread, flushes its mailbox
    if monitor is not None:
        print("monitor " + json.dumps(monitor.summary()))
        if incident_sink is not None:
            incident_sink.close()
    if args.trace:
        written = obs.stop_tracing()
        sink.close()
        print(f"trace written to {written}")
    if exporter is not None:
        if args.metrics_path:
            exporter.write(args.metrics_path)
            print(f"metrics written to {args.metrics_path}")
        exporter.close()

    m = metrics.as_dict()
    m["wall_s"] = wall
    m["feed_events_per_s"] = (len(events) - start_event) / wall \
        if wall > 0 else 0.0
    snap = store.snapshot()
    print("metrics " + json.dumps(
        {k: (round(v, 3) if isinstance(v, float) else v)
         for k, v in m.items()}))
    print(f"final generation {snap.generation}, last_seq {snap.last_seq}, "
          f"queries served {m['queries_served']}")
    print("serve complete")
    if m["queries_served"] < args.min_queries:
        print(f"FAIL: served {m['queries_served']} < --min-queries "
              f"{args.min_queries}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
