"""End-to-end dynamic-PageRank streaming driver (the paper's workload).

Replays a temporal stream (paper §5.1.4: 90% preload + consecutive
batches), maintains ranks with the chosen approach, checkpoints
(ranks, batch_idx) for restart, reports per-batch runtime/error/work.

    PYTHONPATH=src python -m repro.launch.pagerank \
        --dataset sx-mathoverflow --method frontier_prune --batches 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core.api import METHODS, update_pagerank
from repro.core.reference import l1_error, static_pagerank_ref
from repro.data.snap import PAPER_TABLE1, load_temporal
from repro.ft.checkpoint import CheckpointManager
from repro.graph.dynamic import apply_batch, make_batch_update
from repro.graph.generators import TemporalStream
from repro.graph.structure import from_coo
from repro.launch.mesh import make_production_mesh, make_test_mesh


def _resolve_mesh(name: str):
    """--mesh none|test|production -> jax Mesh (or None for single-device).

    ``test`` sizes itself to the visible devices (force more with
    XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU).
    """
    if name == "none":
        return None
    if name == "test":
        return make_test_mesh(len(jax.devices()))
    return make_production_mesh()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sx-mathoverflow",
                    choices=list(PAPER_TABLE1))
    ap.add_argument("--method", default="frontier_prune", choices=METHODS)
    ap.add_argument("--batch-frac", type=float, default=1e-3)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pr_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--check-error", action="store_true")
    ap.add_argument("--mesh", choices=["none", "test", "production"],
                    default="none",
                    help="replay the stream on a multi-device mesh via the "
                         "shard_map engine (repro.dist.pagerank_dist)")
    args = ap.parse_args(argv)

    mesh = _resolve_mesh(args.mesh)
    if mesh is not None:
        print(f"mesh {dict(mesh.shape)} over {len(jax.devices())} devices")
    ds = load_temporal(args.dataset)
    print(f"dataset {ds.name}: |V|={ds.num_vertices:,} "
          f"|E_T|={len(ds.edges):,} synthetic={ds.synthetic}")
    stream = TemporalStream(ds.edges, ds.num_vertices, args.batch_frac,
                            args.batches)
    pre = stream.preload_edges()
    cap = len(pre) + stream.batch_size * stream.num_batches + 64
    graph = from_coo(pre[:, 0], pre[:, 1], ds.num_vertices,
                     edge_capacity=cap)
    print(f"preloaded {int(graph.num_valid_edges()):,} static edges; "
          f"{stream.num_batches} batches of {stream.batch_size}")

    res = update_pagerank(graph, graph, None, None, "static", mesh=mesh)
    ranks = res.ranks
    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
    state_t = dict(ranks=jax.ShapeDtypeStruct((ds.num_vertices,),
                                              jnp.float64),
                   batch_idx=jax.ShapeDtypeStruct((), jnp.int64))
    step0, restored = mgr.restore_latest(state_t)
    start = 0
    if restored is not None:
        ranks = restored["ranks"]
        start = int(restored["batch_idx"])
        print(f"restored at batch {start}")
        for i in range(start):      # replay graph structure to batch start
            upd = make_batch_update(np.zeros((0, 2)), stream.batch(i), 8,
                                    max(8, stream.batch_size))
            graph = apply_batch(graph, upd)

    for i in range(start, stream.num_batches):
        upd = make_batch_update(np.zeros((0, 2)), stream.batch(i), 8,
                                max(8, stream.batch_size))
        t0 = time.perf_counter()
        graph_new = apply_batch(graph, upd)
        r = update_pagerank(graph, graph_new, upd, ranks, args.method,
                            mesh=mesh)
        jax.block_until_ready(r.ranks)
        dt = time.perf_counter() - t0
        msg = (f"batch {i:3d}: {dt*1e3:7.1f} ms  iters={int(r.iterations):3d}"
               f"  affected={int(jnp.sum(r.affected_ever)):,}"
               f"  edges={int(r.edges_processed):,}")
        if args.check_error:
            sv = np.asarray(graph_new.src)[np.asarray(graph_new.valid)]
            dv = np.asarray(graph_new.dst)[np.asarray(graph_new.valid)]
            ref, _ = static_pagerank_ref(sv, dv, ds.num_vertices, tol=1e-14)
            msg += f"  L1err={l1_error(r.ranks, ref):.2e}"
        print(msg, flush=True)
        graph, ranks = graph_new, r.ranks
        mgr.maybe_save(i + 1, dict(ranks=ranks,
                                   batch_idx=jnp.asarray(i + 1)))
    print("stream complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
