import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (test hook: small-device override BEFORE jax initialises — see tests/)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM;
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline;
  * collective-bytes tally parsed from the optimised HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — cost_analysis does not report these.

Results stream to ``results/dryrun_<mesh>.json`` which
benchmarks/roofline consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  --arch gemma3-12b --shape train_4k
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs.registry import REGISTRY, all_cells, get_arch
from repro.dist import sharding as SH
from repro.dist.pagerank_dist import (build_distributed_step,
                                      distributed_in_shardings,
                                      distributed_input_specs)
from repro.launch.mesh import data_axes, make_production_mesh
from repro.train import inputs as I
from repro.train import steps as S

_OP_RE = re.compile(
    r"=\s+(\(?[a-z0-9\[\],{}\s]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([\d,]*)\]")
_BYTES = dict(bf16=2, f16=2, f32=4, f64=8, s32=4, u32=4, s8=1, u8=1,
              pred=1, s64=8, u64=8)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in optimised HLO.

    NOTE: ops inside while/scan bodies are counted ONCE (XLA text has one
    body per loop).  The roofline layer (roofline/analysis.py) therefore
    consumes counts from the *counting-mode* lowering, where layer loops
    are unrolled — see EXPERIMENTS.md §Method.
    """
    out: dict = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute")}
    counts: dict = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":      # start/done pairs: count starts only
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["op_counts"] = counts
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    d["peak_per_device_bytes"] = (
        d.get("argument_size_in_bytes", 0) + d.get("output_size_in_bytes", 0)
        + d.get("temp_size_in_bytes", 0) - d.get("alias_size_in_bytes", 0))
    return d


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or k in ("utilization",))}


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def lower_cell(spec, cell, mesh, counting: bool = False,
               n_layers: int | None = None):
    """Lower one (arch × shape) on a mesh.

    counting=True (LM family): unrolled layers + chunk=seq so XLA's
    count-bodies-once cost analysis and the collective parser see the whole
    program.  With ``n_layers`` override, the L=1/L=2 delta trick
    extrapolates exact full-depth costs (layer stacks are homogeneous —
    gemma3's local/global layers share one HLO since the window is a
    traced scalar).  The production (scan+remat) variant proves memory.
    """
    family = spec.family
    if family == "pagerank":
        d = cell.dims
        fn = build_distributed_step(mesh, n_vertices=d["n_vertices"])
        args = distributed_input_specs(mesh, d["n_vertices"],
                                       d["edge_capacity"])
        shardings = distributed_in_shardings(mesh)
        return jax.jit(fn, in_shardings=shardings).lower(*args)

    cfg = I.effective_config(spec, cell, smoke=False)
    if counting and family == "lm":
        cfg = dataclasses.replace(cfg, counting=True)
    if n_layers is not None and family == "lm":
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    spec = dataclasses.replace(spec, config=cfg)
    batch = I.build_inputs(spec, cell, concrete=False, smoke=False)

    if family == "lm":
        if cell.kind == "train":
            params, opt = I.abstract_state(spec, cell)
            pspec, bspec, ospec = SH.family_shardings(
                "lm", mesh, params, batch, opt)
            # production variant: microbatched accumulation; counting
            # variant: single batch (FLOP-identical, scan-free)
            import jax.numpy as _jnp
            n_micro = 1 if counting else I.MICROBATCHES.get(spec.arch_id, 1)
            fn = S.make_lm_train_step(
                cfg, n_microbatches=n_micro,
                factored=I.FACTORED_V.get(spec.arch_id, False),
                accum_dtype=I.ACCUM_DTYPE.get(spec.arch_id, _jnp.float32))
            return jax.jit(fn, in_shardings=(pspec, ospec, bspec),
                           out_shardings=(pspec, ospec, None),
                           donate_argnums=(0, 1)).lower(params, opt, batch)
        if cell.kind == "prefill":
            params, _ = I.abstract_state(spec, cell, with_opt=False)
            pspec, bspec, _ = SH.family_shardings("lm", mesh, params, batch)
            fn = S.make_lm_prefill(cfg)
            return jax.jit(fn, in_shardings=(pspec, bspec["tokens"]),
                           ).lower(params, batch["tokens"])
        # decode
        params, _ = I.abstract_state(spec, cell, with_opt=False)
        cache = I.abstract_cache(spec, cell)
        pspec, _, _ = SH.family_shardings(
            "lm", mesh, params, dict(tokens=batch["tokens"]))
        cspec = SH.lm_cache_specs(mesh, cache, cell.dims["batch"])
        dp = data_axes(mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        tok_spec = NamedSharding(
            mesh, P(dp if cell.dims["batch"] % max(
                1, SH._axis_size(mesh, dp)) == 0 else None, None))
        fn = S.make_lm_decode_step(cfg)
        return jax.jit(fn, in_shardings=(pspec, cspec, tok_spec),
                       out_shardings=(None, cspec),
                       donate_argnums=(1,)).lower(
            params, cache, batch["tokens"])

    if family == "gnn":
        params, opt = I.abstract_state(spec, cell)
        pspec, bspec, ospec = SH.family_shardings(
            "gnn", mesh, params, batch, opt)
        fn = S.make_gnn_train_step(spec.arch_id, cfg)
        return jax.jit(fn, in_shardings=(pspec, ospec, bspec),
                       out_shardings=(pspec, ospec, None),
                       donate_argnums=(0, 1)).lower(params, opt, batch)

    # recsys
    if cell.kind == "recsys_train":
        params, opt = I.abstract_state(spec, cell)
        pspec, bspec, ospec = SH.family_shardings(
            "recsys", mesh, params, batch, opt)
        fn = S.make_recsys_train_step(cfg)
        return jax.jit(fn, in_shardings=(pspec, ospec, bspec),
                       out_shardings=(pspec, ospec, None),
                       donate_argnums=(0, 1)).lower(params, opt, batch)
    params, _ = I.abstract_state(spec, cell, with_opt=False)
    pspec, bspec, _ = SH.family_shardings("recsys", mesh, params, batch)
    fn = S.make_recsys_serve(cfg) if cell.kind == "recsys_serve" \
        else S.make_recsys_retrieval(cfg)
    return jax.jit(fn, in_shardings=(pspec, bspec)).lower(params, batch)


def run_cell(spec, cell, mesh, mesh_name: str, verbose=True) -> dict:
    rec = dict(arch=spec.arch_id, shape=cell.name, mesh=mesh_name,
               family=spec.family, kind=cell.kind)
    if cell.skip:
        rec["status"] = "SKIP"
        rec["skip_reason"] = cell.skip
        return rec
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            lowered = lower_cell(spec, cell, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            rec["memory"] = _mem_dict(compiled)
            rec["cost"] = _cost_dict(compiled)
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
            # counting-mode lowerings for exact roofline terms: L=1 and
            # L=2 unrolled, extrapolated to full depth (delta trick)
            if spec.family == "lm":
                t1 = time.time()
                c1 = lower_cell(spec, cell, mesh, counting=True,
                                n_layers=1).compile()
                c2 = lower_cell(spec, cell, mesh, counting=True,
                                n_layers=2).compile()
                L = spec.config.n_layers
                cost1, cost2 = _cost_dict(c1), _cost_dict(c2)
                coll1 = collective_bytes(c1.as_text())
                coll2 = collective_bytes(c2.as_text())

                def extrap(a, b):
                    return {k: a.get(k, 0) + (L - 1) *
                            (b.get(k, 0) - a.get(k, 0))
                            for k in set(a) | set(b)
                            if not isinstance(a.get(k, b.get(k)), dict)}

                rec["cost_counting"] = {
                    k: v for k, v in extrap(cost1, cost2).items()
                    if k in ("flops", "bytes accessed")}
                rec["collectives_counting"] = extrap(coll1, coll2)
                rec["counting_method"] = f"delta L=1/2 -> L={L}"
                rec["t_counting_s"] = round(time.time() - t1, 1)
        rec["status"] = "OK"
        rec["t_lower_s"] = round(t_lower, 1)
        rec["t_compile_s"] = round(t_compile, 1)
        if verbose:
            mem = rec["memory"].get("peak_per_device_bytes", 0)
            fl = rec["cost"].get("flops", 0)
            cb = rec["collectives"]["total"]
            print(f"  OK {spec.arch_id}/{cell.name}: "
                  f"peak/dev={mem/2**30:.2f}GiB flops={fl:.3g} "
                  f"coll={cb/2**20:.1f}MiB "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — report, don't abort the sweep
        rec["status"] = "FAIL"
        rec["error"] = repr(e)[:500]
        if verbose:
            print(f"  FAIL {spec.arch_id}/{cell.name}: {repr(e)[:200]}",
                  flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--include-pagerank", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": False, "multi": True}
    wanted = [args.mesh] if args.mesh != "both" else ["single", "multi"]

    def build_mesh(multi_pod: bool):
        ndev = len(jax.devices())
        if ndev >= (512 if multi_pod else 256):
            return make_production_mesh(multi_pod=multi_pod)
        # CI-scale override (REPRO_DRYRUN_DEVICES): shrink proportionally
        if multi_pod:
            d = ndev // 4
            return jax.make_mesh((2, d, 2), ("pod", "data", "model"))
        return jax.make_mesh((ndev // 2, 2), ("data", "model"))

    for mesh_name in wanted:
        mesh = build_mesh(meshes[mesh_name])
        print(f"=== mesh {mesh_name}: {dict(mesh.shape)} "
              f"({len(jax.devices())} devices) ===", flush=True)
        records = []
        path = os.path.join(args.out, f"dryrun_{mesh_name}.json")
        # resume support: skip cells already recorded OK
        done = {}
        if os.path.exists(path):
            with open(path) as f:
                for r in json.load(f):
                    if r.get("status") in ("OK", "SKIP"):
                        done[(r["arch"], r["shape"])] = r
        for spec, cell in all_cells(include_pagerank=args.include_pagerank):
            if args.arch != "all" and spec.arch_id != args.arch:
                continue
            if args.shape != "all" and cell.name != args.shape:
                continue
            if (spec.arch_id, cell.name) in done:
                records.append(done[(spec.arch_id, cell.name)])
                print(f"  cached {spec.arch_id}/{cell.name}", flush=True)
                continue
            records.append(run_cell(spec, cell, mesh, mesh_name))
            with open(path, "w") as f:
                json.dump(records, f, indent=1)
        ok = sum(r["status"] == "OK" for r in records)
        sk = sum(r["status"] == "SKIP" for r in records)
        fail = [r for r in records if r["status"] == "FAIL"]
        print(f"mesh {mesh_name}: {ok} OK, {sk} SKIP, {len(fail)} FAIL")
        for r in fail:
            print(f"  FAILED {r['arch']}/{r['shape']}: {r['error'][:120]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
