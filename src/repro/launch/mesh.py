"""Production mesh definition (required shape, DESIGN.md §4).

A FUNCTION, not a module constant — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8):
    """Small mesh for CI-scale shard_map tests (data × model, model=2).

    Degrades to model=1 on odd/single-device hosts so the CLI ``--mesh``
    path stays runnable without forced device counts.
    """
    model = 2 if devices >= 2 and devices % 2 == 0 else 1
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"))


# canonical impl lives in the dist layer (repro.dist.sharding.data_axes):
# "the batch/edge-parallel axes of a mesh ('pod' included when present)"
from repro.dist.sharding import data_axes  # noqa: E402,F401
