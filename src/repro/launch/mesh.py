"""Production mesh definition (required shape, DESIGN.md §4).

A FUNCTION, not a module constant — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8):
    """Small mesh for CI-scale shard_map tests (2×data × model)."""
    model = 2
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch/edge-parallel axes of a mesh ('pod' included when present)."""
    return tuple(a for a in mesh.axis_names if a != "model")
