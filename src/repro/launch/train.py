"""End-to-end training driver: ``--arch <id> [--steps N]``.

Runs a real (CPU-sized by default) training loop with the full substrate:
config registry, data pipeline, AdamW, checkpoints every ``--ckpt-every``
steps, restart-from-latest, loss logging.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --smoke --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs.registry import get_arch
from repro.data.lm import batches
from repro.ft.checkpoint import CheckpointManager
from repro.optim.adamw import init_adamw
from repro.train import inputs as I
from repro.train import steps as S


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized ~100M-max model)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    if spec.family != "lm":
        raise SystemExit("launch.train drives LM archs; use launch.pagerank "
                         "or examples/ for graph/recsys workloads")
    cfg = spec.smoke_config if args.smoke else spec.config
    print(f"arch={args.arch} params≈{cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    params = I.init_fn(spec, smoke=args.smoke)(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step_fn = jax.jit(S.make_lm_train_step(cfg))
    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)

    start, restored = mgr.restore_latest((params, opt))
    if restored is not None:
        params, opt = restored
        print(f"restored checkpoint at step {start}")
    start = start or 0

    data = batches(cfg.vocab, args.batch, args.seq, seed=1)
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(data)
        params, opt, loss = step_fn(params, opt, batch)
        losses.append(float(loss))
        if (step + 1) % args.log_every == 0:
            rate = args.batch * args.seq * args.log_every \
                / (time.time() - t0)
            recent = float(np.mean(losses[-args.log_every:]))
            print(f"step {step+1:5d} loss {recent:.4f} tok/s {rate:,.0f}",
                  flush=True)
            t0 = time.time()
        mgr.maybe_save(step + 1, (params, opt))
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(start {np.mean(losses[:10]):.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
