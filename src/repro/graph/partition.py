"""2-D graph partition for the production mesh (DESIGN.md §4).

``model`` axis owns contiguous **dst ranges** (vertex state lives here);
``data``(+``pod``) axes stripe the edges *within* each dst range.  The
partition is a pure function of (V, E_cap, mesh shape) so elastic remeshing
(ft/elastic.py) is a repartition of host arrays, nothing more.

Edges are first dst-sorted (graph.structure.sort_edges_by_dst), then each dst
range's slice is padded to the uniform per-device edge capacity.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.structure import EdgeListGraph


@dataclass
class PartitionedGraph:
    """Host-side partitioned arrays, layout [model, edge_par, E_dev]."""

    src: np.ndarray     # int32[M, P, E_dev]
    dst_local: np.ndarray  # int32[M, P, E_dev]  (dst - range_start)
    valid: np.ndarray   # bool[M, P, E_dev]
    vtx_starts: np.ndarray  # int32[M] dst-range starts
    num_vertices: int
    v_per_shard: int

    @property
    def model_shards(self) -> int:
        return self.src.shape[0]

    @property
    def edge_shards(self) -> int:
        return self.src.shape[1]


def vertices_per_shard(num_vertices: int, model_shards: int,
                       window: int = 512) -> int:
    """dst-range length per ``model`` shard, rounded up to ``window``.

    Single source of truth for the vertex layout: partition_graph,
    dist.pagerank_dist.distributed_input_specs and the dist engine all
    derive the padded vertex count ``v_per * model_shards`` from here.
    """
    v_per = -(-num_vertices // model_shards)          # ceil
    return -(-v_per // window) * window


def edges_per_device(edge_capacity: int, model_shards: int,
                     edge_shards: int, lane: int = 128) -> int:
    """Balanced per-device edge-slot estimate for abstract lowering and for
    pre-sizing the streaming engine (the skew-worst-case is E_cap per dst
    range; partition_graph grows e_dev beyond this floor when needed)."""
    e_dev = -(-edge_capacity // max(1, model_shards * edge_shards))
    return max(lane, -(-e_dev // lane) * lane)


def partition_graph(graph: EdgeListGraph, model_shards: int,
                    edge_shards: int, balance_by_active: np.ndarray = None,
                    window: int = 512,
                    min_edges_per_device: int = 0) -> PartitionedGraph:
    """dst-range × edge-stripe partition.

    ``balance_by_active``: optional bool[E_cap] — when given (straggler
    mitigation), live edges whose flag is set are striped first so active
    work spreads evenly across the ``data`` axis.

    ``window``: v_per_shard is rounded up to a multiple of this so the
    frontier-compressed collective path can treat ranks as whole windows.

    ``min_edges_per_device``: floor for the per-device edge capacity — the
    streaming engine passes a capacity-derived floor so the partition
    shape (and hence the compiled shard_map program) is stable across
    batches of a temporal stream.
    """
    V = graph.num_vertices
    v_per = vertices_per_shard(V, model_shards, window)
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    valid = np.asarray(graph.valid)

    per_range_edges = []
    for m in range(model_shards):
        lo, hi = m * v_per, min((m + 1) * v_per, V)
        sel = valid & (dst >= lo) & (dst < hi)
        idx = np.nonzero(sel)[0]
        if balance_by_active is not None and len(idx):
            act = balance_by_active[idx]
            idx = np.concatenate([idx[act], idx[~act]])
        per_range_edges.append(idx)

    e_dev = max(8, max((len(i) for i in per_range_edges), default=8))
    e_dev = -(-e_dev // edge_shards)
    # round up to lane multiple for TPU-friendly layouts
    e_dev = -(-e_dev // 128) * 128
    e_dev = max(e_dev, min_edges_per_device)

    S = np.zeros((model_shards, edge_shards, e_dev), np.int32)
    D = np.zeros((model_shards, edge_shards, e_dev), np.int32)
    M = np.zeros((model_shards, edge_shards, e_dev), bool)
    for m, idx in enumerate(per_range_edges):
        lo = m * v_per
        # round-robin stripe over the edge axis (interleaves active-first)
        for p in range(edge_shards):
            part = idx[p::edge_shards][:e_dev]
            S[m, p, : len(part)] = src[part]
            D[m, p, : len(part)] = dst[part] - lo
            M[m, p, : len(part)] = True
    starts = np.arange(model_shards, dtype=np.int32) * v_per
    return PartitionedGraph(S, D, M, starts, V, v_per)
