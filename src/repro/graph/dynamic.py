"""Dynamic-graph batch updates as pure jit-able functions (paper §3.2).

A batch update Δᵗ = (Δᵗ⁻ deletions, Δᵗ⁺ insertions) transforms Gᵗ⁻¹ → Gᵗ.
Updates are themselves capacity-padded so one compiled ``apply_batch`` serves
every batch of a temporal stream (paper applies 100 consecutive batches).

Semantics match the paper:
  * deletion (u, v): mark matching live slot invalid (no-op if absent);
  * insertion (u, v): claim a free slot (no-op duplicate insert is prevented
    by callers using `dedup_insertions`, matching the paper's static-edge
    dedup); vertices are never added/removed;
  * self-loops are implicit (graph/structure.py) so update batches never
    carry them.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import EdgeListGraph


class BatchUpdate(NamedTuple):
    """Padded edge-update batch. Invalid rows carry count-mask False."""

    del_src: jax.Array   # int32[D_cap]
    del_dst: jax.Array   # int32[D_cap]
    del_mask: jax.Array  # bool[D_cap]
    ins_src: jax.Array   # int32[I_cap]
    ins_dst: jax.Array   # int32[I_cap]
    ins_mask: jax.Array  # bool[I_cap]


def make_batch_update(deletions: np.ndarray, insertions: np.ndarray,
                      del_capacity: int, ins_capacity: int) -> BatchUpdate:
    """Host-side helper: (k,2) int arrays -> padded BatchUpdate."""
    deletions = np.asarray(deletions, np.int32).reshape(-1, 2)
    insertions = np.asarray(insertions, np.int32).reshape(-1, 2)
    nd, ni = len(deletions), len(insertions)
    if nd > del_capacity or ni > ins_capacity:
        raise ValueError("update exceeds capacity")

    def pad(a, cap):
        out = np.zeros((cap,), np.int32)
        out[: len(a)] = a
        return jnp.asarray(out)

    mask = lambda n, cap: jnp.asarray(np.arange(cap) < n)
    return BatchUpdate(
        del_src=pad(deletions[:, 0], del_capacity),
        del_dst=pad(deletions[:, 1], del_capacity),
        del_mask=mask(nd, del_capacity),
        ins_src=pad(insertions[:, 0], ins_capacity),
        ins_dst=pad(insertions[:, 1], ins_capacity),
        ins_mask=mask(ni, ins_capacity),
    )


def _edge_key(src: jax.Array, dst: jax.Array, num_vertices: int) -> jax.Array:
    return src.astype(jnp.int64) * num_vertices + dst.astype(jnp.int64)


@jax.jit
def apply_batch(graph: EdgeListGraph, update: BatchUpdate) -> EdgeListGraph:
    """Pure function Gᵗ⁻¹, Δᵗ → Gᵗ.  O(E_cap·log + |Δ|) with static shapes.

    Deletions: membership test via sorted-key binary search over the *batch*
    (small), applied to every live slot.  Insertions: claim the first |Δ⁺|
    free slots via a cumulative-sum compaction.
    """
    V = graph.num_vertices
    # ---- deletions -------------------------------------------------------
    live_key = _edge_key(graph.src, graph.dst, V)
    del_key = jnp.where(
        update.del_mask, _edge_key(update.del_src, update.del_dst, V), -1)
    del_sorted = jnp.sort(del_key)
    pos = jnp.searchsorted(del_sorted, live_key)
    pos = jnp.clip(pos, 0, del_sorted.shape[0] - 1)
    is_deleted = (del_sorted[pos] == live_key) & graph.valid
    valid = graph.valid & ~is_deleted

    # ---- insertions ------------------------------------------------------
    # Skip inserts that already exist (paper's graphs are simple digraphs).
    live_key_after = jnp.where(valid, live_key, -2)
    live_sorted = jnp.sort(live_key_after)
    ins_key = _edge_key(update.ins_src, update.ins_dst, V)
    ipos = jnp.clip(jnp.searchsorted(live_sorted, ins_key), 0,
                    live_sorted.shape[0] - 1)
    already = live_sorted[ipos] == ins_key
    ins_mask = update.ins_mask & ~already
    # de-dup within the batch itself
    ins_sorted_key = jnp.sort(jnp.where(ins_mask, ins_key, -1))
    first_occurrence = jnp.concatenate(
        [jnp.array([True]), ins_sorted_key[1:] != ins_sorted_key[:-1]])
    # map back: a key is kept iff it is the first among equals
    order = jnp.argsort(jnp.where(ins_mask, ins_key, -1))
    keep_sorted = first_occurrence & (ins_sorted_key >= 0)
    keep = jnp.zeros_like(ins_mask).at[order].set(keep_sorted)
    ins_mask = ins_mask & keep

    # free-slot compaction: i-th masked insertion -> i-th free slot
    free = ~valid
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1           # rank per slot
    ins_rank = jnp.cumsum(ins_mask.astype(jnp.int32)) - 1        # rank per ins
    # slot index of the k-th free slot:
    E_cap = graph.edge_capacity
    slot_of_rank = jnp.full((E_cap,), E_cap, jnp.int32).at[
        jnp.where(free, free_rank, E_cap)].min(jnp.arange(E_cap, dtype=jnp.int32))
    target = jnp.where(ins_mask, slot_of_rank[jnp.clip(ins_rank, 0, E_cap - 1)],
                       E_cap)  # E_cap = drop (out of bounds)
    src = graph.src.at[target].set(update.ins_src, mode="drop")
    dst = graph.dst.at[target].set(update.ins_dst, mode="drop")
    new_valid = valid.at[target].set(True, mode="drop")
    num_edges = jnp.sum(new_valid.astype(jnp.int32))
    return dataclasses.replace(
        graph, src=src, dst=dst, valid=new_valid, num_edges=num_edges)


def touched_vertices_mask(update: BatchUpdate, num_vertices: int) -> jax.Array:
    """bool[V]: u-endpoints of every edge in Δ — seeds for frontier marking."""
    m = jnp.zeros((num_vertices,), bool)
    m = m.at[jnp.where(update.del_mask, update.del_src, 0)].max(
        update.del_mask)
    m = m.at[jnp.where(update.ins_mask, update.ins_src, 0)].max(
        update.ins_mask)
    return m
