"""Static-shape graph structures for jit-compatible dynamic-graph processing.

The paper's graphs mutate every batch (edge insertions + deletions).  JAX jit
requires static shapes, so the framework represents a graph as a *capacity
padded edge list*:

  * ``src``, ``dst``: int32[E_cap] endpoint arrays (slots beyond ``num_edges``
    and deleted slots carry sentinel ``src = dst = 0`` and ``valid = False``).
  * ``valid``: bool[E_cap] liveness mask — deletions flip it, insertions claim
    free slots.  All degree/contribution math masks by ``valid``.
  * degrees are derived (``segment_sum`` of ``valid``), never stored stale.

Every vertex conceptually carries a **self-loop** (paper §3.1 dangling-vertex
mitigation).  We do NOT materialise self-loop edges: the out-degree is
``valid_out_degree + 1`` and the self contribution is folded analytically into
the rank update (DF) or the closed form (DF-P).  This keeps |V| slots free and
keeps the DF-P geometric-series formula exact.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CSRView(NamedTuple):
    """Compressed out-adjacency over the *valid* edges of an EdgeListGraph.

    A plain pytree of device arrays, built by ``EdgeListGraph.to_device_csr``.
    ``deg`` excludes the implicit self-loop; samplers treat slot ``deg[u]``
    as the self-loop.
    """

    indptr: jax.Array    # int32[V + 1]
    indices: jax.Array   # int32[E_cap]  (valid prefix per segment only)
    deg: jax.Array       # int32[V]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeListGraph:
    """Capacity-padded directed graph.  A pytree; safe under jit/shard_map."""

    src: jax.Array          # int32[E_cap]
    dst: jax.Array          # int32[E_cap]
    valid: jax.Array        # bool[E_cap]
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    # Number of *slots* ever claimed (live + dead); free slots are >= num_edges.
    num_edges: jax.Array = dataclasses.field(default=None)  # int32[]

    @property
    def edge_capacity(self) -> int:
        return self.src.shape[0]

    # ---- derived quantities (masked by `valid`) --------------------------
    def out_degree(self, include_self_loop: bool = True) -> jax.Array:
        """int32[V] out-degree; +1 for the implicit self-loop."""
        deg = jax.ops.segment_sum(
            self.valid.astype(jnp.int32), self.src,
            num_segments=self.num_vertices)
        return deg + 1 if include_self_loop else deg

    def in_degree(self, include_self_loop: bool = True) -> jax.Array:
        deg = jax.ops.segment_sum(
            self.valid.astype(jnp.int32), self.dst,
            num_segments=self.num_vertices)
        return deg + 1 if include_self_loop else deg

    def num_valid_edges(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    # ---- message passing primitives --------------------------------------
    def push(self, values: jax.Array) -> jax.Array:
        """sum_{(u,v) in E} values[u] -> out[v].  The GNN/PageRank primitive."""
        contrib = jnp.where(self.valid, values[self.src], 0)
        return jax.ops.segment_sum(contrib, self.dst,
                                   num_segments=self.num_vertices)

    def push_or(self, flags: jax.Array) -> jax.Array:
        """Boolean frontier propagation: out[v] |= flags[u] for (u,v) in E."""
        f = jnp.where(self.valid, flags[self.src].astype(jnp.int32), 0)
        out = jax.ops.segment_max(f, self.dst, num_segments=self.num_vertices)
        return out > 0

    def to_device_csr(self) -> "CSRView":
        """Device CSR view over valid edges (jit-able) — for the random-walk
        sampler (repro.ppr).

        ``indices[indptr[u] : indptr[u] + deg[u]]`` are u's out-neighbours.
        Entries past ``indptr[V]`` are garbage (dst of invalid slots) and
        must never be read.  Stability contract: a vertex whose incident
        edge slots did not change keeps its neighbour list *in the same
        order* across ``apply_batch`` calls (stable argsort over equal keys
        preserves slot order), which is what lets walk repair keep
        untouched walk prefixes bitwise intact.
        """
        V = self.num_vertices
        key = jnp.where(self.valid, self.src, V)
        order = jnp.argsort(key, stable=True)
        deg = jax.ops.segment_sum(self.valid.astype(jnp.int32), self.src,
                                  num_segments=V)
        indptr = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(deg, dtype=jnp.int32)])
        return CSRView(indptr=indptr, indices=self.dst[order], deg=deg)

    def to_host_csr(self):
        """NumPy CSR (indptr, indices) over valid edges — for samplers/oracles."""
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        valid = np.asarray(self.valid)
        s, d = src[valid], dst[valid]
        order = np.argsort(s, kind="stable")
        s, d = s[order], d[order]
        indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
        np.add.at(indptr, s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, d


def sort_edges_by_dst(graph: EdgeListGraph) -> EdgeListGraph:
    """Return an equivalent graph whose slots are dst-sorted.

    Required by the frontier-block-gated Pallas kernel (contiguous dst ranges
    per block) and by the 2D mesh partition (dst-range ownership).  Invalid
    slots sort to the end (sentinel key = num_vertices).
    """
    key = jnp.where(graph.valid, graph.dst, graph.num_vertices)
    order = jnp.argsort(key, stable=True)
    return dataclasses.replace(
        graph,
        src=graph.src[order], dst=graph.dst[order], valid=graph.valid[order])


def from_coo(src: np.ndarray, dst: np.ndarray, num_vertices: int,
             edge_capacity: Optional[int] = None,
             dedup: bool = True) -> EdgeListGraph:
    """Build a graph from host COO arrays (deduplicated, capacity padded)."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if dedup and len(src):
        uniq = np.unique(np.stack([src, dst], axis=1), axis=0)
        src, dst = uniq[:, 0].copy(), uniq[:, 1].copy()
    e = len(src)
    if edge_capacity is None:
        edge_capacity = max(16, int(e * 1.5))
    if e > edge_capacity:
        raise ValueError(f"{e} edges exceed capacity {edge_capacity}")
    pad = edge_capacity - e
    return EdgeListGraph(
        src=jnp.asarray(np.concatenate([src, np.zeros(pad, np.int32)])),
        dst=jnp.asarray(np.concatenate([dst, np.zeros(pad, np.int32)])),
        valid=jnp.asarray(
            np.concatenate([np.ones(e, bool), np.zeros(pad, bool)])),
        num_vertices=int(num_vertices),
        num_edges=jnp.asarray(e, jnp.int32),
    )
