"""GraphSAGE-style neighbour sampling (required for ``minibatch_lg``).

Host-side sampler over a NumPy CSR view (the device graph is edge-list; we
keep a CSR mirror for sampling).  Produces *fanout-padded* block arrays with
static shapes so the sampled subgraph jits:

layer l block:  nodes  int32[B_l]        (B_l = batch * prod(fanouts[:l]))
                parent int32[B_l]        (index into layer l-1 block)
                mask   bool[B_l]

The GNN consumes blocks innermost-first (GraphSAGE §3.1 minibatch algo).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np


@dataclass
class SampledBlock:
    nodes: np.ndarray    # int32[B] global vertex ids (0 where masked)
    parent: np.ndarray   # int32[B] index into previous layer's nodes
    mask: np.ndarray     # bool[B]


@dataclass
class SampledBatch:
    seeds: np.ndarray               # int32[batch]
    blocks: List[SampledBlock]      # one per hop, outermost hop last
    all_nodes: np.ndarray           # unique node ids (padded)
    all_mask: np.ndarray


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 fanouts: Sequence[int], seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> SampledBatch:
        seeds = np.asarray(seeds, np.int32)
        frontier_nodes = seeds
        frontier_mask = np.ones(len(seeds), bool)
        blocks: List[SampledBlock] = []
        for fanout in self.fanouts:
            B = len(frontier_nodes) * fanout
            nodes = np.zeros(B, np.int32)
            parent = np.repeat(np.arange(len(frontier_nodes), dtype=np.int32),
                               fanout)
            mask = np.zeros(B, bool)
            for i, (v, ok) in enumerate(zip(frontier_nodes, frontier_mask)):
                if not ok:
                    continue
                lo, hi = self.indptr[v], self.indptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(fanout, deg)
                picks = self.rng.choice(deg, size=take, replace=False)
                sel = self.indices[lo + picks]
                nodes[i * fanout: i * fanout + take] = sel
                mask[i * fanout: i * fanout + take] = True
            blocks.append(SampledBlock(nodes, parent, mask))
            frontier_nodes, frontier_mask = nodes, mask
        uniq = np.unique(np.concatenate(
            [seeds] + [b.nodes[b.mask] for b in blocks]))
        cap = len(seeds) * int(np.prod([f + 1 for f in self.fanouts]))
        all_nodes = np.zeros(cap, np.int32)
        all_mask = np.zeros(cap, bool)
        take = min(cap, len(uniq))
        all_nodes[:take] = uniq[:take]
        all_mask[:take] = True
        return SampledBatch(seeds, blocks, all_nodes, all_mask)
