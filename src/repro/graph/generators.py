"""Graph + batch-update generators (host-side NumPy, deterministic).

Covers both of the paper's evaluation regimes:
  * §5.1.4 temporal replay: a timestamp-ordered edge stream, 90% preloaded,
    remainder replayed in 100 consecutive batches (``TemporalStream``).
  * §5.2.2 random updates on large static graphs: 80% uniformly-random
    insertions + 20% uniform deletions of existing edges
    (``random_batch_update``).

RMAT gives power-law "web-like" graphs; ER gives uniform "road-like" low
locality; BA gives preferential-attachment "social-like" graphs — matching
the paper's web/social/road/k-mer dataset spread without shipping datasets.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def rmat_edges(scale: int, edge_factor: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> Tuple[np.ndarray, int]:
    """R-MAT power-law digraph: 2**scale vertices, edge_factor·V edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        src_bit = (r >= a + b).astype(np.int64)
        r2 = rng.random(m)
        thresh = np.where(src_bit == 0, a / (a + b), c / (1 - a - b))
        dst_bit = (r2 >= thresh).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    edges = np.unique(np.stack([src, dst], 1), axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]     # self-loops are implicit
    return edges.astype(np.int32), n


def erdos_renyi_edges(n: int, m: int, seed: int = 0) -> Tuple[np.ndarray, int]:
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(int(m * 1.2), 2), dtype=np.int64)
    edges = np.unique(edges, axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]][:m]
    return edges.astype(np.int32), n


def barabasi_albert_edges(n: int, m_per_node: int, seed: int = 0
                          ) -> Tuple[np.ndarray, int]:
    """Preferential attachment; directed new->target, social-network-like."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    repeated: list[int] = list(range(m_per_node))
    edges = []
    for v in range(m_per_node, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m_per_node)
        idx = rng.integers(0, len(repeated), size=m_per_node)
        targets = list({repeated[i] for i in idx})[:m_per_node]
        while len(targets) < m_per_node:
            targets.append(int(rng.integers(0, v + 1)))
    e = np.unique(np.asarray(edges, np.int64), axis=0)
    e = e[e[:, 0] != e[:, 1]]
    return e.astype(np.int32), n


def grid_edges(side: int, seed: int = 0) -> Tuple[np.ndarray, int]:
    """2-D lattice digraph (road-network-like: avg degree ~4, diameter
    ~2·side).  The high-diameter regime where frontier approaches win
    biggest (paper §5.2.2: road/k-mer graphs)."""
    n = side * side
    idx = np.arange(n).reshape(side, side)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:, 1:].ravel(), idx[:, :-1].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e.append(np.stack([idx[1:, :].ravel(), idx[:-1, :].ravel()], 1))
    edges = np.concatenate(e).astype(np.int32)
    return edges, n


def temporal_stream_edges(n: int, m: int, seed: int = 0,
                          locality: float = 0.9,
                          n_communities: int = 64) -> np.ndarray:
    """Timestamp-ordered edge stream with *localised* updates.

    Real-world dynamic graphs (paper §5.2.3) concentrate updates in
    specific regions, and the graphs have community structure that keeps
    rank perturbations from reaching most of the graph.  Model: vertices
    belong to Zipf-sized communities; an edge stays inside its source's
    community with prob. ``locality``, and consecutive edges reuse a
    drifting hot community.  Duplicates allowed (|E_T| ≫ |E| like SNAP).
    """
    rng = np.random.default_rng(seed)
    # Zipf community sizes
    sizes = 1.0 / np.arange(1, n_communities + 1) ** 0.8
    bounds = np.concatenate([[0], np.cumsum(sizes / sizes.sum())]) * n
    bounds = bounds.astype(np.int64)
    bounds[-1] = n

    def sample_dst(c, k):
        lo, hi = bounds[c], max(bounds[c] + 1, bounds[c + 1])
        return rng.integers(lo, hi, size=k)

    def sample_src(c):
        # Zipf-skewed source: few vertices per community source most
        # edges (SX: most users never answer) -> most vertices are pure
        # sinks whose only out-edge is the self-loop, which is what stops
        # frontier propagation on real graphs
        lo, hi = bounds[c], max(bounds[c] + 1, bounds[c + 1])
        size = hi - lo
        r = rng.zipf(1.6)
        return lo + min(r - 1, size - 1)

    src = np.zeros(m, np.int32)
    dst = np.zeros(m, np.int32)
    hot = rng.integers(0, n_communities)
    for i in range(m):
        if rng.random() > 0.98:                 # hot community drifts
            hot = rng.integers(0, n_communities)
        c = hot if rng.random() < locality else \
            rng.integers(0, n_communities)
        s = sample_src(c)
        c2 = c if rng.random() < locality else \
            rng.integers(0, n_communities)
        d = sample_dst(c2, 1)[0]
        if d == s:
            d = bounds[c2] + (s + 1 - bounds[c2]) % max(
                1, bounds[c2 + 1] - bounds[c2])
        src[i], dst[i] = s, d
    return np.stack([src, dst], 1)


def random_batch_update(edges_live: np.ndarray, n: int, batch_size: int,
                        seed: int = 0, frac_insert: float = 0.8
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §5.2.2: 80% random insertions, 20% deletions of existing edges."""
    rng = np.random.default_rng(seed)
    n_ins = int(round(batch_size * frac_insert))
    n_del = batch_size - n_ins
    ins = rng.integers(0, n, size=(n_ins, 2), dtype=np.int64)
    ins = ins[ins[:, 0] != ins[:, 1]]
    if len(edges_live) and n_del:
        idx = rng.choice(len(edges_live), size=min(n_del, len(edges_live)),
                         replace=False)
        dele = edges_live[idx]
    else:
        dele = np.zeros((0, 2), np.int64)
    return dele.astype(np.int32), ins.astype(np.int32)


class TemporalStream:
    """Paper §5.1.4 replay harness: 90% preload, then 100 insert batches."""

    def __init__(self, edges_temporal: np.ndarray, num_vertices: int,
                 batch_frac: float, num_batches: int = 100):
        self.edges = np.asarray(edges_temporal, np.int32)
        self.n = num_vertices
        total = len(self.edges)
        self.batch_size = max(1, int(round(batch_frac * total)))
        self.preload_end = int(0.9 * total)
        self.num_batches = min(
            num_batches,
            max(1, (total - self.preload_end) // self.batch_size))

    def preload_edges(self) -> np.ndarray:
        return self.edges[: self.preload_end]

    def batch(self, i: int) -> np.ndarray:
        lo = self.preload_end + i * self.batch_size
        return self.edges[lo: lo + self.batch_size]


STREAM_REGIMES = ("insert_only", "mixed", "delete_heavy")


def update_stream(scale: int = 6, edge_factor: int = 4, *,
                  regime: str = "mixed", graph: str = "rmat",
                  num_batches: int = 8, batch_size: int = 24,
                  seed: int = 0) -> Tuple[np.ndarray, int, list]:
    """Seeded dynamic-update stream for cross-engine differential testing.

    Returns ``(init_edges (k,2) int32, n, batches)`` where each batch is
    a ``(deletions (a,2), insertions (b,2))`` pair.  The generator keeps
    a host-side live-edge set so deletions target edges that exist;
    every batch also mixes in the no-op edge cases incremental engines
    must agree on (absent-edge deletions, duplicate-of-live insertions,
    in-batch duplicates, delete-then-reinsert of the same edge).

    ``graph``: "rmat" (skewed power-law, 2^scale vertices) or "uniform"
    (Erdős–Rényi at the same vertex/edge counts).  ``regime`` sets the
    deletion fraction per batch: "insert_only" 0, "mixed" ~1/3,
    "delete_heavy" ~2/3.
    """
    if regime not in STREAM_REGIMES:
        raise ValueError(f"unknown regime {regime!r}; one of "
                         f"{STREAM_REGIMES}")
    if graph == "rmat":
        edges, n = rmat_edges(scale, edge_factor, seed=seed)
    elif graph == "uniform":
        n = 2 ** scale
        edges, _ = erdos_renyi_edges(n, n * edge_factor, seed=seed)
    else:
        raise ValueError(f"unknown graph kind {graph!r}")
    edges = np.unique(edges, axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]].astype(np.int32)
    rng = np.random.default_rng(seed + 1)
    live = {tuple(e) for e in edges.tolist()}

    n_del = {"insert_only": 0, "mixed": batch_size // 3,
             "delete_heavy": (2 * batch_size) // 3}[regime]
    n_ins = batch_size - n_del
    batches = []
    for _ in range(num_batches):
        dels = []
        if n_del and live:
            pool = sorted(live)
            picks = rng.choice(len(pool), size=min(n_del, len(pool)),
                               replace=False)
            dels = [pool[i] for i in picks]
        # absent-edge deletion: must be a no-op on every engine
        u, v = rng.integers(0, n, size=2)
        if u != v and (int(u), int(v)) not in live:
            dels.append((int(u), int(v)))
        e = rng.integers(0, n, size=(n_ins, 2))
        ins = [tuple(x) for x in e[e[:, 0] != e[:, 1]].tolist()]
        if ins:
            ins.append(ins[0])                    # in-batch duplicate
        if dels:
            ins.append(dels[0])                   # delete -> reinsert
        live -= set(dels)
        live |= set(ins)
        batches.append((
            np.asarray(dels, np.int32).reshape(-1, 2),
            np.asarray(ins, np.int32).reshape(-1, 2)))
    return edges, n, batches
