"""Serving counters: update latency, query-visible staleness, work.

Everything is recorded host-side (plain floats/ints appended to lists)
so the hot path never syncs the device beyond what the engine already
does, and ``as_dict`` reduces to the numbers the bench harness and the
CLI report:

  * ``update_latency_{p50,p99}_ms`` — wall time of one engine step
    (apply_batch + rank update + publish);
  * ``staleness_{p50,p99}_events`` — at each query, how many accepted
    events the served snapshot is behind the newest submitted one
    (freshness in *events*, the unit the paper's batch fractions use);
  * ``events_per_s`` — applied events over the span between the first
    and last completed batch;
  * ``affected_mean`` / ``iterations_mean`` — per-batch |affected| and
    solver iterations (the paper's work proxies);
  * ``edges_processed`` / ``vertices_processed`` — the engines'
    window-granular (kernel) or per-vertex (XLA) work counters summed
    over all batches, so serving cost is comparable across engines and
    mesh sizes in the same units as ``PageRankResult``;
  * ``packed_rebuilds`` (+ ``packed_rebuilds_by_shard`` on the sharded
    kernel path) — spill/overlay/budget overflow repacks, attributed to
    the shards that overflowed;
  * ``comm_bytes`` — per-iteration cross-shard wire traffic summed over
    all batches (halo exchange on the sharded kernel path; 0 single-pod)
    — the observable the boundary-exchange win shows up in;
  * ``device_programs_per_batch`` — compiled maintenance+solve programs
    launched per micro-batch (the fused update+sweep path is 1 per f32
    phase vs 2 unfused, +1 when the f64 polish runs);
  * admission/fallback/coalescing counters.
"""
from __future__ import annotations

import time
from collections import Counter
from typing import List, Optional, Sequence

import numpy as np


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class ServeMetrics:
    def __init__(self, clock=time.monotonic):
        self._clock = clock
        # per-batch
        self.update_latency_s: List[float] = []
        self.batch_events: List[int] = []
        self.batch_affected: List[int] = []
        self.batch_iterations: List[int] = []
        self.events_applied = 0
        self.events_coalesced = 0
        self.static_fallbacks = 0
        self.budget_carryover = 0   # batches seeded with a carried frontier
        self.walks_resampled = 0
        self.packed_rebuilds = 0   # kernel engine spill-overflow repacks
        self.packed_rebuilds_by_shard: Counter = Counter()
        self.edges_processed = 0
        self.vertices_processed = 0
        self.comm_bytes = 0
        self.batch_device_programs: List[int] = []
        self._t_first_batch = None
        self._t_last_batch = None
        # queries
        self.query_staleness: List[int] = []
        self.queries_served = 0
        # admission
        self.accepted = 0
        self.rejected = 0
        # point-in-time gauges set by the owner (serve engine): values
        # that live on engine attributes — halo occupancy, tune-cache
        # hits, staleness-in-events — so as_dict is the ONE reporting
        # surface and the exporter never reaches into the engine
        self.gauges: dict = {}
        # per-batch frontier-telemetry digests (obs.frontier summaries);
        # recorded only when telemetry is on, so usually empty
        self.frontier_summaries: List[dict] = []

    # ---- recording -------------------------------------------------------
    def record_admission(self, accepted: bool):
        if accepted:
            self.accepted += 1
        else:
            self.rejected += 1

    def record_batch(self, latency_s: float, num_events: int,
                     num_coalesced: int, affected: int, iterations: int,
                     fallback: bool, walks_resampled: int = 0,
                     edges_processed: int = 0, vertices_processed: int = 0,
                     comm_bytes: int = 0, device_programs: int = 0):
        now = self._clock()
        if self._t_first_batch is None:
            self._t_first_batch = now
        self._t_last_batch = now
        self.update_latency_s.append(float(latency_s))
        self.batch_events.append(int(num_events))
        self.batch_affected.append(int(affected))
        self.batch_iterations.append(int(iterations))
        self.events_applied += int(num_events)
        self.events_coalesced += int(num_coalesced)
        self.walks_resampled += int(walks_resampled)
        self.edges_processed += int(edges_processed)
        self.vertices_processed += int(vertices_processed)
        self.comm_bytes += int(comm_bytes)
        self.batch_device_programs.append(int(device_programs))
        if fallback:
            self.static_fallbacks += 1

    def record_packed_rebuild(self, shards: Optional[Sequence[int]] = None):
        """One overflow repack; ``shards`` names the overflowing shards
        on the sharded kernel path (None/empty = single-pod)."""
        self.packed_rebuilds += 1
        for s in shards or ():
            self.packed_rebuilds_by_shard[int(s)] += 1

    def record_budget_carryover(self):
        """One batch whose seed set folded in an unconverged frontier
        carried from a budget-capped previous batch."""
        self.budget_carryover += 1

    def record_query(self, staleness_events: int):
        self.queries_served += 1
        self.query_staleness.append(int(staleness_events))

    def set_gauge(self, name: str, value: float):
        """Set/overwrite a point-in-time gauge (snake_case name)."""
        self.gauges[str(name)] = float(value)

    def record_frontier(self, summary: dict):
        """One batch's frontier-telemetry digest
        (``FrontierTelemetry.summary()``)."""
        self.frontier_summaries.append(dict(summary))

    # ---- reduction -------------------------------------------------------
    def as_dict(self) -> dict:
        lat = self.update_latency_s
        span = ((self._t_last_batch - self._t_first_batch)
                if self._t_first_batch is not None else 0.0)
        # events/s needs a span; a single batch contributes its own latency
        denom = span if span > 0 else (lat[0] if lat else 0.0)
        out = dict(
            batches=len(lat),
            events_applied=self.events_applied,
            events_coalesced=self.events_coalesced,
            events_per_s=(self.events_applied / denom) if denom > 0 else 0.0,
            update_latency_p50_ms=_pct(lat, 50) * 1e3,
            update_latency_p99_ms=_pct(lat, 99) * 1e3,
            staleness_p50_events=_pct(self.query_staleness, 50),
            staleness_p99_events=_pct(self.query_staleness, 99),
            queries_served=self.queries_served,
            affected_mean=(float(np.mean(self.batch_affected))
                           if self.batch_affected else 0.0),
            iterations_mean=(float(np.mean(self.batch_iterations))
                             if self.batch_iterations else 0.0),
            static_fallbacks=self.static_fallbacks,
            budget_carryover=self.budget_carryover,
            walks_resampled=self.walks_resampled,
            edges_processed=self.edges_processed,
            vertices_processed=self.vertices_processed,
            comm_bytes=self.comm_bytes,
            device_programs_per_batch=(
                float(np.mean(self.batch_device_programs))
                if self.batch_device_programs else 0.0),
            packed_rebuilds=self.packed_rebuilds,
            packed_rebuilds_by_shard={
                str(k): v
                for k, v in sorted(self.packed_rebuilds_by_shard.items())},
            admission_accepted=self.accepted,
            admission_rejected=self.rejected,
        )
        if self.frontier_summaries:
            fs = self.frontier_summaries
            out["frontier_batches"] = len(fs)
            out["frontier_iterations_mean"] = float(
                np.mean([s.get("iterations", 0) for s in fs]))
            out["frontier_affected_peak_mean"] = float(
                np.mean([s.get("affected_peak", 0.0) for s in fs]))
            out["frontier_residual_final"] = float(
                fs[-1].get("residual_final", 0.0))
        # gauges last, but core counters always win a name collision
        for k, v in sorted(self.gauges.items()):
            out.setdefault(k, v)
        return out
