"""Chaos harness for the replication tier: seeded faults, provable recovery.

Three pieces, all deterministic under a seed + logical clock:

* ``FaultyTransport`` — the in-process wire between the writer and its
  replicas.  Every message independently risks **drop**, **duplicate**,
  **reorder** (extra random delay) and constant **delay**; nodes can be
  **partitioned** (both planes fail: data-plane messages vanish,
  control-plane calls raise ``LinkDown``) or **down** (process death).
  The control plane (``writer_for``) models an RPC to the writer:
  partitions and a killed writer make it raise, which is what the
  replica's retry/backoff machinery has to survive.

* ``ChaosSchedule`` — declarative, seeded fault injection keyed to
  *event offsets* (not wall time) so every run is reproducible::

      partition:r1@300+200;kill:r0@600+200;kill_writer@900;delay:r1@50+100

  grammar ``kind[:target]@at[+duration]`` with kinds ``kill`` (process
  death, restarted as a late joiner after ``duration``), ``partition``
  (healed after ``duration``), ``delay`` (extra link latency on the
  target for ``duration``), and ``kill_writer`` (heartbeat failover).

* ``ChaosHarness`` — drives a real ``ServeEngine`` writer + N
  ``ReadReplica``s over a seeded event feed on a logical clock, applies
  the schedule, performs heartbeat failover via ``FailoverController``
  (rewinding the feed cursor to the promoted frontier, so no committed
  event is skipped), and **asserts recovery to writer parity after
  every recovery point** (heal / restart / failover) and at the end:
  every alive replica at the writer's generation must match its ranks
  to L∞ ≤ ``parity_tol`` (1e-6).  The run returns a ``ChaosReport``
  with the parity record, incident counts, and per-node counters — the
  CI chaos lane greps its printed form for ``replica_resync`` and
  ``slo_burn``.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import Counter
from typing import Dict, List, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.ft.elastic import ReplicaRoster
from repro.graph.generators import rmat_edges
from repro.graph.structure import from_coo
from repro.serve.engine import ServeEngine
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.replicate import FailoverController, ReadReplica, \
    ReplicationWriter
from repro.serve.state import RankStore

__all__ = ["ChaosAction", "ChaosHarness", "ChaosReport", "FaultyTransport",
           "LinkDown", "LogicalClock", "parse_schedule"]


class LinkDown(RuntimeError):
    """Control-plane call across a partition / to a dead node."""


class LogicalClock:
    """Injected monotone clock: ``clock()`` reads, ``advance`` moves."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


class FaultyTransport:
    """Seeded fault-injectable in-process message fabric."""

    def __init__(self, seed: int = 0, drop_p: float = 0.0,
                 dup_p: float = 0.0, reorder_p: float = 0.0,
                 reorder_window: float = 0.2, delay: float = 0.0):
        self.rng = np.random.default_rng(seed)
        self.drop_p = float(drop_p)
        self.dup_p = float(dup_p)
        self.reorder_p = float(reorder_p)
        self.reorder_window = float(reorder_window)
        self.delay = float(delay)
        self._inbox: Dict[str, list] = {}   # heap of (due, n, msg)
        self._n = 0
        self.partitioned: set = set()
        self.down: set = set()
        self.extra_delay: Dict[str, float] = {}
        self.writer_obj: Optional[ReplicationWriter] = None
        self.dropped = 0
        self.duplicated = 0
        self.reordered = 0
        self.delivered = 0

    # -- membership --
    def register(self, name: str) -> None:
        self._inbox.setdefault(name, [])

    def unregister(self, name: str) -> None:
        self._inbox.pop(name, None)
        self.down.discard(name)
        self.partitioned.discard(name)

    def set_writer(self, writer: ReplicationWriter) -> None:
        self.writer_obj = writer

    # -- fault controls --
    def partition(self, name: str) -> None:
        self.partitioned.add(name)

    def heal(self, name: str) -> None:
        self.partitioned.discard(name)

    def kill(self, name: str) -> None:
        """Process death: node unreachable AND its inbox is lost."""
        self.down.add(name)
        self._inbox[name] = []

    def revive(self, name: str) -> None:
        self.down.discard(name)

    def link_up(self, a: str, b: str) -> bool:
        return not ({a, b} & self.partitioned or {a, b} & self.down)

    # -- data plane --
    def _push(self, src: str, dst: str, msg, now: float) -> None:
        if not self.link_up(src, dst):
            self.dropped += 1
            return
        copies = 1
        if self.dup_p and self.rng.random() < self.dup_p:
            copies = 2
            self.duplicated += 1
        for _ in range(copies):
            due = now + self.delay + self.extra_delay.get(dst, 0.0) \
                + self.extra_delay.get(src, 0.0)
            if self.drop_p and self.rng.random() < self.drop_p:
                self.dropped += 1
                continue
            if self.reorder_p and self.rng.random() < self.reorder_p:
                due += float(self.rng.uniform(0.0, self.reorder_window))
                self.reordered += 1
            self._n += 1
            heapq.heappush(self._inbox[dst], (due, self._n, msg))

    def broadcast(self, src: str, msg, now: float) -> None:
        for dst in self._inbox:
            if dst != src:
                self._push(src, dst, msg, now)

    def send(self, src: str, dst: str, msg, now: float) -> None:
        if dst in self._inbox:
            self._push(src, dst, msg, now)

    def deliver(self, dst: str, now: float) -> list:
        """Due messages for ``dst``, in due order.  A down node gets
        nothing (its process isn't running)."""
        if dst in self.down:
            return []
        box = self._inbox.get(dst, [])
        out = []
        while box and box[0][0] <= now:
            out.append(heapq.heappop(box)[2])
        self.delivered += len(out)
        return out

    # -- control plane --
    def writer_for(self, caller: str) -> ReplicationWriter:
        """The current writer, as an RPC: raises ``LinkDown`` across a
        partition or when the writer process is dead."""
        w = self.writer_obj
        if w is None or not w.alive:
            raise LinkDown(f"{caller}: writer is down")
        if not self.link_up(caller, w.name):
            raise LinkDown(f"{caller}: link to {w.name} is partitioned")
        return w


# ---- declarative schedule ------------------------------------------------

_KINDS = ("kill", "restart", "partition", "delay", "kill_writer")


class ChaosAction(NamedTuple):
    kind: str                 # one of _KINDS
    target: Optional[str]     # replica name; None for kill_writer
    at: int                   # event offset the fault fires at
    duration: Optional[int]   # offsets until heal/restart; None = forever


def parse_schedule(spec: str) -> List[ChaosAction]:
    """``kind[:target]@at[+duration]`` terms, semicolon-separated."""
    actions = []
    for term in filter(None, (t.strip() for t in spec.split(";"))):
        head, _, when = term.partition("@")
        if not when:
            raise ValueError(f"chaos term {term!r}: missing '@offset'")
        kind, _, target = head.partition(":")
        if kind not in _KINDS:
            raise ValueError(f"chaos term {term!r}: unknown kind {kind!r} "
                             f"(options {_KINDS})")
        if kind == "kill_writer" and target:
            raise ValueError(f"chaos term {term!r}: kill_writer takes no "
                             "target")
        if kind != "kill_writer" and not target:
            raise ValueError(f"chaos term {term!r}: {kind} needs a target")
        at, _, dur = when.partition("+")
        actions.append(ChaosAction(kind, target or None, int(at),
                                   int(dur) if dur else None))
    return sorted(actions, key=lambda a: a.at)


# ---- harness -------------------------------------------------------------

@dataclasses.dataclass
class ChaosReport:
    events_fed: int = 0
    generations: int = 0
    failovers: int = 0
    resyncs: int = 0
    parity_checks: int = 0
    parity_max_linf: float = 0.0
    max_staleness: int = 0
    degraded_spells: int = 0
    incidents: Counter = dataclasses.field(default_factory=Counter)
    transport: dict = dataclasses.field(default_factory=dict)

    def lines(self) -> List[str]:
        out = [f"events_fed={self.events_fed} generations="
               f"{self.generations} failovers={self.failovers} "
               f"resyncs={self.resyncs}",
               f"parity: checks={self.parity_checks} "
               f"max_linf={self.parity_max_linf:.3e}",
               f"staleness: max={self.max_staleness} "
               f"degraded_spells={self.degraded_spells}"]
        for kind, n in sorted(self.incidents.items()):
            out.append(f"incident {kind} x{n}")
        out.append("transport " + " ".join(
            f"{k}={v}" for k, v in sorted(self.transport.items())))
        return out


class ChaosHarness:
    """Deterministic writer + replicas + schedule + parity assertions."""

    def __init__(self, num_replicas: int = 2, events: int = 1200,
                 schedule: str = "", seed: int = 0,
                 scale: int = 9, edge_factor: int = 8,
                 flush_size: int = 16, step_every: int = 16,
                 hb_every: int = 8, dt: float = 0.01,
                 drop_p: float = 0.0, dup_p: float = 0.0,
                 reorder_p: float = 0.0,
                 staleness_slo_events: int = 256,
                 heartbeat_timeout: float = 0.5,
                 anchor_every: int = 8, ckpt_every: int = 8,
                 ckpt_dir: Optional[str] = None,
                 parity_tol: float = 1e-6, method: str = "frontier_prune",
                 slo_windows=((2.0, 2.0),), slo_min_events: int = 8,
                 max_retries: int = 3, backoff_base: float = 0.02,
                 verbose: bool = False, **engine_kw):
        self.clock = LogicalClock()
        self.parity_tol = float(parity_tol)
        self.step_every = step_every
        self.hb_every = hb_every
        self.dt = float(dt)
        self.verbose = verbose
        self.report = ChaosReport()
        rng = np.random.default_rng(seed)
        edges, self.n = rmat_edges(scale, edge_factor, seed=seed)
        cap = len(edges) + 4 * events
        self.base_graph = from_coo(edges[:, 0], edges[:, 1], self.n,
                                   edge_capacity=cap)
        # seeded feed: mostly inserts, some deletes of earlier inserts
        self.events: List[tuple] = []
        live: List[tuple] = []
        while len(self.events) < events:
            if live and rng.random() < 0.15:
                self.events.append(("delete",) + live.pop(
                    int(rng.integers(len(live)))))
            else:
                u, v = (int(x) for x in rng.integers(0, self.n, size=2))
                if u == v:
                    continue
                self.events.append(("insert", u, v))
                live.append((u, v))
        self.transport = FaultyTransport(
            seed=seed + 1, drop_p=drop_p, dup_p=dup_p, reorder_p=reorder_p,
            reorder_window=4 * dt, delay=0.0)
        self.roster = ReplicaRoster(heartbeat_timeout=heartbeat_timeout)
        self._mk_replica = lambda name: ReadReplica(
            name, self.transport, self.n, roster=self.roster,
            staleness_slo_events=staleness_slo_events,
            max_retries=max_retries, backoff_base=backoff_base,
            slo_windows=slo_windows, slo_min_events=slo_min_events,
            seed=seed, clock=self.clock)
        self._engine_kw = dict(method=method, **engine_kw)
        self._flush_size = flush_size
        self._ckpt_dir = ckpt_dir
        self._ckpt_every = ckpt_every
        engine = self._engine_factory(self.base_graph, last_seq=-1,
                                      generation=0)
        engine.bootstrap()
        self.writer = ReplicationWriter(
            engine, self.transport, epoch=0, anchor_every=anchor_every,
            clock=self.clock)
        self.writer.attach()
        self.transport.set_writer(self.writer)
        self.writer.heartbeat(self.roster)
        self.controller = FailoverController(
            self.transport, self.roster, self._engine_factory,
            ckpt_dir=ckpt_dir, num_vertices=self.n,
            rebuild_graph=self._graph_at, clock=self.clock)
        self.replicas: List[ReadReplica] = []
        self.dead_replicas: Dict[str, int] = {}   # name -> restart offset
        for i in range(num_replicas):
            r = self._mk_replica(f"r{i}")
            assert r.bootstrap(), "bootstrap against a healthy writer"
            self.replicas.append(r)
        self.schedule = parse_schedule(schedule) if schedule else []
        self._fired: set = set()
        # expand durations into an offset -> [op] timeline
        self.timeline: Dict[int, List[tuple]] = {}
        for a in self.schedule:
            self.timeline.setdefault(a.at, []).append(("open", a))
            if a.duration is not None:
                self.timeline.setdefault(a.at + a.duration, []).append(
                    ("close", a))

    # -- construction helpers --
    def _engine_factory(self, graph, last_seq: int,
                        generation: int) -> ServeEngine:
        ingest = IngestQueue(flush_size=self._flush_size,
                             flush_interval=0.0,
                             max_pending=1 << 20,
                             start_seq=last_seq + 1, clock=self.clock)
        store = RankStore(ckpt_dir=self._ckpt_dir,
                          ckpt_every=self._ckpt_every)
        return ServeEngine(graph, ingest, store, metrics=ServeMetrics(),
                           clock=self.clock, **self._engine_kw)

    def _graph_at(self, last_seq: int):
        """Graph with events[0..last_seq] applied — the event feed is
        the graph's log (checkpoint-ahead failover path)."""
        g = self.base_graph
        src = np.asarray(g.src).copy()
        dst = np.asarray(g.dst).copy()
        valid = np.asarray(g.valid).copy()
        n_edges = int(np.asarray(g.num_edges))
        pos = {}
        for i in range(n_edges):
            if valid[i]:
                pos[(int(src[i]), int(dst[i]))] = i
        for kind, u, v in self.events[: last_seq + 1]:
            if kind == "insert":
                if (u, v) not in pos:
                    src[n_edges], dst[n_edges] = u, v
                    valid[n_edges] = True
                    pos[(u, v)] = n_edges
                    n_edges += 1
            else:
                i = pos.pop((u, v), None)
                if i is not None:
                    valid[i] = False
        return dataclasses.replace(
            self.base_graph, src=jnp.asarray(src), dst=jnp.asarray(dst),
            valid=jnp.asarray(valid),
            num_edges=jnp.asarray(np.int32(n_edges)))

    # -- chaos ops --
    def _apply_ops(self, offset: int) -> bool:
        """Fire due chaos ops; True if a recovery point occurred.

        Each op fires at most once: a failover rewinds the feed cursor
        over already-passed offsets, and a fault re-firing on the replay
        (killing every successive writer at the same offset) would model
        a *periodic* fault, not the scheduled one-shot.
        """
        recovered = False
        for phase, a in self.timeline.get(offset, ()):  # in spec order
            if (phase, a) in self._fired:
                continue
            self._fired.add((phase, a))
            opening = phase == "open"
            if a.kind == "kill_writer" and opening:
                self.writer.kill()
                self._log(f"@{offset} chaos: kill_writer "
                          f"(epoch {self.writer.epoch})")
            elif a.kind == "partition":
                if opening:
                    self.transport.partition(a.target)
                    self._log(f"@{offset} chaos: partition {a.target}")
                else:
                    self.transport.heal(a.target)
                    self._log(f"@{offset} chaos: heal {a.target}")
                    recovered = recovered or not opening
            elif a.kind == "delay":
                self.transport.extra_delay[a.target] = \
                    8 * self.dt if opening else 0.0
                self._log(f"@{offset} chaos: delay {a.target} "
                          f"{'on' if opening else 'off'}")
                recovered = recovered or not opening
            elif a.kind in ("kill", "restart"):
                if opening and a.kind == "kill":
                    self._kill_replica(a.target)
                    self._log(f"@{offset} chaos: kill {a.target}")
                else:
                    self._restart_replica(a.target)
                    self._log(f"@{offset} chaos: restart {a.target}")
                    recovered = True
        return recovered

    def _kill_replica(self, name: str) -> None:
        self.transport.kill(name)
        for r in self.replicas:
            if r.name == name:
                r.leave()
        self.replicas = [r for r in self.replicas if r.name != name]

    def _restart_replica(self, name: str) -> None:
        self.transport.revive(name)
        r = self._mk_replica(name)     # fresh process: late joiner
        r.bootstrap()
        self.replicas.append(r)

    # -- main loop --
    def _maybe_failover(self, cursor: int) -> Optional[int]:
        """Heartbeat + failover check; returns the rewound feed cursor
        (no committed event skipped) when a promotion happened."""
        self.writer.heartbeat(self.roster)
        promoted = self.controller.check(self.writer, self.replicas)
        if promoted is None:
            return None
        new_writer, promoted_replica = promoted
        self._log(f"@{cursor} failover: epoch {self.writer.epoch} -> "
                  f"{new_writer.epoch}, feed resumes at seq "
                  f"{new_writer.engine.ingest.start_seq}")
        if promoted_replica is not None:
            self.replicas = [r for r in self.replicas
                             if r is not promoted_replica]
            self.transport.unregister(promoted_replica.name)
        self.writer = new_writer
        self.transport.set_writer(new_writer)
        return new_writer.engine.ingest.start_seq

    def run(self) -> ChaosReport:
        cursor = 0
        since_step = since_hb = 0
        while cursor < len(self.events):
            self.clock.advance(self.dt)
            recovered = self._apply_ops(cursor)
            kind, u, v = self.events[cursor]
            ingest = self.writer.engine.ingest
            assert ingest.submit(kind, u, v) == cursor, \
                "harness feed must map offsets 1:1 onto ingest seqs"
            cursor += 1
            since_step += 1
            since_hb += 1
            if since_step >= self.step_every:
                since_step = 0
                if self.writer.alive:
                    self.writer.engine.step(force=True)
            if since_hb >= self.hb_every:
                since_hb = 0
                rewound = self._maybe_failover(cursor)
                if rewound is not None:
                    cursor = rewound
                    recovered = True
            for r in self.replicas:
                r.pump()
                self.report.max_staleness = max(self.report.max_staleness,
                                                r.staleness)
            if recovered:
                self._converge_and_check_parity()
        # a writer killed inside the last heartbeat interval still fails
        # over (and the feed tail beyond the promoted frontier re-feeds)
        if not self.writer.alive:
            rewound = self._maybe_failover(cursor)
            if rewound is not None and rewound < len(self.events):
                for seq in range(rewound, len(self.events)):
                    kind, u, v = self.events[seq]
                    assert self.writer.engine.ingest.submit(
                        kind, u, v) == seq
        if self.writer.alive:
            self.writer.engine.step(force=True)
        self._converge_and_check_parity()
        return self._finalize()

    # -- parity --
    def _converge_and_check_parity(self, max_rounds: int = 400) -> None:
        """Quiesce the stream, then L∞-compare every alive replica at
        the writer's generation against the writer's ranks."""
        w = self.writer
        while w.engine.ingest.pending():
            w.engine.step(force=True)
        target = w.next_seq - 1
        for _ in range(max_rounds):
            # advance past any backoff/delay so retries actually fire
            self.clock.advance(max(self.dt, 0.05))
            w.heartbeat(self.roster)
            live = [r for r in self.replicas
                    if r.name not in self.transport.down
                    and r.name not in self.transport.partitioned]
            for r in live:
                r.pump()
            if all(r.epoch == w.epoch and r.applied_seq >= target
                   for r in live):
                break
        else:
            raise AssertionError(
                f"replicas failed to reconverge to seq {target}: "
                + ", ".join(f"{r.name}@{r.epoch}/{r.applied_seq}"
                            for r in self.replicas))
        wr = np.asarray(w.engine.store.snapshot().ranks)
        wgen = w.engine.store.generation
        for r in live:
            assert r.generation == wgen, \
                f"{r.name} at gen {r.generation}, writer at {wgen}"
            linf = float(np.max(np.abs(r.ranks - wr))) if len(wr) else 0.0
            self.report.parity_max_linf = max(self.report.parity_max_linf,
                                              linf)
            assert linf <= self.parity_tol, \
                f"{r.name} diverged: L∞={linf:.3e} at gen {wgen}"
        self.report.parity_checks += 1
        self._log(f"parity OK at gen {wgen} "
                  f"(checks={self.report.parity_checks}, "
                  f"L∞max={self.report.parity_max_linf:.2e})")

    def _finalize(self) -> ChaosReport:
        rep = self.report
        rep.events_fed = len(self.events)
        rep.generations = self.writer.engine.store.generation
        rep.failovers = self.controller.failovers
        for src in list(self.replicas) + [self.controller]:
            for inc in src.incidents:
                rep.incidents[inc.kind] += 1
        rep.resyncs = sum(r.resyncs for r in self.replicas)
        rep.degraded_spells = rep.incidents.get("replica_degraded", 0)
        rep.transport = dict(
            dropped=self.transport.dropped,
            duplicated=self.transport.duplicated,
            reordered=self.transport.reordered,
            delivered=self.transport.delivered)
        return rep

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[chaos t={self.clock.t:8.2f}] {msg}", flush=True)
