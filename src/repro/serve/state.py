"""Double-buffered rank snapshots + checkpointed restart.

The engine mutates a *back* state (graph, ranks) batch after batch;
``publish`` atomically swaps a new immutable ``Snapshot`` in as the
*front* buffer.  Queries read the front pointer under a lock that is
held only for the pointer copy, so a query never observes a torn
(graph, ranks) pair and never blocks on an in-flight update — the
staleness cost is bounded by one micro-batch (see ``ingest``).

``generation`` increments on every publish and is the serving system's
logical clock: tests assert it is monotone, queries report it, and the
checkpoint step is keyed by it.  ``last_seq`` records the newest ingest
event folded into the snapshot, which is what query-visible staleness
(in events) is measured against.

Checkpointing reuses ``ft.checkpoint`` (atomic manifest + rename):
(ranks, generation, last_seq) every ``ckpt_every`` generations.  The
graph itself is NOT checkpointed — restart replays the event log up to
``last_seq`` (launch/serve.py does this), the same replay-from-stream
contract as launch/pagerank.py.  The PPR walk index is not checkpointed
either: its sampling is a pure function of (graph, config seed), so the
restarted engine rebuilds it bit-identically from the replayed graph.
"""
from __future__ import annotations

import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.ft.checkpoint import CheckpointManager
from repro.graph.structure import EdgeListGraph


class Snapshot(NamedTuple):
    graph: EdgeListGraph
    ranks: jax.Array     # f64[V]
    generation: int      # publish counter, monotone from 0
    last_seq: int        # newest ingest seq reflected in `ranks`
    # walk index maintained for THIS graph (repro.ppr), or None when the
    # engine runs without one.  Riding in the snapshot gives PPR queries
    # the same consistency contract as ranks: the index generation IS
    # `generation`, and a query never sees an index that lags the graph.
    ppr_index: Optional[object] = None


class RankStore:
    """Front-buffer snapshot holder with optional periodic checkpoints."""

    def __init__(self, ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
                 keep_last: int = 3):
        self._lock = threading.Lock()
        self._snap: Optional[Snapshot] = None
        self._next_gen = 0
        self._mgr = (CheckpointManager(ckpt_dir, every=max(1, ckpt_every),
                                       keep_last=keep_last)
                     if ckpt_dir else None)

    def seed_generation(self, generation: int):
        """Continue the generation clock from a restored checkpoint, so it
        stays monotone across restarts (the restored snapshot is re-published
        at its own generation)."""
        with self._lock:
            self._next_gen = generation

    def publish(self, graph: EdgeListGraph, ranks: jax.Array,
                last_seq: int, ppr_index=None) -> int:
        """Swap in a new front snapshot; returns its generation."""
        with self._lock:
            gen = self._next_gen
            self._next_gen += 1
            self._snap = Snapshot(graph, ranks, gen, int(last_seq),
                                  ppr_index)
        if self._mgr is not None:
            # gen 0 (the bootstrap snapshot) satisfies `gen % every == 0`,
            # so a restart never has to redo the cold static solve
            self._mgr.maybe_save(gen, self._ckpt_state(self._snap))
        return gen

    @staticmethod
    def _ckpt_state(snap: Snapshot) -> dict:
        return dict(ranks=snap.ranks,
                    generation=jnp.asarray(snap.generation, jnp.int64),
                    last_seq=jnp.asarray(snap.last_seq, jnp.int64))

    def snapshot(self) -> Snapshot:
        """The current front buffer (raises before the first publish)."""
        with self._lock:
            if self._snap is None:
                raise RuntimeError("RankStore has no published snapshot yet "
                                   "(call ServeEngine.bootstrap first)")
            return self._snap

    @property
    def generation(self) -> int:
        with self._lock:
            return -1 if self._snap is None else self._snap.generation

    def restore_latest(self, num_vertices: int):
        """(ranks, generation, last_seq) of the newest checkpoint, or None."""
        if self._mgr is None:
            return None
        target = dict(
            ranks=jax.ShapeDtypeStruct((num_vertices,), jnp.float64),
            generation=jax.ShapeDtypeStruct((), jnp.int64),
            last_seq=jax.ShapeDtypeStruct((), jnp.int64))
        step, state = self._mgr.restore_latest(target)
        if state is None:
            return None
        return state["ranks"], int(state["generation"]), \
            int(state["last_seq"])
