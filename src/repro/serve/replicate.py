"""Read-replica replication: one writer, N replicas, bounded staleness.

The paper's DF-P property — a batch update perturbs only the affected
vertex set — makes a published generation differ from its predecessor
by a tiny sparse rank delta, which is what makes this tier cheap: the
writer (the existing ``ServeEngine``, hooked via ``on_publish``) emits
one generation-stamped ``DeltaMsg`` per publish carrying

  * the exact sparse rank delta — indices where the new f64 rank vector
    differs bitwise from the previous one, plus the new values;
  * the coalesced ``BatchUpdate`` leaves (host copies), so replicas
    maintain their own graph with the same ``apply_batch`` the writer
    ran — replica state is writer state, reproduced;
  * the wire header: ``epoch`` (increments on writer failover), ``seq``
    (contiguous per epoch from 0), ``generation`` and ``last_seq`` (the
    serving clocks of state.py).

PPR replication rides the same stream: walk-index sampling is a pure
function of (graph, config seed), so a replica configured with the
writer's ``IndexConfig`` repairs its index from each delta's touched
set (``repair_walk_index``) and stays bit-identical to the writer's
without any walk data on the wire (DESIGN.md §6 determinism contract).
Anchors carry the writer's index *identity* (statics + base key): a
resyncing replica whose live index matches it heals by repairing the
walks crossing the edge slots the anchor graph rewrote — same bitwise
result as the from-scratch rebuild this path used to run, at the cost
of the missed deltas instead of O(V·R·L).

Periodic full-state **anchors** reuse the flight-recorder anchor format
(obs/recorder.py: ``ranks`` + ``graph_*`` host arrays): a late joiner
bootstraps from the newest anchor plus the replayed delta tail, and a
replica that exhausts its retry budget resyncs the same way.

Fault tolerance (the reason this module exists):

  * **gap detection** — deltas apply strictly in seq order; a gap
    (buffered out-of-order delivery, or a heartbeat showing the writer
    is ahead) triggers bounded-retry retransmission with exponential
    backoff + deterministic jitter, then an anchor resync on give-up;
  * **heartbeat failover** — ``FailoverController`` watches the
    writer's beats in the ``ft.elastic.ReplicaRoster``; on expiry it
    promotes the freshest state among (alive replicas, last committed
    RankStore checkpoint), so no committed generation is ever lost,
    bumps the epoch, and the new writer's bootstrap anchor forces the
    surviving replicas to converge on it;
  * **graceful degradation** — a replica whose staleness-in-events
    exceeds its SLO marks itself degraded: point queries keep working
    (answers always carry ``staleness_events``), top-k/PPR are
    optionally shed (``shed_on_degrade``), an ``obs.slo.SloTracker``
    burns the staleness error budget and emits ``slo_burn`` incidents
    through the same ``Incident`` schema the monitor uses.

Transport is injected (``serve.chaos.FaultyTransport`` in tests, or
anything with the same ``broadcast``/``send``/``deliver``/``check_link``
surface); time is an injected clock, so every retry/backoff/failover
decision is deterministic under the chaos harness.
"""
from __future__ import annotations

import time
import zlib
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.elastic import ReplicaRoster, rescale_serving_state
from repro.graph.dynamic import BatchUpdate, apply_batch, \
    touched_vertices_mask
from repro.graph.structure import EdgeListGraph
from repro.obs.sentinel import WARN, Incident
from repro.obs.slo import SloTracker
from repro.ppr import IndexConfig, build_walk_index, repair_walk_index
from repro.serve.query import QueryClient, QueryResult
from repro.serve.state import RankStore

__all__ = [
    "AnchorMsg", "DeltaMsg", "FailoverController", "Heartbeat",
    "ReadReplica", "ReplicaDegradedError", "ReplicaQueryClient",
    "ReplicationWriter",
]


# ---- wire format ---------------------------------------------------------

class DeltaMsg(NamedTuple):
    """One publish, as shipped: sparse rank delta + the update itself."""
    epoch: int           # writer incarnation; bumps on failover
    seq: int             # contiguous per epoch, from 0
    generation: int      # snapshot generation this delta produces
    last_seq: int        # newest ingest event folded into `generation`
    rank_idx: np.ndarray  # int64[k] vertices whose rank changed
    rank_val: np.ndarray  # f64[k] their new ranks (exact, bitwise)
    update: Dict[str, np.ndarray]   # BatchUpdate leaves, host copies


class AnchorMsg(NamedTuple):
    """Full state at a generation, flight-recorder anchor format."""
    epoch: int
    seq: int             # deltas <= seq are folded in (-1: none yet)
    generation: int
    last_seq: int
    state: Dict[str, np.ndarray]   # ranks + graph_* (obs/recorder.py)
    # walk-index identity of the writer's index at this generation
    # (num_walks/max_len/alpha/key) — lets a resyncing replica prove its
    # own index shares the writer's PRNG stream and *repair* it against
    # the anchor graph instead of rebuilding from scratch; None when the
    # writer serves no PPR
    ppr: Optional[Dict] = None


class Heartbeat(NamedTuple):
    epoch: int
    seq: int             # last delta seq emitted this epoch (-1: none)
    generation: int
    latest_seq: int      # writer ingest frontier (staleness reference)
    t: float


def _anchor_state(graph: EdgeListGraph, ranks) -> Dict[str, np.ndarray]:
    """Host-side anchor, same leaves as FlightRecorder.record_anchor."""
    return dict(
        ranks=np.asarray(ranks),
        graph_src=np.asarray(graph.src),
        graph_dst=np.asarray(graph.dst),
        graph_valid=np.asarray(graph.valid),
        graph_num_edges=np.asarray(graph.num_edges),
    )


def _graph_from_anchor(state: Dict[str, np.ndarray],
                       num_vertices: int) -> EdgeListGraph:
    return EdgeListGraph(
        src=jnp.asarray(state["graph_src"]),
        dst=jnp.asarray(state["graph_dst"]),
        valid=jnp.asarray(state["graph_valid"]),
        num_vertices=num_vertices,
        num_edges=jnp.asarray(state["graph_num_edges"]))


def _ppr_identity(index) -> Optional[Dict]:
    """Wire-format identity of a walk index (WalkIndex or
    ShardedWalkIndex): the statics plus the base PRNG key — everything
    that determines the sampled walks besides the graph itself."""
    if index is None:
        return None
    return dict(num_walks=int(index.num_walks),
                max_len=int(index.max_len),
                alpha=float(index.alpha),
                key=[int(x) for x in np.asarray(index.key)])


def _edge_diff_touched(old: EdgeListGraph, new: EdgeListGraph,
                       num_vertices: int) -> jnp.ndarray:
    """bool[V]: src endpoints of every edge slot that differs between the
    two edge lists.  The anchor graph differs from the replica's only at
    the slots the missed deltas rewrote, so this is a superset of the
    union of their ``touched_vertices_mask``es — and any covering
    superset keeps walk repair bitwise equal to a fresh rebuild (only
    extra walks get (identically) resampled)."""
    diff = ((old.src != new.src) | (old.dst != new.dst)
            | (old.valid != new.valid))
    m = jnp.zeros((num_vertices,), bool)
    hit_old = diff & old.valid
    hit_new = diff & new.valid
    m = m.at[jnp.where(hit_old, old.src, 0)].max(hit_old)
    m = m.at[jnp.where(hit_new, new.src, 0)].max(hit_new)
    return m


# ---- writer side ---------------------------------------------------------

class ReplicationWriter:
    """Hooks a bootstrapped ``ServeEngine``; emits deltas + anchors.

    The engine stays oblivious: ``attach`` assigns ``engine.on_publish``
    and keeps a host copy of the previous rank vector for the exact
    bitwise diff.  A bounded delta log (newest ``log_capacity`` entries)
    serves retransmit requests and late-joiner tails; anything older
    answers with the newest anchor instead.
    """

    def __init__(self, engine, transport, name: str = "writer",
                 epoch: int = 0, anchor_every: int = 32,
                 log_capacity: int = 512, clock=time.monotonic):
        self.engine = engine
        self.transport = transport
        self.name = name
        self.epoch = int(epoch)
        self.anchor_every = int(anchor_every)
        self.log_capacity = int(log_capacity)
        self._clock = clock
        self._log: Dict[int, DeltaMsg] = {}
        self._anchor: Optional[AnchorMsg] = None
        self._prev: Optional[np.ndarray] = None
        self.next_seq = 0
        self.alive = True
        self.deltas_emitted = 0
        self.anchors_taken = 0
        self.retransmits = 0
        transport.register(name)

    # -- lifecycle --
    def attach(self) -> None:
        """Anchor the engine's current snapshot and start emitting."""
        snap = self.engine.store.snapshot()
        self._prev = np.asarray(snap.ranks)
        self._anchor = AnchorMsg(
            self.epoch, self.next_seq - 1, snap.generation, snap.last_seq,
            _anchor_state(snap.graph, snap.ranks),
            _ppr_identity(snap.ppr_index))
        self.anchors_taken += 1
        self.engine.on_publish = self._on_publish

    def kill(self) -> None:
        """Chaos: the writer process dies mid-flight — no more deltas,
        no more heartbeats, control-plane calls fail."""
        self.alive = False
        self.engine.on_publish = None

    # -- data plane --
    def _on_publish(self, snap, batch) -> None:
        if not self.alive:
            return
        new = np.asarray(snap.ranks)
        idx = np.flatnonzero(new != self._prev)
        upd = {f: np.asarray(getattr(batch.update, f))
               for f in BatchUpdate._fields}
        msg = DeltaMsg(self.epoch, self.next_seq, snap.generation,
                       int(batch.last_seq), idx.astype(np.int64),
                       new[idx].copy(), upd)
        self._prev = new
        self._log[msg.seq] = msg
        if len(self._log) > self.log_capacity:
            del self._log[min(self._log)]
        self.next_seq += 1
        self.deltas_emitted += 1
        if snap.generation % self.anchor_every == 0:
            self._anchor = AnchorMsg(self.epoch, msg.seq, snap.generation,
                                     msg.last_seq,
                                     _anchor_state(snap.graph, snap.ranks),
                                     _ppr_identity(snap.ppr_index))
            self.anchors_taken += 1
        self.transport.broadcast(self.name, msg, self._clock())

    def heartbeat(self, roster: Optional[ReplicaRoster] = None) -> None:
        if not self.alive:
            return
        now = self._clock()
        if roster is not None:
            roster.beat(self.name, now)
        self.transport.broadcast(
            self.name,
            Heartbeat(self.epoch, self.next_seq - 1,
                      self.engine.store.generation,
                      self.engine.ingest.latest_seq, now),
            now)

    # -- control plane (replicas call these through transport.check_link;
    #    a dead writer or a partitioned link raises there) --
    def retransmit(self, dest: str, seqs: List[int]) -> bool:
        """Re-send the requested deltas to ``dest``; False when any has
        fallen off the log (the replica must anchor-resync instead)."""
        if not all(s in self._log for s in seqs):
            return False
        now = self._clock()
        for s in seqs:
            self.transport.send(self.name, dest, self._log[s], now)
            self.retransmits += 1
        return True

    def newest_anchor(self) -> AnchorMsg:
        assert self._anchor is not None, "attach() before serving anchors"
        return self._anchor

    def delta_tail(self, after_seq: int) -> List[DeltaMsg]:
        """Logged deltas with seq > after_seq, in order (anchor resync +
        late-joiner bootstrap tail)."""
        return [self._log[s] for s in sorted(self._log)
                if s > after_seq]


# ---- replica side --------------------------------------------------------

class ReplicaDegradedError(RuntimeError):
    """Raised by shed query classes on a degraded replica; carries the
    current ``staleness_events`` so clients can fail over informed."""

    def __init__(self, message: str, staleness_events: int):
        super().__init__(message)
        self.staleness_events = staleness_events


class ReadReplica:
    """Applies the delta stream; answers queries; degrades, never dies.

    Deltas apply strictly in seq order.  Out-of-order arrivals buffer;
    a gap opens the retry state machine: up to ``max_retries``
    retransmit requests with exponential backoff (``backoff_base`` ·
    2^attempt + deterministic jitter), then an anchor resync.  An epoch
    bump (new writer) always resyncs — the new writer's bootstrap
    anchor is the one state everyone agrees on.
    """

    def __init__(self, name: str, transport, num_vertices: int,
                 roster: Optional[ReplicaRoster] = None,
                 ppr_cfg: Optional[IndexConfig] = None,
                 staleness_slo_events: int = 256,
                 shed_on_degrade: bool = True,
                 max_retries: int = 4, backoff_base: float = 0.05,
                 slo_objective: float = 0.99,
                 slo_windows=((60.0, 14.4), (300.0, 6.0)),
                 slo_min_events: int = 12,
                 seed: int = 0, clock=time.monotonic):
        self.name = name
        self.transport = transport
        self.num_vertices = int(num_vertices)
        self.roster = roster
        self.ppr_cfg = ppr_cfg
        self.staleness_slo_events = int(staleness_slo_events)
        self.shed_on_degrade = bool(shed_on_degrade)
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self._clock = clock
        # deterministic per-replica jitter: seed ⊕ stable name digest
        self._rng = np.random.default_rng(
            np.uint32(seed) ^ np.uint32(zlib.crc32(name.encode())))
        self.store = RankStore()
        self.graph: Optional[EdgeListGraph] = None
        self.ranks: Optional[np.ndarray] = None
        self.ppr = None
        self.epoch = -1          # resyncs on the first message seen
        self.applied_seq = -1    # newest contiguously-applied wire seq
        self.generation = -1
        self.last_seq = -1
        self.known_latest_seq = -1   # writer ingest frontier, via hb/deltas
        self.degraded = False
        self._buffer: Dict[int, DeltaMsg] = {}
        # gap retry state: None or dict(missing, attempt, next_t)
        self._gap: Optional[dict] = None
        self.incidents: List[Incident] = []
        self.slo = SloTracker("replica_staleness", slo_objective,
                              windows=slo_windows,
                              min_events=slo_min_events, clock=clock)
        self._active_alerts: set = set()   # edge-triggered slo_burn
        # counters (surfaced by the harness / bench report)
        self.deltas_applied = 0
        self.duplicates = 0
        self.gaps_detected = 0
        self.retries_sent = 0
        self.resyncs = 0
        transport.register(name)
        if roster is not None:
            roster.join(name, clock())

    # -- bookkeeping --
    @property
    def staleness(self) -> int:
        return max(0, self.known_latest_seq - self.last_seq)

    def _incident(self, kind: str, value: float, threshold: float,
                  message: str) -> None:
        self.incidents.append(Incident(
            kind, WARN, self.generation, self.last_seq, float(value),
            float(threshold), message, self._clock()))

    def _note_frontier(self, latest_seq: int) -> None:
        self.known_latest_seq = max(self.known_latest_seq, int(latest_seq))

    def _check_staleness(self) -> None:
        stale = self.staleness
        self.slo.record(stale <= self.staleness_slo_events)
        firing = self.slo.evaluate()
        keys = {(a.slo, a.long_window_s) for a in firing}
        for alert in firing:   # edge-triggered, like obs.slo.SloSet
            if (alert.slo, alert.long_window_s) in self._active_alerts:
                continue
            self._incident(
                "slo_burn", alert.burn_long, alert.threshold,
                f"replica {self.name} staleness SLO burning at "
                f"{alert.burn_long:.1f}x over {alert.long_window_s:g}s")
        self._active_alerts = keys
        if stale > self.staleness_slo_events and not self.degraded:
            self.degraded = True
            self._incident(
                "replica_degraded", stale, self.staleness_slo_events,
                f"replica {self.name} is {stale} events stale "
                f"(SLO {self.staleness_slo_events}); "
                + ("shedding top-k/PPR, " if self.shed_on_degrade else "")
                + "point queries keep serving with staleness metadata")
        elif stale <= self.staleness_slo_events and self.degraded:
            self.degraded = False
            self._incident(
                "replica_recovered", stale, self.staleness_slo_events,
                f"replica {self.name} back inside its staleness SLO")

    # -- the pump: one call drains the inbox and advances retries --
    def pump(self) -> int:
        """Apply every due message; returns deltas applied this call."""
        now = self._clock()
        if self.roster is not None:
            self.roster.beat(self.name, now)
        applied = 0
        for msg in self.transport.deliver(self.name, now):
            if isinstance(msg, Heartbeat):
                self._on_heartbeat(msg)
            elif isinstance(msg, DeltaMsg):
                applied += self._on_delta(msg)
        applied += self._drain_buffer()
        self._advance_gap(now)
        self._check_staleness()
        return applied

    def _on_heartbeat(self, hb: Heartbeat) -> None:
        if hb.epoch > self.epoch:
            self._resync("new writer epoch")
            return
        if hb.epoch < self.epoch:
            return               # stale incarnation still in the pipe
        self._note_frontier(hb.latest_seq)
        # tail-gap detection: the writer is ahead and nothing newer is
        # in flight for us — the missing deltas were dropped outright
        if hb.seq > self.applied_seq and self._gap is None \
                and not self._buffer:
            self._open_gap(hb.seq)

    def _on_delta(self, msg: DeltaMsg) -> int:
        if msg.epoch > self.epoch:
            self._resync("new writer epoch")
            return 0
        if msg.epoch < self.epoch or msg.seq <= self.applied_seq:
            self.duplicates += 1
            return 0
        if msg.seq == self.applied_seq + 1:
            self._apply(msg)
            return 1
        if msg.seq in self._buffer:
            self.duplicates += 1
            return 0
        self._buffer[msg.seq] = msg
        if self._gap is None:
            self._open_gap(msg.seq - 1)
        return 0

    def _drain_buffer(self) -> int:
        n = 0
        while (self.applied_seq + 1) in self._buffer:
            self._apply(self._buffer.pop(self.applied_seq + 1))
            n += 1
        if not self._buffer and self._gap is not None \
                and self.applied_seq >= self._gap["through"]:
            self._gap = None     # retransmits landed; gap closed
        return n

    def _apply(self, msg: DeltaMsg) -> None:
        upd = BatchUpdate(**{f: jnp.asarray(msg.update[f])
                             for f in BatchUpdate._fields})
        self.graph = apply_batch(self.graph, upd)
        self.ranks[msg.rank_idx] = msg.rank_val
        if self.ppr is not None:
            touched = touched_vertices_mask(upd, self.num_vertices)
            self.ppr, _ = repair_walk_index(self.ppr, self.graph, touched)
        self.applied_seq = msg.seq
        self.generation = msg.generation
        self.last_seq = msg.last_seq
        self._note_frontier(msg.last_seq)
        self.deltas_applied += 1
        self._publish()

    def _publish(self) -> None:
        self.store.seed_generation(self.generation)
        self.store.publish(self.graph, jnp.asarray(self.ranks),
                           self.last_seq, ppr_index=self.ppr)

    # -- gap retry state machine --
    def _open_gap(self, through_seq: int) -> None:
        self.gaps_detected += 1
        self._gap = dict(through=int(through_seq), attempt=0,
                         next_t=self._clock())   # first retry immediate

    def _advance_gap(self, now: float) -> None:
        gap = self._gap
        if gap is None or now < gap["next_t"]:
            return
        if gap["attempt"] >= self.max_retries:
            self._resync(
                f"gap at seq {self.applied_seq + 1} survived "
                f"{self.max_retries} retransmit attempts")
            return
        missing = [s for s in range(self.applied_seq + 1,
                                    gap["through"] + 1)
                   if s not in self._buffer]
        if not missing:
            self._gap = None
            return
        gap["attempt"] += 1
        backoff = (self.backoff_base * (2.0 ** gap["attempt"])
                   + float(self._rng.uniform(0.0, self.backoff_base)))
        gap["next_t"] = now + backoff
        try:
            writer = self.transport.writer_for(self.name)
            self.retries_sent += 1
            if not writer.retransmit(self.name, missing):
                # fell off the writer's delta log — anchors only now
                self._resync("retransmit window expired on the writer")
        except Exception:
            # partitioned or dead writer: the attempt is spent, the
            # backoff stands; failover/heal will unblock us
            pass

    # -- anchor resync + late join --
    def _resync(self, reason: str) -> bool:
        try:
            writer = self.transport.writer_for(self.name)
            anchor = writer.newest_anchor()
            tail = writer.delta_tail(anchor.seq)
        except Exception:
            return False         # unreachable; stay on backoff/heartbeat
        self.resyncs += 1
        self._load_anchor(anchor)
        for msg in tail:
            if msg.seq == self.applied_seq + 1:
                self._apply(msg)
        self._gap = None
        self._incident(
            "replica_resync", self.applied_seq, 0,
            f"replica {self.name} resynced from anchor "
            f"gen={anchor.generation} (epoch {anchor.epoch}): {reason}")
        return True

    def _ppr_identity_matches(self, ident: Optional[Dict]) -> bool:
        """Does our live index share the anchor's PRNG stream + statics?
        If so, repairing it on the anchor graph reproduces the writer's
        index bitwise (same draws, same graph)."""
        if ident is None or self.ppr is None:
            return False
        return _ppr_identity(self.ppr) == dict(
            ident, key=[int(x) for x in ident["key"]])

    def _load_anchor(self, anchor: AnchorMsg) -> None:
        old_graph = self.graph           # pre-resync graph, for the diff
        self.graph = _graph_from_anchor(anchor.state, self.num_vertices)
        self.ranks = np.asarray(anchor.state["ranks"],
                                np.float64).copy()
        self.epoch = anchor.epoch
        self.applied_seq = anchor.seq
        self.generation = anchor.generation
        self.last_seq = anchor.last_seq
        self._note_frontier(anchor.last_seq)
        self._buffer = {s: m for s, m in self._buffer.items()
                        if m.epoch == self.epoch and s > anchor.seq}
        if self.ppr_cfg is not None:
            ident = anchor.ppr
            if (old_graph is not None
                    and self._ppr_identity_matches(ident)
                    and old_graph.src.shape == self.graph.src.shape):
                # our index is valid for old_graph and provably on the
                # writer's PRNG stream: repair the walks that cross the
                # edge slots the missed deltas rewrote — an O(|Δ|·R·L)
                # heal, not the O(V·R·L) from-scratch rebuild this path
                # used to do on every resync
                touched = _edge_diff_touched(old_graph, self.graph,
                                             self.num_vertices)
                self.ppr, _ = repair_walk_index(self.ppr, self.graph,
                                                touched)
            else:
                # cold start, config drift, or a legacy anchor without
                # identity: pure function of (graph, seed), still
                # bit-identical to the writer (DESIGN.md §6)
                self.ppr = build_walk_index(self.graph, self.ppr_cfg)
        self._publish()

    def bootstrap(self) -> bool:
        """Late join: newest anchor + replayed delta tail.  False when
        the writer is unreachable (caller retries on its own cadence)."""
        return self._resync("late joiner bootstrap")

    def leave(self) -> None:
        if self.roster is not None:
            self.roster.leave(self.name)


class ReplicaQueryClient(QueryClient):
    """serve/query.py surface over a replica's local snapshot store.

    Staleness comes from the replication stream (writer frontier minus
    applied frontier) instead of a local ingest queue.  On a degraded
    replica with ``shed_on_degrade``, top-k and PPR raise
    ``ReplicaDegradedError`` while point lookups keep answering — the
    degradation ladder's floor.
    """

    def __init__(self, replica: ReadReplica, metrics=None, **kw):
        super().__init__(replica.store, ingest=None, metrics=metrics, **kw)
        self.replica = replica

    def _staleness(self, snap) -> int:
        return self.replica.staleness

    def _shed_check(self, what: str) -> None:
        r = self.replica
        if r.degraded and r.shed_on_degrade:
            raise ReplicaDegradedError(
                f"replica {r.name} is degraded ({r.staleness} events "
                f"stale, SLO {r.staleness_slo_events}); {what} is shed — "
                f"point queries (get_ranks) remain available",
                staleness_events=r.staleness)

    def top_k(self, k: int) -> QueryResult:
        self._shed_check("top_k")
        return super().top_k(k)

    def personalized_top_k(self, seeds, k: int, mode: str = "auto",
                           **ppr_kw) -> QueryResult:
        self._shed_check("personalized_top_k")
        return super().personalized_top_k(seeds, k, mode=mode, **ppr_kw)


# ---- failover ------------------------------------------------------------

class FailoverController:
    """Promotes the freshest replica when the writer's heartbeat lapses.

    Candidate freshness is ordered by (generation, last_seq).  The last
    committed RankStore checkpoint competes as a candidate too: if every
    surviving replica is behind it, promotion restores the checkpoint
    ranks and rebuilds the graph at that frontier via the injected
    ``rebuild_graph(last_seq)`` (the event feed is the graph's log, the
    same replay contract launch/serve.py uses on restart) — so a
    committed generation can never be lost to a lagging replica pool.
    """

    def __init__(self, transport, roster: ReplicaRoster,
                 engine_factory, writer_name: str = "writer",
                 ckpt_dir: Optional[str] = None,
                 num_vertices: Optional[int] = None,
                 rebuild_graph=None, clock=time.monotonic):
        self.transport = transport
        self.roster = roster
        self.engine_factory = engine_factory
        self.writer_name = writer_name
        self.ckpt_dir = ckpt_dir
        self.num_vertices = num_vertices
        self.rebuild_graph = rebuild_graph
        self._clock = clock
        self.failovers = 0
        self.incidents: List[Incident] = []

    def writer_expired(self) -> bool:
        return not self.roster.is_alive(self.writer_name, self._clock())

    def check(self, writer: ReplicationWriter,
              replicas: List[ReadReplica]):
        """(new_writer, promoted_replica_or_None) on failover, else None."""
        if writer.alive and not self.writer_expired():
            return None
        return self.promote(writer, replicas)

    def promote(self, old_writer: ReplicationWriter,
                replicas: List[ReadReplica]):
        now = self._clock()
        link_up = getattr(self.transport, "link_up", None)
        alive = [r for r in replicas
                 if self.roster.is_alive(r.name, now)
                 and r.ranks is not None
                 and (link_up is None
                      or link_up(r.name, self.writer_name))]
        best = max(alive, key=lambda r: (r.generation, r.last_seq),
                   default=None)
        ckpt = (rescale_serving_state(self.ckpt_dir, self.num_vertices)
                if self.ckpt_dir and self.num_vertices else
                (None, None, None))
        use_ckpt = ckpt[0] is not None and (
            best is None or (ckpt[0], ckpt[1]) > (best.generation,
                                                  best.last_seq))
        if use_ckpt:
            if self.rebuild_graph is None:
                raise RuntimeError(
                    "checkpoint is ahead of every surviving replica and "
                    "no rebuild_graph callback was provided — refusing "
                    "to lose committed generation "
                    f"{ckpt[0]} (replicas at "
                    f"{best.generation if best else None})")
            gen, last_seq, ranks = ckpt
            graph = self.rebuild_graph(last_seq)
            promoted = None
            source = f"checkpoint gen={gen}"
        elif best is not None:
            gen, last_seq = best.generation, best.last_seq
            ranks, graph = best.ranks, best.graph
            promoted = best
            source = f"replica {best.name} gen={gen}"
        else:
            raise RuntimeError("no promotion candidate: no checkpoint and "
                               "no alive replica with state")
        engine = self.engine_factory(graph, last_seq=last_seq,
                                     generation=gen)
        if (promoted is not None and promoted.ppr is not None
                and getattr(engine, "_ppr", None) is None
                and getattr(engine, "_ppr_cfg", None) is not None):
            # the promoted replica's index is already valid for `graph`
            # and on the configured PRNG stream: hand it to the new
            # writer so bootstrap skips the O(V·R·L) rebuild that used
            # to stall failover on large indexes
            cfg = engine._ppr_cfg
            want = dict(num_walks=int(cfg.num_walks),
                        max_len=int(cfg.max_len), alpha=float(cfg.alpha),
                        key=[int(x) for x in np.asarray(
                            jax.random.PRNGKey(cfg.seed))])
            if _ppr_identity(promoted.ppr) == want:
                engine._ppr = promoted.ppr
        engine.store.seed_generation(gen)
        engine.bootstrap(ranks=jnp.asarray(np.asarray(ranks, np.float64)),
                         last_seq=last_seq)
        writer = ReplicationWriter(
            engine, self.transport, name=self.writer_name,
            epoch=old_writer.epoch + 1,
            anchor_every=old_writer.anchor_every,
            log_capacity=old_writer.log_capacity, clock=self._clock)
        writer.attach()
        writer.heartbeat(self.roster)
        if promoted is not None:
            promoted.leave()
        self.failovers += 1
        self.incidents.append(Incident(
            "writer_failover", WARN, gen, last_seq, old_writer.epoch + 1,
            old_writer.epoch,
            f"writer epoch {old_writer.epoch} expired; promoted {source} "
            f"as epoch {old_writer.epoch + 1} (last_seq={last_seq})", now))
        return writer, promoted
