"""Edge-event ingest: admission control + adaptive micro-batch coalescing.

Events (insert/delete of an edge) arrive one at a time from any thread;
the queue coalesces them into capacity-padded ``BatchUpdate``s for the
serve engine.  Flush policy is adaptive micro-batching: a batch is ready
when ``flush_size`` events are pending (throughput mode) *or* when the
oldest pending event has waited ``flush_interval`` seconds (tail-latency
bound for trickle traffic).  ``poll(force=True)`` drains regardless —
used at shutdown and by synchronous test drivers.

Coalescing is net-effect per edge: within one window the *last* event
for a given (u, v) wins (insert→delete cancels to a deletion, which
``apply_batch`` treats as a no-op if the edge never existed; the
reverse collapses to an insertion).  This is sound because
``apply_batch`` applies deletions before insertions and already ignores
deletes of absent edges and duplicate inserts.

Admission control: at most ``max_pending`` events may be queued; beyond
that ``submit`` sheds load by returning ``None`` (callers count rejects
via ``ServeMetrics.record_admission``), bounding both memory and the
staleness a slow engine can accumulate.

All ``BatchUpdate``s produced by one queue share the same static
capacities, so one compiled ``apply_batch``/update step serves the whole
event stream.
"""
from __future__ import annotations

import threading
import time
from typing import List, NamedTuple, Optional

import numpy as np

from repro.graph.dynamic import BatchUpdate, make_batch_update

INSERT = "insert"
DELETE = "delete"


class EdgeEvent(NamedTuple):
    kind: str    # INSERT | DELETE
    u: int
    v: int
    seq: int     # global arrival index, monotone
    t: float     # arrival clock reading


class CoalescedBatch(NamedTuple):
    update: BatchUpdate
    num_events: int      # raw events consumed from the queue
    num_coalesced: int   # events cancelled by net-effect coalescing
    first_seq: int
    last_seq: int
    oldest_t: float      # arrival time of the oldest event in the batch


def coalesce_events(events: List[EdgeEvent], del_capacity: int,
                    ins_capacity: int) -> CoalescedBatch:
    """Net-effect coalescing: last event per (u, v) wins."""
    if not events:
        raise ValueError("cannot coalesce an empty window")
    last: dict = {}
    for ev in events:                      # arrival order — later wins
        last[(ev.u, ev.v)] = ev.kind
    dels = np.asarray([k for k, kind in last.items() if kind == DELETE],
                      np.int32).reshape(-1, 2)
    ins = np.asarray([k for k, kind in last.items() if kind == INSERT],
                     np.int32).reshape(-1, 2)
    upd = make_batch_update(dels, ins, del_capacity, ins_capacity)
    return CoalescedBatch(
        update=upd,
        num_events=len(events),
        num_coalesced=len(events) - len(last),
        first_seq=events[0].seq,
        last_seq=events[-1].seq,
        oldest_t=events[0].t,
    )


class IngestQueue:
    """Thread-safe event queue with admission control and flush policy."""

    def __init__(self, flush_size: int = 256, flush_interval: float = 0.05,
                 max_pending: Optional[int] = None, start_seq: int = 0,
                 clock=time.monotonic):
        if flush_size < 1:
            raise ValueError("flush_size must be >= 1")
        self.flush_size = flush_size
        self.flush_interval = flush_interval
        self.max_pending = (8 * flush_size if max_pending is None
                            else max_pending)
        # static BatchUpdate capacities — every batch compiles once
        self.capacity = max(8, flush_size)
        self._clock = clock
        self._lock = threading.Lock()
        self._pending: List[EdgeEvent] = []
        self._next_seq = start_seq
        self.start_seq = start_seq
        self.rejected = 0

    # ---- producer side ---------------------------------------------------
    def submit(self, kind: str, u: int, v: int) -> Optional[int]:
        """Enqueue one event; returns its seq, or None if load-shed."""
        if kind not in (INSERT, DELETE):
            raise ValueError(f"unknown event kind {kind!r}")
        with self._lock:
            if len(self._pending) >= self.max_pending:
                self.rejected += 1
                return None
            seq = self._next_seq
            self._next_seq += 1
            self._pending.append(EdgeEvent(kind, int(u), int(v), seq,
                                           self._clock()))
            return seq

    def submit_insert(self, u: int, v: int) -> Optional[int]:
        return self.submit(INSERT, u, v)

    def submit_delete(self, u: int, v: int) -> Optional[int]:
        return self.submit(DELETE, u, v)

    # ---- consumer side ---------------------------------------------------
    @property
    def latest_seq(self) -> int:
        """Seq of the newest accepted event (start_seq - 1 if none yet)."""
        with self._lock:
            return self._next_seq - 1

    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def poll(self, force: bool = False) -> Optional[CoalescedBatch]:
        """Take one micro-batch if the flush policy triggers, else None."""
        with self._lock:
            n = len(self._pending)
            if n == 0:
                return None
            due = (n >= self.flush_size or force or
                   (self._clock() - self._pending[0].t
                    >= self.flush_interval))
            if not due:
                return None
            window = self._pending[: self.flush_size]
            del self._pending[: self.flush_size]
        return coalesce_events(window, self.capacity, self.capacity)
