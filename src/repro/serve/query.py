"""Query surface over the front snapshot: point ranks, top-k, PPR top-k.

Every query reads ONE atomically-published ``Snapshot`` — the (graph,
ranks, generation) triple is consistent by construction (state.py), and
the answer carries the generation it was served from.  Staleness is
measured in *events*: how many accepted ingest events the snapshot's
``last_seq`` trails the newest submitted seq at query time.

``top_k`` is jit-compiled (``jax.lax.top_k``) and cached per k, so the
hot query path is one compiled executable on the already-device-resident
rank vector.  ``personalized_top_k`` routes through
``core.extensions.personalized_pagerank`` on the snapshot graph — a
full PPR solve from the seed set, i.e. a heavyweight analytical query
served from the same consistent snapshot (cap ``max_iter`` to trade
accuracy for latency).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.extensions import personalized_pagerank
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.state import RankStore


class QueryResult(NamedTuple):
    vertices: np.ndarray   # int64[k]
    ranks: np.ndarray      # f64[k]
    generation: int
    staleness_events: int


class QueryClient:
    def __init__(self, store: RankStore, ingest: Optional[IngestQueue] = None,
                 metrics: Optional[ServeMetrics] = None):
        self.store = store
        self.ingest = ingest
        self.metrics = metrics
        self._topk_fns: dict = {}

    def _staleness(self, snap) -> int:
        if self.ingest is None:
            return 0
        return max(0, self.ingest.latest_seq - snap.last_seq)

    def _record(self, staleness: int):
        if self.metrics is not None:
            self.metrics.record_query(staleness)

    # ---- queries ---------------------------------------------------------
    def get_ranks(self, vertices: Sequence[int]) -> QueryResult:
        """Point lookups of the current ranks for the given vertices."""
        snap = self.store.snapshot()
        verts = np.asarray(vertices, np.int64).reshape(-1)
        stale = self._staleness(snap)
        self._record(stale)
        return QueryResult(verts, np.asarray(snap.ranks)[verts],
                           snap.generation, stale)

    def _topk(self, ranks: jax.Array, k: int):
        fn = self._topk_fns.get(k)
        if fn is None:
            fn = self._topk_fns.setdefault(
                k, jax.jit(partial(jax.lax.top_k, k=k)))
        vals, idx = fn(ranks)
        return np.asarray(idx, np.int64), np.asarray(vals)

    def top_k(self, k: int) -> QueryResult:
        """The k highest-ranked vertices (jit, cached per k)."""
        snap = self.store.snapshot()
        idx, vals = self._topk(snap.ranks, k)
        stale = self._staleness(snap)
        self._record(stale)
        return QueryResult(idx, vals, snap.generation, stale)

    def personalized_top_k(self, seeds: Sequence[int], k: int,
                           **ppr_kw) -> QueryResult:
        """Top-k by Personalized PageRank from a seed set, on the snapshot
        graph (core.extensions)."""
        snap = self.store.snapshot()
        V = snap.graph.num_vertices
        seed_mask = jnp.zeros((V,), bool).at[
            jnp.asarray(np.asarray(seeds, np.int64))].set(True)
        res = personalized_pagerank(snap.graph, seed_mask, **ppr_kw)
        idx, vals = self._topk(res.ranks, k)
        stale = self._staleness(snap)
        self._record(stale)
        return QueryResult(idx, vals, snap.generation, stale)
