"""Query surface over the front snapshot: point ranks, top-k, PPR top-k.

Every query reads ONE atomically-published ``Snapshot`` — the (graph,
ranks, generation) triple is consistent by construction (state.py), and
the answer carries the generation it was served from.  Staleness is
measured in *events*: how many accepted ingest events the snapshot's
``last_seq`` trails the newest submitted seq at query time.

``top_k`` is jit-compiled (``jax.lax.top_k``) and cached per k, so the
hot query path is one compiled executable on the already-device-resident
rank vector.

``personalized_top_k`` has two paths, selected by ``mode``:

* ``"index"`` — answer from the snapshot's random-walk index
  (``repro.ppr``), a few device ops per query; requires the engine to
  maintain one (``ServeEngine(ppr_index=...)``).
* ``"exact"`` — full DF-P PPR solve on the snapshot graph
  (``core.extensions.personalized_pagerank``), the accuracy oracle.
  Solves are memoized per (generation, seed set, solver options), so
  repeated identical queries within a generation are O(1) — the solve
  runs once per snapshot, not once per call.
* ``"auto"`` (default) — the index when the snapshot carries one, no
  solver options were passed (they imply exact semantics), AND the
  seed set's effective sample (Σ deg·R, ``ppr.effective_walks``)
  clears ``min_effective_walks``; the exact path otherwise.  Cold/thin
  seeds get oracle answers, warm seeds get the fast path.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.extensions import personalized_pagerank
from repro.ppr import DEFAULT_MIN_EFFECTIVE_WALKS, effective_walks, \
    ppr_top_k
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.state import RankStore

_EXACT_CACHE_MAX = 32


class QueryResult(NamedTuple):
    vertices: np.ndarray   # int64[k]
    ranks: np.ndarray      # f64[k]
    generation: int
    staleness_events: int


class QueryClient:
    def __init__(self, store: RankStore, ingest: Optional[IngestQueue] = None,
                 metrics: Optional[ServeMetrics] = None,
                 min_effective_walks: int = DEFAULT_MIN_EFFECTIVE_WALKS):
        self.store = store
        self.ingest = ingest
        self.metrics = metrics
        self.min_effective_walks = min_effective_walks
        self._topk_fns: dict = {}
        # exact-PPR memo: (generation, seeds, solver kw) -> rank vector;
        # queries run from any thread, so cache ops take the lock
        self._exact_cache: OrderedDict = OrderedDict()
        self._cache_lock = threading.Lock()

    def _staleness(self, snap) -> int:
        if self.ingest is None:
            return 0
        return max(0, self.ingest.latest_seq - snap.last_seq)

    def _record(self, staleness: int):
        if self.metrics is not None:
            self.metrics.record_query(staleness)

    # ---- queries ---------------------------------------------------------
    def get_ranks(self, vertices: Sequence[int]) -> QueryResult:
        """Point lookups of the current ranks for the given vertices."""
        snap = self.store.snapshot()
        verts = np.asarray(vertices, np.int64).reshape(-1)
        stale = self._staleness(snap)
        self._record(stale)
        return QueryResult(verts, np.asarray(snap.ranks)[verts],
                           snap.generation, stale)

    def _topk(self, ranks: jax.Array, k: int):
        fn = self._topk_fns.get(k)
        if fn is None:
            fn = self._topk_fns.setdefault(
                k, jax.jit(partial(jax.lax.top_k, k=k)))
        vals, idx = fn(ranks)
        return np.asarray(idx, np.int64), np.asarray(vals)

    def top_k(self, k: int) -> QueryResult:
        """The k highest-ranked vertices (jit, cached per k)."""
        snap = self.store.snapshot()
        idx, vals = self._topk(snap.ranks, k)
        stale = self._staleness(snap)
        self._record(stale)
        return QueryResult(idx, vals, snap.generation, stale)

    def _exact_ppr_ranks(self, snap, seeds: Sequence[int],
                         **ppr_kw) -> jax.Array:
        """Memoized exact PPR solve on one snapshot (LRU per (generation,
        seed set, options)) — a published snapshot is immutable, so the
        solution cannot change within a generation."""
        key = (snap.generation,
               tuple(sorted(set(int(s) for s in np.asarray(seeds)
                                .reshape(-1)))),
               tuple(sorted(ppr_kw.items())))
        with self._cache_lock:
            ranks = self._exact_cache.get(key)
            if ranks is not None:
                self._exact_cache.move_to_end(key)
                return ranks
        # solve outside the lock (seconds-long); a concurrent identical
        # query may duplicate the solve, which is wasteful but correct
        V = snap.graph.num_vertices
        seed_mask = jnp.zeros((V,), bool).at[
            jnp.asarray(np.asarray(seeds, np.int64))].set(True)
        ranks = personalized_pagerank(snap.graph, seed_mask, **ppr_kw).ranks
        with self._cache_lock:
            while len(self._exact_cache) >= _EXACT_CACHE_MAX:
                self._exact_cache.popitem(last=False)
            self._exact_cache[key] = ranks
        return ranks

    def personalized_top_k(self, seeds: Sequence[int], k: int,
                           mode: str = "auto", **ppr_kw) -> QueryResult:
        """Top-k by Personalized PageRank from a seed set, on the snapshot
        (see module docstring for the index/exact/auto routing)."""
        if mode not in ("auto", "index", "exact"):
            raise ValueError(f"unknown personalized_top_k mode {mode!r}")
        snap = self.store.snapshot()
        seeds = np.asarray(seeds, np.int64).reshape(-1)
        if len(seeds) == 0 or seeds.min() < 0 or \
                seeds.max() >= snap.graph.num_vertices:
            raise ValueError("seeds must be non-empty and within "
                             f"[0, {snap.graph.num_vertices})")
        index = snap.ppr_index
        if mode == "index" and index is None:
            raise ValueError("mode='index' but the snapshot carries no walk "
                             "index (start ServeEngine with ppr_index=)")
        if mode == "index" and ppr_kw:
            raise ValueError("solver options are exact-path only; "
                             f"mode='index' got {sorted(ppr_kw)}")
        # auto: solver options imply the exact solver's semantics, so
        # their presence routes to it (only explicit mode="index" rejects)
        use_index = index is not None and (
            mode == "index" or
            (mode == "auto" and not ppr_kw and
             effective_walks(index, seeds) >= self.min_effective_walks))
        if use_index:
            idx, vals = ppr_top_k(index, seeds, k)
            idx, vals = np.asarray(idx, np.int64), np.asarray(vals)
        else:
            ranks = self._exact_ppr_ranks(snap, seeds, **ppr_kw)
            idx, vals = self._topk(ranks, k)
        stale = self._staleness(snap)
        self._record(stale)
        return QueryResult(idx, vals, snap.generation, stale)
