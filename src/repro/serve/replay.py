"""Temporal-dataset → serving-feed preparation (paper §5.1.4 split).

One place owns the preload contract for the *serving* form of the
paper's replay protocol: the first 90% of the timestamp-ordered edges
build G⁰, the next ``num_events`` edges become the insert-event feed,
and the edge capacity is sized so the whole feed fits without
recompilation.  ``launch/serve.py`` and ``benchmarks/bench_serving.py``
both consume this (the offline batched form lives in
``graph.generators.TemporalStream``).
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import EdgeListGraph, from_coo

PRELOAD_FRAC = 0.9
CAPACITY_SLACK = 64


def preload_graph_and_feed(ds, num_events: int
                           ) -> tuple[EdgeListGraph, np.ndarray]:
    """(G⁰ from the 90% preload, int32[(num_events,2)] event feed)."""
    pre_end = int(PRELOAD_FRAC * len(ds.edges))
    feed = ds.edges[pre_end: pre_end + num_events]
    pre = ds.edges[:pre_end]
    graph = from_coo(pre[:, 0], pre[:, 1], ds.num_vertices,
                     edge_capacity=len(pre) + len(feed) + CAPACITY_SLACK)
    return graph, feed
