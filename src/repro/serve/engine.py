"""The serve-loop engine: micro-batch in, snapshot out.

One ``step`` = poll the ingest queue for a coalesced micro-batch, apply
it (``apply_batch``), build the method's initial affected set via the
shared ``core.api.build_initial_state`` dispatch, run the DF/DF-P loop,
publish the new (graph, ranks, generation) snapshot.  The step is
synchronous and single-consumer; ``start``/``stop`` wrap it in a daemon
thread for online operation, while tests and benchmarks drive ``step``
directly for determinism.

Static fallback (paper §5.2.2 observation: DF/DF-P lose to Static once
the affected fraction is large): when the *initial* affected set of the
chosen dynamic method covers more than ``static_fallback_frac`` of the
vertices, the step reruns from a cold start instead — same fixed point,
less work at very large coalesced batches.  The initial affected set is
a cheap one-hop (frontier) or reachability (traversal) mask we need
anyway, so the decision adds no extra passes for frontier methods.

``mesh=`` routes the rank update through the distributed shard_map
engine (repro.dist) — ingest/snapshot/query stay host-side either way.

``ppr_index=`` (an ``repro.ppr.IndexConfig`` or prebuilt ``WalkIndex``)
opts the engine into maintaining a random-walk PPR index alongside the
ranks: built at bootstrap, repaired inside every micro-batch step from
the batch's ``touched_vertices_mask`` (only walks intersecting touched
vertices resample), and published with each snapshot so index-backed
``personalized_top_k`` answers stay consistent with the served ranks.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.core.api import LOOP_FLAGS, Method, build_initial_state, \
    distributed_pagerank
from repro.graph.dynamic import apply_batch, touched_vertices_mask
from repro.graph.structure import EdgeListGraph
from repro.ppr import IndexConfig, WalkIndex, build_walk_index, \
    repair_walk_index
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.state import RankStore

DYNAMIC_METHODS = ("naive", "traversal", "frontier", "frontier_prune")


class ServeEngine:
    def __init__(self, graph: EdgeListGraph, ingest: IngestQueue,
                 store: RankStore, metrics: Optional[ServeMetrics] = None,
                 method: Method = "frontier_prune", mesh=None,
                 static_fallback_frac: float = 0.25,
                 ppr_index=None, clock=time.monotonic, **pr_kw):
        self.ingest = ingest
        self.store = store
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.method = method
        self.mesh = mesh
        self.static_fallback_frac = static_fallback_frac
        # opt-in walk index (repro.ppr): an IndexConfig to build at
        # bootstrap, or a prebuilt WalkIndex valid for `graph`
        self._ppr_cfg: Optional[IndexConfig] = None
        self._ppr: Optional[WalkIndex] = None
        if isinstance(ppr_index, IndexConfig):
            self._ppr_cfg = ppr_index
        elif isinstance(ppr_index, WalkIndex):
            self._ppr = ppr_index
        elif ppr_index is not None:
            raise TypeError("ppr_index must be an IndexConfig or WalkIndex")
        self.pr_kw = pr_kw
        self._clock = clock
        self._graph = graph
        self._ranks: Optional[jax.Array] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- lifecycle -------------------------------------------------------
    def bootstrap(self, ranks: Optional[jax.Array] = None,
                  last_seq: Optional[int] = None) -> int:
        """Publish generation 0: a cold static solve, or restored ranks.
        Builds the walk index if one was requested — sampling is a pure
        function of (graph, config seed), so a checkpointed restart
        reproduces the index bit-identically from the replayed graph."""
        if ranks is None:
            ranks = self._solve("static", self._graph, None, None).ranks
        if self._ppr_cfg is not None and self._ppr is None:
            self._ppr = build_walk_index(self._graph, self._ppr_cfg)
        self._ranks = ranks
        seq = self.ingest.start_seq - 1 if last_seq is None else last_seq
        return self.store.publish(self._graph, ranks, seq,
                                  ppr_index=self._ppr)

    # ---- one micro-batch -------------------------------------------------
    def step(self, force: bool = False) -> bool:
        """Apply one coalesced micro-batch if due; True if work was done."""
        if self._ranks is None:
            raise RuntimeError("bootstrap() before step()")
        batch = self.ingest.poll(force=force)
        if batch is None:
            return False
        t0 = self._clock()
        graph_new = apply_batch(self._graph, batch.update)
        method = self.method
        init_state = build_initial_state(self._graph, graph_new,
                                         batch.update, self._ranks, method)
        affected = init_state[1]
        fallback = False
        if method in ("traversal", "frontier", "frontier_prune"):
            frac = float(jnp.mean(affected.astype(jnp.float64)))
            if frac > self.static_fallback_frac:
                method, fallback = "static", True
                init_state = build_initial_state(
                    self._graph, graph_new, batch.update, self._ranks,
                    "static")
        res = self._solve(method, graph_new, batch.update, self._ranks,
                          graph_prev=self._graph, init_state=init_state)
        resampled = 0
        if self._ppr is not None:
            # the same touched signal that seeds the DF frontier drives
            # walk invalidation — stale suffixes resample on Gᵗ
            touched = touched_vertices_mask(batch.update,
                                            graph_new.num_vertices)
            self._ppr, resampled = repair_walk_index(self._ppr, graph_new,
                                                     touched)
        jax.block_until_ready(res.ranks)
        if self._ppr is not None:
            # repair kernels were enqueued after the rank update; the
            # reported batch latency must cover them too
            jax.block_until_ready(self._ppr.steps)
        latency = self._clock() - t0
        self._graph, self._ranks = graph_new, res.ranks
        self.store.publish(graph_new, res.ranks, batch.last_seq,
                           ppr_index=self._ppr)
        self.metrics.record_batch(
            latency, batch.num_events, batch.num_coalesced,
            affected=int(jnp.sum(res.affected_ever)),
            iterations=int(res.iterations), fallback=fallback,
            walks_resampled=resampled)
        return True

    def _solve(self, method: Method, graph_new: EdgeListGraph, update,
               prev_ranks, graph_prev: Optional[EdgeListGraph] = None,
               init_state: Optional[tuple] = None):
        graph_prev = graph_prev if graph_prev is not None else graph_new
        if self.mesh is not None:
            return distributed_pagerank(graph_prev, graph_new, update,
                                        prev_ranks, method, self.mesh,
                                        init_state=init_state,
                                        **self.pr_kw)
        init_ranks, init_affected = (
            init_state if init_state is not None else build_initial_state(
                graph_prev, graph_new, update, prev_ranks, method))
        return pr._pagerank_loop(graph_new, init_ranks, init_affected,
                                 **LOOP_FLAGS[method], **self.pr_kw)

    def drain(self, force: bool = True) -> int:
        """Run steps until the ingest queue is empty; returns batch count."""
        n = 0
        while self.step(force=force):
            n += 1
        return n

    # ---- background thread ----------------------------------------------
    def start(self, idle_sleep: float = 0.001):
        """Run the step loop in a daemon thread until ``stop``."""
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.step():
                    time.sleep(idle_sleep)

        self._thread = threading.Thread(target=loop, name="serve-engine",
                                        daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain(force=True)
