"""The serve-loop engine: micro-batch in, snapshot out.

One ``step`` = poll the ingest queue for a coalesced micro-batch, apply
it (``apply_batch``), build the method's initial affected set via the
shared ``core.api.build_initial_state`` dispatch, run the DF/DF-P loop,
publish the new (graph, ranks, generation) snapshot.  The step is
synchronous and single-consumer; ``start``/``stop`` wrap it in a daemon
thread for online operation, while tests and benchmarks drive ``step``
directly for determinism.

Static fallback (paper §5.2.2 observation: DF/DF-P lose to Static once
the affected fraction is large): when the *initial* affected set of the
chosen dynamic method covers more than ``static_fallback_frac`` of the
vertices, the step reruns from a cold start instead — same fixed point,
less work at very large coalesced batches.  The initial affected set is
a cheap one-hop (frontier) or reachability (traversal) mask we need
anyway, so the decision adds no extra passes for frontier methods.

``mesh=`` routes the rank update through the distributed shard_map
engine (repro.dist) — ingest/snapshot/query stay host-side either way.

``engine="kernel"`` makes the Pallas frontier-gated SpMV the serving
hot path: bootstrap packs the graph into the blocked ``PackedGraph``
once, every micro-batch maintains it *on device* with
``apply_batch_packed`` (no host repack), and dynamic-method solves run
the hybrid-precision ladder (f32 kernel iterations + f64 polish,
core.kernel_engine.hybrid_pagerank).  Published snapshots are unchanged
— f64 ranks, same generation clock.  Static solves (bootstrap, fallback)
stay on the XLA engine: with every window active the gated kernel has
nothing to skip and the cold start wants f64 end-to-end.  If a window's
spill lanes run out, the engine repacks from the current graph at the
same capacity (``metrics.packed_rebuilds`` counts these) — the kernels
never recompile because every shape is pinned at bootstrap.

``engine="kernel"`` + ``mesh=`` is the **sharded** kernel path: the
packed structure is partitioned by dst-window ranges over the mesh's
``model`` axis (kernels.pagerank_spmv.shard), each micro-batch's deltas
are routed to their owning shard and applied under shard_map, and the
hybrid ladder runs the shard_map'd kernel loop with a replicated rank
vector (dist.pagerank_dist.ShardedKernelEngine).  Overflow recovery is
per the single-pod contract — repack at pinned shapes, zero recompiles —
with ``metrics.packed_rebuilds_by_shard`` attributing which shards
overflowed; ``kernel_opts["delta_budget"]`` bounds routed per-shard
rows per batch (None = whole-batch capacity).  Engine work counters
(``edges_processed``/``vertices_processed``) are psum-aggregated across
shards by the solve and land in the same metrics fields as the
single-pod path.
``kernel_opts`` tunes the path: pack sizing (``be``, ``vb``,
``spill_lanes_per_window``, ``num_entries``), ``use_kernel`` (True =
Pallas kernel [interpret mode off-TPU], False = jnp oracle, "auto" =
kernel on TPU only) and any ``hybrid_pagerank`` kwarg (``tol_f32``,
``polish``, ...).  When the caller does NOT fix ``be``/``vb``, bootstrap
**autotunes** the pack geometry for the bootstrap graph via
``kernels.pagerank_spmv.tune`` (roofline model over the graph's degree
distribution, optional first-batch measured search, persistent cache
keyed by graph shape + device kind); the winner is exposed as
``self.kernel_geometry`` / ``self.tune_info`` for the launch log.
``kernel_opts["tune"]=False`` opts out (fixed ``KERNEL_PACK_DEFAULTS``),
``tune_measure=True`` enables the timed candidate search,
``tune_cache_path`` overrides the cache file, ``frontier_frac`` is the
expected per-batch affected fraction the model optimises for.

``ppr_index=`` (an ``repro.ppr.IndexConfig`` or prebuilt ``WalkIndex``)
opts the engine into maintaining a random-walk PPR index alongside the
ranks: built at bootstrap, repaired inside every micro-batch step from
the batch's ``touched_vertices_mask`` (only walks intersecting touched
vertices resample), and published with each snapshot so index-backed
``personalized_top_k`` answers stay consistent with the served ranks.

``monitor=`` (an ``obs.monitor.CorrectnessMonitor``) opts the engine
into correctness observability: per-batch invariant sentinels, sampled
shadow verification, flight recording with bit-for-bit replay, and SLO
burn-rate alerts (DESIGN.md §12).  ``inject_fault`` arms a one-shot
debug corruption so that pipeline can be exercised end-to-end.

``iteration_budget=`` (an ``ft.straggler.IterationBudget`` or an int
``max_iter_per_batch``) caps each dynamic batch's solver iterations so
one pathological micro-batch cannot stall the publish cadence: a solve
that exits at the cap carries its unconverged frontier into the next
batch's seed set (sound for DF/DF-P — vertices re-mark until Δ ≤ τ,
DESIGN.md §13), and ``metrics.budget_carryover`` counts the batches
that started from a carried frontier.  Bootstrap and explicit static
solves are never capped — a cold start wants full convergence.

``on_publish`` (assignable attribute, like ``telemetry_sink``) is
called after every post-batch snapshot publish with ``(snapshot,
batch)`` — the hook the replication writer (serve/replicate.py) uses to
emit generation-stamped deltas without the engine knowing about
replication.

``close()`` shuts the engine down completely: stops the background step
thread if one is running and closes the correctness monitor, which
joins the shadow-verifier thread and flushes its latest-wins mailbox so
a pending divergence is reported rather than dropped on exit.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import pagerank as pr
from repro.core.api import ENGINES, KERNEL_FLAGS, LOOP_FLAGS, Method, \
    build_initial_state, distributed_pagerank
from repro.graph.dynamic import apply_batch, touched_vertices_mask
from repro.graph.structure import EdgeListGraph
from repro.obs import trace as obs_trace
from repro.obs.frontier import FrontierTelemetry
from repro.ppr import IndexConfig, ShardedWalkIndex, WalkIndex, \
    build_sharded_walk_index, build_walk_index, repair_walk_index, \
    repair_walk_index_sharded
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.state import RankStore

DYNAMIC_METHODS = ("naive", "traversal", "frontier", "frontier_prune")

# host-sync round trips the serve loop has issued (block_until_ready
# calls) — tests assert exactly one per step, PPR repair or not
import collections as _collections
SYNC_COUNTS: _collections.Counter = _collections.Counter()


def _block(x) -> None:
    SYNC_COUNTS["block_until_ready"] += 1
    jax.block_until_ready(x)

# serving pack defaults: smaller entries than the offline DEFAULT_BE=2048
# keep the per-window spill reservation (and the padded-lane overhead the
# contributions gather over) small relative to the live edges, while VB
# stays 2×128 lanes (DESIGN.md §8 capacity model)
KERNEL_PACK_DEFAULTS = dict(be=512, vb=256, spill_lanes_per_window=256)
_PACK_KEYS = ("be", "vb", "spill_lanes_per_window", "num_entries",
              "extra_entries", "overlay_capacity")


class ServeEngine:
    def __init__(self, graph: EdgeListGraph, ingest: IngestQueue,
                 store: RankStore, metrics: Optional[ServeMetrics] = None,
                 method: Method = "frontier_prune", mesh=None,
                 engine: str = "xla",
                 kernel_opts: Optional[dict] = None,
                 static_fallback_frac: float = 0.25,
                 ppr_index=None, clock=time.monotonic,
                 telemetry: Optional[bool] = None, monitor=None,
                 iteration_budget=None, **pr_kw):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; options {ENGINES}")
        self.ingest = ingest
        self.store = store
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.method = method
        self.mesh = mesh
        self.engine = engine
        opts = dict(kernel_opts or {})
        explicit = {k: opts.pop(k) for k in _PACK_KEYS if k in opts}
        # autotune unless the caller fixed the geometry (be/vb) themselves
        self._tune = opts.pop("tune", not ({"be", "vb"} & set(explicit)))
        self._tune_measure = opts.pop("tune_measure", False)
        self._tune_cache_path = opts.pop("tune_cache_path", None)
        self._frontier_frac = opts.pop("frontier_frac", 0.05)
        self._explicit_pack = explicit
        self._pack_kw = {**KERNEL_PACK_DEFAULTS, **explicit}
        self.kernel_geometry = None   # set at bootstrap (kernel engine)
        self.tune_info = None
        self._delta_budget = opts.pop("delta_budget", None)
        use_kernel = opts.pop("use_kernel", "auto")
        if use_kernel == "auto":
            use_kernel = jax.default_backend() == "tpu"
        self._kernel_kw = dict(use_kernel=bool(use_kernel), **opts)
        self._packed = None
        self._sharded = None   # dist.ShardedKernelEngine (kernel + mesh)
        self.static_fallback_frac = static_fallback_frac
        # opt-in walk index (repro.ppr): an IndexConfig to build at
        # bootstrap (sharded over `mesh` when one is given), or a prebuilt
        # WalkIndex / ShardedWalkIndex valid for `graph`
        self._ppr_cfg: Optional[IndexConfig] = None
        self._ppr = None
        if isinstance(ppr_index, IndexConfig):
            self._ppr_cfg = ppr_index
        elif isinstance(ppr_index, (WalkIndex, ShardedWalkIndex)):
            self._ppr = ppr_index
        elif ppr_index is not None:
            raise TypeError("ppr_index must be an IndexConfig, WalkIndex "
                            "or ShardedWalkIndex")
        # frontier telemetry: None = follow the global tracer (rows are
        # recorded exactly when a trace is being taken), True/False pins
        # it.  Toggling retraces the solve loops once (static jit flag).
        self.telemetry = telemetry
        self.last_telemetry: Optional[FrontierTelemetry] = None
        # optional obs.export.JsonlSink receiving one frontier record
        # per batch (assigned by the launch driver behind --trace)
        self.telemetry_sink = None
        self.pr_kw = pr_kw
        self._clock = clock
        self._graph = graph
        self._ranks: Optional[jax.Array] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # correctness monitor (obs.monitor.CorrectnessMonitor): hooked
        # after bootstrap and after every publish; None = zero overhead
        self.monitor = monitor
        # per-batch iteration cap with frontier carryover
        # (ft.straggler.IterationBudget); an int means max_iter_per_batch
        if isinstance(iteration_budget, int):
            from repro.ft.straggler import IterationBudget
            iteration_budget = IterationBudget(iteration_budget)
        self._budget = iteration_budget
        # post-publish hook (snapshot, batch) for the replication writer
        self.on_publish = None
        self._closed = False
        # one-shot debug fault armed by inject_fault(); applied (and
        # cleared) by the step that publishes the chosen generation
        self._fault: Optional[dict] = None
        self.faults_injected = 0

    # ---- lifecycle -------------------------------------------------------
    def bootstrap(self, ranks: Optional[jax.Array] = None,
                  last_seq: Optional[int] = None) -> int:
        """Publish generation 0: a cold static solve, or restored ranks.
        Builds the walk index if one was requested — sampling is a pure
        function of (graph, config seed), so a checkpointed restart
        reproduces the index bit-identically from the replayed graph."""
        if ranks is None:
            ranks = self._solve("static", self._graph, None, None).ranks
        if self.engine == "kernel" and self.kernel_geometry is None:
            from repro.kernels.pagerank_spmv.tune import KernelGeometry, \
                tune_geometry
            if self._tune:
                geom, self.tune_info = tune_geometry(
                    self._graph, frontier_frac=self._frontier_frac,
                    expected_inserts=max(1024, 64 * self.ingest.capacity),
                    measure=self._tune_measure,
                    use_kernel=self._kernel_kw.get("use_kernel"),
                    cache_path=self._tune_cache_path)
                # caller-fixed keys still win over the tuned geometry
                self._pack_kw = {**self._pack_kw, **geom.pack_kw(),
                                 **self._explicit_pack}
            self.kernel_geometry = KernelGeometry(
                be=self._pack_kw["be"], vb=self._pack_kw["vb"],
                spill_lanes_per_window=self._pack_kw[
                    "spill_lanes_per_window"])
        if self.engine == "kernel" and self.mesh is not None \
                and self._sharded is None:
            from repro.dist.pagerank_dist import ShardedKernelEngine
            pack_kw = dict(self._pack_kw)
            if "num_entries" not in pack_kw:
                spare = (self._graph.edge_capacity
                         - int(self._graph.num_valid_edges()))
                pack_kw.setdefault("extra_entries",
                                   -(-spare // pack_kw["be"]))
            pack_kw.setdefault(
                "overlay_capacity", max(1024, 64 * self.ingest.capacity))
            kw = dict(self._kernel_kw)
            self._sharded = ShardedKernelEngine(
                self.mesh, self._graph, pack_kw=pack_kw,
                delta_budget=self._delta_budget,
                use_kernel=kw.pop("use_kernel", False), **kw)
        if self.engine == "kernel" and self.mesh is None \
                and self._packed is None:
            from repro.kernels.pagerank_spmv.update import pack_graph
            if "num_entries" not in self._pack_kw:
                # mirror the edge list's stream headroom as empty tail
                # entries, so an overflow repack at the pinned capacity
                # can redistribute them to whichever windows grew
                spare = (self._graph.edge_capacity
                         - int(self._graph.num_valid_edges()))
                self._pack_kw.setdefault(
                    "extra_entries", -(-spare // self._pack_kw["be"]))
            # ~64 micro-batches of insertions between locator repacks
            self._pack_kw.setdefault(
                "overlay_capacity", max(1024, 64 * self.ingest.capacity))
            self._packed = pack_graph(self._graph, **self._pack_kw)
            # pin every static: overflow repacks must not change any
            # shape or static field, or the compiled update/kernel would
            # retrace mid-recovery.  max_entries_per_window is pinned at
            # the total entry capacity — the trivially safe bound, since
            # a repack may redistribute entries to windows that grew (the
            # free-slot scan it bounds is O(|Δ|·M), still tiny at M=NE)
            cap = self._packed.num_entries
            self._pack_kw["num_entries"] = cap
            self._pack_kw["max_entries_per_window"] = cap
            self._pack_kw.pop("extra_entries", None)
            import dataclasses
            self._packed = dataclasses.replace(
                self._packed, max_entries_per_window=cap)
        if self._ppr_cfg is not None and self._ppr is None:
            if self.mesh is not None:
                self._ppr = build_sharded_walk_index(
                    self._graph, self._ppr_cfg, mesh=self.mesh)
            else:
                self._ppr = build_walk_index(self._graph, self._ppr_cfg)
        self._ranks = ranks
        seq = self.ingest.start_seq - 1 if last_seq is None else last_seq
        gen = self.store.publish(self._graph, ranks, seq,
                                 ppr_index=self._ppr)
        if self.monitor is not None:
            # bind the recorder's config + capture the bootstrap anchor
            self.monitor.on_bootstrap(self)
        return gen

    # ---- debug fault injection ------------------------------------------
    def inject_fault(self, generation: int, kind: str = "rank",
                     vertex: int = 0, scale: float = 2.0) -> None:
        """DEBUG ONLY: arm a one-shot corruption for ``generation``.

        ``kind="rank"`` multiplies ``ranks[vertex]`` by ``scale`` on the
        solve's *output*, after convergence but before publish — the
        exact shape of the DF blind spot (a vertex no later frontier
        revisits keeps the corrupt value forever), which is what the
        mass sentinel and shadow verifier exist to catch.
        ``kind="event"`` redirects every insertion in that generation's
        coalesced batch to land on ``vertex`` *before* the update is
        applied (or recorded), so the served graph silently diverges
        from the submitted feed.  Used by tests and the CI incident-
        replay smoke lane; never call it in production serving.
        """
        if kind not in ("rank", "event"):
            raise ValueError(f"unknown fault kind {kind!r}")
        self._fault = dict(generation=int(generation), kind=str(kind),
                           vertex=int(vertex), scale=float(scale))

    # ---- one micro-batch -------------------------------------------------
    def step(self, force: bool = False) -> bool:
        """Apply one coalesced micro-batch if due; True if work was done."""
        if self._ranks is None:
            raise RuntimeError("bootstrap() before step()")
        tr = obs_trace.get_tracer()
        s0 = tr.now()
        batch = self.ingest.poll(force=force)
        if batch is None:
            return False
        # poll may yield nothing, so the span is recorded after the fact
        # (Chrome-trace nesting is by timestamps, not buffer order)
        tr.record("ingest.coalesce", s0, tr.now() - s0,
                  events=batch.num_events, coalesced=batch.num_coalesced)
        tel = tr.enabled if self.telemetry is None else bool(self.telemetry)
        fault = None
        if self._fault is not None \
                and self.store.generation + 1 == self._fault["generation"]:
            fault, self._fault = self._fault, None
            self.faults_injected += 1
        if fault is not None and fault["kind"] == "event":
            # corrupt the batch BEFORE it is applied or recorded: the
            # flight recorder sees (and replays) the corrupted stream,
            # exactly as a feed bug would present
            upd = batch.update
            upd = upd._replace(ins_dst=jnp.where(
                upd.ins_mask,
                jnp.asarray(fault["vertex"], upd.ins_dst.dtype),
                upd.ins_dst))
            batch = batch._replace(update=upd)
        t0 = self._clock()
        r0 = tr.now()
        graph_new = apply_batch(self._graph, batch.update)
        method = self.method
        init_state = build_initial_state(self._graph, graph_new,
                                         batch.update, self._ranks, method)
        if (self._budget is not None and method in DYNAMIC_METHODS
                and self._budget.carried_frontier is not None):
            # a capped previous batch left an unconverged frontier: fold
            # it into this batch's seed set (DF re-marks until Δ ≤ τ)
            seeds = self._budget.seeds_for_batch(np.asarray(init_state[1]))
            init_state = (init_state[0], jnp.asarray(seeds))
            self.metrics.record_budget_carryover()
        affected = init_state[1]
        fallback = False
        if method in ("traversal", "frontier", "frontier_prune"):
            frac = float(jnp.mean(affected.astype(jnp.float64)))
            if frac > self.static_fallback_frac:
                method, fallback = "static", True
                init_state = build_initial_state(
                    self._graph, graph_new, batch.update, self._ranks,
                    "static")
        # budget cap applies to dynamic solves only: a capped static
        # solve restarts cold every batch and would never converge,
        # while a capped DF/DF-P batch soundly resumes from its carried
        # frontier (straggler.IterationBudget)
        cap = (self._budget.max_iter
               if self._budget is not None and method in DYNAMIC_METHODS
               else None)
        # the fused path folds packed maintenance into the solve's first
        # sweep — one device program for the whole f32 phase
        fuse = (self._packed is not None and not fallback
                and method in DYNAMIC_METHODS)
        programs = 0
        if self._sharded is not None:
            from repro.kernels.pagerank_spmv.shard import ShardCapacityError
            try:
                self._sharded.apply_update(batch.update)
                programs += 1
            except ShardCapacityError as e:
                # budget/spill/overlay exhaustion on some shard(s):
                # repack every shard at the pinned shapes (defragments
                # freed lanes back into window order, zero recompiles).
                # Only the typed capacity error means "recoverable by
                # repack" — anything else is a real bug and propagates.
                self._sharded.repack(graph_new)
                self.metrics.record_packed_rebuild(shards=e.shards)
        elif self._packed is not None and not fuse:
            from repro.kernels.pagerank_spmv.update import \
                apply_batch_packed
            try:
                self._packed = apply_batch_packed(self._packed, batch.update)
                programs += 1
            except ValueError:
                # spill/overlay exhaustion: repack at the pinned shapes,
                # which also defragments freed lanes back into window order
                self._packed = self._repack(graph_new)
                self.metrics.record_packed_rebuild()
        # edge-list update + delta routing/packed maintenance (the fused
        # path defers maintenance into the solve program, traced there)
        tr.record("route_update", r0, tr.now() - r0,
                  programs=programs, fused=fuse)
        if fuse:
            from repro.core.kernel_engine import fused_hybrid_pagerank
            kw = dict(KERNEL_FLAGS[method], **self._kernel_kw, **self.pr_kw)
            kw.setdefault("telemetry", tel)
            if cap is not None:
                kw["max_iter"] = cap
            try:
                self._packed, res = fused_hybrid_pagerank(
                    graph_new, self._packed, batch.update, *init_state,
                    **kw)
            except ValueError:
                # overflow surfaced inside the fused program: repack at
                # the pinned shapes and re-run with the SAME update —
                # maintenance is idempotent after the repack (deletions
                # already absent, insertions already live), so only the
                # solve repeats
                self._packed = self._repack(graph_new)
                self.metrics.record_packed_rebuild()
                self._packed, res = fused_hybrid_pagerank(
                    graph_new, self._packed, batch.update, *init_state,
                    **kw)
            programs += 1 + (1 if kw.get("polish", True) else 0)
        else:
            with tr.span("solve", method=method, engine=self.engine):
                res = self._solve(method, graph_new, batch.update,
                                  self._ranks, graph_prev=self._graph,
                                  init_state=init_state, telemetry=tel,
                                  max_iter=cap)
                tr.sync(res.ranks)
            if self.engine == "kernel" and self.mesh is None \
                    and method in DYNAMIC_METHODS:
                programs += 1 + (1 if self._kernel_kw.get("polish", True)
                                 else 0)
            else:
                programs += 1   # one XLA solve (mesh paths count theirs)
        if self._budget is not None:
            if cap is not None:
                # exit-at-cap with Δ still above τ means unconverged:
                # the ever-affected set is the frontier to re-seed
                tol = float(self.pr_kw.get("tol", pr.TOL))
                converged = (int(res.iterations) < cap
                             or float(res.delta) <= tol)
                self._budget.after_batch(converged,
                                         np.asarray(res.affected_ever))
            else:
                # static fallback ran uncapped to full convergence
                self._budget.after_batch(True, None)
        if fault is not None and fault["kind"] == "rank":
            res = res._replace(
                ranks=res.ranks.at[fault["vertex"]].multiply(
                    fault["scale"]))
        resampled = 0
        if self._ppr is not None:
            # the same touched signal that seeds the DF frontier drives
            # walk invalidation — stale suffixes resample on Gᵗ
            touched = touched_vertices_mask(batch.update,
                                            graph_new.num_vertices)
            if isinstance(self._ppr, ShardedWalkIndex):
                self._ppr, resampled = repair_walk_index_sharded(
                    self._ppr, graph_new, touched)
            else:
                self._ppr, resampled = repair_walk_index(
                    self._ppr, graph_new, touched)
        # one host sync covers the batch: the repair kernels (when any
        # walk actually resampled) were enqueued after the rank update,
        # so waiting on both keeps the reported latency honest without a
        # second device round trip — and a no-stale batch never touches
        # the (unchanged) steps buffer at all
        _block((res.ranks, self._ppr.steps) if resampled > 0
               else res.ranks)
        latency = self._clock() - t0
        self._graph, self._ranks = graph_new, res.ranks
        with tr.span("snapshot.publish"):
            self.store.publish(graph_new, res.ranks, batch.last_seq,
                               ppr_index=self._ppr)
        if self.on_publish is not None:
            self.on_publish(self.store.snapshot(), batch)
        comm = 0
        if self._sharded is not None:
            comm = int(getattr(self._sharded, "last_comm_bytes", 0))
        affected_count = int(jnp.sum(res.affected_ever))
        self.metrics.record_batch(
            latency, batch.num_events, batch.num_coalesced,
            affected=affected_count,
            iterations=int(res.iterations), fallback=fallback,
            walks_resampled=resampled,
            edges_processed=int(res.edges_processed),
            vertices_processed=int(res.vertices_processed),
            comm_bytes=comm, device_programs=programs)
        self._observe_batch(tr, batch, res, tel)
        if self.monitor is not None:
            m0 = tr.now()
            self.monitor.on_batch(
                engine=self, batch=batch, graph=graph_new, result=res,
                method=method, fallback=fallback, latency_s=latency,
                affected=affected_count, fault=fault)
            tr.record("monitor.observe", m0, tr.now() - m0)
            if self.faults_injected:
                self.metrics.set_gauge("faults_injected",
                                       float(self.faults_injected))
        tr.record("serve.step", s0, tr.now() - s0, method=method,
                  events=batch.num_events, fallback=fallback,
                  device_programs=programs)
        return True

    def _observe_batch(self, tr, batch, res, tel: bool):
        """Per-batch telemetry capture + engine-attribute gauges."""
        self.last_telemetry = None
        raw = getattr(res, "telemetry", None)
        if tel and raw is not None:
            if isinstance(raw, np.ndarray):
                ft = FrontierTelemetry(raw)   # pre-trimmed by a wrapper
            else:
                # padded device rows straight out of a jitted loop
                ft = FrontierTelemetry.from_padded(raw, res.iterations)
            self.last_telemetry = ft
            summary = ft.summary()
            self.metrics.record_frontier(summary)
            tr.instant("frontier.telemetry", **summary)
            if self.telemetry_sink is not None:
                self.telemetry_sink.write(
                    dict(seq=int(batch.last_seq), summary=summary,
                         rows=ft.rows()), kind="frontier")
        m = self.metrics
        if self.tune_info is not None:
            m.set_gauge("tune_cache_hit_rate",
                        1.0 if getattr(self.tune_info, "cache_hit", False)
                        else 0.0)
        if self._sharded is not None \
                and getattr(self._sharded, "halo", None) is not None:
            from repro.kernels.pagerank_spmv.shard import halo_occupancy
            m.set_gauge("halo_occupancy", halo_occupancy(self._sharded.halo))
        m.set_gauge("staleness_in_events",
                    max(0, self.ingest.latest_seq - int(batch.last_seq)))

    def _repack(self, graph: EdgeListGraph):
        """Repack at the pinned shapes, degrading the spill guarantee.

        Once windows have grown, the bootstrap ``spill_lanes_per_window``
        may no longer fit the pinned ``num_entries``; serving must not
        die on its own recovery path, so retry on the windows' natural
        slack alone.  A failure beyond that is the genuine capacity
        limit (the edge list itself is near overflow) and propagates.
        """
        from repro.kernels.pagerank_spmv.update import pack_graph
        try:
            return pack_graph(graph, **self._pack_kw)
        except ValueError:
            return pack_graph(graph,
                              **{**self._pack_kw,
                                 "spill_lanes_per_window": 0})

    def _solve(self, method: Method, graph_new: EdgeListGraph, update,
               prev_ranks, graph_prev: Optional[EdgeListGraph] = None,
               init_state: Optional[tuple] = None, telemetry: bool = False,
               max_iter: Optional[int] = None):
        graph_prev = graph_prev if graph_prev is not None else graph_new
        # budget cap (constant across batches, so one trace variant)
        capkw = {} if max_iter is None else dict(max_iter=max_iter)
        if self.mesh is not None:
            if self._sharded is not None and method in DYNAMIC_METHODS:
                init_ranks, init_affected = (
                    init_state if init_state is not None
                    else build_initial_state(graph_prev, graph_new, update,
                                             prev_ranks, method))
                return self._sharded.solve(graph_new, init_ranks,
                                           init_affected,
                                           telemetry=telemetry,
                                           **KERNEL_FLAGS[method],
                                           **{**self.pr_kw, **capkw})
            # the XLA shard_map step exposes endpoint scalars only —
            # per-iteration rows would ride the wire every sweep
            return distributed_pagerank(graph_prev, graph_new, update,
                                        prev_ranks, method, self.mesh,
                                        init_state=init_state,
                                        **{**self.pr_kw, **capkw})
        init_ranks, init_affected = (
            init_state if init_state is not None else build_initial_state(
                graph_prev, graph_new, update, prev_ranks, method))
        if self.engine == "kernel" and method in DYNAMIC_METHODS:
            from repro.core.kernel_engine import hybrid_pagerank
            kw = dict(KERNEL_FLAGS[method], **self._kernel_kw,
                      **self.pr_kw, **capkw)
            kw.setdefault("telemetry", telemetry)
            return hybrid_pagerank(graph_new, self._packed, init_ranks,
                                   init_affected, **kw)
        kw = dict(LOOP_FLAGS[method], **self.pr_kw, **capkw)
        kw.setdefault("telemetry", telemetry)
        return pr._pagerank_loop(graph_new, init_ranks, init_affected, **kw)

    def drain(self, force: bool = True) -> int:
        """Run steps until the ingest queue is empty; returns batch count."""
        n = 0
        while self.step(force=force):
            n += 1
        return n

    # ---- background thread ----------------------------------------------
    def start(self, idle_sleep: float = 0.001):
        """Run the step loop in a daemon thread until ``stop``."""
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.step():
                    time.sleep(idle_sleep)

        self._thread = threading.Thread(target=loop, name="serve-engine",
                                        daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain(force=True)

    def close(self):
        """Full shutdown: stop the step thread (without force-draining a
        shedding queue) and close the correctness monitor, which joins
        the shadow-verifier thread and flushes its latest-wins mailbox
        so a pending divergence is reported, never dropped.  Idempotent.
        """
        if self._closed:
            return
        self._closed = True
        self.stop(drain=False)
        if self.monitor is not None:
            self.monitor.close()
