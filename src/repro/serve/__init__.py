"""repro.serve — online rank serving on the DF/DF-P engines.

The paper makes rank *maintenance* cheap enough to run continuously as
edges arrive; this package supplies the missing front end: an event
queue that coalesces edge events into capacity-padded micro-batches
(``ingest``), a double-buffered snapshot store so queries never block on
an in-flight update (``state``), the update loop driving the DF/DF-P
engines with an automatic static fallback at large batch fractions and
an opt-in incrementally-repaired PPR walk index (``engine``,
``ppr_index=``), the query surface — point ranks, jit top-k,
personalized top-k with index/exact routing (``query``) — and per-batch
latency/freshness/work counters (``metrics``).  See DESIGN.md §5 for
the architecture and §6 for the walk index.
"""
from repro.serve.chaos import ChaosHarness, ChaosReport, FaultyTransport, \
    LinkDown, LogicalClock, parse_schedule
from repro.serve.engine import ServeEngine
from repro.serve.ingest import CoalescedBatch, EdgeEvent, IngestQueue, \
    coalesce_events
from repro.serve.metrics import ServeMetrics
from repro.serve.query import QueryClient
from repro.serve.replay import preload_graph_and_feed
from repro.serve.replicate import FailoverController, ReadReplica, \
    ReplicaDegradedError, ReplicaQueryClient, ReplicationWriter
from repro.serve.state import RankStore, Snapshot

__all__ = [
    "ChaosHarness", "ChaosReport", "CoalescedBatch", "EdgeEvent",
    "FailoverController", "FaultyTransport", "IngestQueue", "LinkDown",
    "LogicalClock", "QueryClient", "RankStore", "ReadReplica",
    "ReplicaDegradedError", "ReplicaQueryClient", "ReplicationWriter",
    "ServeEngine", "ServeMetrics", "Snapshot", "coalesce_events",
    "parse_schedule", "preload_graph_and_feed",
]
