"""PPR queries over a WalkIndex: visit-count aggregation + top-k.

The base Monte-Carlo identity: the expected number of visits to v by one
decay-terminated walk from s is PPR(s, v)/(1-α), so scaled visit counts
over R stored walks estimate the PPR vector.  Used directly, the sample
size per query is R — too small to resolve the top-k tail at serving
R.  The query path therefore applies **one-step unrolling** through the
implicit-self-loop closed form (the same Eq.-2 manipulation DF-P uses
for its rank update):

    π_s = [ (1-α)·e_s + α/(d_s+1) · Σ_{u ∈ N⁺(s)} π_u ] / (1 − α/(d_s+1))

i.e. a seed's PPR is an exactly-weighted mixture of its out-neighbours'
PPR vectors plus a point mass at the seed — and each neighbour's π_u is
estimated from *that vertex's own* stored walks.  One query over a
degree-d seed thus aggregates (d)·R walks instead of R, multiplying the
effective sample size by the out-degree with zero extra storage (the
composition trick of Bahmani et al.).  Seed sets average the per-seed
estimates (uniform teleport over seeds — the contract of
core.extensions.personalized_pagerank).  Degree-0 seeds are exact:
π_s = e_s.

Mechanics: gather the [seeds ∪ their neighbours, R, L] walk positions
from the index, one ``jax.ops.segment_sum`` of per-source weights (the
kernels/segment_ops gated SpMM targets feature *matrices* per window,
so this flat count vector stays on the jnp path), add the closed-form
point masses, ``lax.top_k``.  Everything is jit-compiled; seed and
neighbour blocks are padded to power-of-two buckets so an online query
mix reuses a handful of executables — a few device ops per query, the
sub-millisecond path a full DF-P solve cannot offer.

``unroll=False`` exposes the raw R-walk estimator (used by the
estimator-convergence tests; its ε is what estimator.py bounds).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ppr.walks import WalkIndex

_MIN_SEED_CAP = 1       # pow2 seed buckets: 1, 2, 4, ... bound compiles
_MIN_NBR_CAP = 8
_MAX_NBR_WIDTH = 1024   # neighbour-slab width cap (memory + compile bound)


def _counts_local(steps: jax.Array, sources: jax.Array, weights: jax.Array,
                  v_start: jax.Array, num_vertices: int) -> jax.Array:
    """f64[V] visit counts contributed by the rows of one start-vertex
    range [v_start, v_start + steps.shape[0]): sources outside the range
    are masked to zero weight, so summing (or psum-ing) the per-range
    results over all ranges reproduces the full aggregation — the
    sharded query path of ppr/shard.py.  With ``v_start=0`` and a
    full-index ``steps`` this *is* the single-device aggregation."""
    vps = steps.shape[0]
    loc = sources - v_start
    own = (loc >= 0) & (loc < vps)
    sel = steps[jnp.clip(loc, 0, vps - 1)]                # [B, R, L]
    w = jnp.where(own[:, None, None] & (sel >= 0),
                  weights[:, None, None], 0.0)
    return jax.ops.segment_sum(
        w.ravel(), jnp.clip(sel, 0, num_vertices - 1).ravel(),
        num_segments=num_vertices)


def _counts(steps: jax.Array, sources: jax.Array, weights: jax.Array
            ) -> jax.Array:
    """f64[V] Σ over walk positions of the gathered ``sources`` rows,
    each position weighted by its source's scalar weight."""
    V = steps.shape[0]
    return _counts_local(steps, sources, weights, jnp.int32(0), V)


@partial(jax.jit, static_argnames=("normalize",))
def _direct_estimate(steps: jax.Array, alpha: float, seeds_idx: jax.Array,
                     seeds_mask: jax.Array, normalize: bool) -> jax.Array:
    """Raw estimator: (1-α)/R · visit counts of the seeds' own walks."""
    R = steps.shape[1]
    n_seeds = jnp.maximum(jnp.sum(seeds_mask.astype(jnp.float64)), 1.0)
    w = jnp.where(seeds_mask, (1.0 - alpha) / (R * n_seeds), 0.0)
    est = _counts(steps, seeds_idx, w)
    if normalize:
        est = est / jnp.maximum(jnp.sum(est), 1e-300)
    return est


@partial(jax.jit, static_argnames=("width", "num_walks"))
def _nbr_slab(indptr: jax.Array, indices: jax.Array, deg: jax.Array,
              alpha: float, seeds_idx: jax.Array, seeds_mask: jax.Array,
              offset: jax.Array, width: int, num_walks: int
              ) -> Tuple[jax.Array, jax.Array]:
    """(sources int32[S·width], weights f64[S·width]): neighbour columns
    [offset, offset+width) of each seed's CSR row with their per-walk-
    position weights — the graph-side half of one unrolled-estimator
    slab, shared by the single-device and sharded count paths."""
    V = deg.shape[0]
    E = indices.shape[0]
    R = num_walks
    n_seeds = jnp.maximum(jnp.sum(seeds_mask.astype(jnp.float64)), 1.0)
    d = deg[jnp.clip(seeds_idx, 0, V - 1)]                # [S]
    z = 1.0 - alpha / (d + 1.0)                           # closed-form denom
    col = offset + jnp.arange(width, dtype=jnp.int32)[None, :]
    nbr_ok = seeds_mask[:, None] & (col < d[:, None])
    nbr = indices[jnp.clip(indptr[jnp.clip(seeds_idx, 0, V - 1)][:, None]
                           + col, 0, E - 1)]
    nbr = jnp.where(nbr_ok, nbr, 0)
    # per-source weight of one walk position:  α(1-α) / ((d+1)·z·R·|S|)
    w_nbr = jnp.where(nbr_ok,
                      alpha * (1.0 - alpha)
                      / ((d[:, None] + 1.0) * z[:, None] * R * n_seeds),
                      0.0)
    return nbr.ravel(), w_nbr.ravel().astype(jnp.float64)


@partial(jax.jit, static_argnames=("width",))
def _unrolled_chunk(steps: jax.Array, indptr: jax.Array,
                    indices: jax.Array, deg: jax.Array, alpha: float,
                    seeds_idx: jax.Array, seeds_mask: jax.Array,
                    offset: jax.Array, width: int) -> jax.Array:
    """Visit counts of neighbour columns [offset, offset+width) of each
    seed's CSR row — one bounded-size slab of the unrolled estimator."""
    nbr, w_nbr = _nbr_slab(indptr, indices, deg, alpha, seeds_idx,
                           seeds_mask, offset, width, steps.shape[1])
    return _counts(steps, nbr, w_nbr)


@jax.jit
def _seed_point_mass(est: jax.Array, deg: jax.Array, alpha: float,
                     seeds_idx: jax.Array, seeds_mask: jax.Array
                     ) -> jax.Array:
    """Add each seed's closed-form point mass (1-α)/(z·|S|)."""
    V = est.shape[0]
    n_seeds = jnp.maximum(jnp.sum(seeds_mask.astype(jnp.float64)), 1.0)
    d = deg[jnp.clip(seeds_idx, 0, V - 1)]
    z = 1.0 - alpha / (d + 1.0)
    return est.at[jnp.clip(seeds_idx, 0, V - 1)].add(
        jnp.where(seeds_mask, (1.0 - alpha) / (z * n_seeds), 0.0))


def _unrolled_estimate(index: WalkIndex, seeds_idx: jax.Array,
                       seeds_mask: jax.Array, nbr_cap: int,
                       normalize: bool) -> jax.Array:
    """One-step-unrolled estimate; the neighbour axis is processed in
    slabs of at most ``_MAX_NBR_WIDTH`` columns so a hub seed costs a
    bounded gather per slab instead of one pow2(max-degree)-wide buffer
    (which at degree ~4k would be hundreds of MB of transients), and jit
    shape buckets stay capped at the slab width."""
    deg = index.csr.deg.astype(jnp.float64)
    width = min(nbr_cap, _MAX_NBR_WIDTH)
    est = None
    for offset in range(0, nbr_cap, width):
        c = _unrolled_chunk(index.steps, index.csr.indptr,
                            index.csr.indices, deg, index.alpha,
                            seeds_idx, seeds_mask,
                            jnp.asarray(offset, jnp.int32), width)
        est = c if est is None else est + c
    est = _seed_point_mass(est, deg, index.alpha, seeds_idx, seeds_mask)
    if normalize:
        est = est / jnp.maximum(jnp.sum(est), 1e-300)
    return est


def _pad_seeds(seeds: Sequence[int], V: int) -> Tuple[jax.Array, jax.Array]:
    s = np.unique(np.asarray(seeds, np.int64).reshape(-1))
    if len(s) == 0:
        raise ValueError("PPR query needs at least one seed")
    if s.min() < 0 or s.max() >= V:
        raise ValueError(f"seed out of range [0, {V})")
    cap = max(_MIN_SEED_CAP, 1 << (len(s) - 1).bit_length())
    idx = np.zeros((cap,), np.int32)
    idx[: len(s)] = s
    mask = np.arange(cap) < len(s)
    return jnp.asarray(idx), jnp.asarray(mask)


def _nbr_cap(index: WalkIndex, seeds_idx: jax.Array,
             seeds_mask: jax.Array) -> int:
    """pow2 neighbour-block width covering the query's largest seed."""
    d_max = int(jnp.max(jnp.where(seeds_mask, index.csr.deg[seeds_idx], 0)))
    return max(_MIN_NBR_CAP, 1 << max(0, d_max - 1).bit_length())


def ppr_estimate(index: WalkIndex, seeds: Sequence[int],
                 normalize: bool = True, unroll: bool = True) -> jax.Array:
    """f64[V] estimated PPR vector for a seed set (uniform teleport over
    the seeds).  ``normalize=True`` rescales to a distribution (absorbs
    the α^L truncation tail); top-k is unaffected either way.

    Accepts a ``ShardedWalkIndex`` too: the aggregation then runs per
    shard over that shard's rows with one psum of the f64[V] estimate —
    the walk arrays never leave their shards (ppr/shard.py)."""
    if not isinstance(index, WalkIndex):
        from repro.ppr.shard import sharded_ppr_estimate
        return sharded_ppr_estimate(index, seeds, normalize=normalize,
                                    unroll=unroll)
    idx, mask = _pad_seeds(seeds, index.num_vertices)
    if not unroll:
        return _direct_estimate(index.steps, index.alpha, idx, mask,
                                normalize)
    return _unrolled_estimate(index, idx, mask,
                              _nbr_cap(index, idx, mask), normalize)


@partial(jax.jit, static_argnames=("k",))
def _topk(est: jax.Array, k: int):
    vals, idx = jax.lax.top_k(est, k)
    return idx, vals


def ppr_top_k(index: WalkIndex, seeds: Sequence[int], k: int,
              unroll: bool = True) -> Tuple[jax.Array, jax.Array]:
    """(vertices int[k], estimates f64[k]) — the serving fast path."""
    return _topk(ppr_estimate(index, seeds, unroll=unroll), k)
