"""Decay-terminated random-walk index for Monte-Carlo personalized PageRank.

The serving layer answers ``personalized_top_k`` with a full DF-P power
iteration per query — exact, but orders of magnitude too slow for query
traffic.  Bahmani et al. (*Fast Incremental and Personalized PageRank*)
store R short random walks per vertex instead: visit counts over the
walks from a seed estimate its PPR vector in sub-millisecond time, and
the stored walks can be *repaired* per edge batch (repro.ppr.repair)
instead of rebuilt.

Layout — fixed device shapes so one compiled builder/repairer serves the
whole stream:

  ``steps: int32[V, R, L]``   vertex occupied at hop t; slot 0 is the
                              source itself; ``-1`` once the walk has
                              decay-terminated (no validity array —
                              the sentinel IS the mask).

Transition kernel matches the exact solvers (core/pagerank.py): from u,
pick uniformly among u's ``deg`` valid out-edges *plus the implicit
self-loop* (slot ``deg``), i.e. P(stay) = 1/(deg+1); continue with
probability ``alpha`` per hop.  The endpoint of such a walk is
PPR-distributed, and the expected visit count of v is PPR(s, v)/(1-α)
(repro.ppr.query aggregates visits — lower variance than endpoints).

PRNG discipline — the load-bearing design decision: the randomness of
walk i at hop t is ``fold_in(fold_in(base_key, i), t)``, a pure function
of (base_key, walk id, hop).  No draw depends on any other walk, on the
graph, or on process state.  Consequences:

  * rebuild with the same key is bitwise deterministic (checkpointed
    restarts reproduce the index exactly — no hash()/process state);
  * a walk's trajectory is a pure function of (graph, base_key), so
    repairing stale suffixes on Gᵗ reproduces *exactly* the walk a
    fresh build on Gᵗ would draw — repair is bitwise equivalent to
    rebuild while resampling only walks that intersect the update.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.pagerank import ALPHA
from repro.graph.structure import CSRView, EdgeListGraph

DEFAULT_NUM_WALKS = 32
DEFAULT_MAX_LEN = 20


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Build-time knobs; hold one of these to (re)build identical indexes."""

    num_walks: int = DEFAULT_NUM_WALKS    # R walks per vertex
    max_len: int = DEFAULT_MAX_LEN        # L slots incl. the source slot
    alpha: float = ALPHA                  # continue probability (= damping)
    seed: int = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WalkIndex:
    """R decay-terminated walks per vertex; a pytree, safe under jit.

    Carries the CSR view of the graph it was sampled on (query.py's
    one-step-unrolled estimator reads seed neighbour lists from it);
    repair keeps walks and CSR consistent as a unit.
    """

    steps: jax.Array     # int32[V, R, L]; -1 = terminated
    csr: CSRView         # adjacency the walks are valid for
    key: jax.Array       # uint32[2] base PRNG key (classic threefry key)
    num_walks: int = dataclasses.field(metadata=dict(static=True))
    max_len: int = dataclasses.field(metadata=dict(static=True))
    alpha: float = dataclasses.field(metadata=dict(static=True))

    @property
    def num_vertices(self) -> int:
        return self.steps.shape[0]

    def mask(self) -> jax.Array:
        """bool[V, R, L]: positions actually occupied."""
        return self.steps >= 0

    def nbytes(self) -> int:
        return self.steps.size * 4


def _walk_keys(base_key: jax.Array, walk_ids: jax.Array) -> jax.Array:
    """Per-walk keys, fold_in(base_key, walk id) — hop-independent, so
    callers hoist this out of their scan over hops."""
    return jax.vmap(jax.random.fold_in, (None, 0))(base_key, walk_ids)


def _walk_draws(walk_keys: jax.Array, t: jax.Array) -> jax.Array:
    """f[N, 2] uniforms for (walk, hop): [:, 0] continue, [:, 1] choice.

    With ``walk_keys`` from ``_walk_keys``, the draw is a pure function
    of (base_key, walk id, hop) — see module docstring.
    """
    keys = jax.vmap(jax.random.fold_in, (0, None))(walk_keys, t)
    return jax.vmap(lambda k: jax.random.uniform(k, (2,), jnp.float32))(keys)


def _transition(csr: CSRView, cur: jax.Array, choice: jax.Array) -> jax.Array:
    """One hop from ``cur``: slot j ~ U{0..deg}, slot deg = self-loop."""
    deg = csr.deg[cur]
    j = jnp.minimum((choice * (deg + 1).astype(jnp.float32))
                    .astype(jnp.int32), deg)
    idx = jnp.clip(csr.indptr[cur] + j, 0, csr.indices.shape[0] - 1)
    return jnp.where(j >= deg, cur, csr.indices[idx])


@partial(jax.jit,
         static_argnames=("num_vertices", "num_local", "num_walks",
                          "max_len", "alpha"))
def _build_steps_range(csr: CSRView, key: jax.Array, v_start: jax.Array,
                       num_vertices: int, num_local: int, num_walks: int,
                       max_len: int, alpha: float) -> jax.Array:
    """Rows [v_start, v_start + num_local) of the full build, sampled with
    **global** walk ids — bitwise equal to the same slice of a full-index
    build, which is what lets a per-shard build (ppr/shard.py) reproduce
    ``_build_steps`` exactly.  ``v_start`` may be traced (it comes from
    ``lax.axis_index`` under shard_map).  Rows whose global vertex id
    falls at or past ``num_vertices`` (shard padding) come out all ``-1``:
    the sentinel keeps them invisible to staleness and queries.
    """
    R, L = num_walks, max_len
    Nl = num_local * R
    v_start = jnp.asarray(v_start, jnp.int32)
    gids = (v_start.astype(jnp.uint32) * jnp.uint32(R)
            + jnp.arange(Nl, dtype=jnp.uint32))
    walk_keys = _walk_keys(key, gids)
    vloc = v_start + jnp.arange(num_local, dtype=jnp.int32)
    valid = jnp.repeat(vloc < num_vertices, R)
    cur0 = jnp.repeat(jnp.clip(vloc, 0, num_vertices - 1), R)

    def hop(carry, t):
        cur, alive = carry
        u = _walk_draws(walk_keys, t)
        alive = alive & (u[:, 0] < alpha)
        nxt = _transition(csr, cur, u[:, 1])
        cur = jnp.where(alive, nxt, cur)
        return (cur, alive), jnp.where(alive, cur, -1)

    _, tail = jax.lax.scan(hop, (cur0, valid),
                           jnp.arange(1, L, dtype=jnp.int32))
    head = jnp.where(valid, cur0, -1)
    steps = jnp.concatenate([head[None, :], tail], axis=0)   # [L, Nl]
    return steps.T.reshape(num_local, R, L)


@partial(jax.jit,
         static_argnames=("num_vertices", "num_walks", "max_len", "alpha"))
def _build_steps(csr: CSRView, key: jax.Array, num_vertices: int,
                 num_walks: int, max_len: int, alpha: float) -> jax.Array:
    return _build_steps_range(csr, key, jnp.int32(0), num_vertices,
                              num_vertices, num_walks, max_len, alpha)


def build_walk_index(graph: EdgeListGraph,
                     config: IndexConfig = IndexConfig()) -> WalkIndex:
    """Sample the full index on ``graph`` — fully vectorized over V·R walks
    (one ``lax.scan`` over hops, all walks advance in lockstep)."""
    key = jax.random.PRNGKey(config.seed)
    csr = graph.to_device_csr()
    steps = _build_steps(csr, key, graph.num_vertices,
                         config.num_walks, config.max_len, config.alpha)
    return WalkIndex(steps=steps, csr=csr, key=key,
                     num_walks=config.num_walks, max_len=config.max_len,
                     alpha=config.alpha)
