"""Incremental walk-index maintenance from the DF ``touched`` signal.

The DF/DF-P engines localise a batch update Δᵗ to ``touched_vertices_mask``
— the vertices whose out-transition distribution changed.  The same signal
drives Monte-Carlo index repair (Zhang, Lofgren & Goel, *Approximate
Personalized PageRank on Dynamic Graphs*):

  a stored walk is **stale** iff it occupies a touched vertex at any hop
  (including its source slot — a degree-changed source changes the very
  first transition).  Every transition of a non-stale walk left an
  untouched vertex, whose neighbour list is identical (same order — see
  ``EdgeListGraph.to_device_csr``) in Gᵗ⁻¹ and Gᵗ, so the walk is already
  a valid Gᵗ walk and is kept bit-for-bit.

Stale walks are repaired from their **first stale hop** t₀: the prefix
[0..t₀] only ever left untouched vertices, so it is still a valid Gᵗ
trajectory; the suffix is resampled on Gᵗ with the walk's own per-hop
PRNG draws (walks.py).  Because those draws are a pure function of
(base_key, walk, hop), the repaired suffix is exactly what a fresh
build on Gᵗ would produce — repair is *bitwise equivalent* to a full
rebuild while touching only the stale walks (tests assert both).

Cost shape: staleness detection is one fused gather-reduce over the
index (the unavoidable O(V·R·L) read, analogous to DF's per-iteration
frontier scan); resampling is compacted to the S stale walks, padded to
a power-of-two capacity so a temporal stream reuses a handful of
compiled resamplers instead of recompiling per batch.  The scatter back
into the step array copies it — deliberately: the serve engine's
published snapshot still references the previous index's buffers until
the next publish, so in-place buffer donation would corrupt answers
being served from it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph.structure import CSRView, EdgeListGraph
from repro.obs import trace as obs_trace
from repro.ppr.walks import WalkIndex, _transition, _walk_draws, _walk_keys

_device_csr = jax.jit(EdgeListGraph.to_device_csr)


@jax.jit
def stale_walks(steps: jax.Array, touched: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """(stale bool[V, R], first_stale_hop int32[V, R]) for a touched mask."""
    V = touched.shape[0]
    visited = touched[jnp.clip(steps, 0, V - 1)] & (steps >= 0)  # [V, R, L]
    return visited.any(-1), jnp.argmax(visited, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("cap",))
def _stale_ids(stale: jax.Array, t0: jax.Array, cap: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Compact the stale mask to flat walk ids [cap] (sentinel N past the
    stale count) and their first-stale hops, in one fused pass."""
    sf = stale.ravel()
    N = sf.shape[0]
    rank = jnp.cumsum(sf.astype(jnp.int32)) - 1          # id -> output slot
    ids = jnp.full((cap,), N, jnp.int32).at[
        jnp.where(sf, rank, cap)].set(jnp.arange(N, dtype=jnp.int32),
                                      mode="drop")
    t0_sel = t0.ravel()[jnp.minimum(ids, N - 1)]
    return ids, t0_sel


def _resample_impl(csr: CSRView, key: jax.Array, steps: jax.Array,
                   ids: jax.Array, t0: jax.Array, alpha: float,
                   id_offset: jax.Array = 0) -> jax.Array:
    """Re-walk the ``ids`` walks on the new graph, keeping each walk's
    prefix [0..t0]; sentinel ids scatter with mode="drop".

    ``id_offset`` shifts local walk ids into the global PRNG id space —
    a shard whose rows start at global vertex v₀ passes v₀·R so its
    draws are the ones the full-index build would have used
    (ppr/shard.py); 0 for the unsharded index.
    """
    V, R, L = steps.shape
    v = ids // R                                         # sentinel -> V
    r = jnp.minimum(ids % R, R - 1)
    rows = steps[jnp.minimum(v, V - 1), r]               # [cap, L]
    walk_keys = _walk_keys(key, (ids + id_offset).astype(jnp.uint32))
    cur0 = rows[:, 0]                                    # source vertex

    def hop(carry, t):
        cur, alive = carry
        u = _walk_draws(walk_keys, t)
        # the continue draw is graph-independent, so recomputing `alive`
        # from the walk's own stream reproduces the stored mask bitwise
        # inside the kept prefix and extends it correctly past t0
        alive = alive & (u[:, 0] < alpha)
        nxt = _transition(csr, cur, u[:, 1])
        val = jnp.where(t <= t0, rows[:, t],
                        jnp.where(alive, nxt, -1))
        cur = jnp.where(val >= 0, val, cur)
        return (cur, alive), val

    cap = ids.shape[0]
    _, tail = jax.lax.scan(hop, (cur0, jnp.ones((cap,), bool)),
                           jnp.arange(1, L, dtype=jnp.int32))
    new_rows = jnp.concatenate([cur0[None, :], tail], axis=0).T   # [cap, L]
    return steps.at[v, r].set(new_rows, mode="drop")


_resample = jax.jit(_resample_impl, static_argnames=("alpha",))


def _resample_kernel_impl(csr: CSRView, key: jax.Array, steps: jax.Array,
                          ids: jax.Array, t0: jax.Array, alpha: float,
                          id_offset: jax.Array = 0,
                          interpret: bool = False) -> jax.Array:
    """Kernel-path twin of ``_resample_impl``: same gather/scatter frame,
    but the hop recurrence runs in the bucketed Pallas kernel
    (kernels/walk_repair) on per-hop uniforms precomputed here — the
    split that keeps kernel repair bitwise equal to the jnp path."""
    from repro.kernels.walk_repair.walk_repair import resample_rows

    V, R, L = steps.shape
    N = V * R
    v = ids // R
    r = jnp.minimum(ids % R, R - 1)
    rows = steps[jnp.minimum(v, V - 1), r]               # [cap, L]
    walk_keys = _walk_keys(key, (ids + id_offset).astype(jnp.uint32))
    u = jax.vmap(_walk_draws, in_axes=(None, 0), out_axes=1)(
        walk_keys, jnp.arange(1, L, dtype=jnp.int32))    # [cap, L-1, 2]
    num_active = jnp.sum((ids < N).astype(jnp.int32))
    new_rows = resample_rows(csr, rows, t0, u, alpha=alpha,
                             num_active=num_active, interpret=interpret)
    return steps.at[v, r].set(new_rows, mode="drop")


_resample_kernel = jax.jit(_resample_kernel_impl,
                           static_argnames=("alpha", "interpret"))


def repair_walk_index(index: WalkIndex, graph_new: EdgeListGraph,
                      touched: jax.Array, min_capacity: int = 64,
                      use_kernel: bool = False, interpret: bool = False
                      ) -> Tuple[WalkIndex, int]:
    """Repair ``index`` (valid for Gᵗ⁻¹) into the index for ``graph_new``.

    ``touched``: bool[V] from ``touched_vertices_mask`` of the applied
    batch.  Returns (repaired index, number of walks resampled); the
    count is exactly the number of stale walks — the resample-count
    invariant bench_ppr and the tests assert.  The input index is left
    intact (see the module docstring on why no buffer donation).

    ``use_kernel`` routes the resample through the bucketed Pallas
    kernel (kernels/walk_repair; ``interpret=True`` for CPU) — bitwise
    identical to the jnp path, asserted in tests/test_ppr.py.
    """
    tr = obs_trace.get_tracer()
    s0 = tr.now()
    V, R, L = index.steps.shape
    N = V * R
    csr_new = _device_csr(graph_new)
    stale, t0 = stale_walks(index.steps, touched)
    num_stale = int(jnp.sum(stale))
    if num_stale == 0:
        tr.record("ppr.repair", s0, tr.now() - s0, stale=0)
        return dataclasses.replace(index, csr=csr_new), 0
    # pow2 capacity buckets: a stream of varying batches reuses a few
    # compiled resamplers instead of one per distinct stale count
    cap = min(N, max(min_capacity, 1 << (num_stale - 1).bit_length()))
    ids, t0_sel = _stale_ids(stale, t0, cap)
    if use_kernel:
        steps = _resample_kernel(csr_new, index.key, index.steps, ids,
                                 t0_sel, index.alpha, interpret=interpret)
    else:
        steps = _resample(csr_new, index.key, index.steps, ids, t0_sel,
                          index.alpha)
    tr.sync(steps)
    tr.record("ppr.repair", s0, tr.now() - s0, stale=num_stale,
              capacity=cap)
    return dataclasses.replace(index, steps=steps, csr=csr_new), num_stale
