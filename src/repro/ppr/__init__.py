"""repro.ppr — incrementally-maintained random-walk index for low-latency
personalized PageRank.

The exact solvers (core/extensions) answer a PPR query with a full
power iteration; this package answers it from R pre-stored
decay-terminated walks per vertex in a few device ops, and repairs the
stored walks per edge batch from the same ``touched_vertices_mask``
signal the DF/DF-P engines use — the Monte-Carlo analogue of the DF
frontier.  See DESIGN.md §6.

    index = build_walk_index(graph, IndexConfig(num_walks=32))
    verts, est = ppr_top_k(index, seeds=[7], k=10)        # fast path
    index, resampled = repair_walk_index(index, graph_new, touched)

Mesh scale (DESIGN.md §14): ``build_sharded_walk_index`` partitions the
steps array by start-vertex range over the ``model`` mesh axis; repair
and queries then run per shard under shard_map, bitwise equal to the
single-device path.
"""
from repro.ppr.estimator import (DEFAULT_MIN_EFFECTIVE_WALKS, diagnostics,
                                 effective_walks, error_bound,
                                 precision_at_k, truncation_bias,
                                 walks_for_error)
from repro.ppr.query import ppr_estimate, ppr_top_k
from repro.ppr.repair import repair_walk_index, stale_walks
from repro.ppr.shard import (ShardedWalkIndex, WalkShardSpec,
                             build_sharded_walk_index,
                             repair_walk_index_sharded, shard_stale_counts,
                             shard_walk_index, unshard_walk_index)
from repro.ppr.walks import IndexConfig, WalkIndex, build_walk_index

__all__ = [
    "DEFAULT_MIN_EFFECTIVE_WALKS", "IndexConfig", "ShardedWalkIndex",
    "WalkIndex", "WalkShardSpec", "build_sharded_walk_index",
    "build_walk_index", "diagnostics", "effective_walks", "error_bound",
    "ppr_estimate", "ppr_top_k", "precision_at_k", "repair_walk_index",
    "repair_walk_index_sharded", "shard_stale_counts", "shard_walk_index",
    "stale_walks",
    "truncation_bias", "unshard_walk_index", "walks_for_error",
]
